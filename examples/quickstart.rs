//! Quickstart: the physically addressed memory world in 60 lines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use nvm::pmem::BlockAllocator;
use nvm::stack::SplitStack;
use nvm::trees::TreeArray;

fn main() -> anyhow::Result<()> {
    // 1. The OS hands out fixed 32 KB blocks — nothing larger exists.
    let alloc = BlockAllocator::with_capacity_bytes(64 << 20)?;
    println!(
        "allocator: {} blocks of {} KB",
        alloc.capacity(),
        alloc.block_size() >> 10
    );

    // 2. "Large arrays" become trees of blocks (paper §3.2).
    let n = 3_000_000usize;
    let mut arr: TreeArray<f32> = TreeArray::new(&alloc, n)?;
    println!(
        "tree array: {} elements, depth {}, {} leaf blocks",
        arr.len(),
        arr.depth(),
        arr.nleaves()
    );
    for i in (0..n).step_by(1000) {
        arr.set(i, (i as f32).sqrt())?;
    }
    // Naive access walks the tree; the iterator caches the leaf (Fig 2).
    let sum_naive: f64 = (0..n).map(|i| arr.get(i).unwrap() as f64).sum();
    let sum_iter: f64 = arr.iter().map(|v| v as f64).sum();
    assert_eq!(sum_naive, sum_iter);
    println!("sum = {sum_iter:.3} (naive == iterator)");

    // 3. The program stack becomes a block chain (paper §3.1).
    let mut stack = SplitStack::new(&alloc)?;
    for depth in 0..2000u64 {
        stack.call(512, &depth.to_le_bytes())?;
    }
    let stats = stack.stats();
    println!(
        "split stack: {} calls, {} block overflows, peak {} blocks",
        stats.calls, stats.overflows, stats.blocks_peak
    );
    while stack.depth() > 0 {
        stack.ret()?;
    }
    drop(stack);
    drop(arr);

    // 4. Everything returns to the pool; no external fragmentation by
    //    construction.
    println!(
        "allocator at exit: {} blocks live (peak {})",
        alloc.stats().allocated,
        alloc.stats().peak
    );
    assert_eq!(alloc.stats().allocated, 0);
    Ok(())
}
