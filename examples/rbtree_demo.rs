//! Red–black tree without virtual memory (Figure 4 right, interactive).
//!
//! Builds the same pointer-based tree in a physically addressed node
//! pool, then compares the simulated traversal cost with and without
//! address translation — the paper's "up to 50% reduction" case.
//!
//! ```sh
//! cargo run --release --example rbtree_demo [n_keys]
//! ```

use nvm::memsim::{AddressMode, Hierarchy, PageSize};
use nvm::pmem::BlockAllocator;
use nvm::testutil::Rng;
use nvm::workloads::rbtree::{sim_rbtree_traversal, RbTree, NODE_BYTES};
use nvm::workloads::CostModel;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 21); // 2M keys

    // Functional demo: inserts, lookups, invariants.
    let alloc = BlockAllocator::with_capacity_bytes(n * NODE_BYTES + (16 << 20))?;
    let mut t = RbTree::new(&alloc, n)?;
    let mut rng = Rng::new(3);
    let probe_key = loop {
        let k = rng.next_u64();
        t.insert(k);
        break k;
    };
    for _ in 1..n {
        t.insert(rng.next_u64());
    }
    anyhow::ensure!(t.contains(probe_key), "inserted key lost");
    t.check_invariants().map_err(anyhow::Error::msg)?;
    println!("rbtree: {} keys inserted, invariants hold", t.len());
    let sum = t.inorder_sum(None);
    println!("in-order checksum: {sum:#x}");
    drop(t);

    // The paper's measurement: same code, two address modes.
    let model = CostModel::default();
    let pool_v = BlockAllocator::with_capacity_bytes(n * NODE_BYTES + (16 << 20))?;
    let mut hv = Hierarchy::kaby_lake(AddressMode::Virtual(PageSize::P4K));
    let rv = sim_rbtree_traversal(&mut hv, &model, &pool_v, n, 3);
    let pool_p = BlockAllocator::with_capacity_bytes(n * NODE_BYTES + (16 << 20))?;
    let mut hp = Hierarchy::kaby_lake(AddressMode::Physical);
    let rp = sim_rbtree_traversal(&mut hp, &model, &pool_p, n, 3);

    println!(
        "\ntraversal cost: virtual {:.1} cyc/node (TLB miss rate {:.1}%)",
        rv.cycles_per_elem,
        rv.tlb_miss_rate * 100.0
    );
    println!(
        "traversal cost: physical {:.1} cyc/node",
        rp.cycles_per_elem
    );
    println!(
        "removing translation cuts run time by {:.1}% (paper: up to 50%)",
        (1.0 - rp.cycles_per_elem / rv.cycles_per_elem) * 100.0
    );
    Ok(())
}
