//! GUPS on physically addressed trees (Figure 4 left, interactive).
//!
//! Runs real GUPS over a contiguous table and a tree table at a
//! RAM-friendly size, then prints the paper-scale simulated ratios for
//! the full 4–64 GB sweep.
//!
//! ```sh
//! cargo run --release --example gups_demo
//! ```

use std::time::Instant;

use nvm::coordinator::experiments::{fig4_gups, ExpConfig};
use nvm::pmem::BlockAllocator;
use nvm::trees::TreeArray;
use nvm::workloads::gups;

fn main() -> anyhow::Result<()> {
    // Real execution at 256 MB.
    let bytes = 256usize << 20;
    let n = bytes / 8;
    let ops = 4_000_000u64;
    let alloc = BlockAllocator::with_capacity_bytes(bytes + (16 << 20))?;

    let mut vec_table = vec![0u64; n];
    let t0 = Instant::now();
    let c1 = gups::gups_vec(&mut vec_table, ops, 11);
    let vec_t = t0.elapsed();
    drop(vec_table);

    let mut tree_table: TreeArray<u64> = TreeArray::new(&alloc, n)?;
    println!(
        "tree table: {} entries, depth {}, {} leaves",
        n,
        tree_table.depth(),
        tree_table.nleaves()
    );
    let t1 = Instant::now();
    let c2 = gups::gups_tree_naive(&mut tree_table, ops, 11);
    let tree_t = t1.elapsed();
    anyhow::ensure!(c1 == c2, "checksum mismatch: layouts diverged");

    println!(
        "real 256MB GUPS: vec {:.1} ns/op, tree {:.1} ns/op ({:.2}x software walk cost)",
        vec_t.as_nanos() as f64 / ops as f64,
        tree_t.as_nanos() as f64 / ops as f64,
        tree_t.as_secs_f64() / vec_t.as_secs_f64()
    );

    // Paper-scale simulation.
    println!("\nsimulated paper-scale ratios (tree-physical / array-virtual):");
    let t = fig4_gups(&ExpConfig::quick());
    println!("{t}");
    Ok(())
}
