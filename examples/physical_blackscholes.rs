//! End-to-end driver (DESIGN.md §4 "E2E"): prices a real portfolio
//! through the full three-layer stack.
//!
//! * L3 (Rust): block allocator owns the memory; tree arrays hold the
//!   portfolio in 32 KB physically addressed leaves; the batcher
//!   schedules leaf batches.
//! * L2/L1 (AOT): the JAX/Pallas blocked Black-Scholes kernel, compiled
//!   to `artifacts/bs_blocked_256x8192.hlo.txt` at build time, executes
//!   via PJRT. Python is not running.
//!
//! ```sh
//! make artifacts && cargo run --release --example physical_blackscholes [n_options]
//! ```

use std::time::Instant;

use nvm::coordinator::BlockBatcher;
use nvm::pmem::BlockAllocator;
use nvm::runtime::{Engine, Input};
use nvm::trees::TreeArray;
use nvm::workloads::blackscholes as bs;
use nvm::BLOCK_ELEMS_F32 as BELE;

const RATE: f32 = 0.03;
const VOL: f32 = 0.25;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4 << 20); // 4M options ≈ 80 MB across 5 arrays
    let engine = Engine::new()?;
    println!("platform: {}", engine.platform());

    // Build the portfolio in physically addressed tree arrays.
    let alloc = BlockAllocator::with_capacity_bytes(n * 4 * 6 + (64 << 20))?;
    let (spot_v, strike_v, tmat_v) = bs::synth_portfolio(n, 42);
    let mut spot: TreeArray<f32> = TreeArray::new(&alloc, n)?;
    let mut strike: TreeArray<f32> = TreeArray::new(&alloc, n)?;
    let mut tmat: TreeArray<f32> = TreeArray::new(&alloc, n)?;
    spot.copy_from_slice(&spot_v)?;
    strike.copy_from_slice(&strike_v)?;
    tmat.copy_from_slice(&tmat_v)?;
    let mut call: TreeArray<f32> = TreeArray::new(&alloc, n)?;
    let mut put: TreeArray<f32> = TreeArray::new(&alloc, n)?;
    println!(
        "portfolio: {n} options in {} leaf blocks (depth {})",
        spot.nleaves(),
        spot.depth()
    );

    // Warm compilations out of the timed region.
    engine.warm("bs_blocked_256x8192")?;
    engine.warm("bs_contig_2097152")?;

    // --- Blocked (physically addressed) path through the batcher.
    let mut batcher = BlockBatcher::new(&engine);
    let t0 = Instant::now();
    let stats = batcher.price_trees(&spot, &strike, &tmat, RATE, VOL, &mut call, &mut put)?;
    let blocked_t = t0.elapsed();
    println!(
        "blocked  path: {:>8.1} ms  ({} dispatches, {} blocks, {} padded)  {:>6.2} Mopt/s",
        blocked_t.as_secs_f64() * 1e3,
        stats.dispatches,
        stats.blocks,
        stats.padded,
        n as f64 / blocked_t.as_secs_f64() / 1e6
    );

    // --- Contiguous artifact baseline (2M options per dispatch).
    let chunk = 256 * BELE;
    let padded = n.div_ceil(chunk) * chunk;
    let mut call_c = vec![0.0f32; padded];
    let mut spot_p = spot_v.clone();
    let mut strike_p = strike_v.clone();
    let mut tmat_p = tmat_v.clone();
    spot_p.resize(padded, 1.0);
    strike_p.resize(padded, 1.0);
    tmat_p.resize(padded, 1.0);
    let t1 = Instant::now();
    for c in 0..padded / chunk {
        let lo = c * chunk;
        let hi = lo + chunk;
        let out = engine.run_f32(
            "bs_contig_2097152",
            &[
                Input::F32(&spot_p[lo..hi], vec![chunk as i64]),
                Input::F32(&strike_p[lo..hi], vec![chunk as i64]),
                Input::F32(&tmat_p[lo..hi], vec![chunk as i64]),
                Input::ScalarF32(RATE),
                Input::ScalarF32(VOL),
            ],
        )?;
        call_c[lo..hi].copy_from_slice(&out[0]);
    }
    let contig_t = t1.elapsed();
    println!(
        "contig   path: {:>8.1} ms  {:>6.2} Mopt/s",
        contig_t.as_secs_f64() * 1e3,
        n as f64 / contig_t.as_secs_f64() / 1e6
    );

    // --- Numerics: blocked == contiguous == Rust scalar reference.
    let call_blocked = call.to_vec();
    let mut max_dev = 0.0f32;
    for i in (0..n).step_by(997) {
        max_dev = max_dev.max((call_blocked[i] - call_c[i]).abs());
        let (c_ref, _) = bs::price(
            bs::Option1 { spot: spot_v[i], strike: strike_v[i], tmat: tmat_v[i] },
            RATE,
            VOL,
        );
        anyhow::ensure!(
            (call_blocked[i] - c_ref).abs() < 1e-2,
            "kernel vs scalar mismatch at {i}: {} vs {c_ref}",
            call_blocked[i]
        );
    }
    println!("numerics: blocked == contig (max dev {max_dev:.2e}), both == scalar reference");
    println!(
        "layout overhead (blocked/contig): {:.3}x",
        blocked_t.as_secs_f64() / contig_t.as_secs_f64()
    );
    Ok(())
}
