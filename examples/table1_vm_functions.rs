//! The paper's Table 1, live: all four virtual-memory functions
//! provided **without address translation**.
//!
//! | VM function | replacement demonstrated here |
//! |---|---|
//! | Protection | per-block MPU-style [`ProtectionTable`] + domains |
//! | Relocation / Migration | [`Relocator`] + tree-leaf migration |
//! | Swapping | application-controlled [`SwapPool`] |
//! | Contiguity | [`TreeArray`] + split stacks (see other examples) |
//!
//! ```sh
//! cargo run --release --example table1_vm_functions
//! ```

use nvm::pmem::{
    BlockAllocator, CheckedMem, Perms, ProtectionDomain, ProtectionTable, Relocator, SwapPool,
};
use nvm::trees::TreeArray;

fn main() -> anyhow::Result<()> {
    let alloc = BlockAllocator::with_capacity_bytes(16 << 20)?;
    println!("pool: {} x {} KB blocks\n", alloc.capacity(), alloc.block_size() >> 10);

    // --- Protection: domains cannot touch each other's blocks.
    let table = ProtectionTable::new(alloc.capacity());
    let alice = CheckedMem::new(&alloc, &table, ProtectionDomain(1));
    let bob = CheckedMem::new(&alloc, &table, ProtectionDomain(2));
    let secret = alice.alloc(Perms::RW)?;
    alice.write(secret, 0, b"alice's data")?;
    let mut buf = [0u8; 12];
    let denied = bob.read(secret, 0, &mut buf).is_err();
    println!("[protection] bob reading alice's block -> denied: {denied}");
    assert!(denied);
    alice.read(secret, 0, &mut buf)?;
    println!("[protection] alice reads back: {:?}\n", std::str::from_utf8(&buf).unwrap());

    // --- Relocation: move a block; stale ids resolve via forwarding.
    let reloc = Relocator::new(&alloc);
    let old_id = secret;
    table.revoke(old_id)?; // kernel reclaims before moving
    let new_id = reloc.migrate(old_id)?;
    println!("[relocation] {old_id:?} migrated to {new_id:?}; resolve({old_id:?}) = {:?}", reloc.resolve(old_id));
    let mut moved = [0u8; 12];
    alloc.read(reloc.resolve(old_id), 0, &mut moved)?;
    assert_eq!(&moved, b"alice's data");
    println!("[relocation] contents intact after move\n");

    // --- Relocation, tree-native: migrating a leaf patches one pointer.
    let n = 100_000usize;
    let mut arr: TreeArray<f32> = TreeArray::new(&alloc, n)?;
    for i in (0..n).step_by(97) {
        arr.set(i, i as f32)?;
    }
    let before = arr.to_vec();
    for leaf in 0..arr.nleaves() {
        arr.migrate_leaf(leaf)?;
    }
    assert_eq!(arr.to_vec(), before);
    println!("[relocation] migrated all {} leaves of a {}-element tree; array unchanged\n", arr.nleaves(), n);

    // --- Swapping: application-controlled evict/fault.
    let swap = SwapPool::anonymous(&alloc)?;
    let cold = alloc.alloc()?;
    alloc.write(cold, 0, b"cold data")?;
    let live_before = alloc.stats().allocated;
    let slot = swap.evict(cold)?;
    println!(
        "[swapping] evicted block to disk: {} -> {} physical blocks live",
        live_before,
        alloc.stats().allocated
    );
    let back = swap.fault(slot)?;
    let mut cold_buf = [0u8; 9];
    alloc.read(back, 0, &mut cold_buf)?;
    assert_eq!(&cold_buf, b"cold data");
    println!("[swapping] faulted back into {back:?}: {:?}", std::str::from_utf8(&cold_buf).unwrap());
    println!("[swapping] stats: {:?}\n", swap.stats());

    // --- Contiguity: covered by TreeArray above and the quickstart /
    //     stack_splitting examples.
    println!("all four Table 1 functions demonstrated without address translation ✓");
    Ok(())
}
