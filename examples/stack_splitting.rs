//! Split stacks in action (paper §3.1 / Figure 3, interactive).
//!
//! Shows the three costs the paper discusses: the per-call check (via
//! the recursive-Fibonacci microbenchmark, real execution), the rare
//! block-overflow slow path, and the per-benchmark overhead model.
//!
//! ```sh
//! cargo run --release --example stack_splitting
//! ```

use std::time::Instant;

use nvm::coordinator::experiments::{fig3, ExpConfig};
use nvm::pmem::BlockAllocator;
use nvm::stack::{CallTrace, SplitStack, TraceRunner};
use nvm::testutil::Rng;
use nvm::workloads::fib;

fn main() -> anyhow::Result<()> {
    let alloc = BlockAllocator::new(32 * 1024, 1 << 14)?;

    // 1. Deep recursion across many stack blocks, frames intact.
    let mut s = SplitStack::new(&alloc)?;
    for d in 0..100_000u64 {
        s.call(160, &d.to_le_bytes())?;
    }
    let st = s.stats();
    println!(
        "100k-deep recursion: {} blocks chained (max frame payload {} B)",
        st.blocks_peak,
        s.max_frame()
    );
    drop(s);

    // 2. The pessimistic microbenchmark: fib(28), real wallclock.
    let n = 28;
    let t0 = Instant::now();
    let native = fib::fib_native(n);
    let native_t = t0.elapsed();
    let t1 = Instant::now();
    let (split, calls) = fib::fib_split_fresh(&alloc, n)?;
    let split_t = t1.elapsed();
    anyhow::ensure!(native == split, "fib mismatch");
    println!(
        "fib({n}) = {native}: native {:.1} ms, split-stack {:.1} ms ({} calls, {:.1} ns/call overhead)",
        native_t.as_secs_f64() * 1e3,
        split_t.as_secs_f64() * 1e3,
        calls,
        (split_t.as_secs_f64() - native_t.as_secs_f64()) * 1e9 / calls as f64
    );

    // 3. Overflow behaviour on a realistic call mix.
    let mut rng = Rng::new(5);
    let trace = CallTrace::generate(&mut rng, 100_000, 256, 0.5);
    let stats = TraceRunner::run_split(&trace, &alloc)?;
    println!(
        "replayed {} calls: {} overflows ({:.4}% hit the slow path)",
        stats.calls,
        stats.overflows,
        stats.overflows as f64 / stats.calls as f64 * 100.0
    );

    // 4. The Figure 3 model across the suites.
    println!("\nFigure 3 overhead model:");
    let t = fig3(&ExpConfig::quick());
    println!("{t}");
    Ok(())
}
