"""L1 Pallas kernel: GUPS update-value computation.

GUPS (giga-updates-per-second) performs `table[idx] ^= key` at random
indices. The gather + xor half is the kernel (it is the part with data
reuse to tile); the scatter half stays in the L2 jnp model
(`model.gups_step`) where XLA lowers it to a native scatter in the same HLO
module -- Pallas interpret-mode has no scatter primitive worth hand-rolling
for an elementwise xor.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gups_kernel(table_ref, idx_ref, keys_ref, out_ref):
    idx = idx_ref[...]
    table = table_ref[...]
    out_ref[...] = table[idx] ^ keys_ref[...]


@jax.jit
def gups_update_vals(table, idx, keys):
    """Compute the xor-updated values for one GUPS step.

    Args:
      table: int32[n] update table.
      idx:   int32[m] indices into table.
      keys:  int32[m] xor keys.

    Returns:
      int32[m] new values (table[idx] ^ keys).
    """
    (n,) = table.shape
    (m,) = idx.shape
    return pl.pallas_call(
        _gups_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), table.dtype),
        interpret=True,
    )(table, idx, keys)
