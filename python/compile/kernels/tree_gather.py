"""L1 Pallas kernel: arrays-as-trees gather (the software page-table walk).

`tree_gather` is the naive tree access of the paper's Figure 1/2 expressed
as a kernel: every flat element index is split into (indirection slot,
offset) and resolved through the leaf table. This is the access pattern the
paper's Iterator optimization amortizes away; the kernel exists so the
GUPS-style random-access path can run through the AOT artifact with the
*same* addressing logic the Rust `trees::TreeArray` uses.

The whole leaf table is mapped into the grid step (the random gather has no
exploitable block structure -- precisely the paper's "inherently
unpredictable" case). On TPU this would want the leaf table HBM-resident
with a gather custom lowering; interpret=True keeps it runnable on CPU
PJRT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tree_gather_kernel(leaves_ref, idx_ref, out_ref, *, bele):
    idx = idx_ref[...]
    block = idx // bele
    off = idx % bele
    leaves = leaves_ref[...]
    out_ref[...] = leaves[block, off]


@jax.jit
def tree_gather(leaves, idx):
    """Gather elements from depth-1 tree leaves by flat index.

    Args:
      leaves: f32[nblocks, bele] leaf blocks (bele = 8192 for 32 KB blocks).
      idx:    int32[m] flat element indices.

    Returns:
      f32[m] gathered values.
    """
    nblocks, bele = leaves.shape
    (m,) = idx.shape
    kernel = functools.partial(_tree_gather_kernel, bele=bele)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((nblocks, bele), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), leaves.dtype),
        interpret=True,
    )(leaves, idx)
