"""L1 Pallas kernel: tiled Black-Scholes pricing.

The tile size is the paper's 32 KB physical block: 8192 f32 elements. One
grid step prices one block, so the BlockSpec index map plays exactly the
role of the arrays-as-trees indirection layer (DESIGN.md
SS-Hardware-Adaptation): grid step `i` -> leaf block `i`, resident in VMEM
for the whole step.

Lowered with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and numerics are validated through this path. On a real TPU
the same kernel tiles HBM->VMEM at 32 KB per operand (5 operands in flight
x 32 KB = 160 KB << 16 MB VMEM, leaving room for >16-deep double
buffering); the math is pure VPU elementwise work, so the roofline is the
HBM stream bandwidth, identical to the contiguous layout -- the paper's
zero-overhead claim for block-tiled compute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import erf_approx

# 32 KB block / 4-byte f32 = 8192 elements: the paper's allocation unit.
BLOCK_ELEMS = 8192

SQRT2 = 1.4142135623730951


def _bs_kernel(rate_ref, vol_ref, spot_ref, strike_ref, tmat_ref,
               call_ref, put_ref):
    """Price one 32 KB block of options (elementwise, VPU-shaped)."""
    spot = spot_ref[...]
    strike = strike_ref[...]
    tmat = tmat_ref[...]
    rate = rate_ref[0]
    vol = vol_ref[0]

    sqrt_t = jnp.sqrt(tmat)
    sig_t = vol * sqrt_t
    d1 = (jnp.log(spot / strike) + (rate + 0.5 * vol * vol) * tmat) / sig_t
    d2 = d1 - sig_t
    disc = jnp.exp(-rate * tmat)

    def cdf(x):
        # erf_approx, not jax.lax.erf: artifacts must avoid the `erf`
        # HLO opcode (unknown to the pinned xla_extension 0.5.1 parser).
        return 0.5 * (1.0 + erf_approx(x / SQRT2))

    call_ref[...] = spot * cdf(d1) - strike * disc * cdf(d2)
    put_ref[...] = strike * disc * cdf(-d2) - spot * cdf(-d1)


@functools.partial(jax.jit, static_argnames=("block_elems", "blocks_per_step"))
def blackscholes_blocked(spot, strike, tmat, rate, vol,
                         block_elems=BLOCK_ELEMS, blocks_per_step=1):
    """Blocked (physically addressed) layout: inputs are [nblocks, bele].

    Each leaf block of the arrays-as-trees structure is one grid step
    (`blocks_per_step=1`, the TPU tiling); no contiguity is assumed
    across blocks, mirroring the Rust-side `trees::TreeArray` leaf layout
    byte-for-byte.

    `blocks_per_step` widens the tile: `blocks_per_step=nblocks` lowers
    to a single fused grid step, which is how the CPU artifacts are
    compiled (EXPERIMENTS.md §Perf: interpret-mode grid loops pay a full
    array dynamic-update-slice per step — 15x wall-clock at 256 steps —
    while on TPU the per-block grid is what double-buffers HBM->VMEM).
    """
    nblocks, bele = spot.shape
    assert bele == block_elems, (bele, block_elems)
    assert nblocks % blocks_per_step == 0, (nblocks, blocks_per_step)
    grid = (nblocks // blocks_per_step,)
    data_spec = pl.BlockSpec((blocks_per_step, bele), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    out_shape = jax.ShapeDtypeStruct((nblocks, bele), spot.dtype)
    call, put = pl.pallas_call(
        _bs_kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, data_spec, data_spec, data_spec],
        out_specs=[data_spec, data_spec],
        out_shape=[out_shape, out_shape],
        interpret=True,
    )(rate.reshape(1), vol.reshape(1), spot, strike, tmat)
    return call, put


@functools.partial(jax.jit, static_argnames=("block_elems",))
def blackscholes_contig(spot, strike, tmat, rate, vol,
                        block_elems=BLOCK_ELEMS):
    """Contiguous (virtual memory) layout: inputs are flat [n].

    Same kernel, tiled over a flat array -- the traditional large-malloc
    baseline the paper compares against. n must be a multiple of the block
    size (the Rust coordinator pads the tail block).
    """
    (n,) = spot.shape
    assert n % block_elems == 0, (n, block_elems)
    grid = (n // block_elems,)
    data_spec = pl.BlockSpec((block_elems,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    out_shape = jax.ShapeDtypeStruct((n,), spot.dtype)
    call, put = pl.pallas_call(
        _bs_kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, data_spec, data_spec, data_spec],
        out_specs=[data_spec, data_spec],
        out_shape=[out_shape, out_shape],
        interpret=True,
    )(rate.reshape(1), vol.reshape(1), spot, strike, tmat)
    return call, put
