"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its `*_ref` counterpart to float tolerance under pytest
(`python/tests/`). They are also the "contiguous array" compute baseline for
the blocked (physically addressed) layouts.
"""

import jax
import jax.numpy as jnp

SQRT2 = 1.4142135623730951


def erf_approx(x):
    """erf via Abramowitz-Stegun 7.1.26 (|error| <= 1.5e-7).

    Used instead of `jax.lax.erf` in every *exported* computation: the
    pinned xla_extension 0.5.1 HLO parser predates the `erf` opcode, so
    artifacts must lower to elementary ops only. The Rust scalar
    reference (`workloads::blackscholes::erf`) uses the same polynomial,
    keeping all three implementations bit-comparable to ~1e-7.
    """
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    poly = (
        ((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
        + 0.254829592
    ) * t
    return sign * (1.0 - poly * jnp.exp(-ax * ax))


def norm_cdf(x):
    """Standard normal CDF via erf (matches the kernel's formulation)."""
    return 0.5 * (1.0 + erf_approx(x / SQRT2))


def blackscholes_ref(spot, strike, tmat, rate, vol):
    """Black-Scholes European call/put prices, elementwise.

    Args:
      spot, strike, tmat: arrays of identical shape (any rank).
      rate, vol: scalars (python float or 0-d/1-d array broadcastable).

    Returns:
      (call, put) arrays with the same shape as `spot`.
    """
    rate = jnp.asarray(rate, dtype=spot.dtype).reshape(())
    vol = jnp.asarray(vol, dtype=spot.dtype).reshape(())
    sqrt_t = jnp.sqrt(tmat)
    sig_t = vol * sqrt_t
    d1 = (jnp.log(spot / strike) + (rate + 0.5 * vol * vol) * tmat) / sig_t
    d2 = d1 - sig_t
    disc = jnp.exp(-rate * tmat)
    call = spot * norm_cdf(d1) - strike * disc * norm_cdf(d2)
    put = strike * disc * norm_cdf(-d2) - spot * norm_cdf(-d1)
    return call, put


def gups_ref(table, idx, keys):
    """One GUPS step: table[idx] ^= keys (last write wins on duplicates).

    Args:
      table: int32[n] update table.
      idx:   int32[m] random indices into `table` (in range).
      keys:  int32[m] xor keys.

    Returns:
      updated int32[n] table.
    """
    vals = table[idx] ^ keys
    return table.at[idx].set(vals)


def tree_gather_ref(leaves, idx):
    """Naive arrays-as-trees access: flat index -> (block, offset) -> leaf.

    This is the software page-table walk of the paper's Figure 1: the leaf
    table `leaves[nblocks, bele]` is the depth-1 indirection layer, and each
    access splits a flat element index into (indirection slot, offset).

    Args:
      leaves: f32[nblocks, bele] leaf blocks.
      idx:    int32[m] flat element indices (< nblocks*bele).

    Returns:
      f32[m] gathered elements.
    """
    bele = leaves.shape[1]
    block = idx // bele
    off = idx % bele
    return leaves[block, off]
