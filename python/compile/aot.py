"""AOT compile path: lower every L2 model variant to HLO *text*.

Run once by `make artifacts`; the Rust runtime
(`rust/src/runtime/pjrt.rs`) loads the text with
`HloModuleProto::from_text_file`, compiles on the PJRT CPU client, and
executes -- Python never runs on the request path.

HLO text (NOT `lowered.compile()` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the pinned xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Artifact registry: name -> (fn, example arg specs).
# Shapes here are the compiled-executable shapes; the Rust coordinator
# batches its workloads to these (padding tail blocks) and loops for
# larger datasets. Names are parsed by rust/src/runtime/artifacts.rs --
# keep the `<name>.hlo.txt` scheme in sync.
BELE = model.BLOCK_ELEMS  # 8192 f32 = 32 KB, the paper's block size

VARIANTS = {
    # Figure 5 / E2E: Black-Scholes over both layouts, 256 blocks = 8 MB
    # per executable invocation per operand.
    "bs_blocked_256x8192": (
        model.bs_blocked,
        [spec((256, BELE))] * 3 + [spec(()), spec(())],
    ),
    "bs_contig_2097152": (
        model.bs_contig,
        [spec((256 * BELE,))] * 3 + [spec(()), spec(())],
    ),
    # Smaller variant for request-sized batches (1 block) used by the
    # batcher's latency path and the quickstart example.
    "bs_blocked_1x8192": (
        model.bs_blocked,
        [spec((1, BELE))] * 3 + [spec(()), spec(())],
    ),
    "bs_greeks_blocked_16x8192": (
        model.bs_greeks_blocked,
        [spec((16, BELE))] * 3 + [spec(()), spec(())],
    ),
    # Figure 4 compute path: one GUPS round, 1M-entry table, 4096 updates.
    "gups_1048576_4096": (
        model.gups_step,
        [spec((1 << 20,), I32), spec((4096,), I32), spec((4096,), I32)],
    ),
    # Naive arrays-as-trees random access as an artifact.
    "tree_gather_64x8192_4096": (
        model.tree_gather,
        [spec((64, BELE)), spec((4096,), I32)],
    ),
}


def build(out_dir: str, only=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, (fn, args) in sorted(VARIANTS.items()):
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        arg_sig = ";".join(
            f"{a.dtype}[{','.join(str(d) for d in a.shape)}]" for a in args
        )
        manifest.append(f"{name} {arg_sig}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {out_dir}/manifest.txt ({len(manifest)} artifacts)")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--only", nargs="*", help="subset of variant names")
    args = p.parse_args()
    build(args.out_dir, only=args.only)


if __name__ == "__main__":
    main()
