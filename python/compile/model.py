"""L2: JAX compute graphs over the paper's two memory layouts.

Each public function here is one AOT artifact (`aot.py` lowers them to HLO
text). They call the L1 Pallas kernels so everything lowers into a single
HLO module; Python never runs at serving time.

Layouts:
  contiguous  -- flat [n] arrays, the traditional virtual-memory layout.
  blocked     -- [nblocks, 8192] f32 (= 32 KB) leaf blocks, the paper's
                 physically addressed arrays-as-trees leaf layout. The Rust
                 coordinator hands these straight out of its block
                 allocator; no layout change is needed at the boundary.
"""

import jax
import jax.numpy as jnp

from compile.kernels import blackscholes as bs
from compile.kernels import gups as gups_k
from compile.kernels import tree_gather as tg

BLOCK_ELEMS = bs.BLOCK_ELEMS


# ---------------------------------------------------------------------------
# Black-Scholes pricing (Figure 5 / E2E driver compute).
# ---------------------------------------------------------------------------

def bs_blocked(spot, strike, tmat, rate, vol):
    """Price a batch of 32 KB blocks. Inputs [nblocks, 8192] f32.

    CPU artifacts are lowered with one fused grid step covering the whole
    batch (`blocks_per_step = nblocks`): interpret-mode grid loops pay a
    full-array dynamic-update-slice per step, a pure artifact of CPU
    execution (EXPERIMENTS.md SSPerf). The per-block tiling story for TPU
    lives in the kernel's default `blocks_per_step=1`.
    """
    nblocks = spot.shape[0]
    call, put = bs.blackscholes_blocked(
        spot, strike, tmat, rate, vol, blocks_per_step=nblocks
    )
    return call, put


def bs_contig(spot, strike, tmat, rate, vol):
    """Price a flat contiguous array. Inputs [n] f32, n % 8192 == 0."""
    (n,) = spot.shape
    call, put = bs.blackscholes_contig(spot, strike, tmat, rate, vol, block_elems=n)
    return call, put


def bs_greeks_blocked(spot, strike, tmat, rate, vol):
    """Per-element delta and book vega, blocked layout.

    The "bwd" half of the model: jax.grad through the pricing graph
    (the pure-jnp formulation, which is autodiff-able; the Pallas kernel
    has no VJP rule). Tests cross-check against the closed forms
    delta = N(d1), vega = spot*sqrt(t)*phi(d1).
    """
    from compile.kernels import ref

    def book_value(spot_, vol_):
        call, _ = ref.blackscholes_ref(spot_, strike, tmat, rate, vol_)
        return jnp.sum(call)

    delta = jax.grad(book_value, argnums=0)(spot, vol)
    vega = jax.grad(book_value, argnums=1)(spot, vol)
    return delta, vega.reshape(1)


# ---------------------------------------------------------------------------
# GUPS (Figure 4 compute path).
# ---------------------------------------------------------------------------

def gups_step(table, idx, keys):
    """One GUPS round: xor-update `table` at `idx` with `keys`.

    Gather+xor runs in the Pallas kernel; the scatter lowers to a native
    XLA scatter in the same module. Buffer `table` is donated by aot.py so
    the update is in-place at the PJRT level.
    """
    vals = gups_k.gups_update_vals(table, idx, keys)
    return (table.at[idx].set(vals),)


# ---------------------------------------------------------------------------
# Tree gather (naive arrays-as-trees access as an artifact).
# ---------------------------------------------------------------------------

def tree_gather(leaves, idx):
    """Gather flat indices through the depth-1 leaf table."""
    return (tg.tree_gather(leaves, idx),)
