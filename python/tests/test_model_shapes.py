"""L2 model shape/abstract-eval tests + greeks cross-check + AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_all_variants_abstract_eval():
    """Every AOT variant must trace at its registered shapes."""
    for name, (fn, args) in aot.VARIANTS.items():
        out = jax.eval_shape(fn, *args)
        assert out is not None, name


def test_variant_output_shapes():
    out = jax.eval_shape(*_variant("bs_blocked_256x8192"))
    assert tuple(out[0].shape) == (256, model.BLOCK_ELEMS)
    out = jax.eval_shape(*_variant("gups_1048576_4096"))
    assert tuple(out[0].shape) == (1 << 20,)
    out = jax.eval_shape(*_variant("tree_gather_64x8192_4096"))
    assert tuple(out[0].shape) == (4096,)


def _variant(name):
    fn, args = aot.VARIANTS[name]
    return (fn, *args)


def test_greeks_match_closed_form():
    """jax.grad delta/vega == closed-form N(d1) / s*sqrt(t)*phi(d1)."""
    rng = np.random.default_rng(0)
    shape = (2, 64)
    s = jnp.asarray(rng.uniform(20, 180, shape).astype(np.float32))
    k = jnp.asarray(rng.uniform(20, 180, shape).astype(np.float32))
    t = jnp.asarray(rng.uniform(0.1, 2.0, shape).astype(np.float32))
    rate, vol = jnp.float32(0.03), jnp.float32(0.25)
    delta, vega = model.bs_greeks_blocked(s, k, t, rate, vol)

    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(s / k) + (rate + 0.5 * vol * vol) * t) / (vol * sqrt_t)
    delta_cf = ref.norm_cdf(d1)
    phi = jnp.exp(-0.5 * d1 * d1) / np.sqrt(2 * np.pi)
    vega_cf = jnp.sum(s * sqrt_t * phi)

    np.testing.assert_allclose(delta, delta_cf, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(vega, vega_cf, rtol=1e-3)


def test_hlo_text_lowering_roundtrip(tmp_path):
    """The aot recipe emits parseable HLO text with an ENTRY computation."""
    fn, _ = aot.VARIANTS["bs_blocked_1x8192"]
    args = [
        jax.ShapeDtypeStruct((1, model.BLOCK_ELEMS), jnp.float32)
    ] * 3 + [jax.ShapeDtypeStruct((), jnp.float32)] * 2
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[1,8192]" in text


def test_build_subset(tmp_path):
    """aot.build writes the artifact file and manifest for a subset."""
    aot.build(str(tmp_path), only=["tree_gather_64x8192_4096"])
    files = {p.name for p in tmp_path.iterdir()}
    assert "tree_gather_64x8192_4096.hlo.txt" in files
    assert "manifest.txt" in files
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "tree_gather_64x8192_4096 float32[64,8192];int32[4096]" in manifest


def test_erf_approx_matches_lax_erf():
    """The exported polynomial erf tracks jax.lax.erf to ~1.5e-7."""
    x = jnp.linspace(-4.0, 4.0, 2001, dtype=jnp.float32)
    approx = ref.erf_approx(x)
    exact = jax.lax.erf(x)
    # A&S 7.1.26: |error| <= 1.5e-7 in exact arithmetic; f32 evaluation
    # of the polynomial adds a few ulp.
    np.testing.assert_allclose(approx, exact, atol=2e-6)


def test_no_erf_opcode_in_artifacts():
    """xla_extension 0.5.1 rejects the `erf` HLO opcode; artifacts must
    lower to elementary ops only."""
    fn, args = aot.VARIANTS["bs_blocked_1x8192"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    for line in text.splitlines():
        assert " erf(" not in line, f"erf opcode leaked into HLO: {line}"
