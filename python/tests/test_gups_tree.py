"""GUPS and tree-gather kernels vs oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gups as gups_k
from compile.kernels import ref
from compile.kernels import tree_gather as tg


class TestGupsKernel:
    def test_update_vals_match_ref(self):
        rng = np.random.default_rng(0)
        n, m = 1 << 12, 256
        table = jnp.asarray(rng.integers(0, 1 << 30, n, dtype=np.int32))
        idx = jnp.asarray(rng.integers(0, n, m, dtype=np.int32))
        keys = jnp.asarray(rng.integers(0, 1 << 30, m, dtype=np.int32))
        vals = gups_k.gups_update_vals(table, idx, keys)
        np.testing.assert_array_equal(vals, np.asarray(table)[np.asarray(idx)] ^ np.asarray(keys))

    def test_step_matches_ref(self):
        rng = np.random.default_rng(1)
        n, m = 1 << 10, 128
        table = jnp.asarray(rng.integers(0, 1 << 30, n, dtype=np.int32))
        # unique indices: xor-update semantics are order-free then
        idx = jnp.asarray(rng.choice(n, m, replace=False).astype(np.int32))
        keys = jnp.asarray(rng.integers(0, 1 << 30, m, dtype=np.int32))
        (out,) = model.gups_step(table, idx, keys)
        expect = ref.gups_ref(table, idx, keys)
        np.testing.assert_array_equal(out, expect)

    def test_step_is_involution_with_same_keys(self):
        # xor twice with the same keys restores the table (unique idx).
        rng = np.random.default_rng(2)
        n, m = 1 << 10, 64
        table = jnp.asarray(rng.integers(0, 1 << 30, n, dtype=np.int32))
        idx = jnp.asarray(rng.choice(n, m, replace=False).astype(np.int32))
        keys = jnp.asarray(rng.integers(0, 1 << 30, m, dtype=np.int32))
        (once,) = model.gups_step(table, idx, keys)
        (twice,) = model.gups_step(once, idx, keys)
        np.testing.assert_array_equal(twice, table)

    def test_untouched_entries_unchanged(self):
        rng = np.random.default_rng(3)
        n = 1 << 10
        table = jnp.asarray(rng.integers(0, 1 << 30, n, dtype=np.int32))
        idx = jnp.asarray(np.array([1, 2, 3], dtype=np.int32))
        keys = jnp.asarray(np.array([7, 8, 9], dtype=np.int32))
        (out,) = model.gups_step(table, idx, keys)
        mask = np.ones(n, bool)
        mask[[1, 2, 3]] = False
        np.testing.assert_array_equal(np.asarray(out)[mask], np.asarray(table)[mask])


class TestTreeGather:
    def test_matches_ref(self):
        rng = np.random.default_rng(4)
        nblocks, bele, m = 8, 512, 333
        leaves = jnp.asarray(rng.standard_normal((nblocks, bele)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, nblocks * bele, m, dtype=np.int32))
        out = tg.tree_gather(leaves, idx)
        np.testing.assert_array_equal(out, ref.tree_gather_ref(leaves, idx))

    def test_equiv_flat_indexing(self):
        # Tree-of-blocks access == flat contiguous access: the correctness
        # invariant of arrays-as-trees (paper SS3.2).
        rng = np.random.default_rng(5)
        nblocks, bele, m = 4, 256, 100
        flat = rng.standard_normal(nblocks * bele).astype(np.float32)
        leaves = jnp.asarray(flat.reshape(nblocks, bele))
        idx = jnp.asarray(rng.integers(0, nblocks * bele, m, dtype=np.int32))
        out = tg.tree_gather(leaves, idx)
        np.testing.assert_array_equal(out, flat[np.asarray(idx)])

    @settings(max_examples=20, deadline=None)
    @given(
        nblocks=st.integers(1, 8),
        bele=st.sampled_from([64, 128, 512]),
        m=st.integers(1, 200),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_gather(self, nblocks, bele, m, seed):
        rng = np.random.default_rng(seed)
        leaves = jnp.asarray(rng.standard_normal((nblocks, bele)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, nblocks * bele, m, dtype=np.int32))
        out = tg.tree_gather(leaves, idx)
        np.testing.assert_array_equal(out, ref.tree_gather_ref(leaves, idx))
