"""Pallas Black-Scholes kernel vs pure-jnp oracle (the core L1 signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import blackscholes as bs
from compile.kernels import ref

RNG = np.random.default_rng(0)


def market_blocks(nblocks, bele=bs.BLOCK_ELEMS, seed=0):
    rng = np.random.default_rng(seed)
    spot = rng.uniform(5.0, 200.0, (nblocks, bele)).astype(np.float32)
    strike = rng.uniform(5.0, 200.0, (nblocks, bele)).astype(np.float32)
    tmat = rng.uniform(0.05, 3.0, (nblocks, bele)).astype(np.float32)
    return jnp.asarray(spot), jnp.asarray(strike), jnp.asarray(tmat)


RATE = jnp.float32(0.03)
VOL = jnp.float32(0.25)


class TestBlockedKernel:
    def test_matches_ref_single_block(self):
        s, k, t = market_blocks(1)
        call, put = bs.blackscholes_blocked(s, k, t, RATE, VOL)
        call_r, put_r = ref.blackscholes_ref(s, k, t, RATE, VOL)
        np.testing.assert_allclose(call, call_r, rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(put, put_r, rtol=1e-5, atol=1e-4)

    def test_matches_ref_multi_block(self):
        s, k, t = market_blocks(7, seed=3)
        call, put = bs.blackscholes_blocked(s, k, t, RATE, VOL)
        call_r, put_r = ref.blackscholes_ref(s, k, t, RATE, VOL)
        np.testing.assert_allclose(call, call_r, rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(put, put_r, rtol=1e-5, atol=1e-4)

    def test_put_call_parity(self):
        # call - put == spot - strike * e^{-rt}, independent of vol.
        s, k, t = market_blocks(2, seed=5)
        call, put = bs.blackscholes_blocked(s, k, t, RATE, VOL)
        parity = s - k * jnp.exp(-RATE * t)
        np.testing.assert_allclose(call - put, parity, rtol=1e-4, atol=1e-3)

    def test_grid_step_independence(self):
        # Block i's prices must not depend on other blocks (no cross-block
        # contiguity assumption -- the property that makes the blocked
        # layout correct for arrays-as-trees leaves).
        s, k, t = market_blocks(4, seed=7)
        call_all, _ = bs.blackscholes_blocked(s, k, t, RATE, VOL)
        call_one, _ = bs.blackscholes_blocked(
            s[2:3], k[2:3], t[2:3], RATE, VOL
        )
        np.testing.assert_allclose(call_all[2:3], call_one, rtol=1e-6)

    def test_small_block_elems(self):
        # Kernel is parametric in block size (ablation uses 8..128 KB).
        bele = 256
        rng = np.random.default_rng(11)
        s = jnp.asarray(rng.uniform(10, 100, (3, bele)).astype(np.float32))
        k = jnp.asarray(rng.uniform(10, 100, (3, bele)).astype(np.float32))
        t = jnp.asarray(rng.uniform(0.1, 2, (3, bele)).astype(np.float32))
        call, put = bs.blackscholes_blocked(s, k, t, RATE, VOL,
                                            block_elems=bele)
        call_r, put_r = ref.blackscholes_ref(s, k, t, RATE, VOL)
        np.testing.assert_allclose(call, call_r, rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(put, put_r, rtol=1e-5, atol=1e-4)


class TestContigKernel:
    def test_matches_ref(self):
        s2, k2, t2 = market_blocks(3, seed=9)
        s, k, t = s2.reshape(-1), k2.reshape(-1), t2.reshape(-1)
        call, put = bs.blackscholes_contig(s, k, t, RATE, VOL)
        call_r, put_r = ref.blackscholes_ref(s, k, t, RATE, VOL)
        np.testing.assert_allclose(call, call_r, rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(put, put_r, rtol=1e-5, atol=1e-4)

    def test_layouts_agree(self):
        # blocked([nb, bele]) == contig([nb*bele]).reshape -- the two
        # layouts must price identically, which is what lets Figure 5
        # attribute any runtime delta purely to memory layout.
        s2, k2, t2 = market_blocks(4, seed=13)
        cb, pb = bs.blackscholes_blocked(s2, k2, t2, RATE, VOL)
        cc, pc = bs.blackscholes_contig(
            s2.reshape(-1), k2.reshape(-1), t2.reshape(-1), RATE, VOL
        )
        np.testing.assert_allclose(cb.reshape(-1), cc, rtol=1e-6)
        np.testing.assert_allclose(pb.reshape(-1), pc, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    nblocks=st.integers(1, 4),
    bele=st.sampled_from([128, 256, 1024]),
    seed=st.integers(0, 2**31 - 1),
    rate=st.floats(0.0, 0.10),
    vol=st.floats(0.05, 0.9),
)
def test_hypothesis_kernel_vs_ref(nblocks, bele, seed, rate, vol):
    """Shape/parameter sweep: kernel == oracle everywhere."""
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.uniform(1.0, 500.0, (nblocks, bele)).astype(np.float32))
    k = jnp.asarray(rng.uniform(1.0, 500.0, (nblocks, bele)).astype(np.float32))
    t = jnp.asarray(rng.uniform(0.01, 5.0, (nblocks, bele)).astype(np.float32))
    r = jnp.float32(rate)
    v = jnp.float32(vol)
    call, put = bs.blackscholes_blocked(s, k, t, r, v, block_elems=bele)
    call_r, put_r = ref.blackscholes_ref(s, k, t, r, v)
    np.testing.assert_allclose(call, call_r, rtol=2e-5, atol=2e-3)
    np.testing.assert_allclose(put, put_r, rtol=2e-5, atol=2e-3)


def test_prices_nonnegative():
    s, k, t = market_blocks(2, seed=17)
    call, put = bs.blackscholes_blocked(s, k, t, RATE, VOL)
    assert float(jnp.min(call)) >= -1e-3
    assert float(jnp.min(put)) >= -1e-3


def test_deep_itm_call_approaches_forward():
    # spot >> strike: call ~= spot - strike*e^{-rt}.
    bele = bs.BLOCK_ELEMS
    s = jnp.full((1, bele), 1000.0, jnp.float32)
    k = jnp.full((1, bele), 1.0, jnp.float32)
    t = jnp.full((1, bele), 1.0, jnp.float32)
    call, _ = bs.blackscholes_blocked(s, k, t, RATE, VOL)
    expected = 1000.0 - 1.0 * np.exp(-float(RATE))
    np.testing.assert_allclose(call, expected, rtol=1e-4)
