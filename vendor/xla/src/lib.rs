//! Offline stub of the XLA/PJRT Rust bindings.
//!
//! The real bindings (the `/opt/xla-example` pattern: HLO text →
//! `HloModuleProto` → `XlaComputation` → PJRT compile/execute) need a
//! prebuilt PJRT plugin that is not present in the offline build
//! environment. This crate mirrors the subset of the API the `nvm`
//! runtime layer uses so the whole workspace compiles and tests run;
//! [`PjRtClient::cpu`] fails with a clear message, which the callers
//! already treat as "runtime unavailable, skip" (the integration tests
//! and benches print SKIP and return).
//!
//! Replace the `vendor/xla` path dependency with the real bindings on a
//! machine that has them; no `nvm` source change is needed.

use std::path::Path;

/// Error type matching the real bindings' surface.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "XLA/PJRT runtime unavailable in this build (vendor/xla is the offline stub)".to_string(),
    ))
}

/// Element types a [`Literal`] can hold in this stub.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for f64 {}

/// Host-side literal (stub: carries no data; execution never succeeds).
#[derive(Debug, Default, Clone)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    /// Scalar literal.
    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    /// Reshape to `shape`.
    pub fn reshape(&self, _shape: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with host inputs; returns per-device, per-output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client (stub).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client — always fails in the stub; callers treat this as
    /// "runtime unavailable" and skip.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    /// Platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_construction_is_total() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[1, 2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
