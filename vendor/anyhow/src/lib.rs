//! Offline stub of `anyhow`: just the surface the repo's examples use
//! (`Result`, `Error`, `Error::msg`, `ensure!`). Like the real crate,
//! [`Error`] deliberately does **not** implement `std::error::Error`,
//! which is what lets the blanket `From<E: std::error::Error>` impl
//! coexist with the reflexive `From<Error> for Error`.

use std::fmt;

/// Type-erased error (stub: stores the formatted message).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result`, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> super::Result<()> {
            let _ = std::str::from_utf8(&[0xFF])?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn ensure_fires_on_false() {
        fn inner(ok: bool) -> super::Result<u8> {
            crate::ensure!(ok, "wanted {}", ok);
            Ok(1)
        }
        assert!(inner(true).is_ok());
        let e = inner(false).unwrap_err();
        assert_eq!(format!("{e}"), "wanted false");
    }
}
