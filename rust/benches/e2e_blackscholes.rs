//! Bench: the end-to-end headline — Black-Scholes through all three
//! layers. Rust block allocator + tree arrays feed the batcher; the
//! AOT-compiled Pallas kernel executes via PJRT; the contiguous artifact
//! is the VM-layout baseline. Also measures the single-block latency
//! path and the pure-Rust scalar implementation for reference.
//!
//! Requires `make artifacts`. `cargo bench --bench e2e_blackscholes`

use nvm::bench_utils::{bench, section, Sample};
use nvm::coordinator::{BlockBatcher, batcher::BATCH_BLOCKS};
use nvm::pmem::BlockAllocator;
use nvm::runtime::{Engine, Input};
use nvm::telemetry::{results, sink, Direction, MetricRecord};
use nvm::trees::TreeArray;
use nvm::workloads::blackscholes as bs;
use nvm::BLOCK_ELEMS_F32 as BELE;

const RATE: f32 = 0.03;
const VOL: f32 = 0.25;

fn main() {
    sink::begin("e2e_blackscholes", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let engine = match Engine::new() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP e2e bench: {e}");
            let mut rec = sink::take().expect("bench sink installed at main start");
            rec.config("quick", quick);
            rec.config("skipped", format!("no PJRT engine: {e}"));
            results::write_bench_record(rec);
            return;
        }
    };
    println!("platform: {}", engine.platform());
    engine.warm("bs_blocked_256x8192").expect("warm blocked");
    engine.warm("bs_contig_2097152").expect("warm contig");
    engine.warm("bs_blocked_1x8192").expect("warm 1-block");

    let n = if quick { BATCH_BLOCKS * BELE } else { 4 * BATCH_BLOCKS * BELE };
    let alloc = BlockAllocator::with_capacity_bytes(n * 4 * 6 + (64 << 20)).expect("pool");
    let (spot, strike, tmat) = bs::synth_portfolio(n, 42);
    let mut ts: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut tk: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut tt: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    ts.copy_from_slice(&spot).unwrap();
    tk.copy_from_slice(&strike).unwrap();
    tt.copy_from_slice(&tmat).unwrap();
    let mut tc: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut tp: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();

    let iters = if quick { 3 } else { 8 };

    section("E2E throughput (AOT kernel via PJRT)");
    let mut batcher = BlockBatcher::new(&engine);
    let blocked = bench("blocked (tree leaves -> batcher)", 1, iters, || {
        batcher
            .price_trees(&ts, &tk, &tt, RATE, VOL, &mut tc, &mut tp)
            .unwrap()
    });
    println!("{blocked}");

    let chunk = BATCH_BLOCKS * BELE;
    let contig = bench("contiguous artifact", 1, iters, || {
        for c in 0..n / chunk {
            let lo = c * chunk;
            let out = engine
                .run_f32(
                    "bs_contig_2097152",
                    &[
                        Input::F32(&spot[lo..lo + chunk], vec![chunk as i64]),
                        Input::F32(&strike[lo..lo + chunk], vec![chunk as i64]),
                        Input::F32(&tmat[lo..lo + chunk], vec![chunk as i64]),
                        Input::ScalarF32(RATE),
                        Input::ScalarF32(VOL),
                    ],
                )
                .unwrap();
            std::hint::black_box(&out[0][0]);
        }
    });
    println!("{contig}");

    let scalar = bench("pure-Rust scalar reference", 1, iters.min(3), || {
        let mut call = vec![0.0f32; n];
        let mut put = vec![0.0f32; n];
        bs::price_contig(&spot, &strike, &tmat, RATE, VOL, &mut call, &mut put);
        call[0]
    });
    println!("{scalar}");

    let mops = |s: &Sample| n as f64 / (s.mean_ns() * 1e-9) / 1e6;
    println!(
        "\nthroughput: blocked {:.2} Mopt/s | contig {:.2} Mopt/s | scalar {:.2} Mopt/s",
        mops(&blocked),
        mops(&contig),
        mops(&scalar)
    );
    let overhead = blocked.mean_ns() / contig.mean_ns();
    println!(
        "blocked/contig layout overhead: {overhead:.3}x \
         (paper Fig 5: ~1.0 for iter-style blocked access)"
    );
    let to_mops = |ns: f64| n as f64 / (ns * 1e-9) / 1e6;
    for (name, s) in [("blocked", &blocked), ("contig", &contig), ("scalar", &scalar)] {
        sink::metric(s.metric_with(name, "Mopt/s", Direction::Higher, to_mops));
    }
    sink::metric(MetricRecord::from_value(
        "blocked_contig_overhead",
        "x",
        Direction::Lower,
        overhead,
    ));

    section("E2E request latency (single 32 KB block)");
    let spot1 = &spot[..BELE];
    let strike1 = &strike[..BELE];
    let tmat1 = &tmat[..BELE];
    let lat = bench("1-block request", 2, if quick { 20 } else { 100 }, || {
        batcher
            .price_one_block(spot1, strike1, tmat1, RATE, VOL)
            .unwrap()
            .0[0]
    });
    println!("{lat}");
    println!(
        "p50-ish mean latency {:.3} ms for {} options -> {:.2} Mopt/s single-stream",
        lat.mean_ns() / 1e6,
        BELE,
        BELE as f64 / lat.mean_ns() * 1e3
    );
    sink::metric(lat.metric_with("one_block_latency", "ms", Direction::Lower, |ns| ns / 1e6));

    // Numerics guard: blocked output equals scalar reference.
    let call_out = tc.to_vec();
    for i in (0..n).step_by(1009) {
        let (c_ref, _) = bs::price(
            bs::Option1 { spot: spot[i], strike: strike[i], tmat: tmat[i] },
            RATE,
            VOL,
        );
        assert!(
            (call_out[i] - c_ref).abs() < 1e-2,
            "mismatch at {i}: {} vs {c_ref}",
            call_out[i]
        );
    }
    println!("\nnumerics: blocked PJRT output matches scalar reference ✓");

    sink::verdict(
        "numerics_match_scalar",
        true,
        "blocked PJRT output matches the scalar reference within 1e-2",
    );
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("n", n);
    rec.config("iters", iters);
    rec.config("platform", engine.platform());
    results::write_bench_record(rec);
}
