//! Bench: background compaction under churn (the mmd tentpole).
//!
//! Runs the `fragmentation-churn` experiment — `T` reader threads
//! probing one shared tree through per-thread-TLB views while an
//! adversarial alloc/free churn fragments the pool — with the mmd
//! daemon off vs on at 1/2/4 reader threads, and prints a PASS/FAIL
//! verdict on the two acceptance claims:
//!
//! * **readers keep their throughput**: mmd-on read Mrd/s ≥ 0.9× the
//!   mmd-off run at every thread count (the daemon's token budget
//!   bounds the TLB-flush rate it imposes — background compaction must
//!   not tax the serving path more than 10%);
//! * **fragmentation actually falls**: the final fragmentation score
//!   with mmd on is ≥ 2× lower than with mmd off (compaction
//!   consolidates free space instead of reshuffling it).
//!
//! `cargo bench --bench ablation_compaction`  (NVM_QUICK=1 for a fast
//! pass)

use nvm::bench_utils::section;
use nvm::coordinator::experiments::{fragmentation_churn, ExpConfig};
use nvm::telemetry::{results, sink};

const THREADS: [usize; 3] = [1, 2, 4];

fn main() {
    sink::begin("ablation_compaction", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let mut cfg = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };
    // Sweep exactly 1/2/4 reader threads (thread_sweep tops out at
    // cfg.threads).
    cfg.threads = THREADS[THREADS.len() - 1];

    section("Ablation: churn throughput + fragmentation, no-mmd vs mmd");
    let t = fragmentation_churn(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());

    section("verdict");
    let mut all = true;
    for &threads in &THREADS {
        let off_mrd = t.cell(&format!("{threads}T mmd=off"), 0).expect("off row");
        let on_mrd = t.cell(&format!("{threads}T mmd=on"), 0).expect("on row");
        let off_score = t.cell(&format!("{threads}T mmd=off"), 2).unwrap();
        let on_score = t.cell(&format!("{threads}T mmd=on"), 2).unwrap();
        let thr_ok = on_mrd >= 0.9 * off_mrd;
        let frag_ok = on_score * 2.0 <= off_score + 1e-9;
        println!(
            "{} {threads}T reader throughput under mmd: {on_mrd:.2} vs {off_mrd:.2} Mrd/s \
             ({:.2}x, need >= 0.9x)",
            if thr_ok { "PASS" } else { "FAIL" },
            on_mrd / off_mrd
        );
        println!(
            "{} {threads}T final fragmentation score: {on_score:.3} (mmd) vs {off_score:.3} \
             (no mmd), need >= 2x lower",
            if frag_ok { "PASS" } else { "FAIL" }
        );
        sink::verdict(
            &format!("{threads}t_reader_throughput_ge_0.9x"),
            thr_ok,
            &format!("{on_mrd:.2} vs {off_mrd:.2} Mrd/s"),
        );
        sink::verdict(
            &format!("{threads}t_frag_score_2x_lower"),
            frag_ok,
            &format!("{on_score:.3} (mmd) vs {off_score:.3} (no mmd)"),
        );
        all &= thr_ok && frag_ok;
    }
    println!(
        "{}",
        if all {
            "mmd goals met: the daemon defragments a live pool without taxing its readers"
        } else {
            "MMD GOALS NOT MET — investigate (debug build? < 4 cores? tokens_per_tick too high?)"
        }
    );

    sink::with(|r| t.record_into(r));
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("threads", cfg.threads);
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
