//! Bench: regenerates **Figure 4** — GUPS tree/array ratios at 4–64 GB
//! (simulated; both the true-physical extrapolation and the paper's
//! huge-page setup with its §4.3 artifact) and the red–black tree
//! physical/virtual ratio. Plus a real-execution GUPS validation at RAM
//! scale.
//!
//! `cargo bench --bench fig4_gups_rbtree`

use nvm::bench_utils::{bench_for, section, Sample};
use nvm::coordinator::experiments::{fig4_gups, fig4_rbtree, ExpConfig};
use nvm::pmem::BlockAllocator;
use nvm::telemetry::{results, sink, Direction, MetricRecord};
use nvm::trees::TreeArray;
use nvm::workloads::gups;
use std::time::Duration;

fn main() {
    sink::begin("fig4_gups_rbtree", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let mut cfg = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };

    section("Figure 4 left: GUPS (simulated, paper scale)");
    let t = fig4_gups(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());
    sink::with(|r| t.record_into(r));

    section("Figure 4 right: red-black tree (simulated)");
    if quick {
        cfg.sample = cfg.sample.min(100_000);
    }
    let t = fig4_rbtree(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());
    sink::with(|r| t.record_into(r));

    section("GUPS real execution (RAM scale, layout cost only)");
    let budget = if quick {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(1)
    };
    let ops = if quick { 200_000u64 } else { 2_000_000 };
    let alloc = BlockAllocator::with_capacity_bytes(600 << 20).expect("pool");
    println!(
        "{:>8} | {:>12} {:>12} | {:>8}",
        "table", "vec ns/op", "tree ns/op", "ratio"
    );
    for bytes in [8usize << 20, 128 << 20, 512 << 20] {
        let n = bytes / 8;
        let mut vec_table = vec![0u64; n];
        let mut tree_table: TreeArray<u64> = TreeArray::new(&alloc, n).expect("tree");
        let sv = bench_for("vec", budget, || gups::gups_vec(&mut vec_table, ops, 3));
        let st = bench_for("tree", budget, || {
            gups::gups_tree_naive(&mut tree_table, ops, 3)
        });
        let per = |s: &Sample| s.mean_ns() / ops as f64;
        println!(
            "{:>8} | {:>12.2} {:>12.2} | {:>8.2}",
            format!("{}MB", bytes >> 20),
            per(&sv),
            per(&st),
            per(&st) / per(&sv)
        );
        let mb = bytes >> 20;
        sink::metric(sv.metric_ns(&format!("gups_real.{mb}mb.vec"), 1.0 / ops as f64));
        sink::metric(st.metric_ns(&format!("gups_real.{mb}mb.tree"), 1.0 / ops as f64));
        sink::metric(MetricRecord::from_value(
            &format!("gups_real.{mb}mb.ratio"),
            "x",
            Direction::Lower,
            per(&st) / per(&sv),
        ));
    }
    println!(
        "\nnote: both real runs share this machine's VM; the ratio isolates the\n\
         tree's software walk cost. The simulated table above adds the\n\
         translation difference the paper measures."
    );

    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("ops", ops);
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
