//! Bench: allocator contention — alloc/free throughput of the mutex
//! baseline vs the sharded lock-free allocator at 1/2/4/8 threads.
//!
//! This is the acceptance bench for the allocator refactor: the sharded
//! design must beat the single mutex once threads contend (≥4 threads
//! on real hardware; at 1 thread the mutex's uncontended fast path is
//! competitive and may win).
//!
//! `cargo bench --bench ablation_alloc_contention`  (NVM_QUICK=1 for a
//! fast pass)

use nvm::bench_utils::section;
use nvm::coordinator::experiments::{ablation_alloc_contention, ExpConfig};

fn main() {
    let cfg = if std::env::var("NVM_QUICK").is_ok() {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };
    section("Ablation: allocator contention (mutex vs sharded)");
    let t = ablation_alloc_contention(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());

    // Verdict for CHANGES.md: sharded must exceed mutex at >= 4 threads.
    let speed4 = t.cell("sharded/mutex", 2).unwrap();
    let speed8 = t.cell("sharded/mutex", 3).unwrap();
    println!(
        "sharded/mutex at 4T: {speed4:.2}x, at 8T: {speed8:.2}x  ({})",
        if speed4 > 1.0 && speed8 > 1.0 {
            "sharded wins under contention — refactor goal met"
        } else {
            "SHARDED NOT FASTER — investigate (core count? shard config?)"
        }
    );
}
