//! Bench: allocator contention — alloc/free throughput of the mutex
//! baseline vs the sharded allocator vs the two-level reserving
//! allocator, swept over thread counts up to the available parallelism.
//!
//! This is the acceptance bench for the two-level allocator: on a
//! fragmented pool (every other block pinned live, so each allocation
//! must find a single-block hole), the two-level design's reserved
//! subtree must beat the sharded allocator's bitmap scan by >= 1.5x
//! once threads contend (>= 4 threads). At 1 thread the simpler
//! allocators' uncontended fast paths are competitive and may win.
//!
//! `cargo bench --bench ablation_alloc_contention`  (NVM_QUICK=1 for a
//! fast pass)

use nvm::bench_utils::section;
use nvm::coordinator::experiments::{ablation_alloc_contention, ExpConfig};
use nvm::telemetry::{results, sink};

fn main() {
    sink::begin("ablation_alloc_contention", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let cfg = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };
    section("Ablation: allocator contention (mutex vs sharded vs two-level)");
    let t = ablation_alloc_contention(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());

    // Legacy check: sharded must still exceed mutex under contention.
    let contended: Vec<usize> = t
        .columns
        .iter()
        .enumerate()
        .filter(|(_, c)| c.trim_end_matches('T').parse::<usize>().is_ok_and(|n| n >= 4))
        .map(|(i, _)| i)
        .collect();
    if contended.is_empty() {
        println!(
            "VERDICT: SKIP — fewer than 4 hardware threads available \
             (sweep: {:?}); the contention claim needs >= 4T",
            t.columns
        );
        sink::with(|r| t.record_into(r));
        let mut rec = sink::take().expect("bench sink installed at main start");
        rec.config("quick", quick);
        rec.config("skipped", "fewer than 4 hardware threads");
        results::write_bench_record(rec);
        return;
    }
    let sharded_ok = contended
        .iter()
        .all(|&c| t.cell("sharded/mutex", c).unwrap() > 1.0);
    println!(
        "sharded/mutex at >=4T: {}",
        if sharded_ok {
            "above 1.0x — sharded still wins under contention"
        } else {
            "NOT above 1.0x — regression against the mutex baseline"
        }
    );

    // Acceptance verdict: two-level >= 1.5x sharded on the fragmented
    // pool at every contended (>= 4T) thread count.
    let mut pass = true;
    for &c in &contended {
        let r = t.cell("twolevel/sharded (fragmented)", c).unwrap();
        println!(
            "twolevel/sharded (fragmented) at {}: {r:.2}x (target >= 1.5x)",
            t.columns[c]
        );
        if r < 1.5 {
            pass = false;
        }
    }
    println!(
        "VERDICT: {}",
        if pass && sharded_ok {
            "PASS — two-level >= 1.5x sharded on the fragmented pool at >= 4 threads"
        } else {
            "FAIL — two-level below 1.5x sharded on the fragmented pool \
             (reservation not engaging? core count? subtree sizing?)"
        }
    );

    sink::verdict(
        "sharded_beats_mutex_contended",
        sharded_ok,
        "sharded/mutex > 1.0x at every >= 4T column",
    );
    sink::verdict(
        "twolevel_ge_1.5x_sharded_fragmented",
        pass,
        "twolevel/sharded (fragmented) >= 1.5x at every >= 4T column",
    );
    sink::with(|r| t.record_into(r));
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
