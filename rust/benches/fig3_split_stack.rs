//! Bench: regenerates **Figure 3** — split-stack overhead, normalized
//! run time per benchmark profile, plus the *measured* Fibonacci
//! microbenchmark (real native recursion vs real split-stack recursion).
//!
//! `cargo bench --bench fig3_split_stack`

use nvm::bench_utils::{bench, section};
use nvm::coordinator::experiments::{fig3, ExpConfig};
use nvm::pmem::BlockAllocator;
use nvm::telemetry::{results, sink, Direction, MetricRecord};
use nvm::workloads::fib;

fn main() {
    sink::begin("fig3_split_stack", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let cfg = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };

    section("Figure 3 (profile model + replayed overflow rates)");
    let t = fig3(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());

    section("Figure 3 fib microbenchmark (real execution)");
    let n = if quick { 24 } else { 30 };
    let alloc = BlockAllocator::new(32 * 1024, 4096).expect("pool");
    let native = bench("fib native", 1, 5, || fib::fib_native(n));
    let split = bench("fib split-stack", 1, 5, || {
        fib::fib_split_fresh(&alloc, n).unwrap().0
    });
    println!("{native}");
    println!("{split}");
    let ratio = split.mean_ns() / native.mean_ns();
    let (_, calls) = fib::fib_split_fresh(&alloc, n).unwrap();
    let extra_ns = (split.mean_ns() - native.mean_ns()) / calls as f64;
    println!(
        "\nfib({n}): split/native = {ratio:.3}x  ({calls} calls, {extra_ns:.2} ns extra per call)"
    );
    println!(
        "note: our split stack is a library (call/ret are function calls touching\n\
         allocator-backed frames), so the ratio overstates gcc's inlined 3-insn\n\
         check; the per-call cost above feeds the Figure 3 model instead."
    );

    sink::metric(native.metric_with("fib.native", "ms", Direction::Lower, |ns| ns / 1e6));
    sink::metric(split.metric_with("fib.split_stack", "ms", Direction::Lower, |ns| ns / 1e6));
    sink::metric(MetricRecord::from_value(
        "fib.split_native_ratio",
        "x",
        Direction::Lower,
        ratio,
    ));
    sink::metric(MetricRecord::from_value(
        "fib.extra_per_call",
        "ns",
        Direction::Lower,
        extra_ns,
    ));
    sink::with(|r| t.record_into(r));
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("fib_n", n);
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
