//! Bench: the software-translation ablation (paper §4.4).
//!
//! Every way this repo can turn an element index into data, side by
//! side: naive tree walk (Table 2's `depth` dependent loads), the bare
//! Figure 2 single-leaf cursor, the set-associative leaf-TLB cursor,
//! the flat leaf-table mode (one indexed load), and a contiguous `Vec`
//! as the hardware floor — across depths 1–4 and sequential / strided /
//! random access (depth 4 is the PB-scale shape whose flat-vs-walk
//! crossover the interior-node-cache ROADMAP item cares about). A
//! second section compares per-op vs batched (sort-and-run) GUPS on the
//! tree backend.
//!
//! Acceptance (printed as a verdict): flat-table random access must be
//! ≥ 3x the naive walk at depth ≥ 2, and batched GUPS must beat per-op
//! GUPS.
//!
//! `cargo bench --bench ablation_translation`  (NVM_QUICK=1 for a fast
//! pass)

use nvm::bench_utils::{bench, section};
use nvm::pmem::BlockAllocator;
use nvm::telemetry::{results, sink, Direction};
use nvm::testutil::Rng;
use nvm::trees::TreeArray;
use nvm::workloads::gups;

/// 1 KB blocks keep trees deep at bench-friendly sizes
/// (u32: leaf_cap 256, fanout 128).
const BLOCK: usize = 1024;

fn access_patterns(n: usize, accesses: usize, seed: u64) -> Vec<(&'static str, Vec<usize>)> {
    let seq: Vec<usize> = (0..accesses).map(|k| k % n).collect();
    // Prime stride just past the 256-element leaf: every access changes
    // leaf, with periodic revisits — the TLB's home turf.
    let strided: Vec<usize> = (0..accesses).map(|k| (k * 263) % n).collect();
    let mut rng = Rng::new(seed);
    let random: Vec<usize> = (0..accesses).map(|_| rng.range(0, n)).collect();
    vec![("sequential", seq), ("strided", strided), ("random", random)]
}

fn xor_all(vals: impl Iterator<Item = u32>) -> u32 {
    vals.fold(0, |a, v| a ^ v)
}

fn main() {
    sink::begin("ablation_translation", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let (warmup, iters, accesses) = if quick { (1, 3, 40_000) } else { (2, 7, 200_000) };
    let mut verdicts: Vec<(String, bool)> = Vec::new();

    // Depth 4 (ROADMAP: the PB-scale shape) makes the flat-vs-walk gap
    // — and any future interior-node-cache crossover — visible in the
    // same table: > fanout^2 leaves forces a 4-deep walk while the flat
    // table stays one indexed load (at the cost of a 16 Ki-entry
    // pointer table, still ~0.05% of the data).
    for (depth, n) in [
        (1u32, 256usize),
        (2, 256 * 64),
        (3, 256 * 128 * 4),
        (4, 256 * 128 * 128 + 256),
    ] {
        // Pool sized for two trees of this shape (walk + flat) plus
        // interior slack.
        let geo_blocks = n / 256 + n / (256 * 128) + 64;
        let a = BlockAllocator::new(BLOCK, (geo_blocks * 2 + 64).max(2048)).expect("bench pool");
        let data: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let mut tree: TreeArray<u32> = TreeArray::new(&a, n).expect("walk tree");
        tree.copy_from_slice(&data).expect("fill");
        let mut flat_tree: TreeArray<u32> = TreeArray::new(&a, n).expect("flat tree");
        flat_tree.copy_from_slice(&data).expect("fill");
        flat_tree.enable_flat_table();
        assert_eq!(tree.depth(), depth);

        section(&format!("translation modes, depth {depth} ({n} u32 elems)"));
        println!(
            "{:<12} {:>10} {:>10} {:>10} {:>10} {:>10}   (ns/access)",
            "pattern", "naive", "cursor1", "tlb64x4", "flat", "vec"
        );
        for (pname, idxs) in access_patterns(n, accesses, 42) {
            // Correctness cross-check before timing: every mode must
            // produce the same checksum as the Vec baseline.
            let want = xor_all(idxs.iter().map(|&i| data[i]));
            {
                let mut c1 = tree.cursor_with_tlb(0, 1);
                let mut ct = tree.cursor_with_tlb(64, 4);
                assert_eq!(xor_all(idxs.iter().map(|&i| unsafe { tree.get_unchecked(i) })), want);
                assert_eq!(xor_all(idxs.iter().map(|&i| c1.seek(i))), want);
                assert_eq!(xor_all(idxs.iter().map(|&i| ct.seek(i))), want);
                assert_eq!(xor_all(idxs.iter().map(|&i| unsafe { flat_tree.get_unchecked(i) })), want);
            }

            let s_naive = bench("naive", warmup, iters, || {
                xor_all(idxs.iter().map(|&i| unsafe { tree.get_unchecked(i) }))
            });
            let mut c1 = tree.cursor_with_tlb(0, 1);
            let s_c1 = bench("cursor1", warmup, iters, || {
                xor_all(idxs.iter().map(|&i| c1.seek(i)))
            });
            let mut ct = tree.cursor_with_tlb(64, 4);
            let s_tlb = bench("tlb", warmup, iters, || {
                xor_all(idxs.iter().map(|&i| ct.seek(i)))
            });
            let s_flat = bench("flat", warmup, iters, || {
                xor_all(idxs.iter().map(|&i| unsafe { flat_tree.get_unchecked(i) }))
            });
            let s_vec = bench("vec", warmup, iters, || {
                xor_all(idxs.iter().map(|&i| data[i]))
            });

            let per = |s: &nvm::bench_utils::Sample| s.mean_ns() / accesses as f64;
            println!(
                "{:<12} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
                pname,
                per(&s_naive),
                per(&s_c1),
                per(&s_tlb),
                per(&s_flat),
                per(&s_vec)
            );
            for (mode, s) in [
                ("naive", &s_naive),
                ("cursor1", &s_c1),
                ("tlb64x4", &s_tlb),
                ("flat", &s_flat),
                ("vec", &s_vec),
            ] {
                sink::metric(s.metric_ns(
                    &format!("d{depth}.{pname}.{mode}"),
                    1.0 / accesses as f64,
                ));
            }

            if pname == "random" && depth >= 2 {
                let speedup = s_naive.mean_ns() / s_flat.mean_ns();
                verdicts.push((
                    format!("flat vs naive, random, depth {depth}: {speedup:.2}x (need >= 3x)"),
                    speedup >= 3.0,
                ));
                sink::verdict(
                    &format!("d{depth}_flat_ge_3x_naive_random"),
                    speedup >= 3.0,
                    &format!("{speedup:.2}x"),
                );
            }
        }
    }

    // Batched GUPS vs per-op GUPS on the tree backend (paper-size 32 KB
    // blocks: 1 Mi u64 elems -> 256 leaves, depth 2).
    section("batched vs per-op GUPS (tree backend, 32 KB blocks)");
    let ops: u64 = if quick { 200_000 } else { 2_000_000 };
    let a = BlockAllocator::new(32 * 1024, 512).expect("gups pool");
    let n = 1usize << 20;
    let mut per_op_tree: TreeArray<u64> = TreeArray::new(&a, n).expect("gups table");
    let s_per_op = bench("gups per-op", 1, 3, || {
        gups::gups_tree_naive(&mut per_op_tree, ops, 7)
    });
    drop(per_op_tree);
    let mut batched_tree: TreeArray<u64> = TreeArray::new(&a, n).expect("gups table");
    let s_batched = bench("gups batched", 1, 3, || {
        gups::gups_tree_batched(&mut batched_tree, ops, 7, gups::GUPS_BATCH)
    });
    let mups = |s: &nvm::bench_utils::Sample| ops as f64 / (s.mean_ns() / 1e9) / 1e6;
    println!(
        "per-op {:.2} Mupd/s   batched {:.2} Mupd/s  ({} updates, batch {})",
        mups(&s_per_op),
        mups(&s_batched),
        ops,
        gups::GUPS_BATCH
    );
    let g_speed = s_per_op.mean_ns() / s_batched.mean_ns();
    verdicts.push((
        format!("batched vs per-op GUPS: {g_speed:.2}x (need > 1x)"),
        g_speed > 1.0,
    ));
    let as_mups = |ns: f64| ops as f64 / (ns / 1e9) / 1e6;
    sink::metric(s_per_op.metric_with("gups.per_op", "Mupd/s", Direction::Higher, as_mups));
    sink::metric(s_batched.metric_with("gups.batched", "Mupd/s", Direction::Higher, as_mups));
    sink::verdict("gups_batched_beats_per_op", g_speed > 1.0, &format!("{g_speed:.2}x"));

    section("verdict");
    let mut all = true;
    for (what, ok) in &verdicts {
        println!("{} {}", if *ok { "PASS" } else { "FAIL" }, what);
        all &= *ok;
    }
    println!(
        "{}",
        if all {
            "translation-cache goals met: flat table >= 3x naive on random access, batching wins"
        } else {
            "TRANSLATION GOALS NOT MET — investigate (debug build? tiny machine?)"
        }
    );

    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("accesses", accesses);
    rec.config("iters", iters);
    results::write_bench_record(rec);
}
