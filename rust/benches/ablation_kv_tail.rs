//! Bench: pallas-kv tail latency under memory-management churn.
//!
//! Runs the read-heavy zipfian kv workload twice through the open-loop
//! load generator — once fully resident (quiescent baseline) and once
//! with mmd eviction + software paging underneath (a quarter of the
//! leaves parked up front, pinned scratch keeping full residency
//! impossible) — and gates the acceptance claim:
//!
//! * **churn costs bounded tail, not collapse**: p99 arrival-to-response
//!   latency with mmd churn stays ≤ 2× the quiescent p99.
//!
//! Latency is measured from *scheduled* arrival (no coordinated
//! omission), so a stalled server shows up in the tail instead of
//! thinning the load. The full mix table lives in `nvm run kv-serve`;
//! this bench isolates the one number the SLO claim is about.
//!
//! `cargo bench --bench ablation_kv_tail`  (NVM_QUICK=1 for a fast
//! pass)

use nvm::bench_utils::section;
use nvm::coordinator::experiments::{kv_tail_run, ExpConfig};
use nvm::telemetry::{results, sink, Direction, MetricRecord};

fn main() {
    sink::begin("ablation_kv_tail", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let cfg = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };

    section("pallas-kv tail latency: quiescent vs mmd churn (read-heavy zipfian)");
    let quiet = kv_tail_run(&cfg, false);
    let churn = kv_tail_run(&cfg, true);
    for (name, h) in [("quiescent", &quiet), ("churn", &churn)] {
        println!(
            "{name:10} {} ops: p50 {:.1} µs  p99 {:.1} µs  p999 {:.1} µs  max {:.1} µs",
            h.count(),
            h.percentile(0.50) as f64 / 1e3,
            h.percentile(0.99) as f64 / 1e3,
            h.percentile(0.999) as f64 / 1e3,
            h.max_value() as f64 / 1e3,
        );
        sink::metric(MetricRecord::from_hist(
            &format!("{name}.latency"),
            "us",
            Direction::Lower,
            h,
            1e-3,
        ));
    }

    section("verdict");
    let p99_quiet = quiet.percentile(0.99).max(1);
    let p99_churn = churn.percentile(0.99);
    let ratio = p99_churn as f64 / p99_quiet as f64;
    let ok = ratio <= 2.0;
    println!(
        "{} p99 under churn: {:.1} vs {:.1} µs quiescent ({ratio:.2}x, need <= 2.0x)",
        if ok { "PASS" } else { "FAIL" },
        p99_churn as f64 / 1e3,
        p99_quiet as f64 / 1e3,
    );
    println!(
        "{}",
        if ok {
            "kv tail goal met: eviction + software paging under the service keeps p99 within 2x"
        } else {
            "KV TAIL GOAL NOT MET — investigate (debug build? overloaded arrival rate? fault \
             workers starved?)"
        }
    );

    sink::verdict(
        "kv_p99_churn_le_2x_quiescent",
        ok,
        &format!(
            "{:.1} vs {:.1} µs ({ratio:.2}x)",
            p99_churn as f64 / 1e3,
            p99_quiet as f64 / 1e3
        ),
    );
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
