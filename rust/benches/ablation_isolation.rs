//! Bench: multi-tenant isolation (the PR 8 tentpole).
//!
//! Runs the `multi-tenant` experiment — five tenants (zipfian / scan /
//! insert+churn / noisy over-quota / flaky-backing) on one shared pool,
//! one worker-backed fault queue, and one mmd daemon in tenant mode —
//! and prints the per-tenant table plus a PASS/FAIL verdict on the
//! acceptance claim:
//!
//! * **misbehaviour is contained**: the well-behaved zipfian tenant's
//!   throughput with a neighbour overrunning its quota and another
//!   neighbour's backing dead stays >= 0.8x its throughput with the
//!   same neighbour threads behaving. Both phases run the same thread
//!   load, so the ratio isolates the *policy* cost (backpressure,
//!   degraded containment, quota-pressure eviction), not scheduler
//!   noise.
//!
//! The run itself asserts the containment contracts (typed errors only
//! on the bad actors, bit-exact payloads, quotas back to zero), so a
//! completed run is already a correctness pass; the gate here is the
//! performance-isolation claim.
//!
//! `cargo bench --bench ablation_isolation`  (NVM_QUICK=1 for a fast
//! pass)

use nvm::bench_utils::section;
use nvm::coordinator::experiments::{multi_tenant, ExpConfig};
use nvm::telemetry::{results, sink};

fn main() {
    sink::begin("ablation_isolation", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let mut cfg = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };
    cfg.threads = 4;

    section("Ablation: per-tenant throughput, benign vs misbehaving neighbours");
    let t = multi_tenant(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());

    section("per-tenant containment counters");
    for tenant in ["zipfian", "scan", "insert", "noisy", "flaky"] {
        let faults = t.cell(tenant, 1).expect("fault-ins cell");
        let evictions = t.cell(tenant, 2).expect("evictions cell");
        let quota = t.cell(tenant, 3).expect("quota-fails cell");
        let seen = t.cell(tenant, 4).expect("errors-seen cell");
        println!(
            "{tenant}: {faults:.0} fault-ins, {evictions:.0} evictions, \
             {quota:.0} quota fails, {seen:.0} typed errors absorbed"
        );
    }

    section("verdict");
    let benign = t.cell("zipfian benign", 0).expect("benign row");
    let contended = t.cell("zipfian", 0).expect("misbehaving row");
    let ratio = contended / benign;
    let ok = ratio >= 0.8;
    println!(
        "{} well-behaved throughput under misbehaving neighbours: {contended:.2} vs \
         {benign:.2} Mop/s ({ratio:.2}x, need >= 0.8x)",
        if ok { "PASS" } else { "FAIL" }
    );
    println!(
        "{}",
        if ok {
            "isolation goal met: one tenant's overrun or dead backing degrades that tenant only"
        } else {
            "ISOLATION GOAL NOT MET — investigate (debug build? < 4 cores? daemon starved?)"
        }
    );

    sink::verdict(
        "zipfian_throughput_ge_0.8x",
        ok,
        &format!("{contended:.2} vs {benign:.2} Mop/s ({ratio:.2}x)"),
    );
    sink::with(|r| t.record_into(r));
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("threads", cfg.threads);
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
