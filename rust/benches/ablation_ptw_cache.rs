//! Bench: the paper's §4.4 claim — "Our Iterator optimization
//! essentially implements a PTW cache in software." Compares the
//! hardware PTW cache's effect on VM arrays against the software
//! iterator's effect on physical trees for the strided 4 GB scan.
//!
//! `cargo bench --bench ablation_ptw_cache`

use nvm::bench_utils::section;
use nvm::coordinator::experiments::{ablation_ptw_cache, ExpConfig};
use nvm::telemetry::{results, sink, Direction, MetricRecord};

fn main() {
    sink::begin("ablation_ptw_cache", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let cfg = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };
    section("Ablation: hardware PTW cache vs software iterator");
    let t = ablation_ptw_cache(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());

    let on = t.cell("tree phys, iterator on", 0).unwrap();
    let off = t.cell("tree phys, iterator off", 0).unwrap();
    let hw_on = t.cell("array VM, PTW cache on", 0).unwrap();
    let hw_off = t.cell("array VM, PTW cache off", 0).unwrap();
    let sw_saved = (1.0 - on / off) * 100.0;
    let hw_saved = (1.0 - hw_on / hw_off) * 100.0;
    println!(
        "software iterator saves {sw_saved:.1}% of tree access time;\n\
         hardware PTW cache saves {hw_saved:.1}% of VM array access time."
    );

    sink::metric(MetricRecord::from_value(
        "iterator.saved_pct",
        "%",
        Direction::Higher,
        sw_saved,
    ));
    sink::metric(MetricRecord::from_value(
        "ptw_cache.saved_pct",
        "%",
        Direction::Info,
        hw_saved,
    ));
    sink::with(|r| t.record_into(r));
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
