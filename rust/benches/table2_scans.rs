//! Bench: regenerates **Table 2** — tree vs array run-time ratios for
//! linear and strided scans at 4 KB–64 GB, naive and iterator-optimized.
//!
//! Two parts:
//! 1. The simulated table at paper scale (the substitution for the
//!    authors' 128 GB huge-page testbed).
//! 2. Real-execution wallclock ratios at RAM-friendly sizes (4 KB–64 MB)
//!    validating the tree implementation and the Figure 2 iterator.
//!
//! `cargo bench --bench table2_scans`  (NVM_QUICK=1 for a fast pass)

use nvm::bench_utils::{bench_for, section, Sample};
use nvm::coordinator::experiments::{table2, ExpConfig};
use nvm::pmem::BlockAllocator;
use nvm::telemetry::{results, sink, Direction, MetricRecord};
use nvm::testutil::Rng;
use nvm::workloads::{linear_scan, strided_scan};
use std::time::Duration;

fn quick() -> bool {
    std::env::var("NVM_QUICK").is_ok()
}

fn main() {
    sink::begin("table2_scans", "bench");
    let cfg = if quick() {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };

    section("Table 2 (simulated, paper scale)");
    let t = table2(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());
    sink::with(|r| t.record_into(r));

    section("Table 2 (real execution, RAM scale)");
    let budget = if quick() {
        Duration::from_millis(200)
    } else {
        Duration::from_secs(1)
    };
    let alloc = BlockAllocator::with_capacity_bytes(512 << 20).expect("pool");
    let mut rng = Rng::new(7);
    println!(
        "{:>8} {:>6} | {:>12} {:>12} {:>12} | {:>8} {:>8}",
        "size", "depth", "vec ns/el", "naive ns/el", "iter ns/el", "naive/x", "iter/x"
    );
    for bytes in [4usize << 10, 4 << 20, 64 << 20] {
        let n = bytes / 4;
        let data: Vec<f32> = (0..n).map(|_| rng.f32_range(0.0, 1.0)).collect();
        let tree = linear_scan::tree_from(&alloc, &data);
        for (label, stride) in [("linear", 1usize), ("strided", 1024)] {
            let sv = bench_for("vec", budget, || strided_scan::scan_vec(&data, stride));
            let sn = bench_for("naive", budget, || {
                strided_scan::scan_tree_naive(&tree, stride)
            });
            let si = bench_for("iter", budget, || {
                strided_scan::scan_tree_iter(&tree, stride)
            });
            let elems = n.div_ceil(stride);
            let per = |s: &Sample| s.mean_ns() / elems as f64;
            println!(
                "{:>8} {:>6} | {:>12.2} {:>12.2} {:>12.2} | {:>8.2} {:>8.2}  ({label})",
                format!("{}KB", bytes >> 10),
                tree.depth(),
                per(&sv),
                per(&sn),
                per(&si),
                per(&sn) / per(&sv),
                per(&si) / per(&sv),
            );
            let kb = bytes >> 10;
            let scale = 1.0 / elems as f64;
            sink::metric(sv.metric_ns(&format!("real.{kb}kb.{label}.vec"), scale));
            sink::metric(sn.metric_ns(&format!("real.{kb}kb.{label}.naive"), scale));
            sink::metric(si.metric_ns(&format!("real.{kb}kb.{label}.iter"), scale));
            sink::metric(MetricRecord::from_value(
                &format!("real.{kb}kb.{label}.iter_ratio"),
                "x",
                Direction::Lower,
                per(&si) / per(&sv),
            ));
        }
    }

    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick());
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
