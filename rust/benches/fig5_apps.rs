//! Bench: regenerates **Figure 5** — overhead of software-based
//! contiguous memory on blackscholes and deepsjeng(-like) workloads,
//! simulated at paper scale and really executed at RAM scale (pure Rust
//! pricing over Vec vs TreeArray layouts).
//!
//! `cargo bench --bench fig5_apps`

use nvm::bench_utils::{bench_for, section, Sample};
use nvm::coordinator::experiments::{fig5, ExpConfig};
use nvm::pmem::BlockAllocator;
use nvm::telemetry::{results, sink, Direction, MetricRecord};
use nvm::trees::TreeArray;
use nvm::workloads::blackscholes as bs;
use nvm::workloads::hashprobe;
use std::time::Duration;

const RATE: f32 = 0.03;
const VOL: f32 = 0.25;

fn main() {
    sink::begin("fig5_apps", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let cfg = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };

    section("Figure 5 (simulated, paper scale)");
    let t = fig5(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());

    section("blackscholes real execution (RAM scale)");
    let budget = if quick {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };
    let n = if quick { 1 << 20 } else { 1 << 23 }; // up to 8M options
    let tbl_bytes = if quick { 64usize << 20 } else { 256 << 20 };
    // Pool hosts 5 pricing arrays + the probe table simultaneously.
    let alloc =
        BlockAllocator::with_capacity_bytes(n * 4 * 6 + tbl_bytes + (64 << 20)).expect("pool");
    let (spot, strike, tmat) = bs::synth_portfolio(n, 42);
    let mut call = vec![0.0f32; n];
    let mut put = vec![0.0f32; n];
    let sv = bench_for("contig", budget, || {
        bs::price_contig(&spot, &strike, &tmat, RATE, VOL, &mut call, &mut put)
    });

    let mut ts: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut tk: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut tt: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    ts.copy_from_slice(&spot).unwrap();
    tk.copy_from_slice(&strike).unwrap();
    tt.copy_from_slice(&tmat).unwrap();
    let mut tc: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut tp: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let sn = bench_for("tree naive", budget, || {
        bs::price_tree_naive(&ts, &tk, &tt, RATE, VOL, &mut tc, &mut tp)
    });
    let si = bench_for("tree iter", budget, || {
        bs::price_tree_iter(&ts, &tk, &tt, RATE, VOL, &mut tc, &mut tp)
    });
    let per = |s: &Sample| s.mean_ns() / n as f64;
    println!("contiguous : {:.2} ns/option", per(&sv));
    println!(
        "tree naive : {:.2} ns/option  ({:.3}x)",
        per(&sn),
        per(&sn) / per(&sv)
    );
    println!(
        "tree iter  : {:.2} ns/option  ({:.3}x)",
        per(&si),
        per(&si) / per(&sv)
    );
    for (name, s) in [("bs_real.contig", &sv), ("bs_real.naive", &sn), ("bs_real.iter", &si)] {
        sink::metric(s.metric_ns(name, 1.0 / n as f64));
    }
    sink::metric(MetricRecord::from_value(
        "bs_real.iter_overhead",
        "x",
        Direction::Lower,
        per(&si) / per(&sv),
    ));

    section("deepsjeng-like hash probe real execution (RAM scale)");
    let ops = if quick { 200_000u64 } else { 1_000_000 };
    let tn = tbl_bytes / 8;
    let mut vt = vec![0u64; tn];
    let mut tt2: TreeArray<u64> = TreeArray::new(&alloc, tn).unwrap();
    let pv = bench_for("probe vec", budget, || hashprobe::probe_vec(&mut vt, ops, 5));
    let pt = bench_for("probe tree", budget, || {
        hashprobe::probe_tree_naive(&mut tt2, ops, 5)
    });
    let perp = |s: &Sample| s.mean_ns() / ops as f64;
    println!("contiguous : {:.2} ns/probe", perp(&pv));
    println!(
        "tree naive : {:.2} ns/probe  ({:.3}x)",
        perp(&pt),
        perp(&pt) / perp(&pv)
    );
    sink::metric(pv.metric_ns("probe_real.vec", 1.0 / ops as f64));
    sink::metric(pt.metric_ns("probe_real.tree", 1.0 / ops as f64));
    sink::metric(MetricRecord::from_value(
        "probe_real.tree_overhead",
        "x",
        Direction::Lower,
        perp(&pt) / perp(&pv),
    ));

    sink::with(|r| t.record_into(r));
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("n", n);
    rec.config("ops", ops);
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
