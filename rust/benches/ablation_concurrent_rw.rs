//! Bench: concurrent write-side translation (the PR 5 tentpole).
//!
//! Two questions, two verdict gates:
//!
//! 1. **Write scaling** — N threads apply tagged-increment GUPS updates
//!    to one shared tree under two designs: per-leaf **seqlock
//!    TreeWriters** (this PR) vs the obvious strawman, one
//!    `Mutex<TreeArray>` locked around every update. Gate: seqlock
//!    throughput >= 2x the mutex strawman at 4 threads.
//! 2. **Reader tax** — 4 TreeView readers with and without one live
//!    writer hammering the same tree. Gate: reader throughput with 1
//!    writer >= 0.8x the read-only baseline (the seq bracket + retry
//!    traffic must stay cheap).
//!
//! Both modes verify correctness, not just speed: every read asserts
//! the slot-tag invariant, and each timed rep replays the writer
//! streams against a mirror and compares the final table bit-for-bit.
//!
//! `cargo bench --bench ablation_concurrent_rw` (NVM_QUICK=1 for a
//! fast pass)

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use nvm::bench_utils::section;
use nvm::pmem::BlockAllocator;
use nvm::telemetry::{results, sink, Direction, MetricRecord};
use nvm::testutil::Rng;
use nvm::trees::TreeArray;
use nvm::workloads::gups;

/// 4 KB blocks, u64 elements: 512 elems/leaf, fanout 512.
const BLOCK: usize = 4096;
/// 128 leaves -> depth 2; the 64-entry TLBs cover half the leaves.
const N: usize = 512 * 128;
const THREADS: [usize; 4] = [1, 2, 4, 8];
const READERS: usize = 4;

fn fresh_tree<'a>(a: &'a BlockAllocator, init: &[u64]) -> TreeArray<'a, u64> {
    let mut t: TreeArray<u64> = TreeArray::new(a, N).expect("bench tree");
    t.copy_from_slice(init).expect("fill");
    t.enable_flat_table();
    let _ = t.get(0); // build the flat table before sharing
    t
}

fn main() {
    sink::begin("ablation_concurrent_rw", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let (ops, reps) = if quick { (50_000usize, 2usize) } else { (400_000, 3) };

    let a = BlockAllocator::new(BLOCK, 512).expect("bench pool");
    let init: Vec<u64> = (0..N).map(gups::rw_init).collect();

    // Per-thread index streams, identical across modes; the expected
    // final table per thread count is the replayed mirror.
    let streams: Vec<Vec<usize>> = (0..THREADS[THREADS.len() - 1])
        .map(|tid| {
            let mut rng = Rng::new(0xD0_0D + tid as u64);
            (0..ops).map(|_| rng.range(0, N)).collect()
        })
        .collect();
    let expected_for = |threads: usize| -> Vec<u64> {
        let mut m = init.clone();
        for stream in streams.iter().take(threads) {
            for &i in stream {
                m[i] = m[i].wrapping_add(1);
            }
        }
        m
    };

    section(&format!(
        "concurrent writes: {N} u64 elems, {ops} updates/thread, {} cores",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    ));
    println!(
        "{:<10} {:>16} {:>16} {:>10}   (Mupd/s, all threads)",
        "threads", "mutex-strawman", "seqlock-writers", "ratio"
    );

    let mut seqlock_mups = [0.0f64; THREADS.len()];
    let mut mutex_mups = [0.0f64; THREADS.len()];
    for (ti, &threads) in THREADS.iter().enumerate() {
        let expected = expected_for(threads);
        let streams = &streams;

        // Mode 1: Mutex<TreeArray> — the global-lock strawman.
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let m = Mutex::new(fresh_tree(&a, &init));
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for stream in streams.iter().take(threads) {
                    let m = &m;
                    s.spawn(move || {
                        for &i in stream {
                            let mut t = m.lock().unwrap();
                            // SAFETY: i < N by construction; the lock
                            // grants exclusive access.
                            let v = unsafe { t.get_unchecked(i) };
                            unsafe { t.set_unchecked(i, v.wrapping_add(1)) };
                        }
                    });
                }
            });
            best = best.min(t0.elapsed().as_secs_f64());
            assert_eq!(
                m.into_inner().unwrap().to_vec(),
                expected,
                "mutex strawman lost updates at {threads}T"
            );
        }
        mutex_mups[ti] = (threads * ops) as f64 / best / 1e6;

        // Mode 2: per-leaf seqlock TreeWriters.
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let tree = fresh_tree(&a, &init);
            let tree_r = &tree;
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for stream in streams.iter().take(threads) {
                    s.spawn(move || {
                        // SAFETY: all concurrent access in this mode is
                        // through seqlock writers.
                        let mut w = unsafe { tree_r.writer() };
                        for &i in stream {
                            w.update(i, |v| v.wrapping_add(1)).expect("in range");
                        }
                    });
                }
            });
            best = best.min(t0.elapsed().as_secs_f64());
            assert_eq!(tree.to_vec(), expected, "seqlock writers lost updates at {threads}T");
        }
        seqlock_mups[ti] = (threads * ops) as f64 / best / 1e6;

        println!(
            "{:<10} {:>16.2} {:>16.2} {:>9.2}x",
            threads,
            mutex_mups[ti],
            seqlock_mups[ti],
            seqlock_mups[ti] / mutex_mups[ti]
        );
        sink::metric(MetricRecord::from_value(
            &format!("{threads}t.mutex_strawman"),
            "Mupd/s",
            Direction::Higher,
            mutex_mups[ti],
        ));
        sink::metric(MetricRecord::from_value(
            &format!("{threads}t.seqlock_writers"),
            "Mupd/s",
            Direction::Higher,
            seqlock_mups[ti],
        ));
    }

    // Reader tax: READERS views, 0 vs 1 concurrent writer.
    section(&format!("reader tax: {READERS} view readers, 0 vs 1 live writer"));
    let read_streams: Vec<u64> = (0..READERS as u64).map(|tid| 0xBEE5 ^ (tid << 24)).collect();
    let run_readers = |with_writer: bool| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let tree = fresh_tree(&a, &init);
            let tree_r = &tree;
            let stop = AtomicBool::new(false);
            let stop_r = &stop;
            let t0 = std::thread::scope(|s| {
                let writer = if with_writer {
                    Some(s.spawn(move || {
                        // SAFETY: concurrent access is views + writers.
                        let mut w = unsafe { tree_r.writer() };
                        let mut rng = Rng::new(0xF00D);
                        while !stop_r.load(Ordering::Relaxed) {
                            let i = rng.range(0, N);
                            w.update(i, |v| v.wrapping_add(1)).expect("in range");
                        }
                        w.writes()
                    }))
                } else {
                    None
                };
                let t0 = Instant::now();
                let handles: Vec<_> = read_streams
                    .iter()
                    .map(|&rseed| {
                        s.spawn(move || {
                            let mut v = tree_r.view();
                            std::hint::black_box(gups::gups_rw_read(&mut v, ops as u64, rseed));
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
                let secs = t0.elapsed().as_secs_f64();
                stop.store(true, Ordering::Relaxed);
                if let Some(w) = writer {
                    assert!(w.join().unwrap() > 0, "writer never ran");
                }
                secs
            });
            best = best.min(t0);
        }
        (READERS * ops) as f64 / best / 1e6
    };
    let base_mrd = run_readers(false);
    let rw_mrd = run_readers(true);
    println!(
        "read-only: {base_mrd:.2} Mrd/s   with 1 writer: {rw_mrd:.2} Mrd/s   ratio {:.2}",
        rw_mrd / base_mrd
    );

    section("verdict");
    let i4 = THREADS.iter().position(|&t| t == 4).unwrap();
    let vs_mutex = seqlock_mups[i4] / mutex_mups[i4];
    let tax = rw_mrd / base_mrd;
    let verdicts = [
        (
            format!("seqlock writers vs Mutex<TreeArray> at 4T: {vs_mutex:.2}x (need >= 2x)"),
            vs_mutex >= 2.0,
        ),
        (
            format!("reader throughput with 1 writer: {tax:.2}x of read-only (need >= 0.8x)"),
            tax >= 0.8,
        ),
    ];
    let mut all = true;
    for (what, ok) in &verdicts {
        println!("{} {}", if *ok { "PASS" } else { "FAIL" }, what);
        all &= *ok;
    }
    println!(
        "{}",
        if all {
            "concurrent-rw goals met: per-leaf seqlocks scale writes; readers barely notice"
        } else {
            "CONCURRENT RW GOALS NOT MET — investigate (debug build? < 4 cores?)"
        }
    );

    sink::metric(MetricRecord::from_value(
        "readers.read_only",
        "Mrd/s",
        Direction::Higher,
        base_mrd,
    ));
    sink::metric(MetricRecord::from_value(
        "readers.with_writer",
        "Mrd/s",
        Direction::Higher,
        rw_mrd,
    ));
    sink::verdict("seqlock_ge_2x_mutex_4t", vs_mutex >= 2.0, &format!("{vs_mutex:.2}x"));
    sink::verdict("reader_tax_ge_0.8x", tax >= 0.8, &format!("{tax:.2}x"));
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("ops", ops);
    rec.config("reps", reps);
    results::write_bench_record(rec);
}
