//! Bench: the software page-fault path (the swap tentpole).
//!
//! Runs the `larger-than-dram` experiment — checksum-verifying readers
//! plus a same-value writer over a tree whose full residency exceeds
//! the pool, so the mmd daemon must keep leaves parked and every touch
//! of a parked leaf is a software page fault served by the
//! worker-backed fault queue — and prints the fault-in latency
//! distribution plus a PASS/FAIL verdict on the acceptance claim:
//!
//! * **paging costs latency, not correctness or livelihood**: reader
//!   throughput with background eviction + fault-in (healthy backing)
//!   stays ≥ 0.7× the resident-only baseline. The flaky row (injected
//!   transient swap faults + completion-ordering delays) is reported
//!   for its retry counts and latency tail, not gated — injection
//!   cadence, not the fault path, dominates its throughput.
//!
//! `cargo bench --bench ablation_fault_path`  (NVM_QUICK=1 for a fast
//! pass)

use nvm::bench_utils::section;
use nvm::coordinator::experiments::{larger_than_dram, ExpConfig};
use nvm::telemetry::{results, sink};

fn main() {
    sink::begin("ablation_fault_path", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let mut cfg = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };
    cfg.threads = 4;

    section("Ablation: reader throughput + fault-in latency, resident vs paged");
    let t = larger_than_dram(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());

    section("fault-in latency distribution");
    for mode in ["paged", "paged+flaky"] {
        let row = format!("4T {mode}");
        let demand = t.cell(&row, 1).expect("demand cell");
        let retries = t.cell(&row, 2).expect("retries cell");
        let mean_us = t.cell(&row, 3).expect("mean cell");
        let max_us = t.cell(&row, 4).expect("max cell");
        println!(
            "{row}: {demand:.0} demand faults, {retries:.0} retries, \
             mean {mean_us:.1} µs, max {max_us:.1} µs"
        );
    }

    section("verdict");
    let resident = t.cell("4T resident", 0).expect("resident row");
    let paged = t.cell("4T paged", 0).expect("paged row");
    let ratio = paged / resident;
    let ok = ratio >= 0.7;
    println!(
        "{} reader throughput under paging: {paged:.2} vs {resident:.2} Mrd/s \
         ({ratio:.2}x, need >= 0.7x)",
        if ok { "PASS" } else { "FAIL" }
    );
    println!(
        "{}",
        if ok {
            "fault-path goal met: eviction + software page faults cost latency, not throughput collapse"
        } else {
            "FAULT-PATH GOAL NOT MET — investigate (debug build? < 4 cores? queue workers starved?)"
        }
    );

    sink::verdict(
        "paged_throughput_ge_0.7x_resident",
        ok,
        &format!("{paged:.2} vs {resident:.2} Mrd/s ({ratio:.2}x)"),
    );
    sink::with(|r| t.record_into(r));
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("threads", cfg.threads);
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
