//! Bench: the paper's §3 claim — "performance was mostly insensitive to
//! the choice of block size and we report results based on 32 KB
//! blocks." Sweeps 8–128 KB blocks at the 4 GB datapoint.
//!
//! `cargo bench --bench ablation_block_size`

use nvm::bench_utils::section;
use nvm::coordinator::experiments::{ablation_block_size, ExpConfig};
use nvm::telemetry::{results, sink, Direction, MetricRecord};

fn main() {
    sink::begin("ablation_block_size", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let cfg = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };
    section("Ablation: block-size sensitivity");
    let t = ablation_block_size(&cfg);
    println!("{t}");
    println!("{}", t.to_markdown());

    // The claim holds if iter rows vary by <15% across a 16x block-size
    // range; print a verdict for EXPERIMENTS.md.
    let iter_vals: Vec<f64> = (0..5)
        .map(|c| t.cell("linear iter", c).unwrap())
        .collect();
    let spread = iter_vals.iter().cloned().fold(f64::MIN, f64::max)
        / iter_vals.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "linear-iter spread across 8..128 KB blocks: {spread:.3}x  ({})",
        if spread < 1.15 {
            "insensitive — paper's claim holds"
        } else {
            "SENSITIVE — deviates from the paper"
        }
    );

    sink::metric(MetricRecord::from_value(
        "linear_iter.spread",
        "x",
        Direction::Lower,
        spread,
    ));
    sink::verdict(
        "block_size_insensitive",
        spread < 1.15,
        &format!("linear-iter spread {spread:.3}x across 8..128 KB (need < 1.15x)"),
    );
    sink::with(|r| t.record_into(r));
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("sample", cfg.sample);
    rec.config("seed", cfg.seed);
    results::write_bench_record(rec);
}
