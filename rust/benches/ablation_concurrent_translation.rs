//! Bench: concurrent read-side translation (the PR 3 tentpole).
//!
//! N threads hammer one shared tree with random reads under three
//! translation regimes:
//!
//! * **re-walk** — every access walks the tree (the natural "share the
//!   tree, share nothing else" baseline: correct, but pays Table 2's
//!   depth-dependent loads per access on every thread).
//! * **shared locked TLB** — one `Mutex<LeafTlb>` all threads share:
//!   the obvious-but-wrong design this PR exists to beat — the cache
//!   helps, the lock serializes.
//! * **per-thread TLB views** — one [`TreeView`] per thread over the
//!   flat leaf table: private hot set, no lock on the lookup path, two
//!   uncontended atomics per access for the epoch pin.
//!
//! Acceptance (printed as a verdict): per-thread-TLB throughput at 4
//! threads must be >= 2x its own single-thread throughput (it scales),
//! and >= 1.5x the shared-locked-TLB strawman at 4 threads (locking the
//! hot path is the wrong design).
//!
//! `cargo bench --bench ablation_concurrent_translation` (NVM_QUICK=1
//! for a fast pass)

use std::sync::Mutex;
use std::time::Instant;

use nvm::bench_utils::section;
use nvm::pmem::BlockAllocator;
use nvm::telemetry::{results, sink, Direction, MetricRecord};
use nvm::testutil::Rng;
use nvm::trees::{LeafTlb, TreeArray};

/// 1 KB blocks keep the tree deep at bench-friendly sizes
/// (u32: leaf_cap 256, fanout 128).
const BLOCK: usize = 1024;
/// 256 leaves (> fanout 128 -> depth 3: two dependent pointer loads
/// per re-walk); the 64-entry TLBs cover 1/4 of the leaves.
const N: usize = 256 * 256;
const THREADS: [usize; 4] = [1, 2, 4, 8];

/// Run `f(tid)` on `threads` scoped threads, `reps` times; returns the
/// best wall-clock seconds and the xor of all workers' checksums.
fn run_threads<F>(threads: usize, reps: usize, f: &F) -> (f64, u64)
where
    F: Fn(usize) -> u64 + Sync,
{
    let mut best = f64::INFINITY;
    let mut checksum = 0u64;
    for _ in 0..reps {
        let t0 = Instant::now();
        let cs = std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads).map(|tid| s.spawn(move || f(tid))).collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .fold(0u64, |a, v| a ^ v)
        });
        best = best.min(t0.elapsed().as_secs_f64());
        checksum = cs;
    }
    (best, checksum)
}

fn main() {
    sink::begin("ablation_concurrent_translation", "bench");
    let quick = std::env::var("NVM_QUICK").is_ok();
    let (ops, reps) = if quick { (100_000usize, 2usize) } else { (1_000_000, 3) };

    let a = BlockAllocator::new(BLOCK, 2048).expect("bench pool");
    let data: Vec<u32> = (0..N as u32).map(|i| i.wrapping_mul(2654435761)).collect();
    let mut walk_tree: TreeArray<u32> = TreeArray::new(&a, N).expect("walk tree");
    walk_tree.copy_from_slice(&data).expect("fill");
    let mut flat_tree: TreeArray<u32> = TreeArray::new(&a, N).expect("flat tree");
    flat_tree.copy_from_slice(&data).expect("fill");
    flat_tree.enable_flat_table();
    let _ = flat_tree.get(0); // build the table before sharing

    // Per-thread random index streams, identical across modes so the
    // checksums must agree.
    let streams: Vec<Vec<usize>> = (0..THREADS[THREADS.len() - 1])
        .map(|tid| {
            let mut rng = Rng::new(0xC0DE + tid as u64);
            (0..ops).map(|_| rng.range(0, N)).collect()
        })
        .collect();

    let walk_tree = &walk_tree;
    let flat_tree = &flat_tree;
    let streams = &streams;

    section(&format!(
        "concurrent read translation: {N} u32 elems (depth {}), {ops} reads/thread, {} cores",
        walk_tree.depth(),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    ));
    println!(
        "{:<10} {:>12} {:>14} {:>16}   (Mreads/s, all threads)",
        "threads", "re-walk", "locked-TLB", "per-thread-TLB"
    );

    let mut per_thread_mops = [0.0f64; THREADS.len()];
    let mut strawman_mops = [0.0f64; THREADS.len()];
    for (ti, &threads) in THREADS.iter().enumerate() {
        // Mode 1: naive re-walk per access.
        let rewalk = |tid: usize| -> u64 {
            let mut acc = 0u64;
            for &i in &streams[tid] {
                // SAFETY: i < N by construction.
                acc ^= unsafe { walk_tree.get_unchecked(i) } as u64;
            }
            acc
        };
        let (s_walk, cs_walk) = run_threads(threads, reps, &rewalk);

        // Mode 2: one shared, locked TLB (the strawman).
        let shared_tlb = Mutex::new(LeafTlb::new(64, 4));
        let gen = walk_tree.generation();
        let strawman = |tid: usize| -> u64 {
            let mut acc = 0u64;
            for &i in &streams[tid] {
                let leaf = i >> 8; // leaf_cap = 256
                let ptr = {
                    let mut tlb = shared_tlb.lock().unwrap();
                    match tlb.lookup(leaf, gen) {
                        Some((p, _)) => p,
                        None => {
                            let s = walk_tree.leaf_slice(leaf);
                            let p = s.as_ptr() as *mut u8;
                            tlb.insert(leaf, gen, p, s.len());
                            p
                        }
                    }
                };
                // SAFETY: cached pointer covers the whole leaf; no
                // relocation runs during the bench.
                acc ^= unsafe { *(ptr as *const u32).add(i & 255) } as u64;
            }
            acc
        };
        let (s_straw, cs_straw) = run_threads(threads, reps, &strawman);

        // Mode 3: per-thread TLB views over the flat leaf table.
        let per_thread = |tid: usize| -> u64 {
            let mut view = flat_tree.view_with_tlb(64, 4);
            let mut acc = 0u64;
            for &i in &streams[tid] {
                // SAFETY: i < N by construction.
                acc ^= unsafe { view.get_unchecked(i) } as u64;
            }
            acc
        };
        let (s_view, cs_view) = run_threads(threads, reps, &per_thread);

        assert_eq!(cs_walk, cs_straw, "strawman checksum diverged at {threads}T");
        assert_eq!(cs_walk, cs_view, "view checksum diverged at {threads}T");

        let total = (threads * ops) as f64 / 1e6;
        let rewalk_mops = total / s_walk;
        strawman_mops[ti] = total / s_straw;
        per_thread_mops[ti] = total / s_view;
        println!(
            "{:<10} {:>12.2} {:>14.2} {:>16.2}",
            threads, rewalk_mops, strawman_mops[ti], per_thread_mops[ti]
        );
        sink::metric(MetricRecord::from_value(
            &format!("{threads}t.rewalk"),
            "Mreads/s",
            Direction::Higher,
            rewalk_mops,
        ));
        sink::metric(MetricRecord::from_value(
            &format!("{threads}t.locked_tlb"),
            "Mreads/s",
            Direction::Higher,
            strawman_mops[ti],
        ));
        sink::metric(MetricRecord::from_value(
            &format!("{threads}t.per_thread_tlb"),
            "Mreads/s",
            Direction::Higher,
            per_thread_mops[ti],
        ));
    }

    section("verdict");
    let i4 = THREADS.iter().position(|&t| t == 4).unwrap();
    let scale = per_thread_mops[i4] / per_thread_mops[0];
    let vs_straw = per_thread_mops[i4] / strawman_mops[i4];
    let verdicts = [
        (
            format!("per-thread-TLB 4T vs 1T: {scale:.2}x (need >= 2x)"),
            scale >= 2.0,
        ),
        (
            format!("per-thread-TLB vs shared-locked-TLB at 4T: {vs_straw:.2}x (need >= 1.5x)"),
            vs_straw >= 1.5,
        ),
    ];
    let mut all = true;
    for (what, ok) in &verdicts {
        println!("{} {}", if *ok { "PASS" } else { "FAIL" }, what);
        all &= *ok;
    }
    println!(
        "{}",
        if all {
            "concurrent-translation goals met: private TLBs scale, the shared lock does not"
        } else {
            "CONCURRENT TRANSLATION GOALS NOT MET — investigate (debug build? < 4 cores?)"
        }
    );

    sink::verdict("per_thread_4t_vs_1t_ge_2x", scale >= 2.0, &format!("{scale:.2}x"));
    sink::verdict("per_thread_vs_locked_ge_1.5x", vs_straw >= 1.5, &format!("{vs_straw:.2}x"));
    let mut rec = sink::take().expect("bench sink installed at main start");
    rec.config("quick", quick);
    rec.config("ops", ops);
    rec.config("reps", reps);
    results::write_bench_record(rec);
}
