//! The experiment coordinator: registry, runner, thread pool, paper-style
//! report tables, and the block batcher feeding PJRT.
//!
//! This is the L3 "leader": the CLI (`nvm` binary) and every bench target
//! drive experiments through this module, so paper tables are generated
//! by exactly one code path.

pub mod batcher;
pub mod experiments;
pub mod pool;
pub mod report;
pub mod runner;

pub use batcher::BlockBatcher;
pub use experiments::ExpConfig;
pub use report::Table;
pub use runner::{list_experiments, run_experiment, run_experiment_recorded};
