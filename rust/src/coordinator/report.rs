//! Paper-style result tables.

use crate::telemetry::results::{slug, Direction, MetricRecord, Record};

/// A formatted results table (one per paper table/figure series).
#[derive(Clone, Debug)]
pub struct Table {
    /// Title, e.g. "Table 2: tree/array runtime ratios".
    pub title: String,
    /// Column headers (first column is the row label).
    pub columns: Vec<String>,
    /// Rows: label + one value per column.
    pub rows: Vec<(String, Vec<f64>)>,
    /// Mark cells ≥10% away from 1.0 (the paper colors those).
    pub highlight_ratios: bool,
    /// Free-form footnotes rendered under the table (run configuration:
    /// chosen thread count, allocator contention summaries, …).
    pub notes: Vec<String>,
}

impl Table {
    /// New empty table.
    pub fn new(title: impl Into<String>, columns: Vec<String>) -> Self {
        Table {
            title: title.into(),
            columns,
            rows: Vec::new(),
            highlight_ratios: false,
            notes: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, label: impl Into<String>, values: Vec<f64>) -> &mut Self {
        debug_assert_eq!(values.len(), self.columns.len());
        self.rows.push((label.into(), values));
        self
    }

    /// Append a footnote (e.g. `threads=8`).
    pub fn note(&mut self, note: impl Into<String>) -> &mut Self {
        self.notes.push(note.into());
        self
    }

    /// Fetch a cell by row label and column index (tests).
    pub fn cell(&self, row: &str, col: usize) -> Option<f64> {
        self.rows
            .iter()
            .find(|(l, _)| l == row)
            .and_then(|(_, v)| v.get(col).copied())
    }

    /// Flatten every cell into `record` as a metric named
    /// `<table>.<row>.<column>` (all slugged; the table part is the
    /// title up to its first `:`). Cells carry [`Direction::Info`] —
    /// a table mixes ratios, throughputs, and counters, so `diff`
    /// reports changes without judging them; experiments emit their
    /// direction-bearing metrics through the telemetry sink. Notes
    /// ride along as free text.
    pub fn record_into(&self, record: &mut Record) {
        let tslug = slug(self.title.split(':').next().unwrap_or(&self.title));
        for (label, vals) in &self.rows {
            for (col, v) in self.columns.iter().zip(vals) {
                let name = format!("{tslug}.{}.{}", slug(label), slug(col));
                record.metric(MetricRecord::from_value(&name, col, Direction::Info, *v));
            }
        }
        for n in &self.notes {
            record.notes.push(format!("[{}] {n}", self.title));
        }
    }

    /// Render as GitHub-flavored markdown (EXPERIMENTS.md blocks).
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s.push_str("| |");
        for c in &self.columns {
            s.push_str(&format!(" {c} |"));
        }
        s.push_str("\n|---|");
        for _ in &self.columns {
            s.push_str("---|");
        }
        s.push('\n');
        for (label, vals) in &self.rows {
            s.push_str(&format!("| {label} |"));
            for v in vals {
                s.push_str(&format!(" {} |", fmt_cell(*v, self.highlight_ratios)));
            }
            s.push('\n');
        }
        for n in &self.notes {
            s.push_str(&format!("\n_{n}_\n"));
        }
        s
    }
}

fn fmt_cell(v: f64, highlight: bool) -> String {
    let mark = if highlight && (v <= 0.90 || v >= 1.10) {
        "*"
    } else {
        ""
    };
    if v.abs() >= 1000.0 {
        format!("{v:.0}{mark}")
    } else {
        format!("{v:.2}{mark}")
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "\n{}", self.title)?;
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(4))
            .max()
            .unwrap();
        write!(f, "{:label_w$}", "")?;
        for c in &self.columns {
            write!(f, " {c:>9}")?;
        }
        writeln!(f)?;
        for (label, vals) in &self.rows {
            write!(f, "{label:label_w$}")?;
            for v in vals {
                write!(f, " {:>9}", fmt_cell(*v, self.highlight_ratios))?;
            }
            writeln!(f)?;
        }
        for n in &self.notes {
            writeln!(f, "  [{n}]")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("T", vec!["a".into(), "b".into()]);
        t.highlight_ratios = true;
        t.row("r1", vec![1.0, 3.37]);
        t.row("r2", vec![0.57, 1.05]);
        t
    }

    #[test]
    fn cell_lookup() {
        let t = sample();
        assert_eq!(t.cell("r1", 1), Some(3.37));
        assert_eq!(t.cell("nope", 0), None);
    }

    #[test]
    fn markdown_marks_big_ratios() {
        let md = sample().to_markdown();
        assert!(md.contains("3.37*"));
        assert!(md.contains("0.57*"));
        assert!(md.contains("| 1.00 |"));
        assert!(md.contains("1.05 |") && !md.contains("1.05*"));
    }

    #[test]
    fn display_renders_all_rows() {
        let s = format!("{}", sample());
        assert!(s.contains("r1") && s.contains("r2"));
    }

    #[test]
    fn notes_render_in_both_formats() {
        let mut t = sample();
        t.note("threads=8");
        assert!(format!("{t}").contains("threads=8"));
        assert!(t.to_markdown().contains("_threads=8_"));
    }

    #[test]
    fn record_into_flattens_cells() {
        let mut t = Table::new("Table 2: tree/array ratios", vec!["4KB".into(), "64 GB".into()]);
        t.row("linear scan", vec![1.02, 1.37]);
        t.note("threads=8");
        let mut r = Record::new("table2", "experiment");
        t.record_into(&mut r);
        assert_eq!(r.metrics.len(), 2);
        assert_eq!(r.metrics[0].name, "table_2.linear_scan.4kb");
        assert_eq!(r.metrics[0].summary.mean, 1.02);
        assert_eq!(r.metrics[1].name, "table_2.linear_scan.64_gb");
        assert_eq!(r.metrics[1].unit, "64 GB");
        assert_eq!(r.metrics[1].direction, Direction::Info);
        assert_eq!(r.notes, vec!["[Table 2: tree/array ratios] threads=8"]);
    }
}
