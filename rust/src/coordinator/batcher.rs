//! The block batcher: feeds 32 KB tree leaves to the AOT-compiled
//! blocked kernels in fixed-size batches.
//!
//! The blocked Pallas kernel was compiled for `[256, 8192]` f32 inputs
//! (8 MB per operand per dispatch) plus a `[1, 8192]` latency variant.
//! The batcher gathers leaf slices from [`TreeArray`]s into the batch
//! buffer (one memcpy per 32 KB leaf — the leaves themselves are already
//! kernel-tile-shaped, which is the point of the blocked layout), pads
//! the tail batch, executes, and scatters results back into tree leaves.

use crate::error::Result;
use crate::pmem::BlockAlloc;
use crate::runtime::{Engine, Input};
use crate::trees::TreeArray;
use crate::{BLOCK_ELEMS_F32 as BELE};

/// Batch size (blocks per dispatch) of the main blocked artifact.
pub const BATCH_BLOCKS: usize = 256;

/// Statistics from a batched run.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    /// Kernel dispatches issued.
    pub dispatches: u64,
    /// Leaf blocks processed (including padding).
    pub blocks: u64,
    /// Padded (wasted) blocks in the tail dispatch.
    pub padded: u64,
}

/// Batches tree-array leaves through the blocked Black-Scholes artifact.
pub struct BlockBatcher<'e> {
    engine: &'e Engine,
    /// Reusable staging buffers (perf: no allocation per dispatch).
    stage: [Vec<f32>; 3],
    stats: BatchStats,
}

impl<'e> BlockBatcher<'e> {
    /// New batcher over `engine`.
    pub fn new(engine: &'e Engine) -> Self {
        BlockBatcher {
            engine,
            stage: [
                vec![0.0; BATCH_BLOCKS * BELE],
                vec![0.0; BATCH_BLOCKS * BELE],
                vec![0.0; BATCH_BLOCKS * BELE],
            ],
            stats: BatchStats::default(),
        }
    }

    /// Price a whole tree-array portfolio through the blocked kernel,
    /// writing call/put prices into the output trees.
    ///
    /// All five arrays must have identical length.
    pub fn price_trees<'a, A: BlockAlloc>(
        &mut self,
        spot: &TreeArray<'_, f32, A>,
        strike: &TreeArray<'_, f32, A>,
        tmat: &TreeArray<'_, f32, A>,
        rate: f32,
        vol: f32,
        call: &mut TreeArray<'a, f32, A>,
        put: &mut TreeArray<'a, f32, A>,
    ) -> Result<BatchStats> {
        assert_eq!(spot.len(), strike.len());
        assert_eq!(spot.len(), tmat.len());
        assert_eq!(spot.len(), call.len());
        assert_eq!(spot.len(), put.len());
        let nleaves = spot.nleaves();
        let mut leaf = 0usize;
        while leaf < nleaves {
            let batch = (nleaves - leaf).min(BATCH_BLOCKS);
            // Gather leaves into the staging batch (pad tail with 1.0 to
            // keep the kernel's log() finite).
            for (src_idx, stage) in [spot, strike, tmat].into_iter().zip(self.stage.iter_mut()) {
                for b in 0..BATCH_BLOCKS {
                    let dst = &mut stage[b * BELE..(b + 1) * BELE];
                    if b < batch {
                        let s = src_idx.leaf_slice(leaf + b);
                        dst[..s.len()].copy_from_slice(s);
                        if s.len() < BELE {
                            dst[s.len()..].fill(1.0);
                        }
                    } else {
                        dst.fill(1.0);
                    }
                }
            }
            let shape = vec![BATCH_BLOCKS as i64, BELE as i64];
            let out = self.engine.run_f32(
                "bs_blocked_256x8192",
                &[
                    Input::F32(&self.stage[0], shape.clone()),
                    Input::F32(&self.stage[1], shape.clone()),
                    Input::F32(&self.stage[2], shape),
                    Input::ScalarF32(rate),
                    Input::ScalarF32(vol),
                ],
            )?;
            // Scatter call/put back into tree leaves.
            for (out_buf, tree) in out.iter().zip([&mut *call, &mut *put]) {
                for b in 0..batch {
                    let dst = tree.leaf_slice_mut(leaf + b);
                    let n = dst.len();
                    dst.copy_from_slice(&out_buf[b * BELE..b * BELE + n]);
                }
            }
            self.stats.dispatches += 1;
            self.stats.blocks += BATCH_BLOCKS as u64;
            self.stats.padded += (BATCH_BLOCKS - batch) as u64;
            leaf += batch;
        }
        Ok(self.stats)
    }

    /// Latency path: price a single 32 KB block through the `[1, 8192]`
    /// variant (one "request" in serving terms).
    pub fn price_one_block(
        &mut self,
        spot: &[f32],
        strike: &[f32],
        tmat: &[f32],
        rate: f32,
        vol: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        assert_eq!(spot.len(), BELE);
        let shape = vec![1i64, BELE as i64];
        let mut out = self.engine.run_f32(
            "bs_blocked_1x8192",
            &[
                Input::F32(spot, shape.clone()),
                Input::F32(strike, shape.clone()),
                Input::F32(tmat, shape),
                Input::ScalarF32(rate),
                Input::ScalarF32(vol),
            ],
        )?;
        let put = out.pop().expect("put output");
        let call = out.pop().expect("call output");
        Ok((call, put))
    }

    /// Cumulative stats.
    pub fn stats(&self) -> BatchStats {
        self.stats
    }
}
