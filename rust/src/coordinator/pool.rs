//! A minimal scoped thread pool (tokio is unavailable offline; the
//! experiment fan-out is embarrassingly parallel and CPU-bound, so
//! scoped threads + an atomic work index are exactly enough).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `jobs` closures across up to `threads` workers, returning results
/// in job order. Panics in jobs propagate.
pub fn run_parallel<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().unwrap();
                let out = job();
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job completed"))
        .collect()
}

/// Default sweep parallelism.
///
/// Exactly what the code does: `available_parallelism()`, falling back
/// to 4 when the core count cannot be determined, then capped at 8
/// (the experiments are memory-bandwidth-bound; more sweep threads add
/// noise, not speed). Experiments surface the value actually chosen in
/// their run output (`nvm run` prints it and tables carry a
/// `threads=N` note), so a capped or fallback count is visible.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_order() {
        let jobs: Vec<_> = (0..32).map(|i| move || i * 2).collect();
        let out = run_parallel(jobs, 4);
        assert_eq!(out, (0..32).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_parallel(jobs, 1), vec![0, 1, 2]);
    }

    #[test]
    fn empty_jobs() {
        let jobs: Vec<Box<dyn FnOnce() -> u8 + Send>> = vec![];
        assert!(run_parallel(jobs, 4).is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let jobs: Vec<_> = (0..2).map(|i| move || i + 10).collect();
        assert_eq!(run_parallel(jobs, 16), vec![10, 11]);
    }
}
