//! Experiment registry and dispatch (shared by the CLI and benches).

use crate::coordinator::experiments::{self, ExpConfig};
use crate::coordinator::report::Table;
use crate::error::{Error, Result};
use crate::telemetry::results::Record;
use crate::telemetry::sink;

/// Descriptor of a runnable experiment.
pub struct ExperimentInfo {
    /// CLI name.
    pub name: &'static str,
    /// What it reproduces.
    pub description: &'static str,
}

/// All registered experiments.
pub fn list_experiments() -> Vec<ExperimentInfo> {
    vec![
        ExperimentInfo {
            name: "table2",
            description: "Table 2: linear/strided scan tree-vs-array ratios, 4KB-64GB",
        },
        ExperimentInfo {
            name: "fig3",
            description: "Figure 3: split-stack overhead on SPEC/PARSEC profiles + fib",
        },
        ExperimentInfo {
            name: "fig4-gups",
            description: "Figure 4 left: GUPS tree/array ratios, 4-64GB",
        },
        ExperimentInfo {
            name: "fig4-rbtree",
            description: "Figure 4 right: red-black tree physical/virtual ratio",
        },
        ExperimentInfo {
            name: "fig5",
            description: "Figure 5: blackscholes + deepsjeng software-contiguity overhead",
        },
        ExperimentInfo {
            name: "concurrent-gups",
            description: "Concurrent GUPS: threads sharing one two-level allocator (real execution)",
        },
        ExperimentInfo {
            name: "concurrent-probe",
            description: "N per-thread-TLB reader views over one shared tree, with live relocation",
        },
        ExperimentInfo {
            name: "concurrent-rw",
            description: "N view readers + M seqlock writers + mmd compaction on one shared tree",
        },
        ExperimentInfo {
            name: "fragmentation-churn",
            description: "mmd daemon: reader throughput + frag score under churn, off vs on",
        },
        ExperimentInfo {
            name: "larger-than-dram",
            description: "Software page faults: readers+writer over a tree bigger than the pool",
        },
        ExperimentInfo {
            name: "multi-tenant",
            description: "Multi-tenant isolation: 5 tenants on one pool/queue/daemon, benign vs misbehaving",
        },
        ExperimentInfo {
            name: "parallel-blackscholes",
            description: "Partitioned parallel Black-Scholes over one sharded allocator",
        },
        ExperimentInfo {
            name: "batched-workloads",
            description: "Batched GUPS/hashprobe vs per-op naive walks (sort-and-run + flat table)",
        },
        ExperimentInfo {
            name: "ablation-alloc",
            description: "Alloc/free throughput swept over threads: mutex vs sharded vs two-level",
        },
        ExperimentInfo {
            name: "ablation-block-size",
            description: "Block-size sensitivity of Table 2 ratios (paper S3 claim)",
        },
        ExperimentInfo {
            name: "ablation-ptw",
            description: "S4.4 claim: iterator == software PTW cache",
        },
        ExperimentInfo {
            name: "energy",
            description: "S2 claim: translation's share of memory-system energy",
        },
        ExperimentInfo {
            name: "kv-serve",
            description: "pallas-kv: open-loop KV service tail latency under mmd churn + paging",
        },
    ]
}

/// Run one experiment by name.
pub fn run_experiment(name: &str, cfg: &ExpConfig) -> Result<Vec<Table>> {
    let tables = match name {
        "table2" => vec![experiments::table2(cfg)],
        "fig3" => vec![experiments::fig3(cfg)],
        "fig4-gups" => vec![experiments::fig4_gups(cfg)],
        "fig4-rbtree" => vec![experiments::fig4_rbtree(cfg)],
        "fig4" => vec![experiments::fig4_gups(cfg), experiments::fig4_rbtree(cfg)],
        "fig5" => vec![experiments::fig5(cfg)],
        "concurrent-gups" | "concurrent_gups" => vec![experiments::concurrent_gups(cfg)],
        "concurrent-probe" | "concurrent_probe" => vec![experiments::concurrent_probe(cfg)],
        "concurrent-rw" | "concurrent_rw" => vec![experiments::concurrent_rw(cfg)],
        "fragmentation-churn" | "fragmentation_churn" => {
            vec![experiments::fragmentation_churn(cfg)]
        }
        "larger-than-dram" | "larger_than_dram" => {
            vec![experiments::larger_than_dram(cfg)]
        }
        "multi-tenant" | "multi_tenant" => vec![experiments::multi_tenant(cfg)],
        "parallel-blackscholes" | "parallel_blackscholes" => {
            vec![experiments::parallel_blackscholes(cfg)]
        }
        "batched-workloads" | "batched_workloads" => vec![experiments::batched_workloads(cfg)],
        "ablation-alloc" | "ablation_alloc_contention" => {
            vec![experiments::ablation_alloc_contention(cfg)]
        }
        "ablation-block-size" => vec![experiments::ablation_block_size(cfg)],
        "ablation-ptw" => vec![experiments::ablation_ptw_cache(cfg)],
        "energy" => vec![experiments::energy(cfg)],
        "kv-serve" | "kv_serve" => vec![experiments::kv_serve(cfg)],
        "all" => {
            let mut all = Vec::new();
            for e in list_experiments() {
                all.extend(run_experiment(e.name, cfg)?);
            }
            all
        }
        other => {
            return Err(Error::Config(format!(
                "unknown experiment {other:?}; see `nvm list`"
            )))
        }
    };
    Ok(tables)
}

/// Run one experiment with the telemetry sink installed, returning
/// its tables plus one results [`Record`] per experiment: table cells
/// flattened to metrics, anything the experiment emitted through
/// [`sink`] (direction-bearing metrics, traces, action logs,
/// verdicts), and the config it ran under. `"all"` yields one record
/// per registered experiment.
pub fn run_experiment_recorded(
    name: &str,
    cfg: &ExpConfig,
) -> Result<(Vec<Table>, Vec<Record>)> {
    if name == "all" {
        let mut tables = Vec::new();
        let mut records = Vec::new();
        for e in list_experiments() {
            let (t, r) = run_experiment_recorded(e.name, cfg)?;
            tables.extend(t);
            records.extend(r);
        }
        return Ok((tables, records));
    }
    sink::begin(name, "experiment");
    let result = run_experiment(name, cfg);
    // Always uninstall, even on error, so a failed run can't leak its
    // sink into the next one.
    let mut record = sink::take().unwrap_or_else(|| Record::new(name, "experiment"));
    let tables = result?;
    record
        .config("sample", cfg.sample)
        .config("threads", cfg.threads)
        .config("seed", cfg.seed);
    for t in &tables {
        t.record_into(&mut record);
    }
    Ok((tables, vec![record]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("nope", &ExpConfig::quick()).is_err());
    }

    #[test]
    fn recorded_run_flattens_tables() {
        let _g = sink::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let cfg = ExpConfig {
            sample: 20_000,
            threads: 2,
            ..ExpConfig::default()
        };
        let (tables, records) = run_experiment_recorded("table2", &cfg).unwrap();
        assert_eq!(tables.len(), 1);
        assert_eq!(records.len(), 1);
        let r = &records[0];
        assert_eq!(r.name, "table2");
        assert_eq!(r.kind, "experiment");
        assert!(!r.metrics.is_empty());
        assert!(r.config.iter().any(|(k, v)| k == "sample" && v == "20000"));
        // An error still clears the sink.
        assert!(run_experiment_recorded("nope", &cfg).is_err());
        assert!(!sink::active());
    }

    #[test]
    fn registry_names_resolve() {
        // Every listed experiment must dispatch (run with tiny samples).
        let cfg = ExpConfig {
            sample: 20_000,
            threads: 4,
            ..ExpConfig::default()
        };
        for e in list_experiments() {
            // Skip the slowest in unit tests: rbtree builds real trees;
            // fragmentation-churn runs 6 full daemon sub-runs (covered
            // by its own experiment test, the integration sweep, and
            // the release-mode mmd_stress tier); larger-than-dram runs
            // 3 full paging sub-runs (covered by its own e2e test in
            // the release-mode swap_fault tier); multi-tenant runs a
            // two-phase 5-tenant daemon run (covered by its own e2e
            // test in the release-mode multi_tenant tier).
            if e.name == "fig4-rbtree"
                || e.name == "fragmentation-churn"
                || e.name == "larger-than-dram"
                || e.name == "multi-tenant"
            {
                continue;
            }
            let tables = run_experiment(e.name, &cfg).unwrap();
            assert!(!tables.is_empty(), "{} produced no tables", e.name);
        }
    }
}
