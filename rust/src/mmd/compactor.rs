//! The compaction engine: walks the [`TreeRegistry`] and relocates /
//! evicts / restores leaves of live trees through the forwarding
//! machinery, throttled by a per-call token budget.
//!
//! Mechanism, not policy: callers (the [`crate::mmd`] daemon, tests)
//! decide *when* and *how much*; the compactor only executes.
//!
//! # How compaction actually reduces fragmentation
//!
//! Plain `alloc` picks blocks for speed (LIFO reuse, shard affinity),
//! so relocating a leaf through it merely shuffles fragmentation. The
//! compactor instead allocates every destination with
//! [`BlockAlloc::alloc_in_span`] — the **lowest** free block below the
//! leaf's current one — so each move strictly sinks the leaf toward the
//! bottom of its span and free space consolidates on top (the classic
//! two-finger compaction shape, expressed through the allocator). A
//! leaf with no free block below it is already packed and is skipped;
//! total block-id order strictly decreases per move, so repeated passes
//! converge.
//!
//! # Safety inheritance
//!
//! Every relocation is [`TreeArray::migrate_leaf_concurrent_to`]
//! underneath: displaced blocks are *retired* into the pool's epoch
//! limbo and reclaimed only after all registered readers quiesce, so
//! registered [`crate::trees::TreeView`] readers never stall and never
//! see recycled memory. The move also acquires the leaf's **seqlock**,
//! so compaction respects live [`crate::trees::TreeWriter`]s: a pass
//! briefly spins on a leaf a writer holds (writer critical sections are
//! a few stores) and a writer spins out a mid-copy move — a leaf is
//! never simultaneously written and relocated, which is why registered
//! trees may now be written through seqlock writers while the daemon
//! runs. The registry's registration contracts carry the proof
//! obligations; the compactor holds the registry lock for the duration
//! of a pass, so deregistration synchronizes with it.
//!
//! [`TreeRegistry`]: crate::trees::TreeRegistry
//! [`BlockAlloc::alloc_in_span`]: crate::pmem::BlockAlloc::alloc_in_span
//! [`TreeArray::migrate_leaf_concurrent_to`]: crate::trees::TreeArray::migrate_leaf_concurrent_to

use crate::error::Error;
use crate::pmem::faultq::{FaultQueue, LeafFaulter, SwapService};
use crate::pmem::tenant::TenantRegistry;
use crate::pmem::BlockAlloc;
use crate::trees::TreeRegistry;

/// Victims recorded per eviction pass are capped so a pathological
/// burst cannot grow the report without bound.
const VICTIM_CAP: usize = 128;

/// A tenant's slice of a per-tick budget: proportional to its share,
/// never below one (a positive share always makes progress).
fn tenant_cap(budget: usize, share: u64, share_total: u64) -> usize {
    (((budget as u128 * share as u128) / share_total.max(1) as u128) as usize).max(1)
}

/// Work counters for one [`Compactor`] (cumulative).
#[derive(Clone, Copy, Debug, Default)]
pub struct CompactStats {
    /// Leaves relocated (compaction + rebalancing).
    pub leaves_moved: u64,
    /// Bytes copied by those relocations.
    pub bytes_compacted: u64,
    /// Leaves evicted to swap.
    pub evictions: u64,
    /// Leaves faulted back and re-adopted (demand-independent).
    pub restores: u64,
    /// Leaves brought back speculatively (Prefetch action).
    pub prefetched: u64,
    /// Relocations abandoned (destination allocation failed or the
    /// move errored; the destination block was returned).
    pub skipped: u64,
}

/// The engine. Borrows one pool and one registry for its lifetime.
pub struct Compactor<'e, A: BlockAlloc> {
    alloc: &'e A,
    registry: &'e TreeRegistry<'e>,
    stats: CompactStats,
    /// Eviction victims `(registration id, leaf)` since the last
    /// [`Compactor::take_victims`], capped at [`VICTIM_CAP`].
    victims: Vec<(u64, usize)>,
}

impl<'e, A: BlockAlloc> Compactor<'e, A> {
    /// A compactor over `alloc` driving the trees in `registry`.
    pub fn new(alloc: &'e A, registry: &'e TreeRegistry<'e>) -> Self {
        Compactor {
            alloc,
            registry,
            stats: CompactStats::default(),
            victims: Vec::new(),
        }
    }

    /// Cumulative work counters.
    pub fn stats(&self) -> CompactStats {
        self.stats
    }

    /// Drain the eviction victims recorded since the last call —
    /// `(registration id, leaf index)` in eviction order. The daemon
    /// surfaces these in its report so "what did eviction choose" is
    /// observable without tracing.
    pub fn take_victims(&mut self) -> Vec<(u64, usize)> {
        std::mem::take(&mut self.victims)
    }

    /// The shared relocation pass under compaction and rebalancing:
    /// walk every registered tree, and for each resident leaf whose
    /// current block satisfies `candidate`, allocate a destination from
    /// `dest_span(cur)` and move the leaf there — up to `budget` moves.
    /// `stop_on_alloc_fail` distinguishes the two shapes: compaction
    /// treats an empty destination span as "this leaf is packed, try
    /// the next" (per-leaf spans), rebalancing as "the target shard is
    /// full, the pass is over" (one fixed span). Ends with a
    /// non-blocking reclaim so displaced blocks return to the pool as
    /// soon as readers quiesce.
    fn relocate_pass(
        &mut self,
        budget: usize,
        candidate: impl Fn(usize) -> bool,
        dest_span: impl Fn(usize) -> (usize, usize),
        stop_on_alloc_fail: bool,
    ) -> usize {
        let bs = self.alloc.block_size() as u64;
        let mut moved = 0usize;
        let entries = self.registry.lock();
        'outer: for e in entries.iter() {
            for leaf in 0..e.tree.nleaves() {
                if moved >= budget {
                    break 'outer;
                }
                if e.tree.leaf_swap_slot(leaf).is_some() {
                    continue; // no live backing to copy from
                }
                let cur = e.tree.leaf_block(leaf).0 as usize;
                if !candidate(cur) {
                    continue;
                }
                let (dlo, dhi) = dest_span(cur);
                let dest = match self.alloc.alloc_in_span(dlo, dhi) {
                    Ok(d) => d,
                    Err(_) if stop_on_alloc_fail => break 'outer,
                    Err(_) => continue,
                };
                // SAFETY: the registry's registration contract — readers
                // only through epoch-registered views, no raw slices,
                // this pass is the only migrator — plus dest freshly
                // allocated and exclusively ours.
                match unsafe { e.tree.relocate_leaf_to(leaf, dest) } {
                    Ok(()) => {
                        moved += 1;
                        self.stats.leaves_moved += 1;
                        self.stats.bytes_compacted += bs;
                    }
                    Err(_) => {
                        let _ = self.alloc.free(dest);
                        self.stats.skipped += 1;
                    }
                }
            }
        }
        drop(entries);
        self.alloc.epoch().try_reclaim(self.alloc);
        moved
    }

    /// One compaction pass over block-id span `[lo, hi)`: sink up to
    /// `budget` leaves currently in the span into the **lowest** free
    /// blocks below them (same span). Returns leaves moved; 0 means the
    /// span is packed (convergence signal).
    pub fn compact_span(&mut self, budget: usize, lo: usize, hi: usize) -> usize {
        // Destination strictly below the leaf: a leaf with no free
        // block under it is already packed and is skipped.
        self.relocate_pass(budget, move |cur| cur > lo && cur < hi, move |cur| (lo, cur), false)
    }

    /// Migrate up to `budget` leaves whose blocks sit in `from`'s span
    /// into blocks allocated from `to`'s span (stealing-aware
    /// rebalancing: emptying the hot shard's range gives threads homed
    /// there free local blocks again instead of cross-shard steals).
    pub fn rebalance(&mut self, budget: usize, from: (usize, usize), to: (usize, usize)) -> usize {
        self.relocate_pass(
            budget,
            move |cur| cur >= from.0 && cur < from.1,
            move |_| to,
            true, // destination shard full: the pass is over
        )
    }

    /// Evict up to `budget` leaves of evictable registrations into
    /// `swap` (a service over the same allocator), **coldest first**:
    /// candidates are every resident leaf of every evictable tree,
    /// ordered by last-touch tick ascending (never-touched leaves tie
    /// at 0 and go in index order). The view/writer fault hooks bump
    /// the tick on every translation miss and fault-in, so leaves the
    /// workload is actively walking rank hot and stay resident. Each
    /// eviction runs under the leaf's seqlock
    /// ([`crate::trees::CompactTarget::evict_leaf`]); the physical
    /// block is epoch-retired, not freed, so readers stay safe. Chosen
    /// victims are recorded for [`Compactor::take_victims`].
    pub fn evict(&mut self, budget: usize, swap: &dyn SwapService) -> usize {
        let entries = self.registry.lock();
        let mut cands: Vec<(u64, usize, usize)> = Vec::new(); // (touch, entry, leaf)
        for (ei, e) in entries.iter().enumerate() {
            if !e.evictable {
                continue;
            }
            for leaf in 0..e.tree.nleaves() {
                if e.tree.leaf_swap_slot(leaf).is_none() {
                    cands.push((e.tree.leaf_touch(leaf), ei, leaf));
                }
            }
        }
        cands.sort(); // coldest (smallest tick) first; stable by (entry, leaf)
        let mut done = 0usize;
        for &(_, ei, leaf) in cands.iter().take(budget.min(cands.len())) {
            let e = &entries[ei];
            // SAFETY: the evictable registration contract — accessors
            // are fault-capable and a faulter is installed before any
            // of them can hit this leaf.
            match unsafe { e.tree.evict_leaf(leaf, swap) } {
                Ok(_) => {
                    done += 1;
                    self.stats.evictions += 1;
                    if self.victims.len() < VICTIM_CAP {
                        self.victims.push((e.id, leaf));
                    }
                }
                Err(_) => break, // swap I/O trouble: stop the pass
            }
        }
        done
    }

    /// The shared fault-back pass: bring up to `budget` swapped-out
    /// leaves back in through `faulter`, **hottest first** (largest
    /// last-touch tick) — the leaves a demand miss would hit soonest.
    /// `Ok(false)` from a restore (a demand fault won the race) costs
    /// no budget; an error ends the pass — for a direct pool that is
    /// OOM/I-O (caller may reclaim and retry), for a shedding prefetch
    /// gate it is the gate saying "queue busy", which is throttling,
    /// not failure.
    fn fault_back(&mut self, budget: usize, faulter: &dyn LeafFaulter, prefetch: bool) -> usize {
        let entries = self.registry.lock();
        let mut cands: Vec<(std::cmp::Reverse<u64>, usize, usize)> = Vec::new();
        for (ei, e) in entries.iter().enumerate() {
            for leaf in 0..e.tree.nleaves() {
                if e.tree.leaf_swap_slot(leaf).is_some() {
                    cands.push((std::cmp::Reverse(e.tree.leaf_touch(leaf)), ei, leaf));
                }
            }
        }
        cands.sort();
        let mut done = 0usize;
        for &(_, ei, leaf) in cands.iter() {
            if done >= budget {
                break;
            }
            match entries[ei].tree.restore_leaf(leaf, faulter) {
                Ok(true) => {
                    done += 1;
                    if prefetch {
                        self.stats.prefetched += 1;
                    } else {
                        self.stats.restores += 1;
                    }
                }
                Ok(false) => {} // demand fault won the race
                Err(_) => break,
            }
        }
        done
    }

    /// Fault up to `budget` swapped-out leaves back in and re-adopt
    /// them, hottest first. Stops early if the pool cannot supply
    /// blocks (the slot stays resident — the swap fault is
    /// failure-atomic).
    pub fn restore(&mut self, budget: usize, faulter: &dyn LeafFaulter) -> usize {
        self.fault_back(budget, faulter, false)
    }

    /// Speculatively fault up to `budget` predicted-hot swapped-out
    /// leaves back in (the daemon's Prefetch action). Pass a
    /// [`crate::pmem::PrefetchGate`] so speculative work sheds instead
    /// of competing with demand faults when the queue is busy.
    pub fn prefetch(&mut self, budget: usize, faulter: &dyn LeafFaulter) -> usize {
        self.fault_back(budget, faulter, true)
    }

    /// Restore *everything*, reclaiming limbo between attempts so
    /// restores never starve on deferred frees. Used by daemon
    /// shutdown; loops until the registry has no swapped-out leaves or
    /// no progress can be made.
    pub fn restore_all(&mut self, faulter: &dyn LeafFaulter) -> usize {
        let mut total = 0usize;
        loop {
            let n = self.restore(usize::MAX, faulter);
            total += n;
            if self.registry.swapped_out() == 0 {
                return total;
            }
            let reclaimed = self.alloc.epoch().try_reclaim(self.alloc);
            if n == 0 && reclaimed == 0 {
                // Wedged: pool exhausted and nothing reclaimable. The
                // leaves stay in swap; deregistration will refuse.
                return total;
            }
        }
    }

    // ---- tenant-aware passes ---------------------------------------
    //
    // The same mechanisms, with three policy twists the tenant layer
    // needs: (1) each tree's swap traffic goes through its owning
    // tenant's routed backing ([`FaultQueue::scoped`]), so one tenant's
    // dead device fails only that tenant's I/O; (2) pressured tenants'
    // cold leaves evict first (soft-quota backpressure) and the budget
    // splits by share so a noisy tenant cannot absorb a whole pass;
    // (3) a tenant whose I/O fails mid-pass is skipped for the rest of
    // the pass — containment — while every other tenant's work
    // continues.

    /// Evict up to `budget` leaves across tenants: pressured tenants'
    /// leaves first, then coldest within each rank; at most each
    /// tenant's share of the budget per pass; degraded tenants skipped
    /// entirely. Every eviction goes through the leaf's owning tenant's
    /// routed backing and credits its quota
    /// ([`TenantRegistry::evict_credited`]).
    pub fn evict_tenants(
        &mut self,
        budget: usize,
        q: &FaultQueue<'_>,
        tenants: &TenantRegistry,
    ) -> usize {
        let share_total = tenants.share_total().max(1);
        let entries = self.registry.lock();
        // (pressure rank, last-touch, entry, leaf): rank 0 = pressured
        // tenant, so a soft-quota overrun drains before anyone else
        // pays; coldest-first within a rank as usual.
        let mut cands: Vec<(u8, u64, usize, usize)> = Vec::new();
        for (ei, e) in entries.iter().enumerate() {
            if !e.evictable || q.degraded_for(e.tenant) {
                continue;
            }
            let rank = u8::from(!tenants.pressured(e.tenant));
            for leaf in 0..e.tree.nleaves() {
                if e.tree.leaf_swap_slot(leaf).is_none() {
                    cands.push((rank, e.tree.leaf_touch(leaf), ei, leaf));
                }
            }
        }
        cands.sort();
        let mut done = 0usize;
        let mut taken: Vec<(u16, usize)> = Vec::new();
        let mut failed: Vec<u16> = Vec::new();
        for &(rank, _, ei, leaf) in cands.iter() {
            if done >= budget {
                break;
            }
            let e = &entries[ei];
            if failed.contains(&e.tenant) {
                continue;
            }
            let share = tenants.get(e.tenant).map(|t| t.share() as u64).unwrap_or(1);
            let cap = tenant_cap(budget, share, share_total);
            let ti = match taken.iter().position(|(t, _)| *t == e.tenant) {
                Some(i) => i,
                None => {
                    taken.push((e.tenant, 0));
                    taken.len() - 1
                }
            };
            // The share cap keeps one tenant from absorbing a whole
            // pass — but a *pressured* tenant is paying down its own
            // overrun, and every leaf taken from it is one not taken
            // from a healthy neighbour. Rank 0 evicts uncapped.
            if rank != 0 && taken[ti].1 >= cap {
                continue;
            }
            let svc = q.scoped(e.tenant);
            // SAFETY: the evictable registration contract — accessors
            // are fault-capable and a faulter is installed before any
            // of them can hit this leaf.
            match unsafe { e.tree.evict_leaf(leaf, &svc) } {
                Ok(_) => {
                    taken[ti].1 += 1;
                    done += 1;
                    self.stats.evictions += 1;
                    tenants.evict_credited(e.tenant);
                    if self.victims.len() < VICTIM_CAP {
                        self.victims.push((e.id, leaf));
                    }
                }
                // This tenant's backing refuses writes: contain the
                // failure to it, keep the pass going for the others.
                Err(Error::Io(_)) | Err(Error::SwapFaultFailed { .. }) => failed.push(e.tenant),
                // Pool-level trouble (no swap slots, OOM): the pass is
                // over for everyone.
                Err(_) => break,
            }
        }
        done
    }

    /// The tenant-aware fault-back pass. `drain` is the shutdown shape:
    /// it also restores pressured tenants (everything must come home)
    /// and *probes* degraded tenants — one attempt per tenant per pass,
    /// so a backing that recovered mid-drain is noticed and fully
    /// restored, while a still-dead one costs one retry burst and is
    /// re-skipped.
    fn fault_back_tenants(
        &mut self,
        budget: usize,
        q: &FaultQueue<'_>,
        tenants: &TenantRegistry,
        prefetch: bool,
        drain: bool,
    ) -> usize {
        let share_total = tenants.share_total().max(1);
        let entries = self.registry.lock();
        let mut cands: Vec<(std::cmp::Reverse<u64>, usize, usize)> = Vec::new();
        for (ei, e) in entries.iter().enumerate() {
            if !drain {
                if q.degraded_for(e.tenant) {
                    continue; // parked: its backing cannot answer
                }
                if tenants.pressured(e.tenant) {
                    // Restoring into a pressured tenant would recharge
                    // the quota the eviction pass just relieved.
                    continue;
                }
            }
            for leaf in 0..e.tree.nleaves() {
                if e.tree.leaf_swap_slot(leaf).is_some() {
                    cands.push((std::cmp::Reverse(e.tree.leaf_touch(leaf)), ei, leaf));
                }
            }
        }
        cands.sort();
        let mut done = 0usize;
        let mut taken: Vec<(u16, usize)> = Vec::new();
        let mut failed: Vec<u16> = Vec::new();
        for &(_, ei, leaf) in cands.iter() {
            if done >= budget {
                break;
            }
            let e = &entries[ei];
            if failed.contains(&e.tenant) {
                continue;
            }
            let share = tenants.get(e.tenant).map(|t| t.share() as u64).unwrap_or(1);
            let cap = tenant_cap(budget, share, share_total);
            let ti = match taken.iter().position(|(t, _)| *t == e.tenant) {
                Some(i) => i,
                None => {
                    taken.push((e.tenant, 0));
                    taken.len() - 1
                }
            };
            if taken[ti].1 >= cap {
                continue;
            }
            let faulter = q.scoped(e.tenant);
            match e.tree.restore_leaf(leaf, &faulter) {
                Ok(true) => {
                    taken[ti].1 += 1;
                    done += 1;
                    if prefetch {
                        self.stats.prefetched += 1;
                    } else {
                        self.stats.restores += 1;
                    }
                }
                Ok(false) => {} // demand fault won the race
                // This tenant's backing cannot answer: contain.
                Err(Error::SwapFaultFailed { .. }) | Err(Error::Io(_)) => failed.push(e.tenant),
                // Pool-level trouble (OOM): over for everyone.
                Err(_) => break,
            }
        }
        done
    }

    /// Restore up to `budget` swapped-out leaves across tenants,
    /// hottest first with per-share caps; degraded *and pressured*
    /// tenants are skipped (a pressured tenant's leaves stay parked
    /// until its usage drops — that is the backpressure).
    pub fn restore_tenants(
        &mut self,
        budget: usize,
        q: &FaultQueue<'_>,
        tenants: &TenantRegistry,
    ) -> usize {
        self.fault_back_tenants(budget, q, tenants, false, false)
    }

    /// Speculative tenant-aware fault-back (the Prefetch action), same
    /// skip rules as [`Compactor::restore_tenants`].
    pub fn prefetch_tenants(
        &mut self,
        budget: usize,
        q: &FaultQueue<'_>,
        tenants: &TenantRegistry,
    ) -> usize {
        self.fault_back_tenants(budget, q, tenants, true, false)
    }

    /// Tenant-aware shutdown drain: restore everything restorable,
    /// reclaiming limbo between rounds. Probes degraded tenants each
    /// round (recovery detection); leaves whose tenant stays degraded
    /// remain parked — the count excludes them, so a dead backing
    /// cannot wedge shutdown.
    pub fn restore_all_tenants(&mut self, q: &FaultQueue<'_>, tenants: &TenantRegistry) -> usize {
        let mut total = 0usize;
        loop {
            let n = self.fault_back_tenants(usize::MAX, q, tenants, false, true);
            total += n;
            let parked: usize = {
                let g = self.registry.lock();
                g.iter()
                    .filter(|e| !q.degraded_for(e.tenant))
                    .map(|e| e.tree.swapped_leaves())
                    .sum()
            };
            if parked == 0 {
                return total;
            }
            let reclaimed = self.alloc.epoch().try_reclaim(self.alloc);
            if n == 0 && reclaimed == 0 {
                // Wedged: pool exhausted and nothing reclaimable.
                return total;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mmd::stats::FragSampler;
    use crate::pmem::{BlockAllocator, ShardedAllocator, SwapPool};
    use crate::testutil::fragmented_tree;
    use crate::trees::TreeArray;

    fn compaction_halves_score<A: BlockAlloc>(a: &A) {
        let (tree, mirror) = fragmented_tree(a, 40, |i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1);
        let mut sampler = FragSampler::new();
        let s0 = sampler.sample(a);
        assert!(s0.score > 0.5, "setup must fragment the pool: {}", s0.score);
        let registry = TreeRegistry::new();
        // SAFETY: nothing accesses the tree until deregistration.
        let id = unsafe { registry.register(&tree) };
        let mut c = Compactor::new(a, &registry);
        // Budgeted passes converge: each pass's moves strictly sink.
        let mut passes = 0;
        while c.compact_span(8, 0, a.capacity()) > 0 {
            passes += 1;
            assert!(passes < 1000, "compaction failed to converge");
        }
        // Every strided leaf with free space below it sinks at least
        // once (a leaf that started at block 0 has nowhere to go).
        assert!(c.stats().leaves_moved >= 30, "strided leaves must sink");
        let s1 = sampler.sample(a);
        assert!(
            s1.score * 2.0 <= s0.score,
            "compaction must at least halve the score: {} -> {}",
            s0.score,
            s1.score
        );
        // Leaves really are packed low now (only the unmoved root may
        // sit above them).
        for leaf in 0..tree.nleaves() {
            assert!(
                (tree.leaf_block(leaf).0 as usize) <= 41,
                "leaf {leaf} left at {:?}",
                tree.leaf_block(leaf)
            );
        }
        assert_eq!(tree.to_vec(), mirror, "compaction corrupted the tree");
        registry.deregister(id);
        drop(registry);
        drop(tree);
        assert_eq!(a.stats().allocated, 0, "compaction leaked blocks");
        assert_eq!(a.epoch().limbo_len(), 0);
    }

    #[test]
    fn compaction_halves_score_mutex_allocator() {
        let a = BlockAllocator::new(1024, 256).unwrap();
        compaction_halves_score(&a);
    }

    #[test]
    fn compaction_halves_score_sharded_allocator() {
        let a = ShardedAllocator::with_shards(1024, 256, 2).unwrap();
        compaction_halves_score(&a);
    }

    #[test]
    fn compaction_halves_score_twolevel_allocator() {
        let a = crate::pmem::TwoLevelAllocator::with_topology(1024, 256, 1, 2).unwrap();
        compaction_halves_score(&a);
    }

    #[test]
    fn rebalance_moves_leaves_between_subtrees() {
        use crate::pmem::{TwoLevelAllocator, SUBTREE_BLOCKS};
        // Two-subtree pool: the daemon's Rebalance action operates on
        // the allocator's shard_spans, which for the two-level design
        // are the 512-block subtrees.
        let a = TwoLevelAllocator::with_topology(1024, 2 * SUBTREE_BLOCKS, 1, 2).unwrap();
        let spans = a.shard_spans();
        assert_eq!(spans, vec![(0, SUBTREE_BLOCKS), (SUBTREE_BLOCKS, 2 * SUBTREE_BLOCKS)]);
        // Land the whole tree in subtree 1's range.
        let mut held = Vec::new();
        for _ in 0..SUBTREE_BLOCKS {
            held.push(a.alloc_in_span(0, SUBTREE_BLOCKS).unwrap());
        }
        let mut tree: TreeArray<u64, TwoLevelAllocator> = TreeArray::new(&a, 128 * 6).unwrap();
        let data: Vec<u64> = (0..128 * 6).map(|i| i as u64 ^ 0x5A).collect();
        tree.copy_from_slice(&data).unwrap();
        for leaf in 0..tree.nleaves() {
            assert!(
                tree.leaf_block(leaf).0 as usize >= SUBTREE_BLOCKS,
                "setup: tree must start in subtree 1"
            );
        }
        for b in held {
            a.free(b).unwrap();
        }
        let registry = TreeRegistry::new();
        // SAFETY: no accessors until deregistration.
        let id = unsafe { registry.register(&tree) };
        let mut c = Compactor::new(&a, &registry);
        let moved = c.rebalance(usize::MAX, spans[1], spans[0]);
        assert_eq!(moved, 6, "all six leaves migrate to subtree 0's range");
        for leaf in 0..tree.nleaves() {
            assert!(
                (tree.leaf_block(leaf).0 as usize) < SUBTREE_BLOCKS,
                "leaf {leaf} not rebalanced"
            );
        }
        assert_eq!(tree.to_vec(), data);
        registry.deregister(id);
        drop(registry);
        a.epoch().synchronize(&a);
        drop(tree);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn rebalance_moves_leaves_between_spans() {
        let a = ShardedAllocator::with_shards(1024, 128, 2).unwrap();
        // Land the whole tree in shard 1's range [64, 128).
        let mut held = Vec::new();
        for _ in 0..64 {
            held.push(a.alloc_in_span(0, 64).unwrap());
        }
        let mut tree: TreeArray<u64, ShardedAllocator> = TreeArray::new(&a, 128 * 6).unwrap();
        let data: Vec<u64> = (0..128 * 6).map(|i| i as u64 ^ 0xAA).collect();
        tree.copy_from_slice(&data).unwrap();
        for leaf in 0..tree.nleaves() {
            assert!(tree.leaf_block(leaf).0 >= 64, "setup: tree must start in shard 1");
        }
        for b in held {
            a.free(b).unwrap();
        }
        let registry = TreeRegistry::new();
        // SAFETY: no accessors until deregistration.
        let id = unsafe { registry.register(&tree) };
        let mut c = Compactor::new(&a, &registry);
        let moved = c.rebalance(usize::MAX, (64, 128), (0, 64));
        assert_eq!(moved, 6, "all six leaves migrate to shard 0's range");
        for leaf in 0..tree.nleaves() {
            assert!(tree.leaf_block(leaf).0 < 64, "leaf {leaf} not rebalanced");
        }
        assert_eq!(tree.to_vec(), data);
        registry.deregister(id);
        drop(registry);
        a.epoch().synchronize(&a);
        drop(tree);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn evict_restore_roundtrip_preserves_contents_and_frees_memory() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let mut tree: TreeArray<u64> = TreeArray::new(&a, 128 * 8).unwrap();
        let data: Vec<u64> = (0..128 * 8).map(|i| i as u64 ^ 0xF00D).collect();
        tree.copy_from_slice(&data).unwrap();
        tree.enable_flat_table();
        let _ = tree.get(0);
        let registry = TreeRegistry::new();
        // SAFETY: no accessors at all between eviction and restore.
        let id = unsafe { registry.register_evictable(&tree) };
        let swap = SwapPool::anonymous(&a).unwrap();
        let mut c = Compactor::new(&a, &registry);
        let live0 = a.stats().allocated;
        let n = c.evict(4, &swap);
        assert_eq!(n, 4);
        assert_eq!(registry.swapped_out(), 4);
        // No readers registered: the retired blocks reclaim immediately.
        a.epoch().synchronize(&a);
        assert_eq!(a.stats().allocated, live0 - 4, "eviction must free memory");
        assert_eq!(swap.stats().resident_slots, 4);
        // Compaction skips swapped leaves rather than copying dead blocks.
        c.compact_span(usize::MAX, 0, a.capacity());
        let r = c.restore_all(&swap);
        assert_eq!(r, 4);
        assert_eq!(registry.swapped_out(), 0);
        assert_eq!(swap.stats().resident_slots, 0);
        assert_eq!(tree.to_vec(), data, "evict/restore corrupted the tree");
        registry.deregister(id);
        drop(registry);
        a.epoch().synchronize(&a);
        drop(tree);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn eviction_picks_cold_leaves_and_records_victims() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let mut tree: TreeArray<u64> = TreeArray::new(&a, 128 * 6).unwrap();
        let data: Vec<u64> = (0..128 * 6).map(|i| i as u64 | 1).collect();
        tree.copy_from_slice(&data).unwrap();
        let registry = TreeRegistry::new();
        // SAFETY: all accesses below are fault-capable views (and none
        // touch a leaf while it is out).
        let id = unsafe { registry.register_evictable(&tree) };
        let swap = SwapPool::anonymous(&a).unwrap();
        // Touch leaves 0 and 3: their translation misses stamp recency.
        {
            let mut v = tree.view();
            let _ = v.get(0).unwrap();
            let _ = v.get(128 * 3).unwrap();
        }
        let mut c = Compactor::new(&a, &registry);
        assert_eq!(c.evict(4, &swap), 4);
        let victims: Vec<usize> = c
            .take_victims()
            .into_iter()
            .map(|(vid, l)| {
                assert_eq!(vid, id);
                l
            })
            .collect();
        assert_eq!(victims.len(), 4);
        assert!(
            !victims.contains(&0) && !victims.contains(&3),
            "touched (hot) leaves must be evicted last: {victims:?}"
        );
        assert!(c.take_victims().is_empty(), "take_victims drains");
        assert_eq!(c.restore_all(&swap), 4);
        assert_eq!(c.stats().restores, 4);
        assert_eq!(tree.to_vec(), data);
        registry.deregister(id);
    }

    #[test]
    fn prefetch_restores_hottest_swapped_leaf_first() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let mut tree: TreeArray<u64> = TreeArray::new(&a, 128 * 4).unwrap();
        let data: Vec<u64> = (0..128 * 4).map(|i| i as u64 ^ 7).collect();
        tree.copy_from_slice(&data).unwrap();
        let registry = TreeRegistry::new();
        // SAFETY: fault-capable accessors only.
        let id = unsafe { registry.register_evictable(&tree) };
        let swap = SwapPool::anonymous(&a).unwrap();
        {
            let mut v = tree.view();
            let _ = v.get(128 * 2).unwrap(); // leaf 2 is the hottest
        }
        let mut c = Compactor::new(&a, &registry);
        assert_eq!(c.evict(usize::MAX, &swap), 4, "evict everything");
        assert_eq!(c.prefetch(1, &swap), 1);
        assert_eq!(c.stats().prefetched, 1);
        assert!(!tree.leaf_swapped(2), "prefetch must pick the hottest leaf");
        assert_eq!(c.restore_all(&swap), 3);
        assert_eq!(tree.to_vec(), data);
        registry.deregister(id);
    }

    fn tcfg() -> crate::pmem::FaultQueueConfig {
        crate::pmem::FaultQueueConfig {
            max_retries: 3,
            backoff_base: std::time::Duration::from_micros(50),
            backoff_cap: std::time::Duration::from_micros(400),
            ..Default::default()
        }
    }

    #[test]
    fn tenant_eviction_splits_budget_by_share_and_uncaps_pressure() {
        use crate::pmem::tenant::{TenantConfig, TenantRegistry as Tenants};
        use crate::pmem::FaultQueue;
        let a = BlockAllocator::new(1024, 64).unwrap();
        let tenants = Tenants::new();
        let t1 = tenants.admit(TenantConfig {
            soft_quota: 100,
            hard_quota: 200,
            share: 3,
        });
        let t2 = tenants.admit(TenantConfig::new(100, 100));
        // Seed residency so eviction credits have something to credit
        // (real flows charge through a QuotaAlloc; fault_charged is the
        // unchecked path). Both tenants start healthy.
        for _ in 0..5 {
            tenants.fault_charged(t1.id());
        }
        for _ in 0..2 {
            tenants.fault_charged(t2.id());
        }
        assert!(!t1.pressured() && !t2.pressured());
        let mut tree1: TreeArray<u64> = TreeArray::new(&a, 128 * 4).unwrap();
        let mut tree2: TreeArray<u64> = TreeArray::new(&a, 128 * 4).unwrap();
        let d1: Vec<u64> = (0..128 * 4).map(|i| i as u64 | 1).collect();
        let d2: Vec<u64> = (0..128 * 4).map(|i| (i as u64) << 1).collect();
        tree1.copy_from_slice(&d1).unwrap();
        tree2.copy_from_slice(&d2).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let q = FaultQueue::with_tenants(&swap, tcfg(), &tenants);
        let registry = TreeRegistry::new();
        // SAFETY: no accessors touch evicted leaves in this test.
        let id1 = unsafe { registry.register_evictable_for_tenant(&tree1, t1.id()) };
        let id2 = unsafe { registry.register_evictable_for_tenant(&tree2, t2.id()) };
        let mut c = Compactor::new(&a, &registry);
        // Phase 1 — no pressure: budget 4 at shares 3:1 means t1 may
        // take 3 and t2 may take 1; no tenant absorbs the whole pass.
        assert_eq!(c.evict_tenants(4, &q, &tenants), 4);
        let victims = c.take_victims();
        let n1 = victims.iter().filter(|(id, _)| *id == id1).count();
        let n2 = victims.iter().filter(|(id, _)| *id == id2).count();
        assert_eq!((n1, n2), (3, 1), "share split violated: {victims:?}");
        assert_eq!(t1.used(), 2, "evictions must credit the tenant");
        assert_eq!(t2.used(), 1);
        assert_eq!(t1.snapshot().evictions, 3);
        // Bring everything home; nobody is pressured so the tick-mode
        // restore does it all and recharges residency.
        assert_eq!(c.restore_tenants(usize::MAX, &q, &tenants), 4);
        assert_eq!((t1.used(), t2.used()), (5, 2));
        // Phase 2 — t1 blows through its soft quota. Pressure exempts
        // it from the share cap: paying down its own overrun is the
        // point, and every leaf it gives up spares a healthy neighbour.
        for _ in 0..145 {
            tenants.fault_charged(t1.id());
        }
        assert!(t1.pressured() && !t2.pressured());
        assert_eq!(c.evict_tenants(4, &q, &tenants), 4);
        let victims = c.take_victims();
        assert!(
            victims.iter().all(|(id, _)| *id == id1),
            "pressured tenant must absorb the pass uncapped: {victims:?}"
        );
        assert_eq!(t1.used(), 146);
        assert!(t1.pressured(), "still over soft quota after the pass");
        // Tick-mode restore skips the pressured tenant: its leaves stay
        // parked (that IS the backpressure), and t2 has nothing parked.
        assert_eq!(c.restore_tenants(usize::MAX, &q, &tenants), 0);
        assert_eq!(registry.swapped_out_for(t1.id()), 4);
        assert_eq!(registry.swapped_out_for(t2.id()), 0);
        // Shutdown drain brings everything home, pressured or not.
        assert_eq!(c.restore_all_tenants(&q, &tenants), 4);
        assert_eq!(registry.swapped_out(), 0);
        assert_eq!(tree1.to_vec(), d1);
        assert_eq!(tree2.to_vec(), d2);
        registry.deregister(id1);
        registry.deregister(id2);
    }

    #[test]
    fn tenant_restore_contains_a_dead_backing_and_probes_recovery() {
        use crate::pmem::tenant::{TenantConfig, TenantRegistry as Tenants};
        use crate::pmem::FaultQueue;
        use crate::testutil::fault::FailingBacking;
        let a = BlockAllocator::new(1024, 64).unwrap();
        let tenants = Tenants::new();
        let t1 = tenants.admit(TenantConfig::new(100, 100));
        let t2 = tenants.admit(TenantConfig::new(100, 100));
        let swap1 = SwapPool::anonymous(&a).unwrap();
        let (fb, ctl) = FailingBacking::new();
        let swap2 = SwapPool::with_backing(&a, fb);
        let q = FaultQueue::with_tenants(&swap1, tcfg(), &tenants);
        q.route_tenant(t2.id(), &swap2);
        let mut tree1: TreeArray<u64> = TreeArray::new(&a, 128 * 4).unwrap();
        let mut tree2: TreeArray<u64> = TreeArray::new(&a, 128 * 4).unwrap();
        let d1: Vec<u64> = (0..128 * 4).map(|i| i as u64 ^ 0xA5).collect();
        let d2: Vec<u64> = (0..128 * 4).map(|i| i as u64 ^ 0x5A).collect();
        tree1.copy_from_slice(&d1).unwrap();
        tree2.copy_from_slice(&d2).unwrap();
        let registry = TreeRegistry::new();
        // SAFETY: no accessors touch evicted leaves in this test.
        let id1 = unsafe { registry.register_evictable_for_tenant(&tree1, t1.id()) };
        let id2 = unsafe { registry.register_evictable_for_tenant(&tree2, t2.id()) };
        let mut c = Compactor::new(&a, &registry);
        assert_eq!(c.evict_tenants(usize::MAX, &q, &tenants), 8, "both trees park");
        // t2's backing dies. The tick restore must bring t1 fully home,
        // burn exactly one retry burst on t2, and contain the failure.
        ctl.fail_always();
        assert_eq!(c.restore_tenants(usize::MAX, &q, &tenants), 4);
        assert!(q.degraded_for(t2.id()) && !q.degraded_for(t1.id()));
        assert_eq!(registry.swapped_out_for(t1.id()), 0);
        assert_eq!(registry.swapped_out_for(t2.id()), 4);
        // While degraded, tick restores skip t2 entirely: no wasted I/O.
        let ops_before = ctl.ops();
        assert_eq!(c.restore_tenants(usize::MAX, &q, &tenants), 0);
        assert_eq!(ctl.ops(), ops_before, "degraded tenant must not be re-probed per tick");
        // The backing recovers: the shutdown drain's probe notices and
        // restores everything.
        ctl.disarm();
        assert_eq!(c.restore_all_tenants(&q, &tenants), 4);
        assert!(!q.degraded_for(t2.id()), "success clears the tenant's flag");
        assert_eq!(registry.swapped_out(), 0);
        assert_eq!(tree1.to_vec(), d1);
        assert_eq!(tree2.to_vec(), d2);
        registry.deregister(id1);
        registry.deregister(id2);
        drop(registry);
        a.epoch().synchronize(&a);
        drop(tree1);
        drop(tree2);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn non_evictable_trees_are_never_evicted() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let tree: TreeArray<u64> = TreeArray::new(&a, 128 * 4).unwrap();
        let registry = TreeRegistry::new();
        // SAFETY: no accessors during the call below.
        let id = unsafe { registry.register(&tree) };
        let swap = SwapPool::anonymous(&a).unwrap();
        let mut c = Compactor::new(&a, &registry);
        assert_eq!(c.evict(8, &swap), 0, "compaction-only registration");
        assert_eq!(registry.swapped_out(), 0);
        registry.deregister(id);
    }
}
