//! Fragmentation telemetry over a [`BlockAlloc`] pool.
//!
//! With fixed-size blocks there is no *allocation-failure* fragmentation
//! (§3: every free block satisfies every request), but **placement**
//! fragmentation is real: when live blocks are sprinkled across the pool,
//! free space is shredded into short runs — batched allocations lose
//! locality, shard bitmap scans lengthen, and the LIFO warm-reuse story
//! degrades. The daemon's telemetry quantifies exactly that, from one
//! cheap [`BlockAlloc::live_snapshot`] per tick (atomic word loads — the
//! pool is never stopped):
//!
//! * **free-run histogram** — maximal runs of free blocks, bucketed by
//!   power-of-two length; many short runs = shredded space.
//! * **fragmentation score** — `1 - longest_free_run / free_blocks`
//!   (0 = all free space contiguous, → 1 = maximally shredded), the
//!   number compaction is judged by. Defined as 0 for a full pool.
//! * **per-span occupancy and scores** — the same metrics inside each
//!   [`BlockAlloc::shard_spans`] range, feeding imbalance and
//!   span-local-compaction triggers. The spans are allocator-defined
//!   placement units: lock shards for the sharded allocator, 512-block
//!   subtrees for the two-level allocator — under the latter, these
//!   metrics (and the Rebalance/CompactShard actions they trigger) are
//!   subtree-granular.
//! * **limbo depth / reclaim latency** — the pool's [`EpochStats`],
//!   i.e. how much memory deferred reclamation is currently holding
//!   hostage and how long reclaims take in epochs.
//! * **free→realloc recency** (`reuse_rate`) — of the blocks free at
//!   the previous sample, the fraction allocated again by this one: how
//!   hot the free pool is, the §3 warm-reuse signal.

use crate::pmem::{BlockAlloc, EpochStats};
use crate::telemetry::metrics::MetricSource;

/// Free-run histogram buckets: run lengths `1, 2-3, 4-7, …, ≥128`.
pub const RUN_HIST_BUCKETS: usize = 8;

/// One telemetry sample. Produced by [`FragSampler::sample`].
#[derive(Clone, Debug, Default)]
pub struct FragSnapshot {
    /// Pool capacity in blocks.
    pub capacity: usize,
    /// Blocks currently allocated (incl. limbo blocks, which are
    /// allocated by definition).
    pub live: usize,
    /// Blocks currently free.
    pub free: usize,
    /// Maximal runs of consecutive free blocks.
    pub free_runs: usize,
    /// Longest run of consecutive free blocks.
    pub longest_free_run: usize,
    /// Free-run histogram: bucket `b` counts runs of length in
    /// `[2^b, 2^(b+1))`, last bucket open-ended.
    pub run_hist: [usize; RUN_HIST_BUCKETS],
    /// Pool-wide fragmentation score in `[0, 1]`.
    pub score: f64,
    /// The shard block-id spans the per-shard metrics were computed
    /// over ([`BlockAlloc::shard_spans`]) — carried here so the daemon
    /// doesn't recompute them every tick.
    pub shard_spans: Vec<(usize, usize)>,
    /// Live blocks per shard span.
    pub shard_live: Vec<usize>,
    /// Blocks per shard span.
    pub shard_blocks: Vec<usize>,
    /// Shard-local fragmentation scores.
    pub shard_scores: Vec<f64>,
    /// Occupancy spread across shards: max − min live fraction.
    pub imbalance: f64,
    /// Of blocks free at the previous sample, the fraction allocated
    /// now (0 on the first sample).
    pub reuse_rate: f64,
    /// The pool's epoch counters (limbo depth, reclaim latency).
    pub epoch: EpochStats,
}

impl FragSnapshot {
    /// Free fraction of the pool.
    pub fn free_ratio(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            self.free as f64 / self.capacity as f64
        }
    }

    /// Live fraction of shard `s` (0 for an empty span).
    pub fn occupancy(&self, s: usize) -> f64 {
        match (self.shard_live.get(s), self.shard_blocks.get(s)) {
            (Some(&l), Some(&b)) if b > 0 => l as f64 / b as f64,
            _ => 0.0,
        }
    }
}

impl MetricSource for FragSnapshot {
    fn metric_prefix(&self) -> &'static str {
        "frag"
    }

    fn emit(&self, out: &mut dyn FnMut(&str, f64)) {
        out("capacity", self.capacity as f64);
        out("live", self.live as f64);
        out("free", self.free as f64);
        out("free_runs", self.free_runs as f64);
        out("longest_free_run", self.longest_free_run as f64);
        out("score", self.score);
        out("shards", self.shard_spans.len() as f64);
        out("imbalance", self.imbalance);
        out("reuse_rate", self.reuse_rate);
        // The pool's epoch counters ride along under their own prefix
        // so one `record(&snap)` carries both surfaces.
        self.epoch.emit(&mut |name, value| {
            let mut prefixed = String::with_capacity(6 + name.len());
            prefixed.push_str("epoch.");
            prefixed.push_str(name);
            out(&prefixed, value);
        });
    }
}

/// `1 - longest/free`: 0 when all free space is one run (or none free).
fn run_score(longest: usize, free: usize) -> f64 {
    if free == 0 {
        0.0
    } else {
        1.0 - longest as f64 / free as f64
    }
}

/// Scan free runs of `bits` (bit set = live) over block ids `[lo, hi)`.
/// Returns `(free, runs, longest, histogram)`.
type RunScan = (usize, usize, usize, [usize; RUN_HIST_BUCKETS]);

fn scan_runs(bits: &[u64], lo: usize, hi: usize) -> RunScan {
    let mut free = 0usize;
    let mut runs = 0usize;
    let mut longest = 0usize;
    let mut hist = [0usize; RUN_HIST_BUCKETS];
    let mut cur = 0usize;
    let mut close = |cur: usize| {
        if cur > 0 {
            runs += 1;
            longest = longest.max(cur);
            let bucket = (usize::BITS - 1 - cur.leading_zeros()) as usize;
            hist[bucket.min(RUN_HIST_BUCKETS - 1)] += 1;
        }
    };
    for i in lo..hi {
        let is_live = (bits[i / 64] >> (i % 64)) & 1 == 1;
        if is_live {
            close(cur);
            cur = 0;
        } else {
            free += 1;
            cur += 1;
        }
    }
    close(cur);
    (free, runs, longest, hist)
}

/// Reusable sampler: owns the snapshot buffers (no per-tick allocation
/// after the first) and the previous bitmap for the reuse-rate signal.
#[derive(Default)]
pub struct FragSampler {
    cur: Vec<u64>,
    prev: Vec<u64>,
}

impl FragSampler {
    /// A sampler with empty history (first sample reports `reuse_rate` 0).
    pub fn new() -> Self {
        FragSampler::default()
    }

    /// Take one telemetry sample of `a`. Cheap and concurrent-safe: one
    /// bitmap snapshot plus an O(capacity) bit scan on this thread.
    pub fn sample<A: BlockAlloc + ?Sized>(&mut self, a: &A) -> FragSnapshot {
        std::mem::swap(&mut self.cur, &mut self.prev);
        a.live_snapshot(&mut self.cur);
        let capacity = a.capacity();
        let (free, free_runs, longest_free_run, run_hist) = scan_runs(&self.cur, 0, capacity);
        let spans = a.shard_spans();
        let mut shard_live = Vec::with_capacity(spans.len());
        let mut shard_blocks = Vec::with_capacity(spans.len());
        let mut shard_scores = Vec::with_capacity(spans.len());
        let mut occ_min = f64::INFINITY;
        let mut occ_max = 0.0f64;
        for &(lo, hi) in &spans {
            let (sfree, _, slongest, _) = scan_runs(&self.cur, lo, hi.min(capacity));
            let blocks = hi.min(capacity).saturating_sub(lo);
            let live = blocks - sfree;
            shard_live.push(live);
            shard_blocks.push(blocks);
            shard_scores.push(run_score(slongest, sfree));
            if blocks > 0 {
                let occ = live as f64 / blocks as f64;
                occ_min = occ_min.min(occ);
                occ_max = occ_max.max(occ);
            }
        }
        let imbalance = if occ_min.is_finite() { occ_max - occ_min } else { 0.0 };
        // Reuse: blocks free last sample, live now.
        let mut reuse_rate = 0.0;
        if self.prev.len() == self.cur.len() && !self.prev.is_empty() {
            let mut was_free = 0u64;
            let mut reused = 0u64;
            for (p, c) in self.prev.iter().zip(&self.cur) {
                // Tail bits past capacity are zero in both snapshots and
                // only contribute to `was_free` via !p — mask them out by
                // only counting bits below capacity per word.
                was_free += (!p).count_ones() as u64;
                reused += (c & !p).count_ones() as u64;
            }
            // Correct the tail over-count of `was_free` (bits past the
            // capacity read as free in !p but can never be reused).
            let tail = self.prev.len() * 64 - capacity;
            was_free = was_free.saturating_sub(tail as u64);
            if was_free > 0 {
                reuse_rate = reused as f64 / was_free as f64;
            }
        }
        FragSnapshot {
            capacity,
            live: capacity - free,
            free,
            free_runs,
            longest_free_run,
            run_hist,
            score: run_score(longest_free_run, free),
            shard_spans: spans,
            shard_live,
            shard_blocks,
            shard_scores,
            imbalance,
            reuse_rate,
            epoch: a.epoch().stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{BlockAlloc, BlockAllocator, ShardedAllocator};

    #[test]
    fn empty_and_full_pools_score_zero() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let mut s = FragSampler::new();
        let snap = s.sample(&a);
        assert_eq!(snap.free, 64);
        assert_eq!(snap.free_runs, 1);
        assert_eq!(snap.longest_free_run, 64);
        assert_eq!(snap.score, 0.0, "one contiguous free run is unfragmented");
        let all = a.alloc_many(64).unwrap();
        let snap = s.sample(&a);
        assert_eq!(snap.free, 0);
        assert_eq!(snap.score, 0.0, "a full pool has nothing to fragment");
        assert_eq!(snap.live, 64);
        for b in all {
            a.free(b).unwrap();
        }
    }

    #[test]
    fn strided_live_blocks_score_high() {
        let a = BlockAllocator::new(1024, 128).unwrap();
        let all = a.alloc_many(128).unwrap();
        // Keep every 4th block live, free the rest: free runs of 3.
        for (i, b) in all.iter().enumerate() {
            if i % 4 != 0 {
                a.free(*b).unwrap();
            }
        }
        let snap = FragSampler::new().sample(&a);
        assert_eq!(snap.live, 32);
        assert_eq!(snap.free, 96);
        assert_eq!(snap.longest_free_run, 3);
        assert_eq!(snap.free_runs, 32);
        assert!(snap.score > 0.9, "perforated pool must score high: {}", snap.score);
        // Histogram: 32 runs of length 3 land in bucket 1 (2-3).
        assert_eq!(snap.run_hist[1], 32);
        for b in all.iter().step_by(4) {
            a.free(*b).unwrap();
        }
    }

    #[test]
    fn shard_metrics_and_imbalance() {
        // 2 shards over 128 blocks: fill shard 0 completely, leave
        // shard 1 empty -> imbalance 1.0, both shard scores 0.
        let a = ShardedAllocator::with_shards(1024, 128, 2).unwrap();
        let spans = crate::pmem::BlockAlloc::shard_spans(&a);
        assert_eq!(spans, vec![(0, 64), (64, 128)]);
        let mut held = Vec::new();
        for _ in 0..64 {
            held.push(a.alloc_in_span(0, 64).unwrap());
        }
        let snap = FragSampler::new().sample(&a);
        assert_eq!(snap.shard_live, vec![64, 0]);
        assert_eq!(snap.shard_blocks, vec![64, 64]);
        assert!((snap.imbalance - 1.0).abs() < 1e-9);
        assert_eq!(snap.shard_scores, vec![0.0, 0.0]);
        for b in held {
            a.free(b).unwrap();
        }
    }

    #[test]
    fn reuse_rate_tracks_free_to_realloc() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let mut s = FragSampler::new();
        let snap = s.sample(&a);
        assert_eq!(snap.reuse_rate, 0.0, "no history on the first sample");
        // All 64 free at the last sample; allocate 16 -> reuse 16/64.
        let held = a.alloc_many(16).unwrap();
        let snap = s.sample(&a);
        assert!((snap.reuse_rate - 0.25).abs() < 1e-9, "{}", snap.reuse_rate);
        // Nothing changed since: reuse drops to 0 of the remaining 48.
        let snap = s.sample(&a);
        assert_eq!(snap.reuse_rate, 0.0);
        for b in held {
            a.free(b).unwrap();
        }
    }

    #[test]
    fn limbo_depth_flows_through() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let b = a.alloc().unwrap();
        let e = a.epoch().bump();
        a.epoch().retire(b, e);
        let snap = FragSampler::new().sample(&a);
        assert_eq!(snap.epoch.limbo, 1);
        assert_eq!(snap.live, 1, "limbo blocks are still allocated");
        a.epoch().synchronize(&a);
    }
}
