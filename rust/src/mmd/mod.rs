//! `mmd` — the background memory-management daemon.
//!
//! Without virtual memory there is no contiguous-segment illusion to
//! hide fragmentation behind: the paper (§3) argues software must take
//! over the OS's physical-memory duties, and this module is that duty
//! cycle made explicit — a dedicated service with its own policy loop
//! (the Cichlid shape), running in userspace next to the data
//! structures it serves (the user-mode page-management argument). PR 3
//! built the *mechanism* — `migrate_leaf_concurrent` + [`ArenaEpoch`]
//! limbo reclamation — and this subsystem is the thing that *drives*
//! it: fragmentation telemetry, concurrent compaction, and
//! pressure-driven eviction over live trees.
//!
//! # Pieces
//!
//! * [`stats`] — [`FragSampler`]/[`FragSnapshot`]: free-run histogram,
//!   fragmentation score, per-span occupancy, limbo depth, reclaim
//!   latency, free→realloc recency. One
//!   [`BlockAlloc::live_snapshot`] per tick; allocation never stops.
//!   Spans come from [`BlockAlloc::shard_spans`] and are
//!   allocator-defined: shards for the sharded allocator, 512-block
//!   subtrees for the two-level allocator — so with the latter all
//!   telemetry is subtree-granular.
//! * [`policy`] — [`Policy`]/[`ThresholdPolicy`]: maps a snapshot
//!   (plus fault/contention telemetry in [`PolicyCtx`]) to one
//!   [`Action`] (compact pool/span, rebalance spans, evict, restore,
//!   prefetch, idle). Pluggable; the daemon is generic over it.
//! * [`compactor`] — [`Compactor`]: walks the
//!   [`TreeRegistry`](crate::trees::TreeRegistry) and executes actions
//!   through the forwarding machinery
//!   ([`TreeArray::migrate_leaf_concurrent_to`],
//!   [`SwapPool::evict_deferred`], adopt-on-restore), with
//!   [`BlockAlloc::alloc_in_span`] supplying *placement-directed*
//!   destinations — which is what makes relocation reduce
//!   fragmentation instead of reshuffling it.
//! * [`daemon`] — [`MmdHandle`]: lifecycle (spawn/pause/quiesce/
//!   shutdown), the control channel, pacing ([`MmdConfig`]), and the
//!   [`MmdReport`] of actions taken. `spawn_with_tenants` runs the
//!   same loop in multi-tenant mode: quota-pressure eviction, per-share
//!   budget splits, per-tenant degraded containment, and per-tenant
//!   report rows (see [`crate::pmem::TenantRegistry`]).
//!
//! # What runs where
//!
//! Everything heavy runs **on the daemon thread**: telemetry scans,
//! policy decisions, leaf copies, swap I/O, and epoch reclamation
//! (`try_reclaim` each tick, full drain at shutdown). Workload threads
//! pay only what PR 3 already charged them **inline**: an epoch pin per
//! access batch, and a TLB flush when the epoch moved.
//!
//! # The reader-throttling contract
//!
//! The daemon never blocks readers — every pointer patch is an atomic
//! store and displaced/evicted blocks are retired into epoch limbo, so
//! a registered [`TreeView`](crate::trees::TreeView) mid-read keeps
//! dereferencing stable bytes and revalidates on its next pin. The cost
//! it *does* impose is cache pressure: each relocation bumps the arena
//! epoch, i.e. one wholesale TLB flush per registered view.
//! [`MmdConfig::tokens_per_tick`] × tick rate bounds that flush rate;
//! the `ablation_compaction` bench holds the daemon to ≥ 0.9× reader
//! throughput under adversarial churn. Reclamation waits, in turn, land
//! on the daemon (QSBR: readers pay two uncontended atomics, the
//! reclaimer waits), and the waits are bounded — a registered reader
//! that never quiesces stalls limbo, not the daemon loop.
//!
//! # Safety obligations
//!
//! Registration is the unsafe boundary: `TreeRegistry::register`
//! (readers only through epoch-registered views, no raw slices, no
//! writes, daemon is the sole migrator) and `register_evictable`
//! (additionally: every accessor is **fault-capable** — a
//! [`TreeView`](crate::trees::TreeView)/`TreeWriter` whose fault hook
//! brings an evicted leaf back through the tree's installed
//! [`LeafFaulter`](crate::pmem::LeafFaulter) — so eviction no longer
//! demands "no accessors at all", only accessors that can take a
//! software page fault). See [`crate::trees::TreeRegistry`] for the
//! full contracts; everything downstream in this module inherits them
//! through those two calls.
//!
//! [`ArenaEpoch`]: crate::pmem::ArenaEpoch
//! [`BlockAlloc::live_snapshot`]: crate::pmem::BlockAlloc::live_snapshot
//! [`BlockAlloc::shard_spans`]: crate::pmem::BlockAlloc::shard_spans
//! [`BlockAlloc::alloc_in_span`]: crate::pmem::BlockAlloc::alloc_in_span
//! [`TreeArray::migrate_leaf_concurrent_to`]: crate::trees::TreeArray::migrate_leaf_concurrent_to
//! [`SwapPool::evict_deferred`]: crate::pmem::SwapPool::evict_deferred

pub mod compactor;
pub mod daemon;
pub mod policy;
pub mod stats;

pub use compactor::{CompactStats, Compactor};
pub use daemon::{ActionCounts, MmdConfig, MmdHandle, MmdReport, ACTION_LOG_CAP};
pub use policy::{Action, Policy, PolicyCtx, ThresholdPolicy};
pub use stats::{FragSampler, FragSnapshot};
