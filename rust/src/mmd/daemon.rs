//! Daemon lifecycle: a background thread that samples, decides, and
//! acts once per tick, controlled through a channel.
//!
//! ```text
//! spawn -> [tick: sample -> policy.decide -> compactor.<act> -> reclaim]*
//!       -> pause / resume / quiesce (control channel, any time)
//!       -> shutdown: restore evicted leaves, drain limbo, report
//! ```
//!
//! The handle is scoped ([`MmdHandle::spawn`] takes a
//! [`std::thread::Scope`]) so the daemon can serve allocator pools and
//! trees that live on the caller's stack — the same pattern the
//! concurrent experiments already use for reader threads. Dropping the
//! scope without calling [`MmdHandle::shutdown`] still terminates the
//! daemon (the control channel disconnects), but the report is lost and
//! evicted leaves are restored on the disconnect path all the same.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::{Scope, ScopedJoinHandle};
use std::time::Duration;

use crate::mmd::compactor::{CompactStats, Compactor};
use crate::mmd::policy::{Action, Policy, PolicyCtx};
use crate::mmd::stats::FragSampler;
use crate::pmem::{BlockAlloc, SwapPool};
use crate::trees::TreeRegistry;

/// Daemon pacing knobs.
#[derive(Clone, Copy, Debug)]
pub struct MmdConfig {
    /// Tick cadence: how often the daemon samples and acts.
    pub interval: Duration,
    /// Token budget: max leaves moved/evicted/restored per tick. This
    /// is the reader-throttling contract's lever — every relocation
    /// costs each registered view one TLB flush (arena epoch bump), so
    /// the budget bounds the flush rate the daemon can impose.
    pub tokens_per_tick: usize,
    /// Record the fragmentation score into [`MmdReport::score_trace`]
    /// every this many ticks (0 disables the trace).
    pub trace_every: u64,
    /// Start in the paused state (act only after [`MmdHandle::resume`]).
    pub start_paused: bool,
}

impl Default for MmdConfig {
    fn default() -> Self {
        MmdConfig {
            interval: Duration::from_micros(500),
            tokens_per_tick: 16,
            trace_every: 64,
            start_paused: false,
        }
    }
}

/// How many ticks chose each action.
#[derive(Clone, Copy, Debug, Default)]
pub struct ActionCounts {
    /// Ticks with nothing to do.
    pub idle: u64,
    /// Pool-wide compaction ticks.
    pub compact_pool: u64,
    /// Shard-local compaction ticks.
    pub compact_shard: u64,
    /// Rebalance ticks.
    pub rebalance: u64,
    /// Eviction ticks.
    pub evict: u64,
    /// Restore ticks.
    pub restore: u64,
}

/// What the daemon did over its lifetime (returned by
/// [`MmdHandle::shutdown`]).
#[derive(Clone, Debug, Default)]
pub struct MmdReport {
    /// Ticks executed.
    pub ticks: u64,
    /// Per-action tick counts.
    pub actions: ActionCounts,
    /// Compactor work counters (leaves moved, bytes, evictions, …).
    pub compact: CompactStats,
    /// Highest limbo depth observed at a tick boundary.
    pub limbo_high_water: usize,
    /// Pool fragmentation score at the first tick.
    pub initial_score: f64,
    /// Pool fragmentation score after shutdown drained limbo.
    pub final_score: f64,
    /// Blocks the pool's epoch reclaimed over the daemon's lifetime
    /// window (cumulative pool counter at shutdown).
    pub reclaimed: u64,
    /// Fragmentation score sampled every `trace_every` ticks.
    pub score_trace: Vec<f64>,
    /// Blocks still in limbo at shutdown (non-zero only if a registered
    /// reader never quiesced).
    pub limbo_remaining: usize,
    /// The swap backing could not be created when eviction first fired:
    /// every Evict/Restore tick after that was a forced no-op. (False
    /// when eviction never fired — the backing is created lazily.)
    pub swap_unavailable: bool,
}

impl MmdReport {
    /// One-line summary for experiment table notes.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "mmd: {} ticks, moved {} leaves ({} KB), evicted {} / restored {}, \
             score {:.3} -> {:.3}, limbo high-water {}, actions \
             idle={} pool={} shard={} rebal={} evict={} restore={}",
            self.ticks,
            self.compact.leaves_moved,
            self.compact.bytes_compacted / 1024,
            self.compact.evictions,
            self.compact.restores,
            self.initial_score,
            self.final_score,
            self.limbo_high_water,
            self.actions.idle,
            self.actions.compact_pool,
            self.actions.compact_shard,
            self.actions.rebalance,
            self.actions.evict,
            self.actions.restore,
        );
        if self.swap_unavailable {
            s.push_str(" [SWAP UNAVAILABLE: eviction was a no-op]");
        }
        s
    }
}

enum Ctl {
    Pause,
    Resume,
    Quiesce(Sender<usize>),
    Shutdown,
}

/// Handle to a running daemon. See [`MmdHandle::spawn`].
pub struct MmdHandle<'scope> {
    tx: Sender<Ctl>,
    join: ScopedJoinHandle<'scope, MmdReport>,
}

impl<'scope> MmdHandle<'scope> {
    /// Spawn the daemon on `scope` over one allocator pool and one
    /// registry. The policy decides, [`MmdConfig`] paces; everything
    /// heavy (sampling, relocation, swap I/O, reclamation) runs on the
    /// daemon thread — the only inline cost imposed on workload threads
    /// is the usual epoch-pin revalidation they already pay.
    pub fn spawn<'env, A, P>(
        scope: &'scope Scope<'scope, 'env>,
        alloc: &'env A,
        registry: &'env TreeRegistry<'env>,
        policy: P,
        cfg: MmdConfig,
    ) -> MmdHandle<'scope>
    where
        A: BlockAlloc,
        P: Policy + 'env,
    {
        let (tx, rx) = channel();
        let join = scope.spawn(move || daemon_run(alloc, registry, policy, cfg, rx));
        MmdHandle { tx, join }
    }

    /// Stop acting (ticks become no-ops) until [`MmdHandle::resume`].
    pub fn pause(&self) {
        let _ = self.tx.send(Ctl::Pause);
    }

    /// Resume after [`MmdHandle::pause`].
    pub fn resume(&self) {
        let _ = self.tx.send(Ctl::Resume);
    }

    /// Ask the daemon to drain the pool's limbo list and wait for the
    /// answer. Returns the blocks still in limbo afterwards (non-zero
    /// when a registered reader has not quiesced — the drain is bounded,
    /// never a hang).
    pub fn quiesce(&self) -> usize {
        let (ack_tx, ack_rx) = channel();
        if self.tx.send(Ctl::Quiesce(ack_tx)).is_err() {
            return 0;
        }
        ack_rx.recv().unwrap_or(0)
    }

    /// Stop the daemon and collect its report. Shutdown restores every
    /// evicted leaf (so registered trees are whole again) and drains
    /// limbo before returning.
    pub fn shutdown(self) -> MmdReport {
        let _ = self.tx.send(Ctl::Shutdown);
        self.join.join().expect("mmd daemon panicked")
    }
}

/// Bounded limbo drain: with no registered readers one `try_reclaim`
/// empties the list (every retired block is immediately past the
/// OFFLINE minimum); with stale readers we retry a bounded number of
/// times rather than hang the daemon on an idle reader.
fn drain_limbo<A: BlockAlloc>(alloc: &A) -> usize {
    let epoch = alloc.epoch();
    for _ in 0..4096 {
        if epoch.limbo_len() == 0 {
            break;
        }
        epoch.try_reclaim(alloc);
        if epoch.stats().readers > 0 {
            std::thread::yield_now();
        }
    }
    epoch.limbo_len()
}

fn daemon_run<'e, A, P>(
    alloc: &'e A,
    registry: &'e TreeRegistry<'e>,
    mut policy: P,
    cfg: MmdConfig,
    rx: Receiver<Ctl>,
) -> MmdReport
where
    A: BlockAlloc,
    P: Policy,
{
    // Swap backing for the eviction path, created lazily on the first
    // Evict tick (a compaction-only daemon never touches the
    // filesystem). If the environment cannot give us a temp file,
    // `swap_unavailable` is reported and the policy stops being fed
    // evictable capacity, so pressure falls through to compaction
    // instead of demanding no-op evictions forever.
    let mut swap: Option<SwapPool<'e, A>> = None;
    let mut swap_failed = false;
    let mut compactor = Compactor::new(alloc, registry);
    let mut sampler = FragSampler::new();
    // Initial score sampled at spawn (not the first unpaused tick): a
    // paused-then-shut-down daemon must still report where the pool
    // started.
    let mut report = MmdReport {
        initial_score: sampler.sample(alloc).score,
        ..MmdReport::default()
    };
    let mut paused = cfg.start_paused;
    loop {
        match rx.recv_timeout(cfg.interval) {
            Ok(Ctl::Pause) => {
                paused = true;
                continue;
            }
            Ok(Ctl::Resume) => {
                paused = false;
                continue;
            }
            Ok(Ctl::Quiesce(ack)) => {
                let _ = ack.send(drain_limbo(alloc));
                continue;
            }
            Ok(Ctl::Shutdown) | Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => {}
        }
        if paused {
            continue;
        }
        let snap = sampler.sample(alloc);
        report.limbo_high_water = report.limbo_high_water.max(snap.epoch.limbo);
        if cfg.trace_every > 0 && report.ticks % cfg.trace_every == 0 {
            report.score_trace.push(snap.score);
        }
        let (swapped_out, evictable_resident) = registry.eviction_counts();
        let ctx = PolicyCtx {
            swapped_out,
            evictable_resident: if swap_failed { 0 } else { evictable_resident },
        };
        match policy.decide(&snap, &ctx) {
            Action::Idle => report.actions.idle += 1,
            Action::CompactPool => {
                compactor.compact_span(cfg.tokens_per_tick, 0, alloc.capacity());
                report.actions.compact_pool += 1;
            }
            Action::CompactShard(s) => {
                let (lo, hi) = snap
                    .shard_spans
                    .get(s)
                    .copied()
                    .unwrap_or((0, alloc.capacity()));
                compactor.compact_span(cfg.tokens_per_tick, lo, hi);
                report.actions.compact_shard += 1;
            }
            Action::Rebalance { from, to } => {
                let spans = &snap.shard_spans;
                if let (Some(&f), Some(&t)) = (spans.get(from), spans.get(to)) {
                    compactor.rebalance(cfg.tokens_per_tick, f, t);
                }
                report.actions.rebalance += 1;
            }
            Action::Evict { leaves } => {
                if swap.is_none() && !swap_failed {
                    match SwapPool::anonymous(alloc) {
                        Ok(s) => swap = Some(s),
                        Err(_) => {
                            swap_failed = true;
                            report.swap_unavailable = true;
                        }
                    }
                }
                if let Some(sw) = swap.as_ref() {
                    compactor.evict(leaves.min(cfg.tokens_per_tick), sw);
                }
                report.actions.evict += 1;
            }
            Action::Restore { leaves } => {
                if let Some(sw) = swap.as_ref() {
                    compactor.restore(leaves.min(cfg.tokens_per_tick), sw);
                }
                report.actions.restore += 1;
            }
        }
        alloc.epoch().try_reclaim(alloc);
        report.ticks += 1;
    }
    // Shutdown: make registered trees whole (fault every evicted leaf
    // back — the satellite teardown contract), then drain limbo.
    if let Some(sw) = swap.as_ref() {
        compactor.restore_all(sw);
    }
    report.limbo_remaining = drain_limbo(alloc);
    report.compact = compactor.stats();
    let snap = sampler.sample(alloc);
    report.final_score = snap.score;
    report.reclaimed = snap.epoch.reclaimed;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mmd::policy::ThresholdPolicy;
    use crate::pmem::{BlockAllocator, ShardedAllocator};
    use crate::testutil::fragmented_tree;
    use crate::trees::TreeArray;
    use std::time::Instant;

    fn cfg_fast() -> MmdConfig {
        MmdConfig {
            interval: Duration::from_micros(100),
            tokens_per_tick: 16,
            trace_every: 8,
            ..MmdConfig::default()
        }
    }

    /// Poll until `done()` or a generous deadline — the assertions
    /// after the poll say what actually went wrong; the deadline only
    /// bounds how long a genuinely broken daemon can hang the test.
    fn wait_for(mut done: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !done() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn lifecycle_with_empty_registry() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let registry = TreeRegistry::new();
        let report = std::thread::scope(|s| {
            let d = MmdHandle::spawn(s, &a, &registry, ThresholdPolicy::default(), cfg_fast());
            d.pause();
            d.resume();
            assert_eq!(d.quiesce(), 0, "nothing in limbo");
            std::thread::sleep(Duration::from_millis(50));
            d.shutdown()
        });
        assert!(report.ticks > 0, "daemon must tick while idle");
        assert_eq!(report.actions.idle, report.ticks, "empty pool: all idle");
        assert_eq!(report.compact.leaves_moved, 0);
        assert_eq!(report.limbo_remaining, 0);
    }

    #[test]
    fn daemon_compacts_a_fragmented_pool() {
        let a = ShardedAllocator::with_shards(1024, 256, 2).unwrap();
        let (tree, data) = fragmented_tree(&a, 40, |i| i ^ 0xBEEF);
        let s0 = FragSampler::new().sample(&a).score;
        assert!(s0 > 0.5, "setup must fragment the pool: {s0}");
        let registry = TreeRegistry::new();
        // SAFETY: no accessors while the daemon owns relocation.
        let id = unsafe { registry.register(&tree) };
        let report = std::thread::scope(|s| {
            let d = MmdHandle::spawn(s, &a, &registry, ThresholdPolicy::default(), cfg_fast());
            // Converge (no fixed sleep: CI machines stall arbitrarily).
            // Target = the policy's idle threshold: below it the daemon
            // stops compacting, so a lower target would never be met.
            let target = ThresholdPolicy::default().score_hi;
            let mut poll = FragSampler::new();
            wait_for(|| poll.sample(&a).score <= target);
            d.shutdown()
        });
        assert!(report.compact.leaves_moved >= 30, "{}", report.summary());
        assert!(
            report.final_score * 2.0 <= report.initial_score,
            "daemon must at least halve the score: {}",
            report.summary()
        );
        assert!(report.actions.compact_pool > 0);
        assert!(!report.score_trace.is_empty(), "trace must record the trajectory");
        assert_eq!(report.limbo_remaining, 0);
        assert_eq!(tree.to_vec(), data);
        registry.deregister(id);
        drop(registry);
        drop(tree);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn daemon_evicts_under_pressure_and_restores_on_shutdown() {
        let a = BlockAllocator::new(1024, 32).unwrap();
        // Tree of 8 leaves + root, then scratch fills the pool to ~97%:
        // free ratio < 8% trips the eviction trigger.
        let mut tree: TreeArray<u64> = TreeArray::new(&a, 128 * 8).unwrap();
        let data: Vec<u64> = (0..128 * 8).map(|i| i as u64 | 1).collect();
        tree.copy_from_slice(&data).unwrap();
        let scratch = a.alloc_many(22).unwrap(); // 31/32 live
        let registry = TreeRegistry::new();
        // SAFETY: nothing touches the tree while registered.
        let id = unsafe { registry.register_evictable(&tree) };
        let report = std::thread::scope(|s| {
            let d = MmdHandle::spawn(s, &a, &registry, ThresholdPolicy::default(), cfg_fast());
            // Wait until pressure has demonstrably triggered eviction
            // (retired blocks prove evict_deferred ran), not a timer.
            wait_for(|| a.stats().retired > 0);
            d.shutdown()
        });
        assert!(report.actions.evict > 0, "pressure must trigger eviction: {}", report.summary());
        assert!(report.compact.evictions > 0);
        assert_eq!(
            report.compact.restores, report.compact.evictions,
            "shutdown must restore every evicted leaf: {}",
            report.summary()
        );
        assert_eq!(registry.swapped_out(), 0);
        assert_eq!(tree.to_vec(), data, "evict/restore corrupted the tree");
        registry.deregister(id);
        drop(registry);
        for b in scratch {
            a.free(b).unwrap();
        }
        a.epoch().synchronize(&a);
        drop(tree);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn paused_daemon_does_not_act() {
        let a = BlockAllocator::new(1024, 128).unwrap();
        // Fragment enough that an unpaused daemon would certainly act.
        let all = a.alloc_many(128).unwrap();
        for (i, b) in all.iter().enumerate() {
            if i % 4 == 0 {
                a.free(*b).unwrap();
            }
        }
        let tree: TreeArray<u64> = TreeArray::new(&a, 128 * 20).unwrap();
        let registry = TreeRegistry::new();
        // SAFETY: no accessors while registered.
        let id = unsafe { registry.register(&tree) };
        let cfg = MmdConfig {
            start_paused: true,
            ..cfg_fast()
        };
        let report = std::thread::scope(|s| {
            let d = MmdHandle::spawn(s, &a, &registry, ThresholdPolicy::default(), cfg);
            d.pause(); // idempotent; exercises the control channel
            std::thread::sleep(Duration::from_millis(10));
            d.shutdown()
        });
        assert_eq!(report.compact.leaves_moved, 0, "paused daemon must not move leaves");
        registry.deregister(id);
        drop(registry);
        drop(tree);
        for b in all.iter().filter(|b| a.is_live(**b)) {
            a.free(*b).unwrap();
        }
        assert_eq!(a.stats().allocated, 0);
    }
}
