//! Daemon lifecycle: a background thread that samples, decides, and
//! acts once per tick, controlled through a channel.
//!
//! ```text
//! spawn -> [tick: sample -> policy.decide -> compactor.<act> -> reclaim]*
//!       -> pause / resume / quiesce (control channel, any time)
//!       -> shutdown: restore evicted leaves, drain limbo, report
//! ```
//!
//! [`MmdHandle::spawn_with_swap`] runs the same loop over an
//! application-provided [`FaultQueue`]: the daemon evicts through the
//! queue's backing, prefetches/restores through its shedding gate, and
//! feeds the policy live fault telemetry (demand-miss deltas, queue
//! depth, the degraded flag) — the full software-page-fault loop with
//! accessors faulting on demand while the daemon manages residency.
//!
//! The handle is scoped ([`MmdHandle::spawn`] takes a
//! [`std::thread::Scope`]) so the daemon can serve allocator pools and
//! trees that live on the caller's stack — the same pattern the
//! concurrent experiments already use for reader threads. Dropping the
//! scope without calling [`MmdHandle::shutdown`] still terminates the
//! daemon (the control channel disconnects), but the report is lost and
//! evicted leaves are restored on the disconnect path all the same.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::{Scope, ScopedJoinHandle};
use std::time::Duration;

use crate::mmd::compactor::{CompactStats, Compactor};
use crate::mmd::policy::{Action, Policy, PolicyCtx};
use crate::mmd::stats::FragSampler;
use crate::pmem::faultq::{FaultQueue, FaultStats, SwapService};
use crate::pmem::tenant::{TenantRegistry, TenantSnapshot};
use crate::pmem::{BlockAlloc, SwapPool};
use crate::trees::TreeRegistry;

/// Daemon pacing knobs.
#[derive(Clone, Copy, Debug)]
pub struct MmdConfig {
    /// Tick cadence: how often the daemon samples and acts.
    pub interval: Duration,
    /// Token budget: max leaves moved/evicted/restored per tick. This
    /// is the reader-throttling contract's lever — every relocation
    /// costs each registered view one TLB flush (arena epoch bump), so
    /// the budget bounds the flush rate the daemon can impose.
    pub tokens_per_tick: usize,
    /// Record the fragmentation score into [`MmdReport::score_trace`]
    /// every this many ticks (0 disables the trace).
    pub trace_every: u64,
    /// Start in the paused state (act only after [`MmdHandle::resume`]).
    pub start_paused: bool,
}

impl Default for MmdConfig {
    fn default() -> Self {
        MmdConfig {
            interval: Duration::from_micros(500),
            tokens_per_tick: 16,
            trace_every: 64,
            start_paused: false,
        }
    }
}

/// How many ticks chose each action.
#[derive(Clone, Copy, Debug, Default)]
pub struct ActionCounts {
    /// Ticks with nothing to do.
    pub idle: u64,
    /// Pool-wide compaction ticks.
    pub compact_pool: u64,
    /// Shard-local compaction ticks.
    pub compact_shard: u64,
    /// Rebalance ticks.
    pub rebalance: u64,
    /// Eviction ticks.
    pub evict: u64,
    /// Restore ticks.
    pub restore: u64,
    /// Prefetch ticks.
    pub prefetch: u64,
}

/// What the daemon did over its lifetime (returned by
/// [`MmdHandle::shutdown`]).
#[derive(Clone, Debug, Default)]
pub struct MmdReport {
    /// Ticks executed.
    pub ticks: u64,
    /// Per-action tick counts.
    pub actions: ActionCounts,
    /// Compactor work counters (leaves moved, bytes, evictions, …).
    pub compact: CompactStats,
    /// Highest limbo depth observed at a tick boundary.
    pub limbo_high_water: usize,
    /// Pool fragmentation score at the first tick.
    pub initial_score: f64,
    /// Pool fragmentation score after shutdown drained limbo.
    pub final_score: f64,
    /// Blocks the pool's epoch reclaimed over the daemon's lifetime
    /// window (cumulative pool counter at shutdown).
    pub reclaimed: u64,
    /// Fragmentation score sampled every `trace_every` ticks.
    pub score_trace: Vec<f64>,
    /// Per-tick `(tick, action name)` rows in decision order (capped at
    /// [`ACTION_LOG_CAP`]) — the structured companion to
    /// [`ActionCounts`]: counts say *how often*, this says *when*. Tick
    /// numbers are 0-based and line up with the `score_trace` sampling
    /// index (`tick % trace_every == 0`).
    pub action_log: Vec<(u64, &'static str)>,
    /// Blocks still in limbo at shutdown (non-zero only if a registered
    /// reader never quiesced).
    pub limbo_remaining: usize,
    /// The swap backing could not be created when eviction first fired:
    /// every Evict/Restore tick after that was a forced no-op. (False
    /// when eviction never fired — the backing is created lazily.)
    pub swap_unavailable: bool,
    /// The swap path was degraded at shutdown: the fault queue had
    /// exhausted a retry budget without a success since, or eviction
    /// failed several consecutive ticks. While degraded the policy
    /// skips all swap traffic (graceful degradation to a
    /// compaction-only daemon) — this flag is how an experiment learns
    /// that happened instead of mistaking quiet for health.
    pub swap_degraded: bool,
    /// Eviction victims `(registration id, leaf index)` in eviction
    /// order (capped; see [`Compactor::take_victims`]) — the audit
    /// trail for "did recency tracking pick cold leaves".
    pub victims: Vec<(u64, usize)>,
    /// Fault-queue counters at shutdown (all zero for a daemon spawned
    /// without a queue).
    pub fault: FaultStats,
    /// Per-tenant rows at shutdown (empty unless spawned with
    /// [`MmdHandle::spawn_with_tenants`]): blocks used vs. quota,
    /// evictions, faults, pressured/degraded — the isolation audit
    /// trail.
    pub tenants: Vec<TenantSnapshot>,
}

impl MmdReport {
    /// One-line summary for experiment table notes.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "mmd: {} ticks, moved {} leaves ({} KB), evicted {} / restored {}, \
             score {:.3} -> {:.3}, limbo high-water {}, actions \
             idle={} pool={} shard={} rebal={} evict={} restore={} prefetch={}",
            self.ticks,
            self.compact.leaves_moved,
            self.compact.bytes_compacted / 1024,
            self.compact.evictions,
            self.compact.restores,
            self.initial_score,
            self.final_score,
            self.limbo_high_water,
            self.actions.idle,
            self.actions.compact_pool,
            self.actions.compact_shard,
            self.actions.rebalance,
            self.actions.evict,
            self.actions.restore,
            self.actions.prefetch,
        );
        if self.fault.demand > 0 || self.fault.retries > 0 {
            s.push_str(&format!(
                ", faults demand={} retries={} permanent={} mean {} us",
                self.fault.demand,
                self.fault.retries,
                self.fault.permanent,
                self.fault.mean_ns() / 1000,
            ));
        }
        if self.swap_unavailable {
            s.push_str(" [SWAP UNAVAILABLE: eviction was a no-op]");
        }
        if self.swap_degraded {
            s.push_str(" [SWAP DEGRADED: swap traffic was suspended]");
        }
        for t in &self.tenants {
            s.push_str(&format!(
                "\n  tenant {}: {}/{} blocks (soft {}, peak {}), evictions={} faults={} \
                 quota_failures={}{}{}",
                t.tenant,
                t.used,
                t.hard_quota,
                t.soft_quota,
                t.peak,
                t.evictions,
                t.faults,
                t.quota_failures,
                if t.pressured { " [PRESSURED]" } else { "" },
                if t.degraded { " [DEGRADED]" } else { "" },
            ));
        }
        s
    }
}

enum Ctl {
    Pause,
    Resume,
    Quiesce(Sender<usize>),
    Shutdown,
}

/// Handle to a running daemon. See [`MmdHandle::spawn`].
pub struct MmdHandle<'scope> {
    tx: Sender<Ctl>,
    join: ScopedJoinHandle<'scope, MmdReport>,
}

impl<'scope> MmdHandle<'scope> {
    /// Spawn the daemon on `scope` over one allocator pool and one
    /// registry. The policy decides, [`MmdConfig`] paces; everything
    /// heavy (sampling, relocation, swap I/O, reclamation) runs on the
    /// daemon thread — the only inline cost imposed on workload threads
    /// is the usual epoch-pin revalidation they already pay.
    pub fn spawn<'env, A, P>(
        scope: &'scope Scope<'scope, 'env>,
        alloc: &'env A,
        registry: &'env TreeRegistry<'env>,
        policy: P,
        cfg: MmdConfig,
    ) -> MmdHandle<'scope>
    where
        A: BlockAlloc,
        P: Policy + 'env,
    {
        let (tx, rx) = channel();
        let join = scope.spawn(move || daemon_run(alloc, registry, policy, cfg, None, None, rx));
        MmdHandle { tx, join }
    }

    /// Spawn the daemon over an application-provided [`FaultQueue`] —
    /// the same queue whose [`crate::pmem::LeafFaulter`] the
    /// application installed on its trees. The daemon then:
    ///
    /// * evicts through the queue's [`SwapService`] (same backing the
    ///   demand faults read from),
    /// * restores/prefetches through the queue's shedding prefetch
    ///   gate, so daemon swap-ins never steal I/O slots from demand
    ///   misses,
    /// * feeds the policy live queue telemetry: per-tick demand-fault
    ///   deltas (prefetch trigger), current depth (eviction gate), and
    ///   the degraded flag (suspend swap traffic).
    ///
    /// Shutdown still restores every evicted leaf — through the queue
    /// itself (full retry/backoff), not the gate.
    pub fn spawn_with_swap<'env, A, P>(
        scope: &'scope Scope<'scope, 'env>,
        alloc: &'env A,
        registry: &'env TreeRegistry<'env>,
        policy: P,
        cfg: MmdConfig,
        faultq: &'env FaultQueue<'env>,
    ) -> MmdHandle<'scope>
    where
        A: BlockAlloc,
        P: Policy + 'env,
    {
        let (tx, rx) = channel();
        let join =
            scope.spawn(move || daemon_run(alloc, registry, policy, cfg, Some(faultq), None, rx));
        MmdHandle { tx, join }
    }

    /// [`MmdHandle::spawn_with_swap`] plus a [`TenantRegistry`]: the
    /// full multi-tenant daemon. On top of the fault-queue loop it
    ///
    /// * evicts/restores through each tree's owning tenant's routed
    ///   backing ([`FaultQueue::route_tenant`]), pressured tenants
    ///   first, budget split by share,
    /// * skips tenants whose backing is degraded — per-tenant
    ///   containment instead of a global stop — and keeps ticking for
    ///   everyone else,
    /// * feeds the policy quota pressure
    ///   ([`crate::mmd::PolicyCtx::pressured_tenants`]) and the
    ///   latency-spike deltas (TLB invalidations, seq-bracket retries),
    /// * reports per-tenant rows in [`MmdReport::tenants`].
    ///
    /// Shutdown drains every restorable tenant (probing degraded ones
    /// for recovery); leaves of tenants that stay degraded remain
    /// parked and are visible in the report.
    pub fn spawn_with_tenants<'env, A, P>(
        scope: &'scope Scope<'scope, 'env>,
        alloc: &'env A,
        registry: &'env TreeRegistry<'env>,
        policy: P,
        cfg: MmdConfig,
        faultq: &'env FaultQueue<'env>,
        tenants: &'env TenantRegistry,
    ) -> MmdHandle<'scope>
    where
        A: BlockAlloc,
        P: Policy + 'env,
    {
        let (tx, rx) = channel();
        let join = scope.spawn(move || {
            daemon_run(alloc, registry, policy, cfg, Some(faultq), Some(tenants), rx)
        });
        MmdHandle { tx, join }
    }

    /// Stop acting (ticks become no-ops) until [`MmdHandle::resume`].
    pub fn pause(&self) {
        let _ = self.tx.send(Ctl::Pause);
    }

    /// Resume after [`MmdHandle::pause`].
    pub fn resume(&self) {
        let _ = self.tx.send(Ctl::Resume);
    }

    /// Ask the daemon to drain the pool's limbo list and wait for the
    /// answer. Returns the blocks still in limbo afterwards (non-zero
    /// when a registered reader has not quiesced — the drain is bounded,
    /// never a hang).
    pub fn quiesce(&self) -> usize {
        let (ack_tx, ack_rx) = channel();
        if self.tx.send(Ctl::Quiesce(ack_tx)).is_err() {
            return 0;
        }
        ack_rx.recv().unwrap_or(0)
    }

    /// Stop the daemon and collect its report. Shutdown restores every
    /// evicted leaf (so registered trees are whole again) and drains
    /// limbo before returning.
    pub fn shutdown(self) -> MmdReport {
        let _ = self.tx.send(Ctl::Shutdown);
        self.join.join().expect("mmd daemon panicked")
    }
}

/// Bounded limbo drain: with no registered readers one `try_reclaim`
/// empties the list (every retired block is immediately past the
/// OFFLINE minimum); with stale readers we retry a bounded number of
/// times rather than hang the daemon on an idle reader.
fn drain_limbo<A: BlockAlloc>(alloc: &A) -> usize {
    let epoch = alloc.epoch();
    for _ in 0..4096 {
        if epoch.limbo_len() == 0 {
            break;
        }
        epoch.try_reclaim(alloc);
        if epoch.stats().readers > 0 {
            std::thread::yield_now();
        }
    }
    epoch.limbo_len()
}

/// Consecutive failed eviction ticks before the daemon declares its
/// own swap path degraded (ext-mode queues carry their own flag).
const EVICT_FAIL_DEGRADE: u32 = 3;

/// Upper bound on [`MmdReport::action_log`] rows. Long soak runs tick
/// millions of times; the log keeps the opening window (where policy
/// transitions actually happen) and drops the steady-state tail rather
/// than growing without bound.
pub const ACTION_LOG_CAP: usize = 4096;

fn daemon_run<'e, A, P>(
    alloc: &'e A,
    registry: &'e TreeRegistry<'e>,
    mut policy: P,
    cfg: MmdConfig,
    ext: Option<&'e FaultQueue<'e>>,
    tenants: Option<&'e TenantRegistry>,
    rx: Receiver<Ctl>,
) -> MmdReport
where
    A: BlockAlloc,
    P: Policy,
{
    // Swap backing for the eviction path, created lazily on the first
    // Evict tick (a compaction-only daemon never touches the
    // filesystem). If the environment cannot give us a temp file,
    // `swap_unavailable` is reported and the policy stops being fed
    // evictable capacity, so pressure falls through to compaction
    // instead of demanding no-op evictions forever.
    let mut swap: Option<SwapPool<'e, A>> = None;
    let mut swap_failed = false;
    let mut compactor = Compactor::new(alloc, registry);
    let mut sampler = FragSampler::new();
    // Initial score sampled at spawn (not the first unpaused tick): a
    // paused-then-shut-down daemon must still report where the pool
    // started.
    let mut report = MmdReport {
        initial_score: sampler.sample(alloc).score,
        ..MmdReport::default()
    };
    let mut paused = cfg.start_paused;
    // Per-tick deltas: the policy wants "what happened since last
    // tick", the sources are monotonic counters.
    let mut last_lock_waits = registry.lock_waits_total();
    let mut last_demand = ext.map(|q| q.stats().demand).unwrap_or(0);
    let mut last_seq_retries = registry.seq_retries_total();
    let mut last_epoch = alloc.epoch().current();
    // Own-mode degradation: EVICT_FAIL_DEGRADE consecutive eviction
    // ticks that moved nothing (with candidates present) mean the
    // backing is refusing writes — stop asking.
    let mut evict_fail_streak = 0u32;
    let mut own_degraded = false;
    loop {
        match rx.recv_timeout(cfg.interval) {
            Ok(Ctl::Pause) => {
                paused = true;
                continue;
            }
            Ok(Ctl::Resume) => {
                paused = false;
                continue;
            }
            Ok(Ctl::Quiesce(ack)) => {
                let _ = ack.send(drain_limbo(alloc));
                continue;
            }
            Ok(Ctl::Shutdown) | Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => {}
        }
        if paused {
            continue;
        }
        let snap = sampler.sample(alloc);
        report.limbo_high_water = report.limbo_high_water.max(snap.epoch.limbo);
        if cfg.trace_every > 0 && report.ticks % cfg.trace_every == 0 {
            report.score_trace.push(snap.score);
        }
        let (swapped_out, evictable_resident) = registry.eviction_counts();
        let lw = registry.lock_waits_total();
        let lock_waits = lw.saturating_sub(last_lock_waits);
        last_lock_waits = lw;
        let demand_now = ext.map(|q| q.stats().demand).unwrap_or(0);
        let demand_faults = demand_now.saturating_sub(last_demand);
        last_demand = demand_now;
        let sr = registry.seq_retries_total();
        let seq_retries = sr.saturating_sub(last_seq_retries);
        last_seq_retries = sr;
        let tlb_invalidations = snap.epoch.epoch.saturating_sub(last_epoch);
        last_epoch = snap.epoch.epoch;
        // Tenant mode scopes degradation: one dead backing parks one
        // tenant (the tenant-aware passes skip it); only every backing
        // dead means swap traffic as a whole must stop. Without
        // tenants the queue's aggregate flag keeps its PR-7 meaning.
        let swap_degraded = own_degraded
            || match tenants {
                Some(tn) => tn.all_degraded(),
                None => ext.map(|q| q.degraded()).unwrap_or(false),
            };
        let ctx = PolicyCtx {
            swapped_out,
            evictable_resident: if swap_failed { 0 } else { evictable_resident },
            lock_waits,
            demand_faults,
            fault_queue_depth: ext.map(|q| q.depth()).unwrap_or(0),
            swap_degraded,
            pressured_tenants: tenants.map(|tn| tn.pressured_count()).unwrap_or(0),
            pressured_evictable: tenants
                .map(|tn| {
                    tn.rows()
                        .iter()
                        .filter(|r| r.pressured)
                        .map(|r| registry.evictable_resident_for(r.tenant))
                        .sum()
                })
                .unwrap_or(0),
            tlb_invalidations,
            seq_retries,
        };
        report.swap_degraded = swap_degraded;
        let action = policy.decide(&snap, &ctx);
        if report.action_log.len() < ACTION_LOG_CAP {
            report.action_log.push((report.ticks, action.name()));
        }
        match action {
            Action::Idle => report.actions.idle += 1,
            Action::CompactPool => {
                compactor.compact_span(cfg.tokens_per_tick, 0, alloc.capacity());
                report.actions.compact_pool += 1;
            }
            Action::CompactShard(s) => {
                let (lo, hi) = snap
                    .shard_spans
                    .get(s)
                    .copied()
                    .unwrap_or((0, alloc.capacity()));
                compactor.compact_span(cfg.tokens_per_tick, lo, hi);
                report.actions.compact_shard += 1;
            }
            Action::Rebalance { from, to } => {
                let spans = &snap.shard_spans;
                if let (Some(&f), Some(&t)) = (spans.get(from), spans.get(to)) {
                    compactor.rebalance(cfg.tokens_per_tick, f, t);
                }
                report.actions.rebalance += 1;
            }
            Action::Evict { leaves } if tenants.is_some() => {
                // Tenant mode: pressured tenants' cold leaves first,
                // budget split by share, each tenant through its own
                // routed backing, degraded tenants skipped.
                let (q, tn) = (ext.expect("tenant mode requires a fault queue"), tenants.unwrap());
                let did = compactor.evict_tenants(leaves.min(cfg.tokens_per_tick), q, tn);
                if did > 0 {
                    evict_fail_streak = 0;
                    own_degraded = false;
                } else if evictable_resident > 0 {
                    evict_fail_streak += 1;
                    if evict_fail_streak >= EVICT_FAIL_DEGRADE {
                        own_degraded = true;
                    }
                }
                report.actions.evict += 1;
            }
            Action::Restore { leaves } if tenants.is_some() => {
                let (q, tn) = (ext.expect("tenant mode requires a fault queue"), tenants.unwrap());
                compactor.restore_tenants(leaves.min(cfg.tokens_per_tick), q, tn);
                report.actions.restore += 1;
            }
            Action::Prefetch { leaves } if tenants.is_some() => {
                let (q, tn) = (ext.expect("tenant mode requires a fault queue"), tenants.unwrap());
                compactor.prefetch_tenants(leaves.min(cfg.tokens_per_tick), q, tn);
                report.actions.prefetch += 1;
            }
            Action::Evict { leaves } => {
                let svc: Option<&dyn SwapService> = match ext {
                    Some(q) => Some(q.service()),
                    None => {
                        if swap.is_none() && !swap_failed {
                            match SwapPool::anonymous(alloc) {
                                Ok(s) => swap = Some(s),
                                Err(_) => {
                                    swap_failed = true;
                                    report.swap_unavailable = true;
                                }
                            }
                        }
                        swap.as_ref().map(|s| s as &dyn SwapService)
                    }
                };
                if let Some(svc) = svc {
                    let did = compactor.evict(leaves.min(cfg.tokens_per_tick), svc);
                    if did > 0 {
                        evict_fail_streak = 0;
                        own_degraded = false; // the backing writes again
                    } else if evictable_resident > 0 {
                        evict_fail_streak += 1;
                        if evict_fail_streak >= EVICT_FAIL_DEGRADE {
                            own_degraded = true;
                        }
                    }
                }
                report.actions.evict += 1;
            }
            Action::Restore { leaves } => {
                // Ext mode restores through the shedding gate: a bulk
                // restore must never occupy I/O slots a demand miss is
                // waiting for — a shed restore just retries next tick.
                match ext {
                    Some(q) => {
                        compactor.restore(leaves.min(cfg.tokens_per_tick), &q.prefetch_gate());
                    }
                    None => {
                        if let Some(sw) = swap.as_ref() {
                            compactor.restore(leaves.min(cfg.tokens_per_tick), sw);
                        }
                    }
                }
                report.actions.restore += 1;
            }
            Action::Prefetch { leaves } => {
                match ext {
                    Some(q) => {
                        compactor.prefetch(leaves.min(cfg.tokens_per_tick), &q.prefetch_gate());
                    }
                    None => {
                        // Without a queue there is no demand-fault
                        // signal, but a custom policy may still ask:
                        // serve it from the lazy pool when one exists.
                        if let Some(sw) = swap.as_ref() {
                            compactor.prefetch(leaves.min(cfg.tokens_per_tick), sw);
                        }
                    }
                }
                report.actions.prefetch += 1;
            }
        }
        alloc.epoch().try_reclaim(alloc);
        report.ticks += 1;
    }
    // Shutdown: make registered trees whole (fault every evicted leaf
    // back — the satellite teardown contract), then drain limbo. Ext
    // mode restores through the queue itself (full retry/backoff, no
    // shedding): at teardown, completeness beats latency.
    match (ext, tenants) {
        (Some(q), Some(tn)) => {
            // Stats snapshot before the teardown restores so `demand`
            // reflects accessor misses, not shutdown bulk I/O.
            report.fault = q.stats();
            if registry.swapped_out() > 0 {
                compactor.restore_all_tenants(q, tn);
            }
            report.swap_degraded = own_degraded || tn.all_degraded();
            report.tenants = tn.rows();
        }
        (Some(q), None) => {
            report.fault = q.stats();
            if registry.swapped_out() > 0 {
                compactor.restore_all(q);
            }
            report.swap_degraded = own_degraded || q.degraded();
        }
        (None, _) => {
            if let Some(sw) = swap.as_ref() {
                compactor.restore_all(sw);
            }
            report.swap_degraded = own_degraded;
        }
    }
    report.victims = compactor.take_victims();
    report.limbo_remaining = drain_limbo(alloc);
    report.compact = compactor.stats();
    let snap = sampler.sample(alloc);
    report.final_score = snap.score;
    report.reclaimed = snap.epoch.reclaimed;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mmd::policy::ThresholdPolicy;
    use crate::pmem::{BlockAllocator, ShardedAllocator};
    use crate::testutil::fragmented_tree;
    use crate::trees::TreeArray;
    use std::time::Instant;

    fn cfg_fast() -> MmdConfig {
        MmdConfig {
            interval: Duration::from_micros(100),
            tokens_per_tick: 16,
            trace_every: 8,
            ..MmdConfig::default()
        }
    }

    /// Poll until `done()` or a generous deadline — the assertions
    /// after the poll say what actually went wrong; the deadline only
    /// bounds how long a genuinely broken daemon can hang the test.
    fn wait_for(mut done: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !done() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn lifecycle_with_empty_registry() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let registry = TreeRegistry::new();
        let report = std::thread::scope(|s| {
            let d = MmdHandle::spawn(s, &a, &registry, ThresholdPolicy::default(), cfg_fast());
            d.pause();
            d.resume();
            assert_eq!(d.quiesce(), 0, "nothing in limbo");
            std::thread::sleep(Duration::from_millis(50));
            d.shutdown()
        });
        assert!(report.ticks > 0, "daemon must tick while idle");
        assert_eq!(report.actions.idle, report.ticks, "empty pool: all idle");
        assert_eq!(report.compact.leaves_moved, 0);
        assert_eq!(report.limbo_remaining, 0);
    }

    #[test]
    fn daemon_compacts_a_fragmented_pool() {
        let a = ShardedAllocator::with_shards(1024, 256, 2).unwrap();
        let (tree, data) = fragmented_tree(&a, 40, |i| i ^ 0xBEEF);
        let s0 = FragSampler::new().sample(&a).score;
        assert!(s0 > 0.5, "setup must fragment the pool: {s0}");
        let registry = TreeRegistry::new();
        // SAFETY: no accessors while the daemon owns relocation.
        let id = unsafe { registry.register(&tree) };
        let report = std::thread::scope(|s| {
            let d = MmdHandle::spawn(s, &a, &registry, ThresholdPolicy::default(), cfg_fast());
            // Converge (no fixed sleep: CI machines stall arbitrarily).
            // Target = the policy's idle threshold: below it the daemon
            // stops compacting, so a lower target would never be met.
            let target = ThresholdPolicy::default().score_hi;
            let mut poll = FragSampler::new();
            wait_for(|| poll.sample(&a).score <= target);
            d.shutdown()
        });
        assert!(report.compact.leaves_moved >= 30, "{}", report.summary());
        assert!(
            report.final_score * 2.0 <= report.initial_score,
            "daemon must at least halve the score: {}",
            report.summary()
        );
        assert!(report.actions.compact_pool > 0);
        assert!(!report.score_trace.is_empty(), "trace must record the trajectory");
        assert_eq!(report.limbo_remaining, 0);
        assert_eq!(tree.to_vec(), data);
        registry.deregister(id);
        drop(registry);
        drop(tree);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn daemon_evicts_under_pressure_and_restores_on_shutdown() {
        let a = BlockAllocator::new(1024, 32).unwrap();
        // Tree of 8 leaves + root, then scratch fills the pool to ~97%:
        // free ratio < 8% trips the eviction trigger.
        let mut tree: TreeArray<u64> = TreeArray::new(&a, 128 * 8).unwrap();
        let data: Vec<u64> = (0..128 * 8).map(|i| i as u64 | 1).collect();
        tree.copy_from_slice(&data).unwrap();
        let scratch = a.alloc_many(22).unwrap(); // 31/32 live
        let registry = TreeRegistry::new();
        // SAFETY: nothing touches the tree while registered.
        let id = unsafe { registry.register_evictable(&tree) };
        let report = std::thread::scope(|s| {
            let d = MmdHandle::spawn(s, &a, &registry, ThresholdPolicy::default(), cfg_fast());
            // Wait until pressure has demonstrably triggered eviction
            // (retired blocks prove evict_deferred ran), not a timer.
            wait_for(|| a.stats().retired > 0);
            d.shutdown()
        });
        assert!(report.actions.evict > 0, "pressure must trigger eviction: {}", report.summary());
        assert!(report.compact.evictions > 0);
        assert_eq!(
            report.compact.restores, report.compact.evictions,
            "shutdown must restore every evicted leaf: {}",
            report.summary()
        );
        assert_eq!(registry.swapped_out(), 0);
        assert_eq!(tree.to_vec(), data, "evict/restore corrupted the tree");
        registry.deregister(id);
        drop(registry);
        for b in scratch {
            a.free(b).unwrap();
        }
        a.epoch().synchronize(&a);
        drop(tree);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn daemon_over_a_fault_queue_serves_demand_misses() {
        use crate::pmem::{FaultQueue, FaultQueueConfig, SwapPool};
        let a = BlockAllocator::new(1024, 32).unwrap();
        let mut tree: TreeArray<u64> = TreeArray::new(&a, 128 * 8).unwrap();
        let data: Vec<u64> = (0..128 * 8).map(|i| i as u64 ^ 0xABCD).collect();
        tree.copy_from_slice(&data).unwrap();
        let scratch = a.alloc_many(22).unwrap(); // 31/32 live: pressure
        let swap = SwapPool::anonymous(&a).unwrap();
        let q = FaultQueue::new(&swap, FaultQueueConfig::default());
        // SAFETY: cleared below before `q` drops.
        unsafe { tree.install_faulter(&q) };
        let registry = TreeRegistry::new();
        // SAFETY: every accessor below is a fault-capable view and the
        // faulter is installed.
        let id = unsafe { registry.register_evictable(&tree) };
        let report = std::thread::scope(|s| {
            let d = MmdHandle::spawn_with_swap(
                s,
                &a,
                &registry,
                ThresholdPolicy::default(),
                cfg_fast(),
                &q,
            );
            wait_for(|| a.stats().retired > 0);
            // Demand-read the whole tree while leaves are parked: the
            // view fault hook pulls them back through the queue.
            let mut v = tree.view();
            for (i, &want) in data.iter().enumerate() {
                assert_eq!(v.get(i).unwrap(), want, "demand read under eviction");
            }
            assert!(v.faults() > 0, "eviction must have forced demand faults");
            drop(v);
            d.shutdown()
        });
        assert!(report.actions.evict > 0, "{}", report.summary());
        assert!(report.fault.demand > 0, "queue must have served misses: {}", report.summary());
        assert!(!report.victims.is_empty(), "victims must be reported");
        assert!(report.victims.iter().all(|&(vid, _)| vid == id));
        assert!(!report.swap_degraded, "healthy backing must not degrade");
        assert_eq!(registry.swapped_out(), 0, "shutdown restores everything");
        assert_eq!(tree.to_vec(), data);
        registry.deregister(id);
        drop(registry);
        tree.clear_faulter();
        for b in scratch {
            a.free(b).unwrap();
        }
        a.epoch().synchronize(&a);
        drop(tree);
        drop(swap);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn tenant_daemon_parks_the_pressured_tenant_and_reports_rows() {
        use crate::pmem::tenant::{TenantConfig, TenantRegistry};
        use crate::pmem::{FaultQueue, FaultQueueConfig, SwapPool};
        let a = BlockAllocator::new(1024, 32).unwrap();
        let tenants = TenantRegistry::new();
        // t1's seeded residency sits far enough over its soft quota
        // that evicting its whole tree cannot relieve the pressure: the
        // stable end state is "t1 fully parked", not an evict/restore
        // oscillation.
        let t1 = tenants.admit(TenantConfig::new(10, 100));
        let t2 = tenants.admit(TenantConfig::new(100, 100));
        for _ in 0..20 {
            tenants.fault_charged(t1.id());
        }
        assert!(t1.pressured());
        let mut tree1: TreeArray<u64> = TreeArray::new(&a, 128 * 4).unwrap();
        let mut tree2: TreeArray<u64> = TreeArray::new(&a, 128 * 4).unwrap();
        let d1: Vec<u64> = (0..128 * 4).map(|i| i as u64 ^ 0x1111).collect();
        let d2: Vec<u64> = (0..128 * 4).map(|i| i as u64 ^ 0x2222).collect();
        tree1.copy_from_slice(&d1).unwrap();
        tree2.copy_from_slice(&d2).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let q = FaultQueue::with_tenants(&swap, FaultQueueConfig::default(), &tenants);
        let registry = TreeRegistry::new();
        // SAFETY: no accessors race the daemon in this test.
        let id1 = unsafe { registry.register_evictable_for_tenant(&tree1, t1.id()) };
        let id2 = unsafe { registry.register_evictable_for_tenant(&tree2, t2.id()) };
        let report = std::thread::scope(|s| {
            let d = MmdHandle::spawn_with_tenants(
                s,
                &a,
                &registry,
                ThresholdPolicy::default(),
                cfg_fast(),
                &q,
                &tenants,
            );
            // The pool has plenty of headroom, so only quota pressure
            // can drive these evictions.
            wait_for(|| registry.swapped_out_for(t1.id()) == 4);
            d.shutdown()
        });
        // Backpressure hit exactly the over-quota tenant.
        assert!(report.actions.evict > 0, "{}", report.summary());
        assert_eq!(t1.snapshot().evictions, 4, "{}", report.summary());
        assert_eq!(t2.snapshot().evictions, 0, "healthy tenant must be untouched");
        assert!(t1.pressured(), "still over soft quota after parking its whole tree");
        // Shutdown drains everyone, pressured or not, and reports rows.
        assert_eq!(registry.swapped_out(), 0, "{}", report.summary());
        assert_eq!(report.tenants.len(), 2);
        let r1 = report.tenants.iter().find(|r| r.tenant == t1.id()).unwrap();
        assert!(r1.pressured && !r1.degraded);
        assert_eq!(r1.evictions, 4);
        assert!(!report.swap_degraded);
        assert!(report.summary().contains("[PRESSURED]"), "{}", report.summary());
        assert_eq!(tree1.to_vec(), d1);
        assert_eq!(tree2.to_vec(), d2);
        registry.deregister(id1);
        registry.deregister(id2);
        drop(registry);
        drop((tree1, tree2));
        drop(swap);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn paused_daemon_does_not_act() {
        let a = BlockAllocator::new(1024, 128).unwrap();
        // Fragment enough that an unpaused daemon would certainly act.
        let all = a.alloc_many(128).unwrap();
        for (i, b) in all.iter().enumerate() {
            if i % 4 == 0 {
                a.free(*b).unwrap();
            }
        }
        let tree: TreeArray<u64> = TreeArray::new(&a, 128 * 20).unwrap();
        let registry = TreeRegistry::new();
        // SAFETY: no accessors while registered.
        let id = unsafe { registry.register(&tree) };
        let cfg = MmdConfig {
            start_paused: true,
            ..cfg_fast()
        };
        let report = std::thread::scope(|s| {
            let d = MmdHandle::spawn(s, &a, &registry, ThresholdPolicy::default(), cfg);
            d.pause(); // idempotent; exercises the control channel
            std::thread::sleep(Duration::from_millis(10));
            d.shutdown()
        });
        assert_eq!(report.compact.leaves_moved, 0, "paused daemon must not move leaves");
        registry.deregister(id);
        drop(registry);
        drop(tree);
        for b in all.iter().filter(|b| a.is_live(**b)) {
            a.free(*b).unwrap();
        }
        assert_eq!(a.stats().allocated, 0);
    }
}
