//! Pluggable daemon policy: *what* to do about a telemetry sample.
//!
//! The mechanism lives in [`crate::mmd::Compactor`]; the policy only
//! maps a [`FragSnapshot`] (plus the current swapped-out count) to one
//! [`Action`] per tick. This is the Cichlid-style split — a dedicated
//! service with its own policy loop — kept narrow so experiments can
//! substitute policies without touching the engine.
//!
//! [`ThresholdPolicy`] is the shipped implementation, priority-ordered:
//!
//! 1. **Swap pressure** — free ratio below the low watermark: evict
//!    cold leaves of evictable registrations to disk. Gated on the
//!    fault queue being shallow: a deep queue means demand faults are
//!    already fighting for swap I/O, and evicting more would add
//!    traffic *and* likely pick leaves about to fault straight back.
//! 2. **Demand faulting in progress** — leaves are parked, accessors
//!    faulted some in last tick, and there is free headroom: *prefetch*
//!    a few predicted-hot leaves (hottest by last-touch) so the next
//!    misses hit resident memory. Speculative: runs through a shedding
//!    gate, never competing with demand I/O.
//! 3. **Pressure cleared** — leaves parked in swap and free ratio above
//!    the high watermark: restore them (bulk, hysteresis-bounded).
//! 4. **Pool fragmentation** — score above threshold: compact the pool
//!    (sink leaves into the lowest free blocks).
//! 5. **Span-local fragmentation** — the pool looks fine but one
//!    span's free space is shredded: compact inside that span.
//! 6. **Span imbalance** — occupancy spread above threshold: migrate
//!    leaves from the fullest span's range into the emptiest's, so
//!    thread-affine allocation stops degenerating into cross-span
//!    stealing.
//! 7. Otherwise **idle**.
//!
//! Between 1 and 2 sits **tenant quota pressure**: when any tenant is
//! over its soft quota ([`PolicyCtx::pressured_tenants`]), evict even
//! though the *pool* is fine — the tenant-aware eviction pass takes the
//! pressured tenants' cold leaves first, which is what turns a soft
//! quota into backpressure instead of a dead letter. Same limbo and
//! queue-depth gates as pressure eviction.
//!
//! Two standing overrides: when the swap backing is **degraded**
//! (permanent fault-in failures — [`PolicyCtx::swap_degraded`]) every
//! swap-traffic action (evict/prefetch/restore) is skipped — the daemon
//! degrades to a compaction-only service and *reports* the state
//! instead of wedging on a dead device. And when the application ran
//! **hot** last tick, the compaction family defers to Idle — three
//! heat signals, any one trips it: writer seqlock waits
//! ([`PolicyCtx::lock_waits`] — relocation takes the same per-leaf
//! seqlocks writers are fighting over), read-side seq-bracket retries
//! ([`PolicyCtx::seq_retries`] — every relocation forces overlapped
//! reads to re-run), and arena-epoch TLB invalidations
//! ([`PolicyCtx::tlb_invalidations`] — every block move bumps the
//! epoch and flushes every translation cache in the arena, so
//! compacting into an invalidation storm multiplies reader walk
//! costs). Fragmentation keeps; application latency does not.
//!
//! "Span" is whatever [`BlockAlloc::shard_spans`] reports: lock shards
//! for the sharded allocator, 512-block subtrees for the two-level
//! allocator. Under the two-level allocator, `CompactShard` and
//! `Rebalance` therefore act on subtree-granular occupancy — compacting
//! inside one subtree, or draining an overloaded subtree into an
//! underloaded one so CPU-local reservation finds empty subtrees again.
//!
//! [`BlockAlloc::shard_spans`]: crate::pmem::BlockAlloc::shard_spans

use crate::mmd::stats::FragSnapshot;

/// One daemon decision. Budgets (how many leaves per tick) come from
/// [`crate::mmd::MmdConfig`], not the policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Nothing to do this tick.
    Idle,
    /// Sink leaves into the lowest free blocks of the whole pool.
    CompactPool,
    /// Sink leaves into the lowest free blocks of one span's range
    /// (a lock shard, or a 512-block subtree under the two-level
    /// allocator). The index is into the snapshot's `shard_spans`.
    CompactShard(usize),
    /// Migrate leaves out of span `from`'s range into span `to`'s
    /// (indices into the snapshot's `shard_spans`).
    Rebalance {
        /// Source span (overloaded).
        from: usize,
        /// Destination span (underloaded).
        to: usize,
    },
    /// Evict up to `leaves` cold leaves to swap.
    Evict {
        /// Eviction budget for this tick.
        leaves: usize,
    },
    /// Fault up to `leaves` swapped-out leaves back in.
    Restore {
        /// Restore budget for this tick — bounded by the policy so
        /// restoring cannot push the pool straight back into its own
        /// eviction band (watermark hysteresis).
        leaves: usize,
    },
    /// Speculatively fault up to `leaves` predicted-hot swapped-out
    /// leaves back in through the fault queue's shedding prefetch gate
    /// (dropped, not queued, when demand traffic needs the queue).
    Prefetch {
        /// Prefetch budget for this tick.
        leaves: usize,
    },
}

impl Action {
    /// Stable short name for logs and the results schema's per-tick
    /// action rows (argument-free, so rows compare across commits).
    pub fn name(&self) -> &'static str {
        match self {
            Action::Idle => "idle",
            Action::CompactPool => "compact_pool",
            Action::CompactShard(_) => "compact_shard",
            Action::Rebalance { .. } => "rebalance",
            Action::Evict { .. } => "evict",
            Action::Restore { .. } => "restore",
            Action::Prefetch { .. } => "prefetch",
        }
    }
}

/// What the daemon knows beyond the telemetry sample: the registry's
/// eviction state. Keeps `decide` honest — a policy that cannot see
/// that nothing is evictable would demand eviction forever under
/// sustained pressure and starve compaction.
#[derive(Clone, Copy, Debug, Default)]
pub struct PolicyCtx {
    /// Leaves currently parked in swap across the registry.
    pub swapped_out: usize,
    /// Resident leaves of evictable registrations that eviction could
    /// still take (0 when nothing is evictable or swap is unavailable).
    pub evictable_resident: usize,
    /// Seqlock acquisitions lost to contention *since the last tick*
    /// (writer heat — the daemon feeds the registry-wide delta).
    pub lock_waits: u64,
    /// Demand fault-ins served *since the last tick* (accessors hitting
    /// evicted leaves — the signal that prefetching could help).
    pub demand_faults: u64,
    /// Current depth of the async fault queue (0 without a queue).
    pub fault_queue_depth: usize,
    /// The fault path is failing permanently (retries exhausted on the
    /// swap backing and no success since). Swap traffic must stop. In
    /// tenant mode this means *every* tenant is degraded — single
    /// dead backings are handled per-tenant inside the eviction and
    /// restore passes, not by stopping the daemon's swap traffic.
    pub swap_degraded: bool,
    /// Tenants currently over their soft quota
    /// ([`crate::pmem::TenantRegistry::pressured_count`]); 0 without a
    /// tenant registry. Nonzero triggers quota-pressure eviction even
    /// when the pool itself has free headroom.
    pub pressured_tenants: usize,
    /// Resident evictable leaves owned by *pressured* tenants — what
    /// quota-pressure eviction could actually take. The quota branch
    /// gates on (and bounds its budget by) this, so a pressured tenant
    /// with nothing left to evict cannot make the daemon churn healthy
    /// tenants' leaves.
    pub pressured_evictable: usize,
    /// Arena-epoch TLB invalidations *since the last tick* — every
    /// block move bumps the epoch and flushes every reader's
    /// translation cache. A spike means compaction would multiply
    /// reader walk costs.
    pub tlb_invalidations: u64,
    /// Read-side seq-bracket retries *since the last tick* (reads
    /// re-run because a writer or relocation overlapped them). A spike
    /// means relocation is already making readers hurt.
    pub seq_retries: u64,
}

/// A daemon policy. `Send` so it can move onto the daemon thread;
/// stateful policies (hysteresis, EWMA smoothing) are expected — the
/// daemon calls `decide` once per tick from its own thread only.
pub trait Policy: Send {
    /// Map one telemetry sample (+ eviction context) to one action.
    fn decide(&mut self, snap: &FragSnapshot, ctx: &PolicyCtx) -> Action;
}

/// Threshold-triggered policy (see the module docs for the ordering).
#[derive(Clone, Copy, Debug)]
pub struct ThresholdPolicy {
    /// Compact the pool when its score exceeds this.
    pub score_hi: f64,
    /// Compact a single shard when its local score exceeds this (and
    /// the pool score did not trip).
    pub shard_score_hi: f64,
    /// Rebalance when the occupancy spread exceeds this.
    pub imbalance_hi: f64,
    /// Evict when the free ratio falls below this (swap pressure).
    pub evict_below_free: f64,
    /// Restore swapped leaves when the free ratio rises above this.
    pub restore_above_free: f64,
    /// Leaves to evict per pressure tick.
    pub evict_leaves: usize,
    /// Defer compaction/rebalancing while per-tick lock waits exceed
    /// this (writers are hot; relocation would fight them for the same
    /// leaf seqlocks).
    pub writer_waits_hi: u64,
    /// Do not evict while the fault queue is this deep (demand faults
    /// already saturate the swap path).
    pub queue_depth_hi: usize,
    /// Leaves to prefetch per demand-faulting tick.
    pub prefetch_leaves: usize,
    /// Defer compaction/rebalancing while per-tick arena-epoch TLB
    /// invalidations exceed this. The daemon's own relocations bump
    /// the epoch once per moved leaf (≤ `tokens_per_tick`, 16 by
    /// default), so the threshold sits well above the daemon's
    /// self-induced rate — only application-driven invalidation storms
    /// trip it.
    pub tlb_inval_hi: u64,
    /// Defer compaction/rebalancing while per-tick read-side
    /// seq-bracket retries exceed this (readers are already being
    /// forced to re-run; relocation would force more).
    pub seq_retry_hi: u64,
    /// Extra eviction budget multiplier while any tenant is pressured
    /// (quota backpressure wants residency down *now*, before the
    /// tenant hits its hard watermark).
    pub pressure_evict_boost: usize,
}

impl Default for ThresholdPolicy {
    fn default() -> Self {
        ThresholdPolicy {
            score_hi: 0.35,
            shard_score_hi: 0.6,
            imbalance_hi: 0.5,
            evict_below_free: 0.08,
            restore_above_free: 0.25,
            evict_leaves: 8,
            writer_waits_hi: 64,
            queue_depth_hi: 4,
            prefetch_leaves: 4,
            tlb_inval_hi: 256,
            seq_retry_hi: 128,
            pressure_evict_boost: 2,
        }
    }
}

impl Policy for ThresholdPolicy {
    fn decide(&mut self, s: &FragSnapshot, ctx: &PolicyCtx) -> Action {
        let free = s.free_ratio();
        // A degraded swap backing (permanent fault failures) makes
        // every swap-traffic action wrong: eviction would park payloads
        // behind a device that cannot give them back, restore/prefetch
        // would burn the retry budget again. Skip straight to the
        // compaction family — the daemon keeps running and *reports*
        // the state instead of wedging.
        if !ctx.swap_degraded {
            // Evict only when eviction can actually make progress —
            // otherwise sustained pressure must fall through to
            // compaction instead of demanding the impossible every
            // tick. Progress needs (a) evictable resident leaves,
            // (b) limbo that is draining: evicted blocks are *retired*,
            // not freed, so while a stalled reader pins a backlog of at
            // least one evict budget, more eviction only burns swap I/O
            // and TLB shootdowns without freeing anything, and (c) a
            // shallow fault queue: deep demand-fault traffic means the
            // workload is actively using what eviction would take.
            // Quota pressure boosts the budget: a pressured tenant is
            // marching toward its hard watermark, and every tick of
            // delay converts soft backpressure into hard failures.
            let evict_budget = if ctx.pressured_tenants > 0 {
                self.evict_leaves * self.pressure_evict_boost.max(1)
            } else {
                self.evict_leaves
            };
            if free < self.evict_below_free
                && ctx.evictable_resident > 0
                && s.epoch.limbo < self.evict_leaves
                && ctx.fault_queue_depth < self.queue_depth_hi
            {
                return Action::Evict { leaves: evict_budget };
            }
            // Tenant quota pressure with a healthy pool: evict anyway.
            // The tenant-aware eviction pass takes pressured tenants'
            // cold leaves first, so this is what actually relieves a
            // soft-quota overrun (the pool-wide free ratio never will —
            // the arena is fine, one tenant is not). The budget is
            // bounded by the pressured tenants' own evictable leaves so
            // the pass cannot spill onto healthy tenants and churn
            // them. Same limbo and queue gates as pressure eviction.
            if ctx.pressured_tenants > 0
                && ctx.pressured_evictable > 0
                && s.epoch.limbo < self.evict_leaves
                && ctx.fault_queue_depth < self.queue_depth_hi
            {
                return Action::Evict {
                    leaves: evict_budget.min(ctx.pressured_evictable),
                };
            }
            // Demand faults happened last tick and there is headroom:
            // prefetch a few predicted-hot leaves before considering
            // bulk restore. Outranks Restore because it is cheap (small
            // budget, shedding gate) and targeted at the leaves misses
            // will hit next.
            if ctx.swapped_out > 0 && ctx.demand_faults > 0 && free > self.restore_above_free {
                return Action::Prefetch {
                    leaves: self.prefetch_leaves.min(ctx.swapped_out),
                };
            }
            if ctx.swapped_out > 0 && free > self.restore_above_free {
                // Restore only what keeps the pool clear of the
                // eviction band, with one evict budget of margin:
                // without the cap, a single restore tick can cross both
                // watermarks and the evict/restore pair oscillates
                // deterministically (each cycle costing swap I/O and
                // arena-wide TLB shootdowns).
                let evict_floor =
                    (self.evict_below_free * s.capacity as f64).ceil() as usize + self.evict_leaves;
                let headroom = s.free.saturating_sub(evict_floor);
                let leaves = headroom.min(ctx.swapped_out);
                if leaves > 0 {
                    return Action::Restore { leaves };
                }
            }
        }
        // Application hot last tick: the compaction family would make
        // it worse. Writers (same leaf seqlocks), readers being forced
        // to re-run (seq-bracket retries), or an arena-wide TLB
        // invalidation storm (every relocation bumps the epoch and
        // flushes every translation cache) — any one defers. Defer —
        // fragmentation keeps; application latency does not.
        if ctx.lock_waits > self.writer_waits_hi
            || ctx.tlb_invalidations > self.tlb_inval_hi
            || ctx.seq_retries > self.seq_retry_hi
        {
            return Action::Idle;
        }
        if s.score > self.score_hi {
            return Action::CompactPool;
        }
        if let Some((worst, &sc)) = s
            .shard_scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
        {
            if sc > self.shard_score_hi {
                return Action::CompactShard(worst);
            }
        }
        if s.imbalance > self.imbalance_hi && s.shard_blocks.len() > 1 {
            let occ = |i: usize| s.occupancy(i);
            let mut from = 0;
            let mut to = 0;
            for i in 1..s.shard_blocks.len() {
                if occ(i) > occ(from) {
                    from = i;
                }
                if occ(i) < occ(to) {
                    to = i;
                }
            }
            if from != to {
                return Action::Rebalance { from, to };
            }
        }
        Action::Idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap() -> FragSnapshot {
        FragSnapshot {
            capacity: 100,
            live: 40,
            free: 60,
            shard_live: vec![20, 20],
            shard_blocks: vec![50, 50],
            shard_scores: vec![0.0, 0.0],
            ..FragSnapshot::default()
        }
    }

    fn ctx(swapped_out: usize, evictable_resident: usize) -> PolicyCtx {
        PolicyCtx {
            swapped_out,
            evictable_resident,
            ..Default::default()
        }
    }

    #[test]
    fn healthy_pool_idles() {
        let mut p = ThresholdPolicy::default();
        assert_eq!(p.decide(&snap(), &ctx(0, 0)), Action::Idle);
    }

    #[test]
    fn swap_pressure_outranks_everything() {
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.free = 4;
        s.live = 96;
        s.score = 0.9; // fragmented too — eviction still wins
        assert_eq!(p.decide(&s, &ctx(0, 40)), Action::Evict { leaves: 8 });
    }

    #[test]
    fn evict_waits_for_limbo_to_drain() {
        // A stalled reader pins a backlog of retired blocks: evicting
        // more cannot free memory, so pressure falls through to
        // compaction until the limbo drains below one evict budget.
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.free = 4;
        s.live = 96;
        s.score = 0.9;
        s.epoch.limbo = 8; // >= evict_leaves
        assert_eq!(p.decide(&s, &ctx(0, 40)), Action::CompactPool);
        s.epoch.limbo = 3; // draining again
        assert_eq!(p.decide(&s, &ctx(0, 40)), Action::Evict { leaves: 8 });
    }

    #[test]
    fn pressure_without_evictable_leaves_falls_through_to_compaction() {
        // Nothing registered evictable (or swap unavailable): demanding
        // eviction forever would starve compaction — the score trigger
        // must still fire.
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.free = 4;
        s.live = 96;
        s.score = 0.9;
        assert_eq!(p.decide(&s, &ctx(0, 0)), Action::CompactPool);
    }

    #[test]
    fn restore_once_pressure_clears() {
        let mut p = ThresholdPolicy::default();
        let s = snap(); // 60% free, well above the watermark
        // 60 free − (ceil(8) + 8 margin) = 44 of headroom, but only 3
        // leaves are out.
        assert_eq!(p.decide(&s, &ctx(3, 37)), Action::Restore { leaves: 3 });
        // Nothing swapped: no restore, fall through to idle.
        assert_eq!(p.decide(&s, &ctx(0, 40)), Action::Idle);
    }

    #[test]
    fn restore_is_hysteresis_bounded() {
        // The oscillation trap: free barely above the restore watermark
        // with many leaves out. Restoring them all would land free back
        // under the evict watermark; the budget must stop short.
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.capacity = 32;
        s.free = 9; // 28% > restore_above_free (25%)
        s.live = 23;
        // evict_floor = ceil(0.08*32)=3 + margin 8 = 11 > 9 free: no
        // safe restore headroom -> do NOT restore (idle), rather than
        // restore 8 and immediately re-trigger eviction.
        assert_eq!(p.decide(&s, &ctx(8, 0)), Action::Idle);
        // With real headroom the budget is the headroom, not everything.
        s.capacity = 100;
        s.free = 30;
        s.live = 70;
        match p.decide(&s, &ctx(50, 0)) {
            Action::Restore { leaves } => {
                assert_eq!(leaves, 30 - (8 + 8), "headroom-bounded restore");
            }
            other => panic!("expected a bounded restore, got {other:?}"),
        }
    }

    #[test]
    fn degraded_swap_skips_all_swap_traffic() {
        let mut p = ThresholdPolicy::default();
        // Hard pressure + evictable leaves: normally Evict…
        let mut s = snap();
        s.free = 4;
        s.live = 96;
        s.score = 0.9;
        let mut c = ctx(5, 40);
        c.swap_degraded = true;
        // …but a degraded backing must fall through to compaction.
        assert_eq!(p.decide(&s, &c), Action::CompactPool);
        // And with pressure cleared, no restore/prefetch either.
        let s2 = snap();
        let mut c2 = ctx(5, 40);
        c2.swap_degraded = true;
        c2.demand_faults = 3;
        assert_eq!(p.decide(&s2, &c2), Action::Idle);
    }

    #[test]
    fn deep_fault_queue_gates_eviction() {
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.free = 4;
        s.live = 96;
        s.score = 0.9;
        let mut c = ctx(0, 40);
        c.fault_queue_depth = p.queue_depth_hi; // demand faults saturate
        assert_eq!(p.decide(&s, &c), Action::CompactPool, "evicting into demand traffic");
        c.fault_queue_depth = p.queue_depth_hi - 1;
        assert_eq!(p.decide(&s, &c), Action::Evict { leaves: 8 });
    }

    #[test]
    fn demand_faults_trigger_prefetch_before_restore() {
        let mut p = ThresholdPolicy::default();
        let s = snap(); // 60% free: plenty of headroom
        let mut c = ctx(10, 30);
        c.demand_faults = 2;
        assert_eq!(p.decide(&s, &c), Action::Prefetch { leaves: 4 });
        // Budget never exceeds what is actually parked.
        let mut c2 = ctx(2, 30);
        c2.demand_faults = 1;
        assert_eq!(p.decide(&s, &c2), Action::Prefetch { leaves: 2 });
        // No demand faults last tick: bulk restore as before.
        assert_eq!(p.decide(&s, &ctx(10, 30)), Action::Restore { leaves: 10 });
    }

    #[test]
    fn hot_writers_defer_compaction_not_swap_relief() {
        let mut p = ThresholdPolicy::default();
        // Fragmented pool + hot writers: defer.
        let mut s = snap();
        s.score = 0.9;
        let mut c = ctx(0, 0);
        c.lock_waits = p.writer_waits_hi + 1;
        assert_eq!(p.decide(&s, &c), Action::Idle, "compaction must defer on hot writers");
        // Swap pressure outranks writer heat — running out of memory is
        // worse than a contended tick.
        s.free = 4;
        s.live = 96;
        let mut c2 = ctx(0, 40);
        c2.lock_waits = p.writer_waits_hi + 1;
        assert_eq!(p.decide(&s, &c2), Action::Evict { leaves: 8 });
    }

    #[test]
    fn writer_heat_sequence_defers_then_resumes_deterministically() {
        // Satellite: the full deterministic sequence — a fragmented
        // pool, writers hot for 3 ticks, then cooling. The policy must
        // emit Idle exactly while the per-tick wait delta is over
        // threshold and CompactPool on every other tick.
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.score = 0.9;
        let heat: [u64; 6] = [0, 200, 90, 70, 10, 0];
        let expect: Vec<Action> = heat
            .iter()
            .map(|&w| if w > p.writer_waits_hi { Action::Idle } else { Action::CompactPool })
            .collect();
        let got: Vec<Action> = heat
            .iter()
            .map(|&w| {
                let mut c = ctx(0, 0);
                c.lock_waits = w;
                p.decide(&s, &c)
            })
            .collect();
        assert_eq!(got, expect, "deferral must track the wait delta exactly");
        assert_eq!(got[0], Action::CompactPool);
        assert_eq!(got[1], Action::Idle);
        assert_eq!(got[5], Action::CompactPool);
    }

    #[test]
    fn tenant_quota_pressure_evicts_with_a_healthy_pool() {
        let mut p = ThresholdPolicy::default();
        let s = snap(); // 60% free: no pool pressure at all
        let mut c = ctx(0, 40);
        c.pressured_tenants = 1;
        c.pressured_evictable = 40;
        // Boosted budget: the pressured tenant is marching at its hard
        // watermark.
        assert_eq!(p.decide(&s, &c), Action::Evict { leaves: 16 });
        // Same gates as pressure eviction: full limbo parks it…
        let mut s2 = snap();
        s2.epoch.limbo = p.evict_leaves;
        assert_eq!(p.decide(&s2, &c), Action::Idle);
        // …and so does a deep fault queue.
        let mut c2 = c;
        c2.fault_queue_depth = p.queue_depth_hi;
        assert_eq!(p.decide(&snap(), &c2), Action::Idle);
        // Nothing evictable: quota pressure cannot conjure leaves.
        let mut c3 = ctx(0, 0);
        c3.pressured_tenants = 2;
        assert_eq!(p.decide(&snap(), &c3), Action::Idle);
        // Degraded swap kills it like every other swap action.
        let mut c4 = ctx(0, 40);
        c4.pressured_tenants = 1;
        c4.pressured_evictable = 40;
        c4.swap_degraded = true;
        assert_eq!(p.decide(&snap(), &c4), Action::Idle);
        // The budget is clamped to what pressured tenants actually
        // own, so the pass cannot spill onto healthy tenants.
        let mut c5 = ctx(0, 40);
        c5.pressured_tenants = 1;
        c5.pressured_evictable = 3;
        assert_eq!(p.decide(&snap(), &c5), Action::Evict { leaves: 3 });
    }

    #[test]
    fn latency_spike_sequence_defers_then_resumes_deterministically() {
        // Satellite: latency-aware back-off. A fragmented pool under a
        // storm of TLB invalidations, then seq-bracket retries, must
        // defer compaction exactly while either per-tick rate is over
        // threshold and resume the moment both cool.
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.score = 0.9;
        // (tlb_invalidations, seq_retries) per tick.
        let ticks: [(u64, u64); 7] =
            [(0, 0), (1000, 0), (300, 0), (256, 0), (0, 500), (0, 128), (10, 10)];
        let expect: Vec<Action> = ticks
            .iter()
            .map(|&(tlb, sr)| {
                if tlb > p.tlb_inval_hi || sr > p.seq_retry_hi {
                    Action::Idle
                } else {
                    Action::CompactPool
                }
            })
            .collect();
        let got: Vec<Action> = ticks
            .iter()
            .map(|&(tlb, sr)| {
                let mut c = ctx(0, 0);
                c.tlb_invalidations = tlb;
                c.seq_retries = sr;
                p.decide(&s, &c)
            })
            .collect();
        assert_eq!(got, expect, "deferral must track the latency deltas exactly");
        // Thresholds are exclusive: exactly-at-threshold ticks compact.
        assert_eq!(got[3], Action::CompactPool, "tlb == tlb_inval_hi must not defer");
        assert_eq!(got[5], Action::CompactPool, "sr == seq_retry_hi must not defer");
        assert_eq!(got[1], Action::Idle);
        assert_eq!(got[4], Action::Idle);
        // Latency heat must NOT defer swap relief, mirroring writer
        // heat: running out of memory is worse than a slow tick.
        s.free = 4;
        s.live = 96;
        let mut c = ctx(0, 40);
        c.tlb_invalidations = 10_000;
        c.seq_retries = 10_000;
        assert_eq!(p.decide(&s, &c), Action::Evict { leaves: 8 });
    }

    #[test]
    fn score_triggers_pool_compaction() {
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.score = 0.8;
        assert_eq!(p.decide(&s, &ctx(0, 0)), Action::CompactPool);
    }

    #[test]
    fn shard_local_score_triggers_shard_compaction() {
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.score = 0.1; // pool looks fine
        s.shard_scores = vec![0.1, 0.9];
        assert_eq!(p.decide(&s, &ctx(0, 0)), Action::CompactShard(1));
    }

    #[test]
    fn imbalance_triggers_rebalance_fullest_to_emptiest() {
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.shard_live = vec![45, 2];
        s.imbalance = 0.86;
        assert_eq!(p.decide(&s, &ctx(0, 0)), Action::Rebalance { from: 0, to: 1 });
    }

    // ---- deterministic sequence tests (no threads, no sleeps) ------
    //
    // The single-tick tests above pin individual triggers; these drive
    // the policy through a *closed feedback loop* — each decision is
    // applied to a synthetic pool model and the next snapshot fed back
    // — to prove the hysteresis and limbo gates make the evict/restore
    // pair converge instead of oscillating.

    /// Minimal pool model the policy's eviction decisions act on.
    /// Evictions/restores move whole leaves (1 block each); `limbo`
    /// is controlled by the test (it models reader quiescence).
    struct PoolModel {
        capacity: usize,
        free: usize,
        swapped: usize,
        evictable_resident: usize,
        limbo: usize,
    }

    impl PoolModel {
        fn snapshot(&self) -> (FragSnapshot, PolicyCtx) {
            let s = FragSnapshot {
                capacity: self.capacity,
                live: self.capacity - self.free,
                free: self.free,
                epoch: crate::pmem::EpochStats {
                    limbo: self.limbo,
                    ..Default::default()
                },
                ..FragSnapshot::default()
            };
            let ctx = PolicyCtx {
                swapped_out: self.swapped,
                evictable_resident: self.evictable_resident,
                ..Default::default()
            };
            (s, ctx)
        }

        /// Apply one decision; returns the action for the trace.
        fn step(&mut self, p: &mut ThresholdPolicy) -> Action {
            let (s, ctx) = self.snapshot();
            let a = p.decide(&s, &ctx);
            match a {
                Action::Evict { leaves } => {
                    let moved = leaves.min(self.evictable_resident);
                    self.evictable_resident -= moved;
                    self.swapped += moved;
                    self.free += moved; // modeled post-quiescence
                }
                Action::Restore { leaves } => {
                    assert!(leaves <= self.free, "restore budget exceeds free blocks");
                    self.swapped -= leaves.min(self.swapped);
                    self.evictable_resident += leaves;
                    self.free -= leaves;
                }
                _ => {}
            }
            a
        }
    }

    #[test]
    fn evict_restore_feedback_reaches_a_fixpoint_without_oscillation() {
        // Start under hard pressure with plenty evictable. The loop
        // must evict to relieve pressure, possibly restore *bounded*
        // amounts once clear, and settle — never alternating
        // Evict -> Restore -> Evict (each such cycle would be wasted
        // swap I/O plus an arena-wide shootdown).
        let mut p = ThresholdPolicy::default();
        let mut m = PoolModel {
            capacity: 100,
            free: 4,
            swapped: 0,
            evictable_resident: 60,
            limbo: 0,
        };
        let mut trace = Vec::new();
        // Phase 1: sustained pressure until the policy stops reacting.
        for _ in 0..32 {
            trace.push(m.step(&mut p));
        }
        // Phase 2: the application releases memory (pressure clears for
        // real), putting free well above the restore watermark — the
        // parked leaves must come back, bounded, without re-eviction.
        m.free += 30;
        for _ in 0..32 {
            trace.push(m.step(&mut p));
        }
        assert!(
            trace.iter().any(|a| matches!(a, Action::Restore { .. })),
            "cleared pressure never restored the parked leaves: {trace:?}"
        );
        assert_eq!(m.swapped, 0, "not every parked leaf came back: {trace:?}");
        let evict_after_restore = trace
            .windows(2)
            .any(|w| matches!(w[1], Action::Evict { .. }) && matches!(w[0], Action::Restore { .. }));
        assert!(
            !evict_after_restore,
            "restore handed blocks straight back to eviction: {trace:?}"
        );
        // More strongly: once any Restore has fired, no Evict ever
        // follows in the noiseless model (hysteresis margin holds).
        if let Some(first_restore) = trace.iter().position(|a| matches!(a, Action::Restore { .. })) {
            assert!(
                !trace[first_restore..].iter().any(|a| matches!(a, Action::Evict { .. })),
                "eviction re-fired after restores began: {trace:?}"
            );
        }
        // And the loop settles: the tail is all Idle (nothing restored
        // pushes free back under any trigger in a quiet pool).
        assert!(
            trace[trace.len() - 8..].iter().all(|a| *a == Action::Idle),
            "no fixpoint reached: {trace:?}"
        );
        assert!(
            trace.iter().any(|a| matches!(a, Action::Evict { .. })),
            "pressure never relieved: {trace:?}"
        );
    }

    #[test]
    fn restore_budget_never_reenters_the_eviction_band() {
        // Sweep every free level in the restore-eligible range with a
        // deep swap backlog: whatever budget the policy grants, applying
        // it must leave the *very next* decision non-evicting. This is
        // the two-tick oscillation proof, exhaustively over the band.
        let p0 = ThresholdPolicy::default();
        let capacity = 100usize;
        let restore_floor = (p0.restore_above_free * capacity as f64) as usize + 1;
        for free in restore_floor..=capacity {
            let mut p = ThresholdPolicy::default();
            let mut m = PoolModel {
                capacity,
                free,
                swapped: 50,
                evictable_resident: 0,
                limbo: 0,
            };
            let a = m.step(&mut p);
            if matches!(a, Action::Restore { .. }) {
                m.evictable_resident = 50; // give eviction every chance
                let next = m.step(&mut p);
                assert!(
                    !matches!(next, Action::Evict { .. }),
                    "free={free}: {a:?} then {next:?} — restore crossed both watermarks"
                );
            }
        }
    }

    #[test]
    fn limbo_gate_holds_under_sustained_pressure_until_drain() {
        // A stalled reader pins `limbo` at one evict budget. However
        // long the pressure lasts, the policy must not demand more
        // eviction (it cannot free anything) — and the moment limbo
        // drains below the budget, eviction resumes.
        let mut p = ThresholdPolicy::default();
        let mut m = PoolModel {
            capacity: 100,
            free: 4,
            swapped: 8,
            evictable_resident: 40,
            limbo: ThresholdPolicy::default().evict_leaves,
        };
        for tick in 0..32 {
            let (s, ctx) = m.snapshot();
            let a = p.decide(&s, &ctx);
            assert!(
                !matches!(a, Action::Evict { .. }),
                "tick {tick}: evicted into a full limbo: {a:?}"
            );
        }
        m.limbo = 0; // readers quiesced
        assert!(
            matches!(m.step(&mut p), Action::Evict { .. }),
            "eviction must resume once limbo drains"
        );
    }

    #[test]
    fn eviction_stops_exactly_when_pressure_clears_not_at_exhaustion() {
        // Feedback run with a small evictable set: eviction must stop
        // as soon as free crosses the watermark, leaving the remaining
        // evictable leaves resident (eviction is pressure-driven, not
        // greedy).
        let mut p = ThresholdPolicy::default();
        let mut m = PoolModel {
            capacity: 1000,
            free: 60, // 6% < evict_below_free (8%)
            swapped: 0,
            evictable_resident: 400,
            limbo: 0,
        };
        for _ in 0..64 {
            m.step(&mut p);
        }
        assert!(
            m.evictable_resident > 300,
            "policy kept evicting far past the watermark: {} resident left",
            m.evictable_resident
        );
        let (s, _) = m.snapshot();
        assert!(s.free_ratio() >= p.evict_below_free, "pressure never cleared");
    }
}
