//! Pluggable daemon policy: *what* to do about a telemetry sample.
//!
//! The mechanism lives in [`crate::mmd::Compactor`]; the policy only
//! maps a [`FragSnapshot`] (plus the current swapped-out count) to one
//! [`Action`] per tick. This is the Cichlid-style split — a dedicated
//! service with its own policy loop — kept narrow so experiments can
//! substitute policies without touching the engine.
//!
//! [`ThresholdPolicy`] is the shipped implementation, priority-ordered:
//!
//! 1. **Swap pressure** — free ratio below the low watermark: evict
//!    cold leaves of evictable registrations to disk.
//! 2. **Pressure cleared** — leaves parked in swap and free ratio above
//!    the high watermark: restore them.
//! 3. **Pool fragmentation** — score above threshold: compact the pool
//!    (sink leaves into the lowest free blocks).
//! 4. **Shard-local fragmentation** — the pool looks fine but one
//!    shard's free space is shredded: compact inside that shard.
//! 5. **Shard imbalance** — occupancy spread above threshold: migrate
//!    leaves from the fullest shard's range into the emptiest's, so
//!    thread-affine allocation stops degenerating into cross-shard
//!    stealing.
//! 6. Otherwise **idle**.

use crate::mmd::stats::FragSnapshot;

/// One daemon decision. Budgets (how many leaves per tick) come from
/// [`crate::mmd::MmdConfig`], not the policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Nothing to do this tick.
    Idle,
    /// Sink leaves into the lowest free blocks of the whole pool.
    CompactPool,
    /// Sink leaves into the lowest free blocks of one shard's range.
    CompactShard(usize),
    /// Migrate leaves out of shard `from`'s range into shard `to`'s.
    Rebalance {
        /// Source shard (overloaded).
        from: usize,
        /// Destination shard (underloaded).
        to: usize,
    },
    /// Evict up to `leaves` cold leaves to swap.
    Evict {
        /// Eviction budget for this tick.
        leaves: usize,
    },
    /// Fault up to `leaves` swapped-out leaves back in.
    Restore {
        /// Restore budget for this tick — bounded by the policy so
        /// restoring cannot push the pool straight back into its own
        /// eviction band (watermark hysteresis).
        leaves: usize,
    },
}

/// What the daemon knows beyond the telemetry sample: the registry's
/// eviction state. Keeps `decide` honest — a policy that cannot see
/// that nothing is evictable would demand eviction forever under
/// sustained pressure and starve compaction.
#[derive(Clone, Copy, Debug, Default)]
pub struct PolicyCtx {
    /// Leaves currently parked in swap across the registry.
    pub swapped_out: usize,
    /// Resident leaves of evictable registrations that eviction could
    /// still take (0 when nothing is evictable or swap is unavailable).
    pub evictable_resident: usize,
}

/// A daemon policy. `Send` so it can move onto the daemon thread;
/// stateful policies (hysteresis, EWMA smoothing) are expected — the
/// daemon calls `decide` once per tick from its own thread only.
pub trait Policy: Send {
    /// Map one telemetry sample (+ eviction context) to one action.
    fn decide(&mut self, snap: &FragSnapshot, ctx: &PolicyCtx) -> Action;
}

/// Threshold-triggered policy (see the module docs for the ordering).
#[derive(Clone, Copy, Debug)]
pub struct ThresholdPolicy {
    /// Compact the pool when its score exceeds this.
    pub score_hi: f64,
    /// Compact a single shard when its local score exceeds this (and
    /// the pool score did not trip).
    pub shard_score_hi: f64,
    /// Rebalance when the occupancy spread exceeds this.
    pub imbalance_hi: f64,
    /// Evict when the free ratio falls below this (swap pressure).
    pub evict_below_free: f64,
    /// Restore swapped leaves when the free ratio rises above this.
    pub restore_above_free: f64,
    /// Leaves to evict per pressure tick.
    pub evict_leaves: usize,
}

impl Default for ThresholdPolicy {
    fn default() -> Self {
        ThresholdPolicy {
            score_hi: 0.35,
            shard_score_hi: 0.6,
            imbalance_hi: 0.5,
            evict_below_free: 0.08,
            restore_above_free: 0.25,
            evict_leaves: 8,
        }
    }
}

impl Policy for ThresholdPolicy {
    fn decide(&mut self, s: &FragSnapshot, ctx: &PolicyCtx) -> Action {
        let free = s.free_ratio();
        // Evict only when eviction can actually make progress —
        // otherwise sustained pressure must fall through to compaction
        // instead of demanding the impossible every tick. Progress
        // needs (a) evictable resident leaves and (b) limbo that is
        // draining: evicted blocks are *retired*, not freed, so while a
        // stalled reader pins a backlog of at least one evict budget,
        // more eviction only burns swap I/O and TLB shootdowns without
        // freeing anything.
        if free < self.evict_below_free
            && ctx.evictable_resident > 0
            && s.epoch.limbo < self.evict_leaves
        {
            return Action::Evict {
                leaves: self.evict_leaves,
            };
        }
        if ctx.swapped_out > 0 && free > self.restore_above_free {
            // Restore only what keeps the pool clear of the eviction
            // band, with one evict budget of margin: without the cap, a
            // single restore tick can cross both watermarks and the
            // evict/restore pair oscillates deterministically (each
            // cycle costing swap I/O and arena-wide TLB shootdowns).
            let evict_floor =
                (self.evict_below_free * s.capacity as f64).ceil() as usize + self.evict_leaves;
            let headroom = s.free.saturating_sub(evict_floor);
            let leaves = headroom.min(ctx.swapped_out);
            if leaves > 0 {
                return Action::Restore { leaves };
            }
        }
        if s.score > self.score_hi {
            return Action::CompactPool;
        }
        if let Some((worst, &sc)) = s
            .shard_scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
        {
            if sc > self.shard_score_hi {
                return Action::CompactShard(worst);
            }
        }
        if s.imbalance > self.imbalance_hi && s.shard_blocks.len() > 1 {
            let occ = |i: usize| s.occupancy(i);
            let mut from = 0;
            let mut to = 0;
            for i in 1..s.shard_blocks.len() {
                if occ(i) > occ(from) {
                    from = i;
                }
                if occ(i) < occ(to) {
                    to = i;
                }
            }
            if from != to {
                return Action::Rebalance { from, to };
            }
        }
        Action::Idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap() -> FragSnapshot {
        FragSnapshot {
            capacity: 100,
            live: 40,
            free: 60,
            shard_live: vec![20, 20],
            shard_blocks: vec![50, 50],
            shard_scores: vec![0.0, 0.0],
            ..FragSnapshot::default()
        }
    }

    fn ctx(swapped_out: usize, evictable_resident: usize) -> PolicyCtx {
        PolicyCtx {
            swapped_out,
            evictable_resident,
        }
    }

    #[test]
    fn healthy_pool_idles() {
        let mut p = ThresholdPolicy::default();
        assert_eq!(p.decide(&snap(), &ctx(0, 0)), Action::Idle);
    }

    #[test]
    fn swap_pressure_outranks_everything() {
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.free = 4;
        s.live = 96;
        s.score = 0.9; // fragmented too — eviction still wins
        assert_eq!(p.decide(&s, &ctx(0, 40)), Action::Evict { leaves: 8 });
    }

    #[test]
    fn evict_waits_for_limbo_to_drain() {
        // A stalled reader pins a backlog of retired blocks: evicting
        // more cannot free memory, so pressure falls through to
        // compaction until the limbo drains below one evict budget.
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.free = 4;
        s.live = 96;
        s.score = 0.9;
        s.epoch.limbo = 8; // >= evict_leaves
        assert_eq!(p.decide(&s, &ctx(0, 40)), Action::CompactPool);
        s.epoch.limbo = 3; // draining again
        assert_eq!(p.decide(&s, &ctx(0, 40)), Action::Evict { leaves: 8 });
    }

    #[test]
    fn pressure_without_evictable_leaves_falls_through_to_compaction() {
        // Nothing registered evictable (or swap unavailable): demanding
        // eviction forever would starve compaction — the score trigger
        // must still fire.
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.free = 4;
        s.live = 96;
        s.score = 0.9;
        assert_eq!(p.decide(&s, &ctx(0, 0)), Action::CompactPool);
    }

    #[test]
    fn restore_once_pressure_clears() {
        let mut p = ThresholdPolicy::default();
        let s = snap(); // 60% free, well above the watermark
        // 60 free − (ceil(8) + 8 margin) = 44 of headroom, but only 3
        // leaves are out.
        assert_eq!(p.decide(&s, &ctx(3, 37)), Action::Restore { leaves: 3 });
        // Nothing swapped: no restore, fall through to idle.
        assert_eq!(p.decide(&s, &ctx(0, 40)), Action::Idle);
    }

    #[test]
    fn restore_is_hysteresis_bounded() {
        // The oscillation trap: free barely above the restore watermark
        // with many leaves out. Restoring them all would land free back
        // under the evict watermark; the budget must stop short.
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.capacity = 32;
        s.free = 9; // 28% > restore_above_free (25%)
        s.live = 23;
        // evict_floor = ceil(0.08*32)=3 + margin 8 = 11 > 9 free: no
        // safe restore headroom -> do NOT restore (idle), rather than
        // restore 8 and immediately re-trigger eviction.
        assert_eq!(p.decide(&s, &ctx(8, 0)), Action::Idle);
        // With real headroom the budget is the headroom, not everything.
        s.capacity = 100;
        s.free = 30;
        s.live = 70;
        match p.decide(&s, &ctx(50, 0)) {
            Action::Restore { leaves } => {
                assert_eq!(leaves, 30 - (8 + 8), "headroom-bounded restore");
            }
            other => panic!("expected a bounded restore, got {other:?}"),
        }
    }

    #[test]
    fn score_triggers_pool_compaction() {
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.score = 0.8;
        assert_eq!(p.decide(&s, &ctx(0, 0)), Action::CompactPool);
    }

    #[test]
    fn shard_local_score_triggers_shard_compaction() {
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.score = 0.1; // pool looks fine
        s.shard_scores = vec![0.1, 0.9];
        assert_eq!(p.decide(&s, &ctx(0, 0)), Action::CompactShard(1));
    }

    #[test]
    fn imbalance_triggers_rebalance_fullest_to_emptiest() {
        let mut p = ThresholdPolicy::default();
        let mut s = snap();
        s.shard_live = vec![45, 2];
        s.imbalance = 0.86;
        assert_eq!(p.decide(&s, &ctx(0, 0)), Action::Rebalance { from: 0, to: 1 });
    }
}
