//! The shared simulation cost model for Table 2 / Figure 4.
//!
//! # Cost model
//!
//! [`crate::memsim::Hierarchy::access`] returns the *serialized* latency
//! of one access. Out-of-order cores overlap independent misses
//! (memory-level parallelism), but serialize dependent pointer chases.
//! The model therefore distinguishes:
//!
//! * **independent** accesses (array scan elements, leaf-data streams):
//!   charged `l1 + (cycles - l1) / mlp` — the miss portion overlaps with
//!   `mlp` in-flight neighbors;
//! * **dependent** accesses (tree pointer walks: each level's address
//!   comes from the previous load): charged in full, summed.
//!
//! Per-element loop compute (`compute` cycles) is added to every element
//! so ratios are runtime-like rather than pure-memory. The tree paths
//! also charge the paper's depth-check branch (§4.2: "our implementation
//! checks the depth of the tree before accessing data") and the
//! iterator's bookkeeping on optimized runs.

use crate::memsim::Hierarchy;
use crate::trees::{TreeGeometry, TreeTraceModel};
use crate::testutil::Rng;

/// Tunable cost-model constants (defaults calibrated in EXPERIMENTS.md).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Memory-level parallelism for independent access streams.
    pub mlp: f64,
    /// Overlap factor for page walks of independent accesses (walks of
    /// neighboring elements proceed concurrently; §4.2's "hardware
    /// optimizations ... reduced the time to handle each TLB miss").
    pub walk_mlp: f64,
    /// Loop compute cycles per element.
    pub compute: f64,
    /// Depth-check branch cost on every naive tree access (cycles).
    pub depth_check: f64,
    /// Iterator bookkeeping per access on optimized runs (cycles).
    pub iter_overhead: f64,
    /// L1 hit latency (subtracted before applying MLP overlap).
    pub l1_latency: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        let env = |k: &str, d: f64| {
            std::env::var(k)
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(d)
        };
        // Defaults calibrated against the paper's Table 2 shape
        // (EXPERIMENTS.md §Calibration); overridable per-run via env
        // for sensitivity studies.
        CostModel {
            mlp: env("NVM_MLP", 4.0),
            walk_mlp: env("NVM_WALK_MLP", 1.5),
            compute: env("NVM_COMPUTE", 1.0),
            depth_check: env("NVM_DEPTH_CHECK", 1.5),
            iter_overhead: env("NVM_ITER_OVERHEAD", 0.3),
            l1_latency: 4.0,
        }
    }
}

impl CostModel {
    /// Effective cycles for one independent access of raw latency `c`.
    #[inline]
    pub fn independent(&self, c: u64) -> f64 {
        let c = c as f64;
        if c <= self.l1_latency {
            c
        } else {
            self.l1_latency + (c - self.l1_latency) / self.mlp
        }
    }

    /// Effective cycles for one independent access given split
    /// `(translation, data)` latencies: the walk overlaps with
    /// neighboring elements' work, the data miss with other data misses.
    #[inline]
    pub fn independent_split(&self, trans: u64, data: u64) -> f64 {
        self.independent(data) + trans as f64 / self.walk_mlp
    }

    /// Effective per-element cycles for a *random-access chain*
    /// (translation → [interior pointers →] data). Chains of different
    /// elements are mutually independent, so the OoO window overlaps
    /// them: throughput ≈ chain latency / cross-element MLP. Used by
    /// GUPS and the hash-probe, where this overlap dominates (paper
    /// §4.2: hardware hid much of the strided/random TLB-miss cost).
    #[inline]
    pub fn random_chain(&self, chain_cycles: f64) -> f64 {
        chain_cycles / self.mlp.max(1.0)
    }
}

/// Scan pattern for the microbenchmarks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanKind {
    /// Every element in order (Table 2 "Linear Scan").
    Linear,
    /// Every `stride`-th element (Table 2 "Strided Scan", stride 1024
    /// elements = 4 KB).
    Strided(usize),
    /// Uniform random elements (GUPS).
    Random,
}

/// Result of one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    /// Mean cycles per element access (the paper's measured quantity).
    pub cycles_per_elem: f64,
    /// Elements simulated.
    pub elems: u64,
    /// DTLB miss rate observed.
    pub tlb_miss_rate: f64,
}

/// Number of accesses to simulate per run: enough to reach steady state
/// at every size (working sets cycle within this budget) while keeping
/// full Table 2 sweeps under a minute.
pub const DEFAULT_SAMPLE: u64 = 2_000_000;

fn indices(kind: ScanKind, len: usize, sample: u64, rng: &mut Rng) -> impl Iterator<Item = usize> + '_ {
    let mut i = 0usize;
    let mut count = 0u64;
    std::iter::from_fn(move || {
        if count >= sample {
            return None;
        }
        count += 1;
        let idx = match kind {
            ScanKind::Linear => {
                let v = i;
                i = (i + 1) % len;
                v
            }
            ScanKind::Strided(s) => {
                let v = i;
                i = (i + s) % len.max(1);
                v
            }
            ScanKind::Random => rng.below(len as u64) as usize,
        };
        Some(idx)
    })
}

/// Simulate a **contiguous array** scan: one independent access per
/// element at `base + i*elem_size`.
pub fn sim_array_scan(
    h: &mut Hierarchy,
    model: &CostModel,
    len: usize,
    elem_size: usize,
    kind: ScanKind,
    sample: u64,
    seed: u64,
) -> SimResult {
    let mut rng = Rng::new(seed);
    let base = 0x10_0000u64; // arbitrary aligned base
    let mut cycles = 0.0f64;
    let mut n = 0u64;
    let random = kind == ScanKind::Random;
    for i in indices(kind, len, sample, &mut rng) {
        let addr = base + (i * elem_size) as u64;
        let (t, d) = h.access_split(addr);
        cycles += if random {
            model.random_chain((t + d) as f64)
        } else {
            model.independent_split(t, d)
        } + model.compute;
        n += 1;
    }
    SimResult {
        cycles_per_elem: cycles / n as f64,
        elems: n,
        tlb_miss_rate: h.stats().tlb_miss_rate(),
    }
}

/// Simulate a **naive tree** scan: every element access walks root→leaf
/// (dependent chain) plus the depth-check branch (Table 2 "Naive" rows).
pub fn sim_tree_scan_naive(
    h: &mut Hierarchy,
    model: &CostModel,
    geo: TreeGeometry,
    kind: ScanKind,
    sample: u64,
    seed: u64,
) -> SimResult {
    let tm = TreeTraceModel::new(geo, 0x10_0000);
    let mut rng = Rng::new(seed);
    let mut path = Vec::with_capacity(4);
    let mut cycles = 0.0f64;
    let mut n = 0u64;
    let random = kind == ScanKind::Random;
    for i in indices(kind, geo.len, sample, &mut rng) {
        tm.access_path(i, &mut path);
        // Interior pointer loads are a dependent chain: full latency
        // within the element.
        let (ptrs, leaf) = path.split_at(path.len() - 1);
        let mut chain = 0.0f64;
        for &a in ptrs {
            chain += h.access(a) as f64;
        }
        if random {
            // Chains of different elements overlap in the OoO window.
            let (t, d) = h.access_split(leaf[0]);
            chain += (t + d) as f64 + model.depth_check;
            cycles += model.random_chain(chain);
        } else {
            // The final data load overlaps with *neighbouring* element
            // accesses once its address is known (like array elements);
            // the interior chain is charged serialized (it is also the
            // per-element instruction cost of the walk).
            let (t, d) = h.access_split(leaf[0]);
            cycles += chain + model.independent_split(t, d) + model.depth_check;
        }
        cycles += model.compute;
        n += 1;
    }
    SimResult {
        cycles_per_elem: cycles / n as f64,
        elems: n,
        tlb_miss_rate: h.stats().tlb_miss_rate(),
    }
}

/// Simulate an **iterator-optimized tree** scan (Table 2 "Iter" rows,
/// Figure 2): accesses within the cached leaf touch only the leaf; the
/// full walk happens on leaf-boundary crossings.
pub fn sim_tree_scan_iter(
    h: &mut Hierarchy,
    model: &CostModel,
    geo: TreeGeometry,
    kind: ScanKind,
    sample: u64,
    seed: u64,
) -> SimResult {
    let tm = TreeTraceModel::new(geo, 0x10_0000);
    let mut rng = Rng::new(seed);
    let mut path = Vec::with_capacity(4);
    let mut cycles = 0.0f64;
    let mut n = 0u64;
    let random = kind == ScanKind::Random;
    let mut cached_leaf = usize::MAX;
    for i in indices(kind, geo.len, sample, &mut rng) {
        let leaf = geo.leaf_of(i);
        if leaf != cached_leaf {
            // Boundary: full dependent walk to refill the leaf cache.
            tm.access_path(i, &mut path);
            let (ptrs, data) = path.split_at(path.len() - 1);
            let mut chain = 0.0f64;
            for &a in ptrs {
                chain += h.access(a) as f64;
            }
            let (t, d) = h.access_split(data[0]);
            cycles += if random {
                model.random_chain(chain + (t + d) as f64)
            } else {
                chain + model.independent_split(t, d)
            };
            cached_leaf = leaf;
        } else {
            // Leaf-cache hit: single data access, stream-overlapped.
            let (t, d) = h.access_split(tm.leaf_elem_addr(i));
            cycles += if random {
                model.random_chain((t + d) as f64)
            } else {
                model.independent_split(t, d)
            };
        }
        cycles += model.iter_overhead + model.compute;
        n += 1;
    }
    SimResult {
        cycles_per_elem: cycles / n as f64,
        elems: n,
        tlb_miss_rate: h.stats().tlb_miss_rate(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::{AddressMode, PageSize};

    const BS: usize = 32 * 1024;

    fn phys() -> Hierarchy {
        Hierarchy::kaby_lake(AddressMode::Physical)
    }
    fn virt() -> Hierarchy {
        Hierarchy::kaby_lake(AddressMode::Virtual(PageSize::P4K))
    }

    #[test]
    fn cost_model_overlap() {
        let m = CostModel::default();
        assert_eq!(m.independent(4), 4.0);
        assert_eq!(m.independent(250), 4.0 + 246.0 / 4.0);
    }

    #[test]
    fn linear_iter_tree_close_to_array() {
        // Table 2's headline: with the Iterator optimization, linear
        // scans over physical trees cost ≈ the same as arrays on VM.
        let m = CostModel::default();
        let len = 1 << 26; // 256 MB of f32: depth 3
        let geo = TreeGeometry::new(BS, 4, len).unwrap();
        let a = sim_array_scan(&mut virt(), &m, len, 4, ScanKind::Linear, 500_000, 1);
        let t = sim_tree_scan_iter(&mut phys(), &m, geo, ScanKind::Linear, 500_000, 1);
        let ratio = t.cycles_per_elem / a.cycles_per_elem;
        assert!(
            (0.7..=1.35).contains(&ratio),
            "linear iter ratio {ratio:.2} (tree {:.2} vs array {:.2})",
            t.cycles_per_elem,
            a.cycles_per_elem
        );
    }

    #[test]
    fn linear_naive_tree_slower_and_plateaus() {
        let m = CostModel::default();
        let mut ratios = Vec::new();
        for len in [1 << 20, 1 << 26] {
            // 4 MB (depth 2), 256 MB (depth 3)
            let geo = TreeGeometry::new(BS, 4, len).unwrap();
            let a = sim_array_scan(&mut virt(), &m, len, 4, ScanKind::Linear, 300_000, 2);
            let t = sim_tree_scan_naive(&mut phys(), &m, geo, ScanKind::Linear, 300_000, 2);
            ratios.push(t.cycles_per_elem / a.cycles_per_elem);
        }
        assert!(ratios[0] > 1.3, "depth-2 naive ratio {:.2}", ratios[0]);
        assert!(ratios[1] > ratios[0], "deeper should cost more: {ratios:?}");
    }

    #[test]
    fn strided_large_arrays_thrash_tlb() {
        let m = CostModel::default();
        let len = 1 << 30; // 4 GB of f32
        let r = sim_array_scan(&mut virt(), &m, len, 4, ScanKind::Strided(1024), 300_000, 3);
        assert!(
            r.tlb_miss_rate > 0.9,
            "expected paper's >90% TLB miss rate, got {:.3}",
            r.tlb_miss_rate
        );
    }

    #[test]
    fn random_physical_beats_virtual() {
        // Figure 4's direction at ≥16 GB: remove translation, win.
        let m = CostModel { mlp: 2.0, ..Default::default() };
        let len = 1usize << 32; // 16 GB of f32 (modeled)
        let geo = TreeGeometry::new(BS, 4, len).unwrap();
        let a = sim_array_scan(&mut virt(), &m, len, 4, ScanKind::Random, 300_000, 4);
        let t = sim_tree_scan_iter(&mut phys(), &m, geo, ScanKind::Random, 300_000, 4);
        let ratio = t.cycles_per_elem / a.cycles_per_elem;
        assert!(ratio < 1.1, "random 16 GB: tree/array = {ratio:.2}, want < 1.1");
    }

    #[test]
    fn deterministic_given_seed() {
        let m = CostModel::default();
        let r1 = sim_array_scan(&mut virt(), &m, 1 << 22, 4, ScanKind::Random, 100_000, 7);
        let r2 = sim_array_scan(&mut virt(), &m, 1 << 22, 4, ScanKind::Random, 100_000, 7);
        assert_eq!(r1.cycles_per_elem, r2.cycles_per_elem);
    }
}
