//! Black-Scholes pricing (PARSEC's `blackscholes`, Figure 5).
//!
//! Pure-Rust scalar pricing for the CPU baselines (contiguous vs tree
//! layouts), numerically cross-checked in `rust/tests/` against the
//! AOT-compiled Pallas kernel executed through PJRT — proving the
//! L3↔L1 boundary agrees end to end.

use crate::pmem::BlockAlloc;
use crate::trees::TreeArray;

/// One option's market parameters.
#[derive(Clone, Copy, Debug)]
pub struct Option1 {
    /// Spot price.
    pub spot: f32,
    /// Strike price.
    pub strike: f32,
    /// Time to maturity (years).
    pub tmat: f32,
}

/// erf via the Abramowitz–Stegun 7.1.26 rational approximation
/// (|error| ≤ 1.5e-7, well inside f32 tolerance; matches
/// `jax.lax.erf` to ~1e-6 on the pricing range).
#[inline]
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
#[inline]
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Price one European option; returns (call, put).
#[inline]
pub fn price(o: Option1, rate: f32, vol: f32) -> (f32, f32) {
    let (s, k, t) = (o.spot as f64, o.strike as f64, o.tmat as f64);
    let (r, v) = (rate as f64, vol as f64);
    let sqrt_t = t.sqrt();
    let sig_t = v * sqrt_t;
    let d1 = ((s / k).ln() + (r + 0.5 * v * v) * t) / sig_t;
    let d2 = d1 - sig_t;
    let disc = (-r * t).exp();
    let call = s * norm_cdf(d1) - k * disc * norm_cdf(d2);
    let put = k * disc * norm_cdf(-d2) - s * norm_cdf(-d1);
    (call as f32, put as f32)
}

/// Price a contiguous portfolio (spot/strike/tmat parallel slices) into
/// `call`/`put`. The Figure 5 VM baseline.
pub fn price_contig(
    spot: &[f32],
    strike: &[f32],
    tmat: &[f32],
    rate: f32,
    vol: f32,
    call: &mut [f32],
    put: &mut [f32],
) {
    for i in 0..spot.len() {
        let (c, p) = price(
            Option1 {
                spot: spot[i],
                strike: strike[i],
                tmat: tmat[i],
            },
            rate,
            vol,
        );
        call[i] = c;
        put[i] = p;
    }
}

/// Price tree-layout arrays via naive per-element walks.
pub fn price_tree_naive<A: BlockAlloc>(
    spot: &TreeArray<'_, f32, A>,
    strike: &TreeArray<'_, f32, A>,
    tmat: &TreeArray<'_, f32, A>,
    rate: f32,
    vol: f32,
    call: &mut TreeArray<'_, f32, A>,
    put: &mut TreeArray<'_, f32, A>,
) {
    for i in 0..spot.len() {
        // SAFETY: all arrays share len (asserted by callers/tests).
        let (c, p) = unsafe {
            price(
                Option1 {
                    spot: spot.get_unchecked(i),
                    strike: strike.get_unchecked(i),
                    tmat: tmat.get_unchecked(i),
                },
                rate,
                vol,
            )
        };
        unsafe {
            call.set_unchecked(i, c);
            put.set_unchecked(i, p);
        }
    }
}

/// Price tree-layout arrays leaf-at-a-time (the Iterator-style
/// optimization: one walk per 32 KB leaf, then contiguous slices).
pub fn price_tree_iter<A: BlockAlloc>(
    spot: &TreeArray<'_, f32, A>,
    strike: &TreeArray<'_, f32, A>,
    tmat: &TreeArray<'_, f32, A>,
    rate: f32,
    vol: f32,
    call: &mut TreeArray<'_, f32, A>,
    put: &mut TreeArray<'_, f32, A>,
) {
    for leaf in 0..spot.nleaves() {
        let s = spot.leaf_slice(leaf);
        let k = strike.leaf_slice(leaf);
        let t = tmat.leaf_slice(leaf);
        // Price into temporaries then copy into the output leaves (the
        // borrow checker forbids holding two &mut leaves of one array).
        let mut cbuf = vec![0.0f32; s.len()];
        let mut pbuf = vec![0.0f32; s.len()];
        price_contig(s, k, t, rate, vol, &mut cbuf, &mut pbuf);
        call.leaf_slice_mut(leaf).copy_from_slice(&cbuf);
        put.leaf_slice_mut(leaf).copy_from_slice(&pbuf);
    }
}

/// Deterministic synthetic portfolio (matches the Python tests' ranges).
pub fn synth_portfolio(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = crate::testutil::Rng::new(seed);
    let spot = (0..n).map(|_| rng.f32_range(5.0, 200.0)).collect();
    let strike = (0..n).map(|_| rng.f32_range(5.0, 200.0)).collect();
    let tmat = (0..n).map(|_| rng.f32_range(0.05, 3.0)).collect();
    (spot, strike, tmat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;
    use crate::workloads::linear_scan::tree_from;

    const RATE: f32 = 0.03;
    const VOL: f32 = 0.25;

    #[test]
    fn erf_reference_points() {
        // A&S 7.1.26 has |error| <= 1.5e-7 (the polynomial does not
        // vanish exactly at 0).
        assert!((erf(0.0)).abs() < 1e-8);
        assert!((erf(1.0) - 0.8427007929).abs() < 2e-7);
        assert!((erf(-1.0) + 0.8427007929).abs() < 2e-7);
        assert!((erf(3.0) - 0.9999779095).abs() < 2e-7);
    }

    #[test]
    fn put_call_parity() {
        let (s, k, t) = (100.0f32, 90.0f32, 1.5f32);
        let (c, p) = price(Option1 { spot: s, strike: k, tmat: t }, RATE, VOL);
        let parity = s - k * (-RATE * t).exp();
        assert!((c - p - parity).abs() < 1e-3, "parity violated: {}", c - p - parity);
    }

    #[test]
    fn deep_itm_call() {
        let (c, _) = price(
            Option1 { spot: 1000.0, strike: 1.0, tmat: 1.0 },
            RATE,
            VOL,
        );
        let expect = 1000.0 - 1.0 * (-RATE).exp();
        assert!((c - expect).abs() / expect < 1e-4);
    }

    #[test]
    fn layouts_price_identically() {
        let a = BlockAllocator::new(4096, 1 << 12).unwrap();
        let n = 4096 / 4 * 5 + 33;
        let (s, k, t) = synth_portfolio(n, 3);
        let mut call_c = vec![0.0f32; n];
        let mut put_c = vec![0.0f32; n];
        price_contig(&s, &k, &t, RATE, VOL, &mut call_c, &mut put_c);

        let ts = tree_from(&a, &s);
        let tk = tree_from(&a, &k);
        let tt = tree_from(&a, &t);
        let mut tc: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        let mut tp: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        price_tree_naive(&ts, &tk, &tt, RATE, VOL, &mut tc, &mut tp);
        assert_eq!(tc.to_vec(), call_c);
        assert_eq!(tp.to_vec(), put_c);

        let mut tc2: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        let mut tp2: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        price_tree_iter(&ts, &tk, &tt, RATE, VOL, &mut tc2, &mut tp2);
        assert_eq!(tc2.to_vec(), call_c);
        assert_eq!(tp2.to_vec(), put_c);
    }
}
