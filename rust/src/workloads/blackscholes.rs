//! Black-Scholes pricing (PARSEC's `blackscholes`, Figure 5).
//!
//! Pure-Rust scalar pricing for the CPU baselines (contiguous vs tree
//! layouts), numerically cross-checked in `rust/tests/` against the
//! AOT-compiled Pallas kernel executed through PJRT — proving the
//! L3↔L1 boundary agrees end to end.

use crate::error::{Error, Result};
use crate::pmem::BlockAlloc;
use crate::trees::{TreeArray, TreeView};

/// One option's market parameters.
#[derive(Clone, Copy, Debug)]
pub struct Option1 {
    /// Spot price.
    pub spot: f32,
    /// Strike price.
    pub strike: f32,
    /// Time to maturity (years).
    pub tmat: f32,
}

/// erf via the Abramowitz–Stegun 7.1.26 rational approximation
/// (|error| ≤ 1.5e-7, well inside f32 tolerance; matches
/// `jax.lax.erf` to ~1e-6 on the pricing range).
#[inline]
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
#[inline]
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Price one European option; returns (call, put).
#[inline]
pub fn price(o: Option1, rate: f32, vol: f32) -> (f32, f32) {
    let (s, k, t) = (o.spot as f64, o.strike as f64, o.tmat as f64);
    let (r, v) = (rate as f64, vol as f64);
    let sqrt_t = t.sqrt();
    let sig_t = v * sqrt_t;
    let d1 = ((s / k).ln() + (r + 0.5 * v * v) * t) / sig_t;
    let d2 = d1 - sig_t;
    let disc = (-r * t).exp();
    let call = s * norm_cdf(d1) - k * disc * norm_cdf(d2);
    let put = k * disc * norm_cdf(-d2) - s * norm_cdf(-d1);
    (call as f32, put as f32)
}

/// Price a contiguous portfolio (spot/strike/tmat parallel slices) into
/// `call`/`put`. The Figure 5 VM baseline.
pub fn price_contig(
    spot: &[f32],
    strike: &[f32],
    tmat: &[f32],
    rate: f32,
    vol: f32,
    call: &mut [f32],
    put: &mut [f32],
) {
    for i in 0..spot.len() {
        let (c, p) = price(
            Option1 {
                spot: spot[i],
                strike: strike[i],
                tmat: tmat[i],
            },
            rate,
            vol,
        );
        call[i] = c;
        put[i] = p;
    }
}

/// Price tree-layout arrays via naive per-element walks.
pub fn price_tree_naive<A: BlockAlloc>(
    spot: &TreeArray<'_, f32, A>,
    strike: &TreeArray<'_, f32, A>,
    tmat: &TreeArray<'_, f32, A>,
    rate: f32,
    vol: f32,
    call: &mut TreeArray<'_, f32, A>,
    put: &mut TreeArray<'_, f32, A>,
) {
    for i in 0..spot.len() {
        // SAFETY: all arrays share len (asserted by callers/tests).
        let (c, p) = unsafe {
            price(
                Option1 {
                    spot: spot.get_unchecked(i),
                    strike: strike.get_unchecked(i),
                    tmat: tmat.get_unchecked(i),
                },
                rate,
                vol,
            )
        };
        unsafe {
            call.set_unchecked(i, c);
            put.set_unchecked(i, p);
        }
    }
}

/// Price tree-layout arrays leaf-at-a-time (the Iterator-style
/// optimization: one walk per 32 KB leaf, then contiguous slices).
pub fn price_tree_iter<A: BlockAlloc>(
    spot: &TreeArray<'_, f32, A>,
    strike: &TreeArray<'_, f32, A>,
    tmat: &TreeArray<'_, f32, A>,
    rate: f32,
    vol: f32,
    call: &mut TreeArray<'_, f32, A>,
    put: &mut TreeArray<'_, f32, A>,
) {
    for leaf in 0..spot.nleaves() {
        let s = spot.leaf_slice(leaf);
        let k = strike.leaf_slice(leaf);
        let t = tmat.leaf_slice(leaf);
        // Price into temporaries then copy into the output leaves (the
        // borrow checker forbids holding two &mut leaves of one array).
        let mut cbuf = vec![0.0f32; s.len()];
        let mut pbuf = vec![0.0f32; s.len()];
        price_contig(s, k, t, rate, vol, &mut cbuf, &mut pbuf);
        call.leaf_slice_mut(leaf).copy_from_slice(&cbuf);
        put.leaf_slice_mut(leaf).copy_from_slice(&pbuf);
    }
}

/// Price tree-layout arrays through *shared views*, leaf-blocked: each
/// input array is visited via [`TreeView::for_each_leaf_run`] — one
/// translation and one epoch pin per leaf-sized batch (vs per element
/// in [`price_tree_naive`]) — and each leaf's contiguous slices feed
/// the blocked kernel ([`price_contig`], the scalar twin of the Pallas
/// blocked kernel). Unlike [`price_tree_iter`] this needs no `&`/`&mut`
/// tree access, so it runs over shared views while mmd relocates blocks
/// underneath (bulk-path contract: no concurrent *writers*).
///
/// All three inputs must have the same length; leaf geometries may
/// differ (runs are re-chunked per array).
pub fn price_view_blocked<A: BlockAlloc>(
    spot: &mut TreeView<'_, '_, f32, A>,
    strike: &mut TreeView<'_, '_, f32, A>,
    tmat: &mut TreeView<'_, '_, f32, A>,
    rate: f32,
    vol: f32,
    call: &mut [f32],
    put: &mut [f32],
) -> Result<()> {
    let n = spot.len();
    if strike.len() != n || tmat.len() != n || call.len() != n || put.len() != n {
        return Err(Error::Config(format!(
            "price_view_blocked: mismatched lengths (spot {n}, strike {}, tmat {}, call {}, put {})",
            strike.len(),
            tmat.len(),
            call.len(),
            put.len()
        )));
    }
    let leaf_cap = spot.geometry().leaf_cap;
    let kcap = strike.geometry().leaf_cap;
    let tcap = tmat.geometry().leaf_cap;
    let mut idx_buf: Vec<usize> = Vec::with_capacity(leaf_cap);
    let mut kbuf: Vec<f32> = Vec::with_capacity(leaf_cap);
    let mut tbuf: Vec<f32> = Vec::with_capacity(leaf_cap);
    for leaf in 0..spot.nleaves() {
        let lo = leaf * leaf_cap;
        let hi = (lo + leaf_cap).min(n);
        idx_buf.clear();
        idx_buf.extend(lo..hi);
        // Gather strike/tmat for this block of options. A sorted
        // contiguous index range makes every leaf run contiguous inside
        // its leaf, so each run is one slice copy.
        kbuf.clear();
        strike.for_each_leaf_run(&idx_buf, |_, elems, pos| {
            let off = idx_buf[pos[0] as usize] % kcap;
            kbuf.extend_from_slice(&elems[off..off + pos.len()]);
        })?;
        tbuf.clear();
        tmat.for_each_leaf_run(&idx_buf, |_, elems, pos| {
            let off = idx_buf[pos[0] as usize] % tcap;
            tbuf.extend_from_slice(&elems[off..off + pos.len()]);
        })?;
        // Price straight out of spot's leaf block: the whole range is
        // one run here (idx_buf spans exactly one spot leaf).
        let (call_run, put_run) = (&mut call[lo..hi], &mut put[lo..hi]);
        spot.for_each_leaf_run(&idx_buf, |_, elems, pos| {
            price_contig(&elems[..pos.len()], &kbuf, &tbuf, rate, vol, call_run, put_run);
        })?;
    }
    Ok(())
}

/// Deterministic synthetic portfolio (matches the Python tests' ranges).
pub fn synth_portfolio(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = crate::testutil::Rng::new(seed);
    let spot = (0..n).map(|_| rng.f32_range(5.0, 200.0)).collect();
    let strike = (0..n).map(|_| rng.f32_range(5.0, 200.0)).collect();
    let tmat = (0..n).map(|_| rng.f32_range(0.05, 3.0)).collect();
    (spot, strike, tmat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;
    use crate::workloads::linear_scan::tree_from;

    const RATE: f32 = 0.03;
    const VOL: f32 = 0.25;

    #[test]
    fn erf_reference_points() {
        // A&S 7.1.26 has |error| <= 1.5e-7 (the polynomial does not
        // vanish exactly at 0).
        assert!((erf(0.0)).abs() < 1e-8);
        assert!((erf(1.0) - 0.8427007929).abs() < 2e-7);
        assert!((erf(-1.0) + 0.8427007929).abs() < 2e-7);
        assert!((erf(3.0) - 0.9999779095).abs() < 2e-7);
    }

    #[test]
    fn put_call_parity() {
        let (s, k, t) = (100.0f32, 90.0f32, 1.5f32);
        let (c, p) = price(Option1 { spot: s, strike: k, tmat: t }, RATE, VOL);
        let parity = s - k * (-RATE * t).exp();
        assert!((c - p - parity).abs() < 1e-3, "parity violated: {}", c - p - parity);
    }

    #[test]
    fn deep_itm_call() {
        let (c, _) = price(
            Option1 { spot: 1000.0, strike: 1.0, tmat: 1.0 },
            RATE,
            VOL,
        );
        let expect = 1000.0 - 1.0 * (-RATE).exp();
        assert!((c - expect).abs() / expect < 1e-4);
    }

    #[test]
    fn layouts_price_identically() {
        let a = BlockAllocator::new(4096, 1 << 12).unwrap();
        let n = 4096 / 4 * 5 + 33;
        let (s, k, t) = synth_portfolio(n, 3);
        let mut call_c = vec![0.0f32; n];
        let mut put_c = vec![0.0f32; n];
        price_contig(&s, &k, &t, RATE, VOL, &mut call_c, &mut put_c);

        let ts = tree_from(&a, &s);
        let tk = tree_from(&a, &k);
        let tt = tree_from(&a, &t);
        let mut tc: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        let mut tp: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        price_tree_naive(&ts, &tk, &tt, RATE, VOL, &mut tc, &mut tp);
        assert_eq!(tc.to_vec(), call_c);
        assert_eq!(tp.to_vec(), put_c);

        let mut tc2: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        let mut tp2: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        price_tree_iter(&ts, &tk, &tt, RATE, VOL, &mut tc2, &mut tp2);
        assert_eq!(tc2.to_vec(), call_c);
        assert_eq!(tp2.to_vec(), put_c);
    }

    #[test]
    fn view_blocked_pricing_matches_contig_and_amortizes_pins() {
        let a = crate::pmem::TwoLevelAllocator::new(4096, 1 << 12).unwrap();
        let n = 4096 / 4 * 5 + 33;
        let (s, k, t) = synth_portfolio(n, 7);
        let mut call_c = vec![0.0f32; n];
        let mut put_c = vec![0.0f32; n];
        price_contig(&s, &k, &t, RATE, VOL, &mut call_c, &mut put_c);

        let ts = tree_from(&a, &s);
        let tk = tree_from(&a, &k);
        let tt = tree_from(&a, &t);
        let mut vs = ts.view();
        let mut vk = tk.view();
        let mut vt = tt.view();
        let mut call_v = vec![0.0f32; n];
        let mut put_v = vec![0.0f32; n];
        price_view_blocked(&mut vs, &mut vk, &mut vt, RATE, VOL, &mut call_v, &mut put_v)
            .unwrap();
        assert_eq!(call_v, call_c, "blocked view pricing diverged from contig");
        assert_eq!(put_v, put_c);
        let es = a.epoch().stats();
        assert!(es.saved_pins > 0, "blocked path must amortize pins: {es:?}");
        assert!(
            price_view_blocked(
                &mut vs,
                &mut vk,
                &mut vt,
                RATE,
                VOL,
                &mut call_v[..n - 1],
                &mut put_v
            )
            .is_err(),
            "length mismatch must be rejected"
        );
        drop(vs);
        drop(vk);
        drop(vt);
        a.epoch().synchronize(&a);
    }
}
