//! Red–black tree benchmark (Figure 4, right).
//!
//! The paper's point: pointer-based structures use *no* contiguous
//! memory, so they run identically on physical and virtual memory — and
//! removing translation is pure profit (up to 50% runtime reduction).
//! The same implementation runs in both modes; for the simulated
//! comparison the traversal's node addresses are recorded and replayed
//! through the hierarchy.

use crate::error::Result;
use crate::memsim::Hierarchy;
use crate::pmem::{BlockAlloc, BlockAllocator, SlabPool};
use crate::testutil::Rng;
use crate::workloads::trace::CostModel;
use crate::workloads::SimResult;

const RED: u8 = 0;
const BLACK: u8 = 1;
const NIL: u32 = u32::MAX;

/// One tree node (pool index links, not host pointers, so the node pool
/// can live in allocator blocks and addresses are stable + simulable).
#[derive(Clone, Copy, Debug)]
struct Node {
    key: u64,
    left: u32,
    right: u32,
    parent: u32,
    color: u8,
}

/// A red–black tree whose nodes live in a slab of 32-byte slots carved
/// from physically-addressed blocks ([`SlabPool`]): blocks are claimed
/// lazily as the tree grows instead of reserving the worst case up
/// front, and every node keeps a stable simulated physical address.
pub struct RbTree<'a, A: BlockAlloc = BlockAllocator> {
    slab: SlabPool<'a, A>,
    nodes: Vec<Node>,
    /// Physical address of node i's slab slot, assigned at insert.
    addrs: Vec<u64>,
    root: u32,
    len: usize,
}

/// Simulated size of one node (key + 3 links + color, padded): 32 bytes.
pub const NODE_BYTES: usize = 32;

impl<'a, A: BlockAlloc> RbTree<'a, A> {
    /// Create an empty tree expecting about `cap` nodes (a sizing hint
    /// for the host-side vectors; the node slab grows on demand).
    pub fn new(alloc: &'a A, cap: usize) -> Result<Self> {
        Ok(RbTree {
            slab: SlabPool::new(alloc, NODE_BYTES)?,
            nodes: Vec::with_capacity(cap),
            addrs: Vec::with_capacity(cap),
            root: NIL,
            len: 0,
        })
    }

    /// Number of keys.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Simulated physical address of node `i`.
    #[inline]
    pub fn node_addr(&self, i: u32) -> u64 {
        self.addrs[i as usize]
    }

    /// Insert `key` (duplicates allowed; they go right).
    pub fn insert(&mut self, key: u64) {
        let idx = self.nodes.len() as u32;
        let slot = self.slab.alloc_slot().expect("rbtree node pool exhausted");
        self.addrs.push(self.slab.phys_addr(slot));
        self.nodes.push(Node {
            key,
            left: NIL,
            right: NIL,
            parent: NIL,
            color: RED,
        });
        self.len += 1;
        // BST insert.
        let mut parent = NIL;
        let mut cur = self.root;
        while cur != NIL {
            parent = cur;
            cur = if key < self.nodes[cur as usize].key {
                self.nodes[cur as usize].left
            } else {
                self.nodes[cur as usize].right
            };
        }
        self.nodes[idx as usize].parent = parent;
        if parent == NIL {
            self.root = idx;
        } else if key < self.nodes[parent as usize].key {
            self.nodes[parent as usize].left = idx;
        } else {
            self.nodes[parent as usize].right = idx;
        }
        self.fix_insert(idx);
    }

    fn rotate_left(&mut self, x: u32) {
        let y = self.nodes[x as usize].right;
        let yl = self.nodes[y as usize].left;
        self.nodes[x as usize].right = yl;
        if yl != NIL {
            self.nodes[yl as usize].parent = x;
        }
        let xp = self.nodes[x as usize].parent;
        self.nodes[y as usize].parent = xp;
        if xp == NIL {
            self.root = y;
        } else if self.nodes[xp as usize].left == x {
            self.nodes[xp as usize].left = y;
        } else {
            self.nodes[xp as usize].right = y;
        }
        self.nodes[y as usize].left = x;
        self.nodes[x as usize].parent = y;
    }

    fn rotate_right(&mut self, x: u32) {
        let y = self.nodes[x as usize].left;
        let yr = self.nodes[y as usize].right;
        self.nodes[x as usize].left = yr;
        if yr != NIL {
            self.nodes[yr as usize].parent = x;
        }
        let xp = self.nodes[x as usize].parent;
        self.nodes[y as usize].parent = xp;
        if xp == NIL {
            self.root = y;
        } else if self.nodes[xp as usize].right == x {
            self.nodes[xp as usize].right = y;
        } else {
            self.nodes[xp as usize].left = y;
        }
        self.nodes[y as usize].right = x;
        self.nodes[x as usize].parent = y;
    }

    fn fix_insert(&mut self, mut z: u32) {
        while z != self.root && self.color_of(self.parent_of(z)) == RED {
            let p = self.parent_of(z);
            let g = self.parent_of(p);
            if p == self.nodes[g as usize].left {
                let u = self.nodes[g as usize].right;
                if self.color_of(u) == RED {
                    self.set_color(p, BLACK);
                    self.set_color(u, BLACK);
                    self.set_color(g, RED);
                    z = g;
                } else {
                    if z == self.nodes[p as usize].right {
                        z = p;
                        self.rotate_left(z);
                    }
                    let p = self.parent_of(z);
                    let g = self.parent_of(p);
                    self.set_color(p, BLACK);
                    self.set_color(g, RED);
                    self.rotate_right(g);
                }
            } else {
                let u = self.nodes[g as usize].left;
                if self.color_of(u) == RED {
                    self.set_color(p, BLACK);
                    self.set_color(u, BLACK);
                    self.set_color(g, RED);
                    z = g;
                } else {
                    if z == self.nodes[p as usize].left {
                        z = p;
                        self.rotate_right(z);
                    }
                    let p = self.parent_of(z);
                    let g = self.parent_of(p);
                    self.set_color(p, BLACK);
                    self.set_color(g, RED);
                    self.rotate_left(g);
                }
            }
        }
        let r = self.root;
        self.set_color(r, BLACK);
    }

    #[inline]
    fn color_of(&self, i: u32) -> u8 {
        if i == NIL {
            BLACK
        } else {
            self.nodes[i as usize].color
        }
    }
    #[inline]
    fn set_color(&mut self, i: u32, c: u8) {
        if i != NIL {
            self.nodes[i as usize].color = c;
        }
    }
    #[inline]
    fn parent_of(&self, i: u32) -> u32 {
        if i == NIL {
            NIL
        } else {
            self.nodes[i as usize].parent
        }
    }

    /// Look up `key`; true if present.
    pub fn contains(&self, key: u64) -> bool {
        let mut cur = self.root;
        while cur != NIL {
            let n = &self.nodes[cur as usize];
            if key == n.key {
                return true;
            }
            cur = if key < n.key { n.left } else { n.right };
        }
        false
    }

    /// In-order traversal summing keys; `visit` (if given) receives the
    /// physical address of every node touched, in order — the trace the
    /// simulation replays.
    pub fn inorder_sum(&self, mut visit: Option<&mut Vec<u64>>) -> u64 {
        let mut sum = 0u64;
        // Explicit stack (recursion would blow real stacks at 10^7 nodes).
        let mut stack: Vec<u32> = Vec::with_capacity(64);
        let mut cur = self.root;
        while cur != NIL || !stack.is_empty() {
            while cur != NIL {
                if let Some(v) = visit.as_deref_mut() {
                    v.push(self.node_addr(cur));
                }
                stack.push(cur);
                cur = self.nodes[cur as usize].left;
            }
            cur = stack.pop().unwrap();
            sum = sum.wrapping_add(self.nodes[cur as usize].key);
            cur = self.nodes[cur as usize].right;
        }
        sum
    }

    /// Validate red–black invariants (tests / property checks).
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        if self.root != NIL && self.nodes[self.root as usize].color != BLACK {
            return Err("root not black".into());
        }
        // No red node has a red child; equal black height on all paths.
        fn walk<A: BlockAlloc>(t: &RbTree<'_, A>, i: u32) -> std::result::Result<u32, String> {
            if i == NIL {
                return Ok(1);
            }
            let n = &t.nodes[i as usize];
            if n.color == RED {
                if t.color_of(n.left) == RED || t.color_of(n.right) == RED {
                    return Err(format!("red-red violation at key {}", n.key));
                }
            }
            if n.left != NIL && t.nodes[n.left as usize].key > n.key {
                return Err("BST order violated (left)".into());
            }
            if n.right != NIL && t.nodes[n.right as usize].key < n.key {
                return Err("BST order violated (right)".into());
            }
            let lh = walk(t, n.left)?;
            let rh = walk(t, n.right)?;
            if lh != rh {
                return Err(format!("black height mismatch at key {}", n.key));
            }
            Ok(lh + (n.color == BLACK) as u32)
        }
        walk(self, self.root).map(|_| ())
    }
}

/// Build a tree of `n` random keys, record the in-order traversal trace,
/// and replay it through `h` — the Figure 4 (right) measurement for one
/// address mode. Returns cycles per node visit.
pub fn sim_rbtree_traversal<A: BlockAlloc>(
    h: &mut Hierarchy,
    model: &CostModel,
    alloc: &A,
    n: usize,
    seed: u64,
) -> SimResult {
    let mut rng = Rng::new(seed);
    let mut t = RbTree::new(alloc, n).expect("rbtree pool");
    for _ in 0..n {
        t.insert(rng.next_u64());
    }
    let mut trace = Vec::with_capacity(n * 2);
    let _sum = t.inorder_sum(Some(&mut trace));
    // Tree traversal is a dependent pointer chase: full latencies.
    let mut cycles = 0.0f64;
    for &addr in &trace {
        cycles += h.access(addr) as f64 + model.compute;
    }
    SimResult {
        cycles_per_elem: cycles / trace.len() as f64,
        elems: trace.len() as u64,
        tlb_miss_rate: h.stats().tlb_miss_rate(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::{AddressMode, PageSize};
    use crate::testutil::forall;

    fn alloc() -> BlockAllocator {
        BlockAllocator::new(32 * 1024, 1 << 14).unwrap()
    }

    #[test]
    fn node_pool_is_slab_backed_and_frees_on_drop() {
        use crate::pmem::TwoLevelAllocator;
        let a = TwoLevelAllocator::new(4096, 64).unwrap();
        {
            let mut t = RbTree::new(&a, 1000).unwrap();
            for k in 0..1000u64 {
                t.insert(k);
            }
            // Blocks are claimed lazily: exactly enough for 1000 nodes.
            assert_eq!(a.stats().allocated, (1000 * NODE_BYTES).div_ceil(4096));
            t.check_invariants().unwrap();
        }
        assert_eq!(a.stats().allocated, 0, "drop returns the slab's blocks");
    }

    #[test]
    fn insert_and_contains() {
        let a = alloc();
        let mut t = RbTree::new(&a, 100).unwrap();
        for k in [5u64, 3, 8, 1, 4, 9, 7] {
            t.insert(k);
        }
        assert!(t.contains(4));
        assert!(!t.contains(6));
        t.check_invariants().unwrap();
    }

    #[test]
    fn inorder_is_sorted_sum() {
        let a = alloc();
        let mut t = RbTree::new(&a, 1000).unwrap();
        let mut expect = 0u64;
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let k = rng.next_u64() >> 32;
            expect = expect.wrapping_add(k);
            t.insert(k);
        }
        assert_eq!(t.inorder_sum(None), expect);
    }

    #[test]
    fn sequential_inserts_stay_balanced() {
        let a = alloc();
        let mut t = RbTree::new(&a, 4096).unwrap();
        for k in 0..4096u64 {
            t.insert(k); // adversarial (sorted) insert order
        }
        t.check_invariants().unwrap();
    }

    #[test]
    fn prop_invariants_hold_under_random_inserts() {
        forall(20, |g| {
            let a = BlockAllocator::new(4096, 1 << 12).unwrap();
            let n = g.usize_in(1, 2000);
            let mut t = RbTree::new(&a, n).unwrap();
            for _ in 0..n {
                t.insert(g.rng().next_u64());
            }
            assert_eq!(t.len(), n);
            t.check_invariants().unwrap();
        });
    }

    #[test]
    fn traversal_trace_has_low_locality() {
        let a = alloc();
        let mut t = RbTree::new(&a, 10_000).unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            t.insert(rng.next_u64());
        }
        let mut trace = Vec::new();
        t.inorder_sum(Some(&mut trace));
        // Consecutive visits should mostly land on different blocks —
        // that's why this benchmark hurts the TLB.
        let bs = 32 * 1024;
        let jumps = trace
            .windows(2)
            .filter(|w| w[0] / bs != w[1] / bs)
            .count();
        assert!(
            jumps as f64 / trace.len() as f64 > 0.5,
            "trace too local: {jumps}/{}",
            trace.len()
        );
    }

    #[test]
    fn physical_traversal_faster_than_virtual() {
        // Figure 4 right: same structure, no translation -> faster.
        let m = CostModel::default();
        let a1 = alloc();
        let a2 = alloc();
        let mut hv = Hierarchy::kaby_lake(AddressMode::Virtual(PageSize::P4K));
        let mut hp = Hierarchy::kaby_lake(AddressMode::Physical);
        let rv = sim_rbtree_traversal(&mut hv, &m, &a1, 200_000, 11);
        let rp = sim_rbtree_traversal(&mut hp, &m, &a2, 200_000, 11);
        let ratio = rp.cycles_per_elem / rv.cycles_per_elem;
        assert!(ratio < 0.95, "physical/virtual = {ratio:.3}, expected clear win");
    }
}
