//! The paper's evaluation workloads.
//!
//! Each microbenchmark exists in two forms:
//!
//! 1. **Real execution** — actual data structures ([`crate::trees`],
//!    `Vec`) exercised for wallclock ratios at sizes that fit in RAM.
//!    These validate the implementation and the iterator optimization.
//! 2. **Simulated execution** — address traces fed to
//!    [`crate::memsim::Hierarchy`] under `Physical` vs `Virtual` modes,
//!    producing cycles-per-element at the paper's full 4 KB–64 GB range
//!    (64 GB arrays are modeled, not materialized; paper §4.3 had the
//!    same problem and solved it less faithfully with huge pages).
//!
//! [`trace`] holds the shared cost model; the remaining modules are the
//! individual workloads of §4.

pub mod blackscholes;
pub mod fib;
pub mod gups;
pub mod hashprobe;
pub mod linear_scan;
pub mod rbtree;
pub mod strided_scan;
pub mod trace;

pub use trace::{CostModel, ScanKind, SimResult};
