//! The paper's evaluation workloads.
//!
//! Each microbenchmark exists in two forms:
//!
//! 1. **Real execution** — actual data structures ([`crate::trees`],
//!    `Vec`) exercised for wallclock ratios at sizes that fit in RAM.
//!    These validate the implementation and the iterator optimization.
//! 2. **Simulated execution** — address traces fed to
//!    [`crate::memsim::Hierarchy`] under `Physical` vs `Virtual` modes,
//!    producing cycles-per-element at the paper's full 4 KB–64 GB range
//!    (64 GB arrays are modeled, not materialized; paper §4.3 had the
//!    same problem and solved it less faithfully with huge pages).
//!
//! [`trace`] holds the shared cost model; the remaining modules are the
//! individual workloads of §4.

pub mod blackscholes;
pub mod fib;
pub mod gups;
pub mod hashprobe;
pub mod linear_scan;
pub mod rbtree;
pub mod strided_scan;
pub mod trace;

pub use trace::{CostModel, ScanKind, SimResult};

/// Pick an [`crate::trees::TreeArray::update_batch`] batch size from
/// the table's leaf count and an *observed* leaf-TLB hit rate (ROADMAP
/// open item: adaptive batch sizing).
///
/// Rationale: sort-and-run amortization pays off when each distinct
/// leaf a batch touches appears several times, so the batch scales with
/// the number of leaves random indices will scatter over (~4 expected
/// hits per touched leaf). But when a TLB already serves most
/// translations (hit rate near 1), grouping buys little — only the
/// *miss* fraction benefits — so the batch shrinks toward the floor and
/// stops paying sort latency for nothing. Clamped to [64, 16384] and
/// rounded to a power of two (the sort buffers like it).
pub fn adaptive_batch_size(nleaves: usize, tlb_hit_rate: f64) -> usize {
    let miss = (1.0 - tlb_hit_rate).clamp(0.05, 1.0);
    (((nleaves as f64) * 4.0 * miss) as usize)
        .clamp(64, 16 * 1024)
        .next_power_of_two()
}

#[cfg(test)]
mod adaptive_tests {
    use super::adaptive_batch_size;

    #[test]
    fn scales_with_leaves_and_shrinks_with_hit_rate() {
        assert!(adaptive_batch_size(4096, 0.0) > adaptive_batch_size(128, 0.0));
        assert!(adaptive_batch_size(4096, 0.95) < adaptive_batch_size(4096, 0.0));
        // Clamps: tiny tables hit the floor, huge ones the ceiling.
        assert_eq!(adaptive_batch_size(1, 0.0), 64);
        assert_eq!(adaptive_batch_size(1 << 30, 0.0), 16 * 1024);
        // Power of two for the sort buffers.
        for &(nl, hr) in &[(100usize, 0.3f64), (1000, 0.7), (50_000, 0.5)] {
            assert!(adaptive_batch_size(nl, hr).is_power_of_two());
        }
    }
}
