//! GUPS — giga-updates per second (Figure 4), real + simulated.
//!
//! The HPC RandomAccess kernel: `table[idx] ^= key` at pseudorandom
//! indices. The paper uses it as the worst case for both translation
//! (random pages) and tree walks (random leaves, no leaf-cache reuse).

use crate::memsim::Hierarchy;
use crate::pmem::BlockAlloc;
use crate::testutil::Rng;
use crate::trees::{TreeArray, TreeGeometry, TreeTraceModel, TreeView, TreeWriter};
use crate::workloads::trace::CostModel;
use crate::workloads::SimResult;

/// Xor-fold a whole tree, one translation per leaf
/// ([`TreeArray::for_each_leaf`]) instead of one cursor step per
/// element — the bulk-drain path every gups checksum uses.
fn checksum_tree<A: BlockAlloc>(t: &TreeArray<'_, u64, A>) -> u64 {
    let mut acc = 0u64;
    t.for_each_leaf(|_, elems| {
        for &v in elems {
            acc ^= v;
        }
    });
    acc
}

/// Real GUPS over a contiguous table. Returns a checksum.
pub fn gups_vec(table: &mut [u64], ops: u64, seed: u64) -> u64 {
    let mut rng = Rng::new(seed);
    let n = table.len() as u64;
    for _ in 0..ops {
        let r = rng.next_u64();
        let i = (r % n) as usize;
        table[i] ^= r;
    }
    table.iter().fold(0u64, |a, &v| a ^ v)
}

/// Real GUPS over a tree table using naive walks.
pub fn gups_tree_naive<A: BlockAlloc>(t: &mut TreeArray<'_, u64, A>, ops: u64, seed: u64) -> u64 {
    let mut rng = Rng::new(seed);
    let n = t.len() as u64;
    for _ in 0..ops {
        let r = rng.next_u64();
        let i = (r % n) as usize;
        // SAFETY: i < len by construction.
        unsafe {
            let v = t.get_unchecked(i);
            t.set_unchecked(i, v ^ r);
        }
    }
    checksum_tree(t)
}

/// Default batch size for [`gups_tree_batched`].
pub const GUPS_BATCH: usize = 1024;

/// Real GUPS over a tree table with *batched* updates: indices are
/// generated `batch` at a time and applied through
/// [`TreeArray::update_batch`], which groups them by leaf so each
/// distinct leaf is translated once per batch instead of once per
/// update. Bit-identical to [`gups_vec`]/[`gups_tree_naive`] for the
/// same seed (xor updates commute across distinct slots; same-slot
/// updates keep batch order).
pub fn gups_tree_batched<A: BlockAlloc>(
    t: &mut TreeArray<'_, u64, A>,
    ops: u64,
    seed: u64,
    batch: usize,
) -> u64 {
    let batch = batch.max(1);
    let mut rng = Rng::new(seed);
    let n = t.len() as u64;
    let mut idxs = Vec::with_capacity(batch);
    let mut keys = Vec::with_capacity(batch);
    let mut done = 0u64;
    while done < ops {
        let b = batch.min((ops - done) as usize);
        idxs.clear();
        keys.clear();
        for _ in 0..b {
            let r = rng.next_u64();
            idxs.push((r % n) as usize);
            keys.push(r);
        }
        t.update_batch(&idxs, |pos, v| *v ^= keys[pos])
            .expect("indices in range by construction");
        done += b as u64;
    }
    checksum_tree(t)
}

/// The read side of GUPS through a shared [`TreeView`]: `ops` random
/// dependent-mixed reads, order-sensitively folded so any stale or torn
/// read changes the result. Run one view per worker thread over one
/// shared table; checksums are reproducible from the table's contents
/// with [`gups_read_reference`].
pub fn gups_view_read<A: BlockAlloc>(
    view: &mut TreeView<'_, '_, u64, A>,
    ops: u64,
    seed: u64,
) -> u64 {
    let mut rng = Rng::new(seed);
    let n = view.len() as u64;
    let mut acc = 0u64;
    for _ in 0..ops {
        let r = rng.next_u64();
        let i = (r % n) as usize;
        // SAFETY: i < len by construction.
        let v = unsafe { view.get_unchecked(i) };
        acc = acc.rotate_left(7) ^ v ^ r;
    }
    acc
}

/// The read side of GUPS through a shared view with *batched* lookups:
/// indices are generated `batch` at a time and resolved through
/// [`TreeView::get_batch`], which groups them by leaf (one translation
/// per distinct leaf per batch) and pins the arena epoch once per batch
/// instead of once per read (the pins saved surface in
/// [`crate::pmem::EpochStats::saved_pins`]). Checksum is bit-identical
/// to [`gups_view_read`]/[`gups_read_reference`] for the same seed: the
/// order-sensitive fold runs over the returned values in generation
/// order, which `get_batch` preserves (`out[pos]` = element
/// `idxs[pos]`).
pub fn gups_view_read_batched<A: BlockAlloc>(
    view: &mut TreeView<'_, '_, u64, A>,
    ops: u64,
    seed: u64,
    batch: usize,
) -> u64 {
    let batch = batch.max(1);
    let mut rng = Rng::new(seed);
    let n = view.len() as u64;
    let mut idxs = Vec::with_capacity(batch);
    let mut keys = Vec::with_capacity(batch);
    let mut acc = 0u64;
    let mut done = 0u64;
    while done < ops {
        let b = batch.min((ops - done) as usize);
        idxs.clear();
        keys.clear();
        for _ in 0..b {
            let r = rng.next_u64();
            idxs.push((r % n) as usize);
            keys.push(r);
        }
        let vals = view.get_batch(&idxs).expect("indices in range by construction");
        for (v, k) in vals.iter().zip(&keys) {
            acc = acc.rotate_left(7) ^ v ^ k;
        }
        done += b as u64;
    }
    acc
}

/// Reference checksum for [`gups_view_read`] over the table's contents
/// (what every worker must produce regardless of thread count or
/// concurrent relocation — relocation moves bytes, never changes them).
pub fn gups_read_reference(table: &[u64], ops: u64, seed: u64) -> u64 {
    let mut rng = Rng::new(seed);
    let n = table.len() as u64;
    let mut acc = 0u64;
    for _ in 0..ops {
        let r = rng.next_u64();
        acc = acc.rotate_left(7) ^ table[(r % n) as usize] ^ r;
    }
    acc
}

// ---- concurrent read/write GUPS (seqlock writers, PR 5) ----
//
// Under concurrent mutation a static reference checksum is impossible
// (readers legitimately observe any prefix of the writers' progress),
// so the RW variant makes every value *self-certifying*: slot `i`
// always holds `i` in its high tag bits and a monotone update count
// below. A torn read, a stale-block read racing a post-move write, or
// a write landing on the wrong leaf all break the tag invariant the
// readers assert per read — and because tagged increments commute
// across writers, the final table is still exactly reproducible by
// replaying every writer's seeded stream against a mirror.

/// Bit position of the slot-identity tag in a concurrent-RW table
/// value: `value >> RW_TAG_SHIFT == slot index`, update count below.
pub const RW_TAG_SHIFT: u32 = 40;

/// Initial concurrent-RW table value for slot `i` (tag up, count 0).
/// Tables must stay below 2^24 elements so tags can't collide.
pub fn rw_init(i: usize) -> u64 {
    debug_assert!((i as u64) < 1 << (64 - RW_TAG_SHIFT));
    (i as u64) << RW_TAG_SHIFT
}

/// One writer's stream: `ops` tagged increments at seeded random slots
/// through a seqlock [`TreeWriter`]. Returns `ops` (the update count
/// contributed). Safe under concurrent views, other writers, and
/// `migrate_leaf_concurrent`-family relocation.
pub fn gups_rw_write<A: BlockAlloc>(
    w: &mut TreeWriter<'_, '_, u64, A>,
    ops: u64,
    seed: u64,
) -> u64 {
    let mut rng = Rng::new(seed);
    let n = w.len() as u64;
    for _ in 0..ops {
        let i = (rng.next_u64() % n) as usize;
        w.update(i, |v| v.wrapping_add(1)).expect("index in range by construction");
    }
    ops
}

/// Replay [`gups_rw_write`]'s stream against a contiguous mirror —
/// apply every writer's stream (any order: increments commute) and the
/// mirror is the exact expected final table.
pub fn rw_apply_reference(mirror: &mut [u64], ops: u64, seed: u64) {
    let mut rng = Rng::new(seed);
    let n = mirror.len() as u64;
    for _ in 0..ops {
        let i = (rng.next_u64() % n) as usize;
        mirror[i] = mirror[i].wrapping_add(1);
    }
}

/// The read side under live writers: `ops` seeded random reads through
/// a view, each asserted against the tag invariant (`value >>
/// RW_TAG_SHIFT == slot`) — the seq bracket must make every returned
/// value a committed one, so a torn/stale/misdirected read panics here.
/// Returns a fold of the observed values (kept live by callers via
/// `black_box` so the loop cannot be elided).
pub fn gups_rw_read<A: BlockAlloc>(
    view: &mut TreeView<'_, '_, u64, A>,
    ops: u64,
    seed: u64,
) -> u64 {
    let mut rng = Rng::new(seed);
    let n = view.len() as u64;
    let mut acc = 0u64;
    for _ in 0..ops {
        let i = (rng.next_u64() % n) as usize;
        // SAFETY: i < n by construction.
        let v = unsafe { view.get_unchecked(i) };
        assert_eq!(
            v >> RW_TAG_SHIFT,
            i as u64,
            "torn or misdirected concurrent read at slot {i} (value {v:#x})"
        );
        acc = acc.rotate_left(7) ^ v;
    }
    acc
}

/// Simulated GUPS at paper scale (4–64 GB tables).
///
/// Each update = one table access (read-modify-write counted once — the
/// write hits the same line). Random updates have limited but nonzero
/// MLP (the kernel issues several independent updates ahead); walks are
/// dependent. Array mode charges the access; tree mode charges the
/// dependent pointer chain + data access.
pub fn sim_gups(
    h: &mut Hierarchy,
    model: &CostModel,
    table_bytes: u64,
    tree: bool,
    ops: u64,
    seed: u64,
) -> SimResult {
    let elem = 8u64; // u64 table entries
    let len = (table_bytes / elem) as usize;
    let mut rng = Rng::new(seed);
    let mut cycles = 0.0f64;
    if tree {
        let geo = TreeGeometry::new(32 * 1024, 8, len).expect("geometry");
        let tm = TreeTraceModel::new(geo, 0x10_0000);
        let mut path = Vec::with_capacity(4);
        for _ in 0..ops {
            let i = rng.below(len as u64) as usize;
            tm.access_path(i, &mut path);
            // Per-element chain: interior pointers then the update; the
            // chains of different updates overlap in the OoO window.
            let mut chain = model.depth_check;
            for &a in &path {
                chain += h.access(a) as f64;
            }
            cycles += model.random_chain(chain) + model.compute;
        }
    } else {
        let base = 0x10_0000u64;
        for _ in 0..ops {
            let i = rng.below(len as u64);
            let (t, d) = h.access_split(base + i * elem);
            cycles += model.random_chain((t + d) as f64) + model.compute;
        }
    }
    SimResult {
        cycles_per_elem: cycles / ops as f64,
        elems: ops,
        tlb_miss_rate: h.stats().tlb_miss_rate(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::{AddressMode, PageSize};
    use crate::pmem::BlockAllocator;

    #[test]
    fn real_gups_vec_and_tree_agree() {
        let a = BlockAllocator::new(4096, 4096).unwrap();
        let n = 1 << 14;
        let mut vec_table = vec![0u64; n];
        let mut tree_table: TreeArray<u64> = TreeArray::new(&a, n).unwrap();
        let c1 = gups_vec(&mut vec_table, 50_000, 9);
        let c2 = gups_tree_naive(&mut tree_table, 50_000, 9);
        assert_eq!(c1, c2, "same seed must produce identical tables");
        // And the actual contents match.
        assert_eq!(tree_table.to_vec(), vec_table);
    }

    #[test]
    fn batched_gups_bit_identical_to_per_op() {
        let a = BlockAllocator::new(4096, 4096).unwrap();
        let n = 1 << 14;
        let mut vec_table = vec![0u64; n];
        let c1 = gups_vec(&mut vec_table, 30_000, 13);
        for batch in [1usize, 7, 256, GUPS_BATCH] {
            let mut tree_table: TreeArray<u64> = TreeArray::new(&a, n).unwrap();
            let c2 = gups_tree_batched(&mut tree_table, 30_000, 13, batch);
            assert_eq!(c1, c2, "batch={batch}: checksum diverged");
            assert_eq!(tree_table.to_vec(), vec_table, "batch={batch}");
        }
    }

    #[test]
    fn batched_gups_on_flat_table_tree() {
        let a = BlockAllocator::new(4096, 4096).unwrap();
        let n = 1 << 14;
        let mut vec_table = vec![0u64; n];
        let c1 = gups_vec(&mut vec_table, 20_000, 21);
        let mut tree_table: TreeArray<u64> = TreeArray::new(&a, n).unwrap();
        tree_table.enable_flat_table();
        let c2 = gups_tree_batched(&mut tree_table, 20_000, 21, 512);
        assert_eq!(c1, c2);
    }

    #[test]
    fn view_read_matches_reference_and_survives_migration() {
        let a = BlockAllocator::new(4096, 4096).unwrap();
        let n = 1 << 13;
        let mut tree: TreeArray<u64> = TreeArray::new(&a, n).unwrap();
        let mut vec_table = vec![0u64; n];
        gups_vec(&mut vec_table, 20_000, 3);
        tree.copy_from_slice(&vec_table).unwrap();
        let want = gups_read_reference(&vec_table, 10_000, 8);
        let mut view = tree.view();
        assert_eq!(gups_view_read(&mut view, 10_000, 8), want);
        // Relocate under the live view; the checksum must not move.
        // SAFETY: only epoch-registered views read the tree.
        unsafe { tree.migrate_leaf_concurrent(0) }.unwrap();
        assert_eq!(gups_view_read(&mut view, 10_000, 8), want);
        drop(view);
        a.epoch().synchronize(&a);
    }

    #[test]
    fn batched_view_read_bit_identical_and_amortizes_pins() {
        let a = crate::pmem::TwoLevelAllocator::new(4096, 4096).unwrap();
        let n = 1 << 13;
        let mut tree: TreeArray<u64, _> = TreeArray::new(&a, n).unwrap();
        let mut vec_table = vec![0u64; n];
        gups_vec(&mut vec_table, 20_000, 5);
        tree.copy_from_slice(&vec_table).unwrap();
        let want = gups_read_reference(&vec_table, 10_000, 17);
        let mut view = tree.view();
        for batch in [1usize, 7, 256, GUPS_BATCH] {
            assert_eq!(
                gups_view_read_batched(&mut view, 10_000, 17, batch),
                want,
                "batch={batch}: checksum diverged"
            );
        }
        let s = a.epoch().stats();
        assert!(
            s.saved_pins > 0,
            "batched reads must amortize epoch pins: {s:?}"
        );
        // Survives relocation under the live view, like the scalar path.
        // SAFETY: only epoch-registered views read the tree.
        unsafe { tree.migrate_leaf_concurrent(0) }.unwrap();
        assert_eq!(gups_view_read_batched(&mut view, 10_000, 17, 512), want);
        drop(view);
        a.epoch().synchronize(&a);
    }

    #[test]
    fn rw_writer_streams_replay_onto_the_mirror() {
        let a = BlockAllocator::new(4096, 64).unwrap();
        let n = 1 << 12;
        let mut tree: TreeArray<u64> = TreeArray::new(&a, n).unwrap();
        let mut mirror: Vec<u64> = (0..n).map(rw_init).collect();
        tree.copy_from_slice(&mirror).unwrap();
        {
            // SAFETY: single thread; writer is the only accessor.
            let mut w = unsafe { tree.writer() };
            assert_eq!(gups_rw_write(&mut w, 5_000, 11), 5_000);
            gups_rw_write(&mut w, 3_000, 22);
        }
        // Replay in the opposite order: increments commute.
        rw_apply_reference(&mut mirror, 3_000, 22);
        rw_apply_reference(&mut mirror, 5_000, 11);
        assert_eq!(tree.to_vec(), mirror);
    }

    #[test]
    fn rw_read_invariant_holds_across_writes_and_migration() {
        let a = BlockAllocator::new(4096, 64).unwrap();
        let n = 1 << 12;
        let mut tree: TreeArray<u64> = TreeArray::new(&a, n).unwrap();
        let init: Vec<u64> = (0..n).map(rw_init).collect();
        tree.copy_from_slice(&init).unwrap();
        let mut view = tree.view();
        std::hint::black_box(gups_rw_read(&mut view, 2_000, 7));
        {
            let mut w = unsafe { tree.writer() };
            gups_rw_write(&mut w, 2_000, 9);
            std::hint::black_box(gups_rw_read(&mut view, 2_000, 7));
        }
        // SAFETY: accessors are the epoch-registered view only.
        unsafe { tree.migrate_leaf_concurrent(0) }.unwrap();
        std::hint::black_box(gups_rw_read(&mut view, 2_000, 7));
        drop(view);
        a.epoch().synchronize(&a);
    }

    fn gups_ratio(bytes: u64) -> f64 {
        let m = CostModel::default();
        let mut hv = Hierarchy::kaby_lake(AddressMode::Virtual(PageSize::P4K));
        let mut hp = Hierarchy::kaby_lake(AddressMode::Physical);
        let a = sim_gups(&mut hv, &m, bytes, false, 200_000, 5);
        let t = sim_gups(&mut hp, &m, bytes, true, 200_000, 5);
        t.cycles_per_elem / a.cycles_per_elem
    }

    #[test]
    fn sim_gups_trees_win_at_16gb_and_beyond() {
        // Figure 4's headline: "trees even outperform arrays for the
        // 16 GB GUPS dataset, so physical addressing should perform
        // better at that size or larger." (Known model deviation,
        // EXPERIMENTS.md: our simulator already favors trees at 4-8 GB,
        // where the paper measured a small tree penalty.)
        let r16 = gups_ratio(16 << 30);
        let r64 = gups_ratio(64 << 30);
        assert!(r16 < 1.0, "16 GB GUPS tree/array = {r16:.3}, want < 1.0");
        assert!(r64 < 1.1, "64 GB GUPS tree/array = {r64:.3}, want < 1.1");
        // And the win is not absurd (sanity against broken baselines).
        assert!(r16 > 0.3, "16 GB ratio {r16:.3} suspiciously low");
    }
}
