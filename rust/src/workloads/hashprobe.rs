//! deepsjeng-like hash-table probe (Figure 5's bad-locality benchmark).
//!
//! SPECInt2017's deepsjeng allocates one large transposition table (the
//! `_r` input uses ~700 MB, `_s` ~7 GB) and probes it at
//! Zobrist-hash-random slots. The memory behaviour the paper relies on
//! is exactly that: a single huge array accessed unpredictably. This
//! module reproduces it with an open-addressing probe loop over
//! contiguous and tree layouts plus a simulated variant for the 7 GB
//! point.

use crate::memsim::Hierarchy;
use crate::pmem::BlockAlloc;
use crate::testutil::Rng;
use crate::trees::{TreeArray, TreeGeometry, TreeTraceModel, TreeView};
use crate::workloads::trace::CostModel;
use crate::workloads::SimResult;

/// One transposition-table entry: packed key+score (8 bytes, like
/// deepsjeng's packed hash entries).
pub type Entry = u64;

/// Mix a position id into a table slot (splitmix-style Zobrist stand-in).
#[inline]
fn slot_of(pos: u64, len: usize) -> usize {
    let mut z = pos.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    (z % len as u64) as usize
}

/// Probe/store loop over a contiguous table: for each simulated search
/// node, read the entry, and with probability ~1/2 store back. Returns a
/// checksum.
pub fn probe_vec(table: &mut [Entry], ops: u64, seed: u64) -> u64 {
    let mut rng = Rng::new(seed);
    let n = table.len();
    let mut acc = 0u64;
    for _ in 0..ops {
        let pos = rng.next_u64();
        let s = slot_of(pos, n);
        let e = table[s];
        acc = acc.wrapping_add(e);
        if pos & 1 == 0 {
            table[s] = e ^ pos;
        }
    }
    acc
}

/// The same loop over a tree-layout table via naive walks.
pub fn probe_tree_naive<A: BlockAlloc>(
    table: &mut TreeArray<'_, Entry, A>,
    ops: u64,
    seed: u64,
) -> u64 {
    let mut rng = Rng::new(seed);
    let n = table.len();
    let mut acc = 0u64;
    for _ in 0..ops {
        let pos = rng.next_u64();
        let s = slot_of(pos, n);
        // SAFETY: s < n by construction.
        let e = unsafe { table.get_unchecked(s) };
        acc = acc.wrapping_add(e);
        if pos & 1 == 0 {
            unsafe { table.set_unchecked(s, e ^ pos) };
        }
    }
    acc
}

/// The probe/store loop with *batched* table access: `batch` probes are
/// hashed up front and applied through [`TreeArray::update_batch`], one
/// translation per distinct leaf run. Checksum-identical to
/// [`probe_vec`]/[`probe_tree_naive`]: the accumulator is a commutative
/// wrapping sum, and same-slot probes stay in batch order (stable
/// grouping), so read-after-store semantics within a batch hold.
pub fn probe_tree_batched<A: BlockAlloc>(
    table: &mut TreeArray<'_, Entry, A>,
    ops: u64,
    seed: u64,
    batch: usize,
) -> u64 {
    let batch = batch.max(1);
    let mut rng = Rng::new(seed);
    let n = table.len();
    let mut acc = 0u64;
    let mut idxs = Vec::with_capacity(batch);
    let mut keys = Vec::with_capacity(batch);
    let mut done = 0u64;
    while done < ops {
        let b = batch.min((ops - done) as usize);
        idxs.clear();
        keys.clear();
        for _ in 0..b {
            let pos = rng.next_u64();
            idxs.push(slot_of(pos, n));
            keys.push(pos);
        }
        table
            .update_batch(&idxs, |p, e| {
                acc = acc.wrapping_add(*e);
                if keys[p] & 1 == 0 {
                    *e ^= keys[p];
                }
            })
            .expect("slots in range by construction");
        done += b as u64;
    }
    acc
}

/// The read side of the transposition-table probe through a shared
/// [`TreeView`]: `ops` hashed lookups, no stores — the concurrent-read
/// serving scenario (N worker threads, one table). Checksums reproduce
/// from the table's contents via [`probe_read_reference`].
pub fn probe_view<A: BlockAlloc>(
    view: &mut TreeView<'_, '_, Entry, A>,
    ops: u64,
    seed: u64,
) -> u64 {
    let mut rng = Rng::new(seed);
    let n = view.len();
    let mut acc = 0u64;
    for _ in 0..ops {
        let pos = rng.next_u64();
        let s = slot_of(pos, n);
        // SAFETY: s < n by construction.
        let e = unsafe { view.get_unchecked(s) };
        acc = acc.rotate_left(9) ^ e.wrapping_add(pos);
    }
    acc
}

/// Reference checksum for [`probe_view`] over the table's contents.
pub fn probe_read_reference(table: &[Entry], ops: u64, seed: u64) -> u64 {
    let mut rng = Rng::new(seed);
    let n = table.len();
    let mut acc = 0u64;
    for _ in 0..ops {
        let pos = rng.next_u64();
        acc = acc.rotate_left(9) ^ table[slot_of(pos, n)].wrapping_add(pos);
    }
    acc
}

/// Simulated probe loop at paper scale (700 MB / 7 GB tables).
pub fn sim_probe(
    h: &mut Hierarchy,
    model: &CostModel,
    table_bytes: u64,
    tree: bool,
    ops: u64,
    seed: u64,
) -> SimResult {
    let len = (table_bytes / 8) as usize;
    let mut rng = Rng::new(seed);
    let mut cycles = 0.0f64;
    if tree {
        let geo = TreeGeometry::new(32 * 1024, 8, len).expect("geometry");
        let tm = TreeTraceModel::new(geo, 0x10_0000);
        let mut path = Vec::with_capacity(4);
        for _ in 0..ops {
            let s = slot_of(rng.next_u64(), len);
            tm.access_path(s, &mut path);
            // Independent probe chains overlap across probes.
            let mut chain = model.depth_check;
            for &a in &path {
                chain += h.access(a) as f64;
            }
            cycles += model.random_chain(chain) + model.compute;
        }
    } else {
        let base = 0x10_0000u64;
        for _ in 0..ops {
            let s = slot_of(rng.next_u64(), len) as u64;
            let (t, d) = h.access_split(base + s * 8);
            cycles += model.random_chain((t + d) as f64) + model.compute;
        }
    }
    SimResult {
        cycles_per_elem: cycles / ops as f64,
        elems: ops,
        tlb_miss_rate: h.stats().tlb_miss_rate(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::{AddressMode, PageSize};
    use crate::pmem::BlockAllocator;

    #[test]
    fn vec_and_tree_probe_agree() {
        let a = BlockAllocator::new(4096, 1 << 12).unwrap();
        let n = 1 << 14;
        let mut v = vec![0u64; n];
        let mut t: TreeArray<u64> = TreeArray::new(&a, n).unwrap();
        let c1 = probe_vec(&mut v, 100_000, 5);
        let c2 = probe_tree_naive(&mut t, 100_000, 5);
        assert_eq!(c1, c2);
        assert_eq!(t.to_vec(), v);
    }

    #[test]
    fn batched_probe_identical_to_per_op() {
        let a = BlockAllocator::new(4096, 1 << 12).unwrap();
        let n = 1 << 14;
        let mut v = vec![0u64; n];
        let c1 = probe_vec(&mut v, 60_000, 9);
        for batch in [1usize, 64, 1024] {
            let mut t: TreeArray<u64> = TreeArray::new(&a, n).unwrap();
            let c2 = probe_tree_batched(&mut t, 60_000, 9, batch);
            assert_eq!(c1, c2, "batch={batch}: checksum diverged");
            assert_eq!(t.to_vec(), v, "batch={batch}: table diverged");
        }
    }

    #[test]
    fn probe_view_matches_reference() {
        let a = BlockAllocator::new(4096, 1 << 10).unwrap();
        let n = 1 << 13;
        let mut v = vec![0u64; n];
        probe_vec(&mut v, 30_000, 4); // scatter nonzero entries
        let mut t: TreeArray<u64> = TreeArray::new(&a, n).unwrap();
        t.copy_from_slice(&v).unwrap();
        t.enable_flat_table();
        let want = probe_read_reference(&v, 15_000, 11);
        let mut view = t.view();
        assert_eq!(probe_view(&mut view, 15_000, 11), want);
        assert!(view.tlb_stats().hits > 0);
    }

    #[test]
    fn slots_cover_table() {
        let n = 1000;
        let mut seen = vec![false; n];
        for pos in 0..50_000u64 {
            seen[slot_of(pos, n)] = true;
        }
        let covered = seen.iter().filter(|&&b| b).count();
        assert!(covered > 990, "hash covers only {covered}/1000 slots");
    }

    #[test]
    fn sim_7gb_tree_physical_vs_array_virtual() {
        // Figure 5 deepsjeng_s: 7 GB table; overhead of trees must stay
        // small (paper: < 3%) because the TLB savings offset the walks.
        let m = CostModel { mlp: 2.0, ..Default::default() };
        let mut hv = Hierarchy::kaby_lake(AddressMode::Virtual(PageSize::P4K));
        let mut hp = Hierarchy::kaby_lake(AddressMode::Physical);
        let a = sim_probe(&mut hv, &m, 7 << 30, false, 200_000, 6);
        let t = sim_probe(&mut hp, &m, 7 << 30, true, 200_000, 6);
        let ratio = t.cycles_per_elem / a.cycles_per_elem;
        assert!(ratio < 1.15, "7 GB probe tree/array = {ratio:.3}");
    }
}
