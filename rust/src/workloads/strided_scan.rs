//! Strided scan microbenchmark (Table 2 rows 3–4), real execution.
//! The paper strides 1024 elements (= 4 KB with f32), touching one
//! element per page on the VM baseline.

use crate::pmem::BlockAlloc;
use crate::trees::TreeArray;

/// Paper's stride: every 1024th element (4 KB apart).
pub const PAPER_STRIDE: usize = 1024;

/// Strided sum over a contiguous slice.
pub fn scan_vec(data: &[f32], stride: usize) -> f64 {
    let mut acc = 0.0f64;
    let mut i = 0usize;
    while i < data.len() {
        acc += data[i] as f64;
        i += stride;
    }
    acc
}

/// Strided sum via naive tree walks.
pub fn scan_tree_naive<A: BlockAlloc>(t: &TreeArray<'_, f32, A>, stride: usize) -> f64 {
    let mut acc = 0.0f64;
    let mut i = 0usize;
    while i < t.len() {
        // SAFETY: loop bound.
        acc += unsafe { t.get_unchecked(i) } as f64;
        i += stride;
    }
    acc
}

/// Strided sum via the cursor (leaf cache catches within-leaf strides).
pub fn scan_tree_iter<A: BlockAlloc>(t: &TreeArray<'_, f32, A>, stride: usize) -> f64 {
    let mut acc = 0.0f64;
    let mut c = t.cursor();
    let mut i = 0usize;
    while i < t.len() {
        acc += c.seek(i) as f64;
        i += stride;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;
    use crate::testutil::{forall, Rng};
    use crate::workloads::linear_scan::tree_from;

    #[test]
    fn scans_agree_paper_stride() {
        let a = BlockAllocator::new(4096, 4096).unwrap();
        let mut rng = Rng::new(1);
        let d: Vec<f32> = (0..1 << 20).map(|_| rng.f32_range(0.0, 1.0)).collect();
        let t = tree_from(&a, &d);
        let v = scan_vec(&d, PAPER_STRIDE);
        assert!((v - scan_tree_naive(&t, PAPER_STRIDE)).abs() < 1e-6);
        assert!((v - scan_tree_iter(&t, PAPER_STRIDE)).abs() < 1e-6);
    }

    #[test]
    fn prop_any_stride_agrees() {
        forall(15, |g| {
            let a = BlockAllocator::new(1024, 1 << 13).unwrap();
            let n = g.usize_in(1, 1 << 17);
            let stride = g.usize_in(1, 4096);
            let d: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let t = tree_from(&a, &d);
            let v = scan_vec(&d, stride);
            assert_eq!(v, scan_tree_naive(&t, stride));
            assert_eq!(v, scan_tree_iter(&t, stride));
        });
    }
}
