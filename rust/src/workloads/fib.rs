//! Recursive Fibonacci — the paper's pessimistic split-stack
//! microbenchmark ("amplify the performance cost of stack splitting
//! beyond what would be seen in most programs"; measured 15%).
//!
//! Two real implementations: native Rust recursion (the contiguous-stack
//! baseline) and recursion through [`SplitStack`] frames, where every
//! call pays the space check and locals live in stack blocks. Their
//! wallclock ratio is this repo's measured fib datapoint for Figure 3.

use crate::error::Result;
use crate::pmem::BlockAlloc;
use crate::stack::SplitStack;

/// Native recursion baseline.
pub fn fib_native(n: u32) -> u64 {
    if n < 2 {
        n as u64
    } else {
        fib_native(n - 1) + fib_native(n - 2)
    }
}

/// Iterative closed-loop reference (for correctness checks).
pub fn fib_reference(n: u32) -> u64 {
    let (mut a, mut b) = (0u64, 1u64);
    for _ in 0..n {
        let t = a + b;
        a = b;
        b = t;
    }
    a
}

/// Recursion where every call pushes a real frame on a [`SplitStack`]
/// (8-byte local holding `n`). This exercises the check on every call
/// exactly as gcc's `-fsplit-stack` prologue does.
pub fn fib_split<A: BlockAlloc>(s: &mut SplitStack<'_, A>, n: u32) -> Result<u64> {
    let frame = s.call(16, &(n as u64).to_le_bytes())?;
    let result = if n < 2 {
        n as u64
    } else {
        let a = fib_split(s, n - 1)?;
        let b = fib_split(s, n - 2)?;
        // Touch the local to keep the frame live and honest.
        let mut buf = [0u8; 8];
        s.read_local(frame, 0, &mut buf)?;
        debug_assert_eq!(u64::from_le_bytes(buf), n as u64);
        a + b
    };
    s.ret()?;
    Ok(result)
}

/// Convenience: run `fib_split` with a fresh stack over `alloc`.
pub fn fib_split_fresh<A: BlockAlloc>(alloc: &A, n: u32) -> Result<(u64, u64)> {
    let mut s = SplitStack::new(alloc)?;
    let v = fib_split(&mut s, n)?;
    let calls = s.stats().calls;
    Ok((v, calls))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;

    #[test]
    fn native_matches_reference() {
        for n in 0..20 {
            assert_eq!(fib_native(n), fib_reference(n));
        }
    }

    #[test]
    fn split_matches_reference() {
        let a = BlockAllocator::new(4096, 256).unwrap();
        for n in [0u32, 1, 2, 10, 18] {
            let (v, _) = fib_split_fresh(&a, n).unwrap();
            assert_eq!(v, fib_reference(n), "fib({n})");
        }
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn call_count_is_fib_tree_size() {
        // Recursive fib(n) makes 2*fib(n+1)-1 calls.
        let a = BlockAllocator::new(4096, 256).unwrap();
        let (_, calls) = fib_split_fresh(&a, 12).unwrap();
        assert_eq!(calls, 2 * fib_reference(13) - 1);
    }
}
