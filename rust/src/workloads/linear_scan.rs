//! Linear scan microbenchmark (Table 2 rows 1–2), real execution.

use crate::pmem::BlockAlloc;
use crate::trees::TreeArray;

/// Sum every element of a contiguous `Vec` (the VM baseline).
pub fn scan_vec(data: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for &v in data {
        acc += v as f64;
    }
    acc
}

/// Sum every element through naive tree `get` (full walk per element).
pub fn scan_tree_naive<A: BlockAlloc>(t: &TreeArray<'_, f32, A>) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..t.len() {
        // SAFETY: i < len by loop bound.
        acc += unsafe { t.get_unchecked(i) } as f64;
    }
    acc
}

/// Sum every element through the Figure 2 iterator.
pub fn scan_tree_iter<A: BlockAlloc>(t: &TreeArray<'_, f32, A>) -> f64 {
    let mut acc = 0.0f64;
    for v in t.iter() {
        acc += v as f64;
    }
    acc
}

/// Build a tree array mirroring `data` (helper shared by benches).
pub fn tree_from<'a, A: BlockAlloc>(alloc: &'a A, data: &[f32]) -> TreeArray<'a, f32, A> {
    let mut t = TreeArray::new(alloc, data.len()).expect("tree alloc");
    t.copy_from_slice(data).expect("tree fill");
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;
    use crate::testutil::Rng;

    fn data(n: usize) -> Vec<f32> {
        let mut rng = Rng::new(42);
        (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect()
    }

    #[test]
    fn all_three_scans_agree() {
        let a = BlockAllocator::new(4096, 4096).unwrap();
        let d = data(4096 * 3 + 17);
        let t = tree_from(&a, &d);
        let v = scan_vec(&d);
        let n = scan_tree_naive(&t);
        let i = scan_tree_iter(&t);
        assert!((v - n).abs() < 1e-6, "{v} vs naive {n}");
        assert!((v - i).abs() < 1e-6, "{v} vs iter {i}");
    }

    #[test]
    fn depth1_tree_scan() {
        let a = BlockAllocator::new(4096, 64).unwrap();
        let d = data(100);
        let t = tree_from(&a, &d);
        assert_eq!(t.depth(), 1);
        assert!((scan_vec(&d) - scan_tree_iter(&t)).abs() < 1e-9);
    }
}
