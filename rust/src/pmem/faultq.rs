//! The software page-fault queue: asynchronous swap I/O with bounded
//! depth, retry with exponential backoff, and permanent-failure
//! escalation.
//!
//! Without virtual memory there is no hardware page-fault mechanism to
//! lean on (the paper's premise): when an accessor touches an evicted
//! leaf, *software* must notice, read the payload back, and splice it
//! into the tree — and it must do so correctly under concurrency and
//! under I/O failure. This module is the I/O half of that story; the
//! splice half lives in [`crate::trees`] (the view/writer fault hooks
//! adopt the faulted block under the leaf's seqlock).
//!
//! # Pieces
//!
//! * [`SwapService`] / [`LeafFaulter`] — the type-erased swap surface.
//!   [`SwapPool`] implements both (inline, synchronous); the daemon,
//!   compactor, and tree fault hooks are written against the traits so
//!   the same code runs over a bare pool or over a [`FaultQueue`].
//! * [`FaultQueue`] — a small I/O dispatcher over any [`SwapService`].
//!   With no workers attached it executes fault-ins **inline** on the
//!   calling thread (still with retry/backoff/escalation — the default
//!   for tests and single-threaded use). [`FaultQueue::attach_workers`]
//!   adds a bounded-depth request queue drained by scoped worker
//!   threads, so concurrent demand faults from many accessor threads
//!   are throttled to a fixed I/O parallelism.
//!
//! # Failure model
//!
//! Each request makes up to [`FaultQueueConfig::max_retries`] attempts:
//!
//! * **Transient** backing errors ([`Error::Io`]) sleep an
//!   exponentially growing backoff and retry — the underlying
//!   [`SwapPool::fault`] is failure-atomic, so the slot's payload is
//!   intact across a failed attempt.
//! * **Memory pressure** ([`Error::OutOfMemory`]) runs a
//!   [`SwapService::reclaim`] pass (evicted blocks may be sitting in
//!   epoch limbo) and retries under the same budget.
//! * Exhausting the budget on I/O errors **escalates**: the queue
//!   marks the requesting *tenant* degraded and surfaces the typed
//!   [`Error::SwapFaultFailed`] — never a panic, never a wedge; the
//!   slot stays resident, so the fault can be retried after the
//!   backing recovers (a later success for that tenant clears its
//!   flag). Other errors (not-resident, coalesced-by-peer) pass
//!   through unchanged.
//!
//! # Tenant scoping
//!
//! Degraded state is **per-tenant**, never global. Every request
//! carries a tenant tag: tenant-unaware callers (the plain
//! [`LeafFaulter`] impl) run as [`DEFAULT_TENANT`], and a tree that
//! belongs to tenant `t` is armed with [`FaultQueue::scoped`]`(t)` so
//! its demand faults carry `t`. Each tenant may also route to its own
//! backing ([`FaultQueue::route_tenant`]) — one tenant's dead swap
//! file parks *its* leaves behind its own sticky flag
//! ([`FaultQueue::degraded_for`]) while every other tenant keeps
//! faulting through the queue normally. [`FaultQueue::degraded`] is
//! the any-tenant aggregate (what a single-tenant caller means by
//! "degraded"). With a [`TenantRegistry`] attached
//! ([`FaultQueue::with_tenants`]), verdicts are mirrored onto the
//! tenants' own flags and successful fault-ins charge the faulted
//! block back to the owning tenant's residency quota.
//!
//! # Timeout accounting
//!
//! Blocking I/O cannot be cancelled, so there are no hard deadlines;
//! instead every request's wall-clock duration is recorded
//! ([`FaultStats::total_ns`] / [`FaultStats::max_ns`]) and requests
//! slower than [`FaultQueueConfig::slow_fault`] are counted
//! ([`FaultStats::slow_faults`]) — the mmd policy reads these to
//! throttle eviction when the backing store is slow.
//!
//! # Bounded depth
//!
//! The queue never wedges on its own limit: a **demand** fault that
//! finds the queue full runs inline on the requester's thread
//! ([`FaultStats::shed_inline`]); a **prefetch** (speculative, via
//! [`FaultQueue::prefetch_gate`]) is dropped instead
//! ([`FaultStats::shed_prefetch`]) — speculation must never steal I/O
//! slots from demand misses.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::pmem::swap::{SwapBacking, SwapPool, SwapSlot};
use crate::pmem::tenant::{TenantRegistry, DEFAULT_TENANT};
use crate::pmem::{BlockAlloc, BlockId};
use crate::telemetry::metrics::MetricSource;
use crate::telemetry::stat::LogHistogram;

/// The type-erased eviction surface: what the mmd compactor needs to
/// push a leaf out. Implemented by [`SwapPool`] (over any allocator and
/// backing), so daemon code is not generic over either.
pub trait SwapService: Sync {
    /// Evict `block` under live readers: payload to the backing store,
    /// physical block retired into epoch limbo.
    fn evict_deferred(&self, block: BlockId) -> Result<SwapSlot>;

    /// Read `slot`'s payload back into a fresh block (synchronous; the
    /// slot is released on success). Failure-atomic per
    /// [`SwapPool::fault`].
    fn fault(&self, slot: SwapSlot) -> Result<BlockId>;

    /// One non-blocking epoch-reclaim pass (frees limbo blocks whose
    /// readers have quiesced). Called between `OutOfMemory` retries.
    fn reclaim(&self);
}

/// The type-erased fault-in surface: what a tree fault hook (or the
/// daemon's restore/prefetch pass) needs to bring one slot back.
/// Implemented by [`SwapPool`] (inline I/O on the calling thread) and
/// by [`FaultQueue`] (queued I/O with retry/backoff/escalation).
pub trait LeafFaulter: Sync {
    /// Fault `slot` back in; on success the returned block holds the
    /// payload and ownership transfers to the caller.
    fn fault_in(&self, slot: SwapSlot) -> Result<BlockId>;
}

impl<A: BlockAlloc + Sync, B: SwapBacking> SwapService for SwapPool<'_, A, B> {
    fn evict_deferred(&self, block: BlockId) -> Result<SwapSlot> {
        SwapPool::evict_deferred(self, block)
    }

    fn fault(&self, slot: SwapSlot) -> Result<BlockId> {
        SwapPool::fault(self, slot)
    }

    fn reclaim(&self) {
        SwapPool::reclaim(self);
    }
}

impl<A: BlockAlloc + Sync, B: SwapBacking> LeafFaulter for SwapPool<'_, A, B> {
    fn fault_in(&self, slot: SwapSlot) -> Result<BlockId> {
        SwapPool::fault(self, slot)
    }
}

/// Tunables for a [`FaultQueue`].
#[derive(Clone, Copy, Debug)]
pub struct FaultQueueConfig {
    /// Queued requests beyond this shed (inline for demand, dropped for
    /// prefetch). Only meaningful with workers attached.
    pub max_depth: usize,
    /// I/O attempts per request (≥ 1) before permanent escalation.
    pub max_retries: u32,
    /// First retry's backoff sleep; doubles per retry.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Requests slower than this count as [`FaultStats::slow_faults`].
    pub slow_fault: Duration,
}

impl Default for FaultQueueConfig {
    fn default() -> Self {
        FaultQueueConfig {
            max_depth: 16,
            max_retries: 4,
            backoff_base: Duration::from_micros(100),
            backoff_cap: Duration::from_millis(10),
            slow_fault: Duration::from_millis(50),
        }
    }
}

/// Counters a [`FaultQueue`] keeps (all monotonic except `depth_hw`,
/// which is a high-water mark).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultStats {
    /// Successful fault-ins executed by the queue (demand + prefetch).
    pub faults: u64,
    /// Demand fault-ins requested ([`LeafFaulter::fault_in`] calls).
    pub demand: u64,
    /// Attempts retried after a transient error.
    pub retries: u64,
    /// Requests escalated to [`Error::SwapFaultFailed`].
    pub permanent: u64,
    /// Demand faults run on the requester's thread because the queue
    /// was full.
    pub shed_inline: u64,
    /// Prefetches dropped because the queue was full or degraded.
    pub shed_prefetch: u64,
    /// Requests slower than [`FaultQueueConfig::slow_fault`].
    pub slow_faults: u64,
    /// Deepest the request queue has been.
    pub depth_hw: usize,
    /// Total wall-clock nanoseconds spent in fault execution.
    pub total_ns: u64,
    /// Slowest single request in nanoseconds.
    pub max_ns: u64,
}

impl FaultStats {
    /// Mean fault-in latency in nanoseconds (0 when nothing completed).
    pub fn mean_ns(&self) -> u64 {
        if self.faults == 0 {
            0
        } else {
            self.total_ns / self.faults
        }
    }
}

impl MetricSource for FaultStats {
    fn metric_prefix(&self) -> &'static str {
        "fault"
    }

    fn emit(&self, out: &mut dyn FnMut(&str, f64)) {
        out("faults", self.faults as f64);
        out("demand", self.demand as f64);
        out("retries", self.retries as f64);
        out("permanent", self.permanent as f64);
        out("shed_inline", self.shed_inline as f64);
        out("shed_prefetch", self.shed_prefetch as f64);
        out("slow_faults", self.slow_faults as f64);
        out("depth_hw", self.depth_hw as f64);
        out("mean_us", self.mean_ns() as f64 / 1e3);
        out("max_us", self.max_ns as f64 / 1e3);
    }
}

struct QState {
    /// Pending requests: `(request id, raw slot, tenant)`.
    queue: VecDeque<(u64, u64, u16)>,
    /// Finished requests awaiting pickup by their requester.
    completions: HashMap<u64, Result<BlockId>>,
    next_id: u64,
    /// Attached worker count; 0 = inline mode.
    workers: usize,
    shutdown: bool,
}

/// The asynchronous swap-in dispatcher. See the module docs for the
/// execution/failure model. `'p` ties the queue to the
/// [`SwapService`] it drains into.
pub struct FaultQueue<'p> {
    svc: &'p dyn SwapService,
    cfg: FaultQueueConfig,
    state: Mutex<QState>,
    /// Workers park here waiting for requests.
    work_cv: Condvar,
    /// Requesters park here waiting for their completion.
    done_cv: Condvar,
    /// Per-tenant backing routes; tenants not listed use `svc`.
    routes: Mutex<Vec<(u16, &'p dyn SwapService)>>,
    /// Tenants whose last request exhausted its retries (sticky until
    /// that tenant's next success).
    degraded_set: Mutex<Vec<u16>>,
    /// `!degraded_set.is_empty()`, mirrored for lock-free reads.
    degraded_any: AtomicBool,
    /// Optional tenant ledger: degraded verdicts are mirrored onto it
    /// and successful fault-ins charge the owning tenant's residency.
    tenants: Option<&'p TenantRegistry>,
    s_faults: AtomicU64,
    s_demand: AtomicU64,
    s_retries: AtomicU64,
    s_permanent: AtomicU64,
    s_shed_inline: AtomicU64,
    s_shed_prefetch: AtomicU64,
    s_slow: AtomicU64,
    s_depth_hw: AtomicUsize,
    s_total_ns: AtomicU64,
    s_max_ns: AtomicU64,
    /// Per-request fault-in latency distribution (ns). One mutexed
    /// record per fault — noise next to the swap I/O it measures.
    s_lat: Mutex<LogHistogram>,
}

impl<'p> FaultQueue<'p> {
    /// A queue over `svc` with the given tunables, in **inline** mode
    /// (no workers: every request executes on the calling thread, with
    /// the full retry/backoff/escalation machinery).
    pub fn new(svc: &'p dyn SwapService, cfg: FaultQueueConfig) -> Self {
        Self::build(svc, cfg, None)
    }

    /// Like [`FaultQueue::new`], with a tenant ledger attached:
    /// per-tenant degraded verdicts are mirrored onto the registry's
    /// flags and every successful tenant fault-in charges the faulted
    /// block back to that tenant's residency quota.
    pub fn with_tenants(
        svc: &'p dyn SwapService,
        cfg: FaultQueueConfig,
        tenants: &'p TenantRegistry,
    ) -> Self {
        Self::build(svc, cfg, Some(tenants))
    }

    fn build(
        svc: &'p dyn SwapService,
        cfg: FaultQueueConfig,
        tenants: Option<&'p TenantRegistry>,
    ) -> Self {
        FaultQueue {
            svc,
            cfg,
            state: Mutex::new(QState {
                queue: VecDeque::new(),
                completions: HashMap::new(),
                next_id: 0,
                workers: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            routes: Mutex::new(Vec::new()),
            degraded_set: Mutex::new(Vec::new()),
            degraded_any: AtomicBool::new(false),
            tenants,
            s_faults: AtomicU64::new(0),
            s_demand: AtomicU64::new(0),
            s_retries: AtomicU64::new(0),
            s_permanent: AtomicU64::new(0),
            s_shed_inline: AtomicU64::new(0),
            s_shed_prefetch: AtomicU64::new(0),
            s_slow: AtomicU64::new(0),
            s_depth_hw: AtomicUsize::new(0),
            s_total_ns: AtomicU64::new(0),
            s_max_ns: AtomicU64::new(0),
            s_lat: Mutex::new(LogHistogram::new()),
        }
    }

    /// The default service this queue drains into (the daemon evicts
    /// through the same service its fault queue faults from). Tenants
    /// with a route of their own use theirs instead; see
    /// [`FaultQueue::route_tenant`].
    pub fn service(&self) -> &'p dyn SwapService {
        self.svc
    }

    /// Route tenant `tenant`'s swap I/O to its own service (its own
    /// backing file): subsequent requests tagged with that tenant
    /// execute against `svc` instead of the default. Re-routing an
    /// already-routed tenant replaces the route (tenant churn).
    pub fn route_tenant(&self, tenant: u16, svc: &'p dyn SwapService) {
        let mut routes = self.routes.lock().unwrap();
        if let Some(r) = routes.iter_mut().find(|(t, _)| *t == tenant) {
            r.1 = svc;
        } else {
            routes.push((tenant, svc));
        }
    }

    /// Drop tenant `tenant`'s route (departure); its traffic falls back
    /// to the default service. Idempotent.
    pub fn unroute_tenant(&self, tenant: u16) {
        let mut routes = self.routes.lock().unwrap();
        routes.retain(|(t, _)| *t != tenant);
    }

    fn svc_for(&self, tenant: u16) -> &'p dyn SwapService {
        self.routes
            .lock()
            .unwrap()
            .iter()
            .find(|(t, _)| *t == tenant)
            .map(|(_, s)| *s)
            .unwrap_or(self.svc)
    }

    /// A [`LeafFaulter`] (and [`SwapService`]) view of this queue tagged
    /// with `tenant`: demand faults through it carry the tenant's
    /// identity (degraded scoping, residency charging, per-tenant
    /// backing route), and evictions through it land on the tenant's
    /// routed backing. Arm a tenant's trees with this
    /// (`tree.install_faulter(&q.scoped(t))`) instead of the bare
    /// queue.
    pub fn scoped(&self, tenant: u16) -> TenantFaulter<'_, 'p> {
        TenantFaulter { q: self, tenant }
    }

    /// Spawn `n` scoped worker threads draining the request queue.
    /// Until [`FaultQueue::shutdown_workers`] runs, requests enqueue
    /// (bounded by [`FaultQueueConfig::max_depth`]) and requesters
    /// block on their completion — so many accessor threads share a
    /// fixed I/O parallelism.
    ///
    /// Call `shutdown_workers` before the scope ends or the scope's
    /// implicit join will wait forever on the parked workers.
    pub fn attach_workers<'scope, 'env>(
        &'env self,
        scope: &'scope std::thread::Scope<'scope, 'env>,
        n: usize,
    ) {
        self.state.lock().unwrap().workers += n;
        for _ in 0..n {
            scope.spawn(move || self.worker_loop());
        }
    }

    /// Stop the workers: the queue drains outstanding requests, parked
    /// workers exit, and subsequent requests execute inline. Idempotent.
    pub fn shutdown_workers(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        st.workers = 0;
        drop(st);
        self.work_cv.notify_all();
    }

    /// Has **any** tenant's request exhausted its retries since that
    /// tenant's last success? The aggregate view — what a
    /// single-tenant caller means by "degraded" (sticky per tenant,
    /// cleared by that tenant's next successful fault-in). Tenant-aware
    /// callers want [`FaultQueue::degraded_for`].
    pub fn degraded(&self) -> bool {
        self.degraded_any.load(Ordering::Relaxed)
    }

    /// Is `tenant`'s swap traffic degraded? Scoped containment: one
    /// tenant's dead backing parks its leaves behind this flag while
    /// other tenants keep faulting normally.
    pub fn degraded_for(&self, tenant: u16) -> bool {
        self.degraded_set.lock().unwrap().contains(&tenant)
    }

    fn mark_degraded(&self, tenant: u16) {
        let mut set = self.degraded_set.lock().unwrap();
        if !set.contains(&tenant) {
            set.push(tenant);
        }
        self.degraded_any.store(true, Ordering::Relaxed);
        drop(set);
        if let Some(reg) = self.tenants {
            reg.set_degraded(tenant, true);
        }
    }

    fn clear_degraded(&self, tenant: u16) {
        // Fast path: nothing is degraded, nothing to clear — keeps the
        // per-success cost at one relaxed load.
        if !self.degraded_any.load(Ordering::Relaxed) {
            return;
        }
        let mut set = self.degraded_set.lock().unwrap();
        if let Some(p) = set.iter().position(|&t| t == tenant) {
            set.swap_remove(p);
        }
        self.degraded_any.store(!set.is_empty(), Ordering::Relaxed);
        drop(set);
        if let Some(reg) = self.tenants {
            reg.set_degraded(tenant, false);
        }
    }

    /// Requests currently queued (excludes in-flight executions).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> FaultStats {
        FaultStats {
            faults: self.s_faults.load(Ordering::Relaxed),
            demand: self.s_demand.load(Ordering::Relaxed),
            retries: self.s_retries.load(Ordering::Relaxed),
            permanent: self.s_permanent.load(Ordering::Relaxed),
            shed_inline: self.s_shed_inline.load(Ordering::Relaxed),
            shed_prefetch: self.s_shed_prefetch.load(Ordering::Relaxed),
            slow_faults: self.s_slow.load(Ordering::Relaxed),
            depth_hw: self.s_depth_hw.load(Ordering::Relaxed),
            total_ns: self.s_total_ns.load(Ordering::Relaxed),
            max_ns: self.s_max_ns.load(Ordering::Relaxed),
        }
    }

    /// The fault-in latency distribution (ns), cloned out so callers
    /// report percentiles without holding the queue's histogram lock.
    pub fn latency_hist(&self) -> LogHistogram {
        self.s_lat.lock().unwrap().clone()
    }

    /// A [`LeafFaulter`] view of this queue with **prefetch** shedding:
    /// requests through the gate are dropped (typed error, counted)
    /// when the queue is full or degraded, so speculative swap-ins
    /// never compete with demand misses for I/O slots.
    pub fn prefetch_gate(&self) -> PrefetchGate<'_, 'p> {
        PrefetchGate(self)
    }

    /// Enqueue (or, in inline mode, execute) one fault-in request on
    /// `tenant`'s behalf and wait for its result.
    fn request(&self, slot: SwapSlot, tenant: u16) -> Result<BlockId> {
        let id = {
            let mut st = self.state.lock().unwrap();
            if st.workers == 0 || st.shutdown {
                drop(st);
                return self.execute(slot, tenant);
            }
            if st.queue.len() >= self.cfg.max_depth {
                drop(st);
                // Bounded depth, no wedging: overflow demand runs on
                // the requester's own thread.
                self.s_shed_inline.fetch_add(1, Ordering::Relaxed);
                return self.execute(slot, tenant);
            }
            let id = st.next_id;
            st.next_id += 1;
            st.queue.push_back((id, slot.raw(), tenant));
            self.s_depth_hw.fetch_max(st.queue.len(), Ordering::Relaxed);
            id
        };
        self.work_cv.notify_one();
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(res) = st.completions.remove(&id) {
                return res;
            }
            st = self.done_cv.wait(st).unwrap();
        }
    }

    fn worker_loop(&self) {
        loop {
            let (id, raw, tenant) = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if let Some(req) = st.queue.pop_front() {
                        break req;
                    }
                    if st.shutdown {
                        return; // queue drained, workers released
                    }
                    st = self.work_cv.wait(st).unwrap();
                }
            };
            let res = self.execute(SwapSlot::from_raw(raw), tenant);
            self.state.lock().unwrap().completions.insert(id, res);
            self.done_cv.notify_all();
        }
    }

    /// One request: retry loop + backoff + escalation + accounting,
    /// against `tenant`'s routed service.
    fn execute(&self, slot: SwapSlot, tenant: u16) -> Result<BlockId> {
        let svc = self.svc_for(tenant);
        let start = Instant::now();
        let mut attempts = 0u32;
        let mut backoff = self.cfg.backoff_base;
        let budget = self.cfg.max_retries.max(1);
        let res = loop {
            attempts += 1;
            match svc.fault(slot) {
                Ok(b) => break Ok(b),
                Err(e @ (Error::Io(_) | Error::OutOfMemory { .. })) => {
                    if attempts >= budget {
                        if matches!(e, Error::Io(_)) {
                            // Permanent escalation: typed error, sticky
                            // per-tenant degraded flag. The slot is
                            // still resident (fault is failure-atomic),
                            // so recovery is a later retry, not data
                            // loss — and only THIS tenant's swap
                            // traffic is suspended.
                            self.mark_degraded(tenant);
                            self.s_permanent.fetch_add(1, Ordering::Relaxed);
                            break Err(Error::SwapFaultFailed {
                                slot: slot.raw(),
                                attempts,
                            });
                        }
                        // OOM with no memory to reclaim is pressure,
                        // not a backing failure: pass it through.
                        break Err(e);
                    }
                    self.s_retries.fetch_add(1, Ordering::Relaxed);
                    if matches!(e, Error::OutOfMemory { .. }) {
                        // The arena may be full of limbo blocks whose
                        // readers have quiesced; reclaim before the
                        // next allocation attempt.
                        svc.reclaim();
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(self.cfg.backoff_cap);
                }
                // Not-resident / coalesced-by-peer and friends are
                // answers, not failures: pass through unretried.
                Err(e) => break Err(e),
            }
        };
        let dur = start.elapsed();
        let ns = dur.as_nanos() as u64;
        self.s_total_ns.fetch_add(ns, Ordering::Relaxed);
        self.s_max_ns.fetch_max(ns, Ordering::Relaxed);
        self.s_lat.lock().unwrap().record(ns);
        if dur > self.cfg.slow_fault {
            self.s_slow.fetch_add(1, Ordering::Relaxed);
        }
        if res.is_ok() {
            self.s_faults.fetch_add(1, Ordering::Relaxed);
            // Recovery: this tenant's backing is serving reads again.
            self.clear_degraded(tenant);
            if let Some(reg) = self.tenants {
                // The faulted block is resident on the tenant's behalf.
                reg.fault_charged(tenant);
            }
        }
        res
    }
}

impl LeafFaulter for FaultQueue<'_> {
    fn fault_in(&self, slot: SwapSlot) -> Result<BlockId> {
        self.s_demand.fetch_add(1, Ordering::Relaxed);
        self.request(slot, DEFAULT_TENANT)
    }
}

/// A tenant-tagged view of a [`FaultQueue`]: demand faults through it
/// carry the tenant's identity (see [`FaultQueue::scoped`]), and its
/// [`SwapService`] face targets the tenant's routed backing — so the
/// compactor can evict a tenant's leaf to that tenant's swap file with
/// the same call shape it uses for the shared pool.
pub struct TenantFaulter<'q, 'p> {
    q: &'q FaultQueue<'p>,
    tenant: u16,
}

impl TenantFaulter<'_, '_> {
    /// The tenant this handle is tagged with.
    pub fn tenant(&self) -> u16 {
        self.tenant
    }
}

impl LeafFaulter for TenantFaulter<'_, '_> {
    fn fault_in(&self, slot: SwapSlot) -> Result<BlockId> {
        self.q.s_demand.fetch_add(1, Ordering::Relaxed);
        self.q.request(slot, self.tenant)
    }
}

impl SwapService for TenantFaulter<'_, '_> {
    fn evict_deferred(&self, block: BlockId) -> Result<SwapSlot> {
        self.q.svc_for(self.tenant).evict_deferred(block)
    }

    fn fault(&self, slot: SwapSlot) -> Result<BlockId> {
        self.q.svc_for(self.tenant).fault(slot)
    }

    fn reclaim(&self) {
        self.q.svc_for(self.tenant).reclaim();
    }
}

/// The prefetch-side [`LeafFaulter`] over a [`FaultQueue`]: sheds
/// (typed error + counter) instead of queueing when the queue is full
/// or degraded. See [`FaultQueue::prefetch_gate`].
pub struct PrefetchGate<'q, 'p>(&'q FaultQueue<'p>);

impl LeafFaulter for PrefetchGate<'_, '_> {
    fn fault_in(&self, slot: SwapSlot) -> Result<BlockId> {
        let q = self.0;
        if q.degraded() || q.depth() >= q.cfg.max_depth {
            q.s_shed_prefetch.fetch_add(1, Ordering::Relaxed);
            return Err(Error::Config("fault queue busy: prefetch shed".into()));
        }
        q.request(slot, DEFAULT_TENANT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;
    use crate::testutil::FailingBacking;

    fn quick_cfg() -> FaultQueueConfig {
        FaultQueueConfig {
            max_retries: 3,
            backoff_base: Duration::from_micros(50),
            backoff_cap: Duration::from_micros(400),
            ..FaultQueueConfig::default()
        }
    }

    #[test]
    fn transient_failure_retries_and_succeeds() {
        let a = BlockAllocator::new(1024, 4).unwrap();
        let (backing, ctl) = FailingBacking::new();
        let swap = SwapPool::with_backing(&a, backing);
        let b = a.alloc().unwrap();
        a.write(b, 0, b"retry me").unwrap();
        let slot = swap.evict(b).unwrap();
        let q = FaultQueue::new(&swap, quick_cfg());
        ctl.fail_nth(1); // first read fails, retry reads clean
        let nb = q.fault_in(slot).unwrap();
        let mut out = [0u8; 8];
        a.read(nb, 0, &mut out).unwrap();
        assert_eq!(&out, b"retry me");
        let st = q.stats();
        assert_eq!(st.retries, 1, "one transient error, one retry");
        assert_eq!(st.faults, 1);
        assert_eq!(st.demand, 1);
        assert!(!q.degraded());
        a.free(nb).unwrap();
    }

    #[test]
    fn permanent_failure_escalates_typed_and_recovers() {
        let a = BlockAllocator::new(1024, 4).unwrap();
        let (backing, ctl) = FailingBacking::new();
        let swap = SwapPool::with_backing(&a, backing);
        let b = a.alloc().unwrap();
        a.write(b, 0, b"survive").unwrap();
        let slot = swap.evict(b).unwrap();
        let q = FaultQueue::new(&swap, quick_cfg());
        ctl.fail_always();
        match q.fault_in(slot) {
            Err(Error::SwapFaultFailed { attempts, .. }) => {
                assert_eq!(attempts, 3, "must burn the whole retry budget")
            }
            other => panic!("expected SwapFaultFailed, got {other:?}"),
        }
        assert!(q.degraded(), "exhausted retries must mark the queue degraded");
        assert_eq!(q.stats().permanent, 1);
        assert_eq!(swap.stats().resident_slots, 1, "payload must survive escalation");
        assert_eq!(a.stats().allocated, 0, "failed fault must not leak blocks");
        // Backing recovers: the same slot faults in and the flag clears.
        ctl.disarm();
        let nb = q.fault_in(slot).unwrap();
        assert!(!q.degraded(), "a success must clear the degraded flag");
        let mut out = [0u8; 7];
        a.read(nb, 0, &mut out).unwrap();
        assert_eq!(&out, b"survive");
        a.free(nb).unwrap();
    }

    #[test]
    fn oom_retries_after_reclaiming_limbo() {
        // The arena is "full" only because the evicted block sits in
        // limbo: the queue's OOM retry path reclaims and succeeds.
        let a = BlockAllocator::new(1024, 2).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        a.write(b, 0, b"limbo").unwrap();
        let slot = swap.evict_deferred(b).unwrap(); // b retired, still allocated
        let hog = a.alloc().unwrap(); // pool now exhausted
        let q = FaultQueue::new(&swap, quick_cfg());
        let nb = q.fault_in(slot).expect("OOM retry must reclaim limbo and succeed");
        assert!(q.stats().retries >= 1);
        let mut out = [0u8; 5];
        a.read(nb, 0, &mut out).unwrap();
        assert_eq!(&out, b"limbo");
        a.free(nb).unwrap();
        a.free(hog).unwrap();
    }

    #[test]
    fn workers_serve_concurrent_demand() {
        let a = BlockAllocator::new(512, 16).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let mut slots = Vec::new();
        for i in 0..6u32 {
            let b = a.alloc().unwrap();
            a.write(b, 0, &i.to_le_bytes()).unwrap();
            slots.push(swap.evict(b).unwrap());
        }
        let q = FaultQueue::new(&swap, quick_cfg());
        std::thread::scope(|s| {
            q.attach_workers(s, 2);
            let got: Vec<_> = {
                let handles: Vec<_> = slots
                    .iter()
                    .enumerate()
                    .map(|(i, &slot)| {
                        let q = &q;
                        let a = &a;
                        s.spawn(move || {
                            let b = q.fault_in(slot).unwrap();
                            let mut out = [0u8; 4];
                            a.read(b, 0, &mut out).unwrap();
                            assert_eq!(u32::from_le_bytes(out), i as u32);
                            b
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            };
            for b in got {
                a.free(b).unwrap();
            }
            q.shutdown_workers();
        });
        let st = q.stats();
        assert_eq!(st.faults, 6);
        assert_eq!(st.demand, 6);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn full_queue_sheds_demand_inline_and_drops_prefetch() {
        let a = BlockAllocator::new(512, 4).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        a.write(b, 0, b"shed").unwrap();
        let slot = swap.evict(b).unwrap();
        let cfg = FaultQueueConfig {
            max_depth: 0, // always "full": deterministic shed paths
            ..quick_cfg()
        };
        let q = FaultQueue::new(&swap, cfg);
        std::thread::scope(|s| {
            q.attach_workers(s, 1);
            // Prefetch is dropped, not queued — and the slot survives.
            assert!(q.prefetch_gate().fault_in(slot).is_err());
            assert_eq!(q.stats().shed_prefetch, 1);
            assert_eq!(swap.stats().resident_slots, 1);
            // Demand runs inline on this thread instead of waiting.
            let nb = q.fault_in(slot).unwrap();
            assert_eq!(q.stats().shed_inline, 1);
            let mut out = [0u8; 4];
            a.read(nb, 0, &mut out).unwrap();
            assert_eq!(&out, b"shed");
            a.free(nb).unwrap();
            q.shutdown_workers();
        });
    }

    #[test]
    fn degraded_scoping_is_per_tenant_with_routed_backings() {
        use crate::pmem::tenant::TenantConfig;
        let a = BlockAllocator::new(1024, 8).unwrap();
        let (b1, ctl1) = FailingBacking::new();
        let (b2, ctl2) = FailingBacking::new();
        let swap1 = SwapPool::with_backing(&a, b1);
        let swap2 = SwapPool::with_backing(&a, b2);
        let reg = TenantRegistry::new();
        let t1 = reg.admit(TenantConfig::new(4, 8));
        let t2 = reg.admit(TenantConfig::new(4, 8));
        let q = FaultQueue::with_tenants(&swap1, quick_cfg(), &reg);
        q.route_tenant(t1.id(), &swap1);
        q.route_tenant(t2.id(), &swap2);
        // One parked payload per tenant, each on its own backing —
        // evicted through the tenant-scoped SwapService face.
        let blk1 = a.alloc().unwrap();
        a.write(blk1, 0, b"tenant-1").unwrap();
        let ops2_before = ctl2.ops();
        let slot1 = q.scoped(t1.id()).evict_deferred(blk1).unwrap();
        assert_eq!(ctl2.ops(), ops2_before, "t1 eviction must not touch t2's backing");
        a.epoch().synchronize(&a);
        let blk2 = a.alloc().unwrap();
        a.write(blk2, 0, b"tenant-2").unwrap();
        let slot2 = q.scoped(t2.id()).evict_deferred(blk2).unwrap();
        a.epoch().synchronize(&a);
        // Tenant 1's backing dies permanently.
        ctl1.fail_always();
        match q.scoped(t1.id()).fault_in(slot1) {
            Err(Error::SwapFaultFailed { attempts: 3, .. }) => {}
            other => panic!("expected SwapFaultFailed after 3 attempts, got {other:?}"),
        }
        assert!(q.degraded_for(t1.id()), "t1 must be degraded");
        assert!(!q.degraded_for(t2.id()), "t2 must be untouched by t1's dead backing");
        assert!(q.degraded(), "aggregate view reports any-tenant degradation");
        assert!(t1.degraded() && !t2.degraded(), "registry mirrors the verdicts");
        // Tenant 2 keeps faulting normally while tenant 1 is degraded.
        let nb2 = q.scoped(t2.id()).fault_in(slot2).unwrap();
        let mut out = [0u8; 8];
        a.read(nb2, 0, &mut out).unwrap();
        assert_eq!(&out, b"tenant-2");
        assert!(q.degraded_for(t1.id()), "t2's success must not clear t1's flag");
        assert_eq!(t2.snapshot().faults, 1, "successful fault-in is charged to t2");
        assert_eq!(t2.used(), 1);
        // Tenant 1's backing recovers: its next success clears ITS flag
        // and the aggregate goes quiet.
        ctl1.disarm();
        let nb1 = q.scoped(t1.id()).fault_in(slot1).unwrap();
        assert!(!q.degraded_for(t1.id()) && !q.degraded());
        assert!(!t1.degraded());
        a.read(nb1, 0, &mut out).unwrap();
        assert_eq!(&out, b"tenant-1");
        a.free(nb1).unwrap();
        a.free(nb2).unwrap();
        // Departure: the route drops, traffic falls back to the default.
        q.unroute_tenant(t2.id());
        let blk3 = a.alloc().unwrap();
        let ops1_before = ctl1.ops();
        let slot3 = q.scoped(t2.id()).evict_deferred(blk3).unwrap();
        assert!(ctl1.ops() > ops1_before, "unrouted tenant must use the default backing");
        let nb3 = q.scoped(t2.id()).fault_in(slot3).unwrap();
        a.free(nb3).unwrap();
        a.epoch().synchronize(&a);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn latency_accounting_counts_slow_faults() {
        let a = BlockAllocator::new(512, 4).unwrap();
        let (backing, ctl) = FailingBacking::new();
        let swap = SwapPool::with_backing(&a, backing);
        let b = a.alloc().unwrap();
        let slot = swap.evict(b).unwrap();
        let cfg = FaultQueueConfig {
            slow_fault: Duration::from_millis(2),
            ..quick_cfg()
        };
        let q = FaultQueue::new(&swap, cfg);
        ctl.delay_all(Duration::from_millis(5));
        let nb = q.fault_in(slot).unwrap();
        let st = q.stats();
        assert_eq!(st.slow_faults, 1, "a 5 ms fault must count against a 2 ms threshold");
        assert!(st.max_ns >= 2_000_000);
        assert!(st.mean_ns() > 0);
        let hist = q.latency_hist();
        assert_eq!(hist.count(), 1, "the fault must land in the latency histogram");
        assert!(hist.percentile(1.0) >= 2_000_000);
        a.free(nb).unwrap();
    }
}
