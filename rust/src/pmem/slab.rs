//! Small-object slab classes inside single blocks.
//!
//! The pool's minimum allocation unit is one block (32 KB in the
//! paper's experiments) — far too coarse for the [`crate::workloads`]
//! `RbTree`'s 32-byte nodes. [`SlabPool`] carves one power-of-two size
//! class out of whole blocks obtained from any [`BlockAlloc`]: blocks
//! are claimed lazily one at a time as the class grows, every slot has
//! a stable simulated physical address (the property the paper's
//! pointer-chasing benchmark measures), and fully-empty blocks can be
//! returned to the block pool.
//!
//! This is deliberately a *host-side* metadata design: the free list
//! and per-slot liveness live in ordinary memory, the slots themselves
//! in arena blocks — mirroring how the paper's software memory manager
//! keeps bookkeeping out of the managed pool.

use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::pmem::{BlockAlloc, BlockId};

/// The supported power-of-two slab classes (bytes). 8 B is the
/// smallest natural alignment worth a class; one full block is the
/// point where the caller should just allocate blocks.
pub const SLAB_CLASSES: [usize; 8] = [8, 16, 32, 64, 128, 256, 512, 1024];

/// Smallest slab class holding `bytes`, if any.
pub fn class_for(bytes: usize) -> Option<usize> {
    SLAB_CLASSES.iter().copied().find(|&c| c >= bytes)
}

/// Handle to one live slot. The block position index is private so
/// handles cannot be forged; the public fields locate the slot's bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotAddr {
    /// Block holding the slot.
    pub block: BlockId,
    /// Slot index within the block.
    pub slot: u32,
    bidx: u32,
}

/// Occupancy snapshot of a slab pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SlabStats {
    /// Slot size in bytes.
    pub slot_bytes: usize,
    /// Slots per backing block.
    pub slots_per_block: usize,
    /// Blocks currently claimed from the block pool.
    pub blocks: usize,
    /// Live (allocated) slots.
    pub live_slots: usize,
    /// Free slots across all claimed blocks.
    pub free_slots: usize,
}

struct SlabBlock {
    /// `None` once the (empty) block was returned to the pool; the
    /// tombstone keeps `bidx` handles stable.
    id: Option<BlockId>,
    /// Per-slot liveness bitmap (bit set = live) — the double-free
    /// check the free list alone can't provide.
    live: Vec<u64>,
    live_count: usize,
}

struct Inner {
    blocks: Vec<SlabBlock>,
    /// LIFO free list of `(bidx, slot)`.
    free: Vec<(u32, u32)>,
}

/// One size class of small objects carved from whole blocks (see the
/// module docs).
pub struct SlabPool<'a, A: BlockAlloc> {
    alloc: &'a A,
    slot_bytes: usize,
    slots_per_block: usize,
    inner: Mutex<Inner>,
}

impl<'a, A: BlockAlloc> SlabPool<'a, A> {
    /// Pool for objects of `obj_bytes`, rounded up to the smallest slab
    /// class.
    pub fn new(alloc: &'a A, obj_bytes: usize) -> Result<Self> {
        let class = class_for(obj_bytes).ok_or_else(|| {
            Error::Config(format!(
                "object size {obj_bytes} exceeds the largest slab class {}",
                SLAB_CLASSES[SLAB_CLASSES.len() - 1]
            ))
        })?;
        Self::with_slot_bytes(alloc, class)
    }

    /// Pool with an exact slot size (must be a power of two ≥ 8 and no
    /// larger than one block, so every slot is naturally aligned inside
    /// its block — the arena's block alignment guarantees the rest).
    pub fn with_slot_bytes(alloc: &'a A, slot_bytes: usize) -> Result<Self> {
        if !slot_bytes.is_power_of_two() || slot_bytes < 8 || slot_bytes > alloc.block_size() {
            return Err(Error::Config(format!(
                "slot_bytes {slot_bytes} must be a power of two in 8..={}",
                alloc.block_size()
            )));
        }
        Ok(SlabPool {
            alloc,
            slot_bytes,
            slots_per_block: alloc.block_size() / slot_bytes,
            inner: Mutex::new(Inner {
                blocks: Vec::new(),
                free: Vec::new(),
            }),
        })
    }

    /// Slot size in bytes.
    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    /// Allocate one slot, claiming a fresh block from the block pool if
    /// every claimed block is full.
    pub fn alloc_slot(&self) -> Result<SlotAddr> {
        let mut g = self.inner.lock().unwrap();
        if g.free.is_empty() {
            // Grow by one block (zeroed: freed slots may hold stale
            // bytes from a prior tenant of the block).
            let id = self.alloc.alloc_zeroed()?;
            let bidx = g.blocks.len() as u32;
            g.blocks.push(SlabBlock {
                id: Some(id),
                live: vec![0u64; self.slots_per_block.div_ceil(64)],
                live_count: 0,
            });
            // Push in reverse so the LIFO hands out ascending slots.
            for slot in (0..self.slots_per_block as u32).rev() {
                g.free.push((bidx, slot));
            }
        }
        let (bidx, slot) = g.free.pop().expect("refilled above");
        let b = &mut g.blocks[bidx as usize];
        b.live[slot as usize / 64] |= 1u64 << (slot % 64);
        b.live_count += 1;
        let block = b.id.expect("free list never points into tombstones");
        Ok(SlotAddr { block, slot, bidx })
    }

    /// Return a slot. Double frees and forged handles are rejected.
    pub fn free_slot(&self, s: SlotAddr) -> Result<()> {
        if s.slot as usize >= self.slots_per_block {
            return Err(Error::InvalidBlock(s.block));
        }
        let mut g = self.inner.lock().unwrap();
        let b = g
            .blocks
            .get_mut(s.bidx as usize)
            .filter(|b| b.id == Some(s.block))
            .ok_or(Error::InvalidBlock(s.block))?;
        let (w, bit) = (s.slot as usize / 64, 1u64 << (s.slot % 64));
        if b.live[w] & bit == 0 {
            return Err(Error::InvalidBlock(s.block));
        }
        b.live[w] &= !bit;
        b.live_count -= 1;
        g.free.push((s.bidx, s.slot));
        Ok(())
    }

    /// Simulated physical address of the slot's first byte.
    pub fn phys_addr(&self, s: SlotAddr) -> u64 {
        s.block.phys_addr(self.alloc.block_size()) + (s.slot as usize * self.slot_bytes) as u64
    }

    /// Write up to a slot's bytes at its start (bounds-checked against
    /// the slot, then the block).
    pub fn write_slot(&self, s: SlotAddr, data: &[u8]) -> Result<()> {
        if data.len() > self.slot_bytes {
            return Err(Error::IndexOutOfBounds {
                index: data.len(),
                len: self.slot_bytes,
            });
        }
        self.alloc
            .write(s.block, s.slot as usize * self.slot_bytes, data)
    }

    /// Read up to a slot's bytes from its start.
    pub fn read_slot(&self, s: SlotAddr, out: &mut [u8]) -> Result<()> {
        if out.len() > self.slot_bytes {
            return Err(Error::IndexOutOfBounds {
                index: out.len(),
                len: self.slot_bytes,
            });
        }
        self.alloc
            .read(s.block, s.slot as usize * self.slot_bytes, out)
    }

    /// Return every fully-empty claimed block to the block pool;
    /// reports how many blocks were released. Live slots are never
    /// moved (their physical addresses are load-bearing), so only
    /// all-free blocks qualify.
    pub fn release_empty_blocks(&self) -> usize {
        let mut g = self.inner.lock().unwrap();
        let mut released = Vec::new();
        for (bidx, b) in g.blocks.iter_mut().enumerate() {
            if b.live_count == 0 {
                if let Some(id) = b.id.take() {
                    // Tombstone: bidx stays valid for other handles.
                    let _ = self.alloc.free(id);
                    released.push(bidx as u32);
                }
            }
        }
        if !released.is_empty() {
            g.free.retain(|(bidx, _)| !released.contains(bidx));
        }
        released.len()
    }

    /// Occupancy snapshot.
    pub fn stats(&self) -> SlabStats {
        let g = self.inner.lock().unwrap();
        let blocks = g.blocks.iter().filter(|b| b.id.is_some()).count();
        let live: usize = g.blocks.iter().map(|b| b.live_count).sum();
        SlabStats {
            slot_bytes: self.slot_bytes,
            slots_per_block: self.slots_per_block,
            blocks,
            live_slots: live,
            free_slots: blocks * self.slots_per_block - live,
        }
    }
}

impl<A: BlockAlloc> Drop for SlabPool<'_, A> {
    fn drop(&mut self) {
        let g = self.inner.get_mut().unwrap();
        for b in &mut g.blocks {
            if let Some(id) = b.id.take() {
                let _ = self.alloc.free(id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::TwoLevelAllocator;

    #[test]
    fn class_rounding() {
        assert_eq!(class_for(1), Some(8));
        assert_eq!(class_for(32), Some(32));
        assert_eq!(class_for(33), Some(64));
        assert_eq!(class_for(4096), None);
    }

    #[test]
    fn slots_have_distinct_stable_addresses() {
        let a = TwoLevelAllocator::new(1024, 64).unwrap();
        let p = SlabPool::new(&a, 32).unwrap();
        let slots: Vec<_> = (0..100).map(|_| p.alloc_slot().unwrap()).collect();
        let mut addrs: Vec<u64> = slots.iter().map(|&s| p.phys_addr(s)).collect();
        addrs.sort_unstable();
        addrs.dedup();
        assert_eq!(addrs.len(), 100, "slot addresses must not alias");
        // 100 slots of 32 B fit in one 1024-B block? No: 32 slots per
        // block -> 4 blocks claimed, lazily.
        assert_eq!(p.stats().blocks, 4);
        assert_eq!(a.stats().allocated, 4);
    }

    #[test]
    fn slot_data_roundtrips_and_is_zeroed() {
        let a = TwoLevelAllocator::new(1024, 8).unwrap();
        let p = SlabPool::new(&a, 32).unwrap();
        let s = p.alloc_slot().unwrap();
        let mut out = [0xFFu8; 32];
        p.read_slot(s, &mut out).unwrap();
        assert_eq!(out, [0u8; 32], "fresh slot must be zeroed");
        p.write_slot(s, &[9u8; 32]).unwrap();
        p.read_slot(s, &mut out).unwrap();
        assert_eq!(out, [9u8; 32]);
        assert!(p.write_slot(s, &[0u8; 33]).is_err(), "overflow rejected");
    }

    #[test]
    fn free_and_reuse_without_growth() {
        let a = TwoLevelAllocator::new(1024, 8).unwrap();
        let p = SlabPool::new(&a, 64).unwrap(); // 16 slots per block
        let slots: Vec<_> = (0..16).map(|_| p.alloc_slot().unwrap()).collect();
        assert_eq!(p.stats().blocks, 1);
        for s in &slots {
            p.free_slot(*s).unwrap();
        }
        for _ in 0..16 {
            p.alloc_slot().unwrap();
        }
        assert_eq!(p.stats().blocks, 1, "reuse must not claim new blocks");
    }

    #[test]
    fn double_free_rejected() {
        let a = TwoLevelAllocator::new(1024, 8).unwrap();
        let p = SlabPool::new(&a, 32).unwrap();
        let s = p.alloc_slot().unwrap();
        p.free_slot(s).unwrap();
        assert!(p.free_slot(s).is_err());
        assert_eq!(p.stats().live_slots, 0);
    }

    #[test]
    fn empty_blocks_return_to_the_pool() {
        let a = TwoLevelAllocator::new(1024, 8).unwrap();
        let p = SlabPool::new(&a, 512).unwrap(); // 2 slots per block
        let s0 = p.alloc_slot().unwrap();
        let s1 = p.alloc_slot().unwrap();
        let s2 = p.alloc_slot().unwrap(); // second block
        assert_eq!(a.stats().allocated, 2);
        p.free_slot(s0).unwrap();
        p.free_slot(s1).unwrap();
        assert_eq!(p.release_empty_blocks(), 1);
        assert_eq!(a.stats().allocated, 1);
        // The survivor's handle still works; the pool can still grow.
        let mut out = [0u8; 8];
        p.read_slot(s2, &mut out).unwrap();
        let s3 = p.alloc_slot().unwrap();
        assert_ne!(p.phys_addr(s3), p.phys_addr(s2));
        drop(p);
        assert_eq!(a.stats().allocated, 0, "drop returns all blocks");
    }

    #[test]
    fn invalid_slot_sizes_rejected() {
        let a = TwoLevelAllocator::new(1024, 8).unwrap();
        assert!(SlabPool::with_slot_bytes(&a, 48).is_err());
        assert!(SlabPool::with_slot_bytes(&a, 4).is_err());
        assert!(SlabPool::with_slot_bytes(&a, 2048).is_err());
        assert!(SlabPool::with_slot_bytes(&a, 1024).is_ok());
    }
}
