//! Two-level llfree-style lock-free block allocator.
//!
//! The flat per-shard bitmaps of [`crate::pmem::ShardedAllocator`] scan
//! linearly under fragmentation and have no placement policy. This
//! module replaces them with the two-level design of llfree (LLFree:
//! scalable and optionally-persistent page-frame allocation, ISCA '23
//! lineage; see PAPERS.md / SNIPPETS 1–2):
//!
//! * the **lower allocator** owns the blocks inside one fixed-size
//!   *subtree* of [`SUBTREE_BLOCKS`] blocks — a cache-line-aligned
//!   bitfield (8 × `AtomicU64`, bit set = free) claimed with word-level
//!   CAS, so one subtree's entire free state is a single cache line;
//! * the **upper allocator** is a packed array of subtree roots, each
//!   one `AtomicU32` holding the subtree's free-block count plus a
//!   RESERVED flag. Each CPU slot ("core") *reserves* one
//!   partially-filled subtree; the common allocation path is a single
//!   CAS inside the reserved bitfield — no search, no shared cursor,
//!   and no cache line shared with any other core.
//!
//! Placement is NUMA-aware at subtree granularity: subtrees are
//! partitioned contiguously across logical nodes,
//! [`TwoLevelAllocator::alloc_on`] takes an explicit node hint, refills
//! prefer same-node subtrees (same-node stealing before crossing), and
//! crossings are counted in [`PlacementStats`]. Reservation is
//! *adaptive*: when the number of active cores grows past the number of
//! subtrees, new reservations stop paying for themselves and the pool
//! degrades gracefully to a shared scan with direct handoff — a core
//! may then claim blocks inside another core's reserved subtree rather
//! than fail.
//!
//! Counter discipline (the part worth auditing): bitfield bits are the
//! ground truth of block ownership; `allocated` and the per-subtree
//! free counts are kept conservatively consistent by ordering. A free
//! *increments* counters before publishing the free bit, and a claim
//! *decrements* them after clearing the bit — so a subtree count of
//! zero proves the subtree is empty (counts never understate free
//! space), and `allocated` never exceeds capacity. The same speculative
//! orderings as the sharded allocator protect double frees.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};

use crate::error::{Error, Result};
use crate::pmem::alloc_trait::{span_word_mask, AllocStats, BlockAlloc, ContentionStats};
use crate::pmem::arena::Arena;
use crate::pmem::epoch::ArenaEpoch;
use crate::pmem::sharded::{mix, thread_token};
use crate::pmem::BlockId;

/// Blocks per subtree: 512 blocks = 8 bitmap words = one 64-byte cache
/// line, llfree's lower-level geometry.
pub const SUBTREE_BLOCKS: usize = 512;
const WORDS_PER_SUBTREE: usize = SUBTREE_BLOCKS / 64;

/// Upper-level root state: bit 31 flags the subtree as reserved by some
/// core; bits 0..31 hold the free-block count.
const RESERVED: u32 = 1 << 31;
const COUNT_MASK: u32 = RESERVED - 1;

/// One subtree's free bitmap: exactly one cache line, so the hot-path
/// CAS of one core never contends with a neighboring subtree's.
#[repr(C, align(64))]
struct Bitfield {
    words: [AtomicU64; WORDS_PER_SUBTREE],
}

/// One packed upper-level entry (deliberately *not* padded: the refill
/// search scans many roots, so dense packing is the point).
struct SubtreeRoot {
    state: AtomicU32,
}

/// Per-core slot, padded to its own cache line so cores never
/// false-share reservation state.
#[repr(C, align(64))]
struct Local {
    /// Reserved subtree index + 1; 0 = no reservation.
    reserved: AtomicUsize,
    /// Word cursor inside the reserved subtree (resume hint).
    cursor: AtomicUsize,
    /// 1 once this slot has served an allocation (active-core census
    /// for adaptive reservation).
    touched: AtomicUsize,
}

/// Placement/reservation telemetry specific to the two-level design
/// (the generic counters live in [`ContentionStats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlacementStats {
    /// Logical NUMA nodes the subtrees are partitioned across.
    pub nodes: usize,
    /// Subtree reservations installed (upper-level refills).
    pub reservations: u64,
    /// Allocations served by the shared fallback — outside the calling
    /// core's reservation, possibly inside another core's.
    pub handoffs: u64,
    /// Allocations or reservations served off the hinted node.
    pub cross_node: u64,
}

/// The two-level allocator (see module docs).
pub struct TwoLevelAllocator {
    arena: Arena,
    /// Lower level: one cache-line bitfield per subtree (bit = free).
    fields: Box<[Bitfield]>,
    /// Upper level: packed free-count + RESERVED flag per subtree.
    roots: Box<[SubtreeRoot]>,
    /// Per-core reservation slots.
    locals: Box<[Local]>,
    /// Logical NUMA nodes (subtrees partitioned contiguously).
    nodes: usize,
    /// Distinct cores that have allocated (adaptive-reservation census).
    active_cores: AtomicUsize,

    allocated: AtomicUsize,
    peak: AtomicUsize,
    total_allocs: AtomicU64,
    total_frees: AtomicU64,
    failed_allocs: AtomicU64,

    reservations: AtomicU64,
    handoffs: AtomicU64,
    cross_node: AtomicU64,
    cas_retries: AtomicU64,

    epoch: ArenaEpoch,
}

impl TwoLevelAllocator {
    /// Create a pool on one logical NUMA node with one reservation slot
    /// per available hardware thread (capped at 64).
    pub fn new(block_size: usize, capacity_blocks: usize) -> Result<Self> {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(64);
        Self::with_topology(block_size, capacity_blocks, 1, cores)
    }

    /// Create a pool with an explicit topology: `nodes` logical NUMA
    /// nodes (subtrees are partitioned contiguously across them, so
    /// each node must own at least one subtree) and `cores` reservation
    /// slots. Threads hash onto slots; benchmarks and the daemon may
    /// instead pass an explicit core to [`TwoLevelAllocator::alloc_core_on`].
    pub fn with_topology(
        block_size: usize,
        capacity_blocks: usize,
        nodes: usize,
        cores: usize,
    ) -> Result<Self> {
        let arena = Arena::new(block_size, capacity_blocks)?;
        let nsub = capacity_blocks.div_ceil(SUBTREE_BLOCKS);
        if nodes == 0 || nodes > nsub {
            return Err(Error::Config(format!(
                "nodes {nodes} must be in 1..={nsub} (one subtree per node minimum)"
            )));
        }
        if cores == 0 {
            return Err(Error::Config("cores must be >= 1".into()));
        }
        let mut fields = Vec::with_capacity(nsub);
        for s in 0..nsub {
            let words = std::array::from_fn(|j| {
                let first = (s * WORDS_PER_SUBTREE + j) * 64;
                AtomicU64::new(if first + 64 <= capacity_blocks {
                    !0u64
                } else if first < capacity_blocks {
                    (1u64 << (capacity_blocks - first)) - 1
                } else {
                    0
                })
            });
            fields.push(Bitfield { words });
        }
        let roots = (0..nsub)
            .map(|s| SubtreeRoot {
                state: AtomicU32::new(
                    (SUBTREE_BLOCKS.min(capacity_blocks - s * SUBTREE_BLOCKS)) as u32,
                ),
            })
            .collect();
        let locals = (0..cores)
            .map(|_| Local {
                reserved: AtomicUsize::new(0),
                cursor: AtomicUsize::new(0),
                touched: AtomicUsize::new(0),
            })
            .collect();
        Ok(TwoLevelAllocator {
            arena,
            fields: fields.into_boxed_slice(),
            roots,
            locals,
            nodes,
            active_cores: AtomicUsize::new(0),
            allocated: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            total_allocs: AtomicU64::new(0),
            total_frees: AtomicU64::new(0),
            failed_allocs: AtomicU64::new(0),
            reservations: AtomicU64::new(0),
            handoffs: AtomicU64::new(0),
            cross_node: AtomicU64::new(0),
            cas_retries: AtomicU64::new(0),
            epoch: ArenaEpoch::new(),
        })
    }

    /// Number of subtrees (upper-level entries).
    pub fn subtree_count(&self) -> usize {
        self.roots.len()
    }

    /// Number of reservation slots.
    pub fn cores(&self) -> usize {
        self.locals.len()
    }

    /// Number of logical NUMA nodes.
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Logical node owning subtree `s`.
    #[inline]
    pub fn node_of_subtree(&self, s: usize) -> usize {
        s * self.nodes / self.roots.len()
    }

    /// Logical node owning block `id`.
    pub fn node_of_block(&self, id: BlockId) -> usize {
        self.node_of_subtree(id.0 as usize / SUBTREE_BLOCKS)
    }

    /// `(live, blocks)` occupancy of subtree `s` — the signal the mmd
    /// policy's per-shard decisions consume through `shard_spans`.
    pub fn subtree_occupancy(&self, s: usize) -> (usize, usize) {
        let span = self.subtree_span(s);
        let free = (self.roots[s].state.load(Ordering::Acquire) & COUNT_MASK) as usize;
        (span.saturating_sub(free), span)
    }

    /// The subtree currently reserved by `core`, if any.
    pub fn reserved_subtree_of(&self, core: usize) -> Option<usize> {
        let r = self.locals[core % self.locals.len()]
            .reserved
            .load(Ordering::Acquire);
        if r == 0 {
            None
        } else {
            Some(r - 1)
        }
    }

    /// Placement/reservation telemetry.
    pub fn placement_stats(&self) -> PlacementStats {
        PlacementStats {
            nodes: self.nodes,
            reservations: self.reservations.load(Ordering::Relaxed),
            handoffs: self.handoffs.load(Ordering::Relaxed),
            cross_node: self.cross_node.load(Ordering::Relaxed),
        }
    }

    /// Allocate with an explicit node hint from the calling thread's
    /// hashed core slot.
    pub fn alloc_on(&self, node: usize) -> Result<BlockId> {
        self.alloc_core_on(self.current_core(), node)
    }

    /// This thread's reservation slot (stable per thread, hashed token).
    #[inline]
    fn current_core(&self) -> usize {
        (mix(thread_token() as u64) % self.locals.len() as u64) as usize
    }

    /// Home node of a core slot: slots are partitioned across nodes the
    /// same way subtrees are.
    #[inline]
    fn home_node(&self, core: usize) -> usize {
        core * self.nodes / self.locals.len()
    }

    #[inline]
    fn subtree_span(&self, s: usize) -> usize {
        SUBTREE_BLOCKS.min(self.arena.capacity() - s * SUBTREE_BLOCKS)
    }

    /// Subtree range `[lo, hi)` owned by logical node `n`.
    #[inline]
    fn node_subtrees(&self, n: usize) -> (usize, usize) {
        let nsub = self.roots.len();
        (n * nsub / self.nodes, (n + 1) * nsub / self.nodes)
    }

    #[inline]
    fn word(&self, w: usize) -> &AtomicU64 {
        &self.fields[w / WORDS_PER_SUBTREE].words[w % WORDS_PER_SUBTREE]
    }

    /// First-use census of a core slot; returns the active-core count.
    #[inline]
    fn note_active(&self, l: &Local) -> usize {
        if l.touched.swap(1, Ordering::Relaxed) == 0 {
            self.active_cores.fetch_add(1, Ordering::Relaxed) + 1
        } else {
            self.active_cores.load(Ordering::Relaxed)
        }
    }

    /// Claim one free bit inside subtree `s`, scanning its (at most 8)
    /// words from `start_word`. Lock-free word CAS; decrements the
    /// subtree's free count on success.
    fn claim_one(&self, s: usize, start_word: usize) -> Option<u32> {
        for k in 0..WORDS_PER_SUBTREE {
            let j = (start_word + k) % WORDS_PER_SUBTREE;
            let word = &self.fields[s].words[j];
            let mut cur = word.load(Ordering::Relaxed);
            while cur != 0 {
                let bit = cur.trailing_zeros();
                match word.compare_exchange_weak(
                    cur,
                    cur & !(1u64 << bit),
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        self.roots[s].state.fetch_sub(1, Ordering::AcqRel);
                        let id = (s * WORDS_PER_SUBTREE + j) * 64 + bit as usize;
                        return Some(id as u32);
                    }
                    Err(actual) => {
                        self.cas_retries.fetch_add(1, Ordering::Relaxed);
                        cur = actual;
                    }
                }
            }
        }
        None
    }

    /// Try to set the RESERVED flag on subtree `s`. Refuses subtrees
    /// that are empty, already reserved, or (when `want_partial`) still
    /// completely free — partially-filled subtrees are preferred so
    /// fully-free ones stay available for bulk placement.
    fn try_reserve(&self, s: usize, want_partial: bool) -> bool {
        let st = &self.roots[s].state;
        let mut cur = st.load(Ordering::Relaxed);
        loop {
            let free = (cur & COUNT_MASK) as usize;
            if cur & RESERVED != 0 || free == 0 {
                return false;
            }
            if want_partial && free >= self.subtree_span(s) {
                return false;
            }
            match st.compare_exchange_weak(cur, cur | RESERVED, Ordering::AcqRel, Ordering::Relaxed)
            {
                Ok(_) => return true,
                Err(actual) => {
                    self.cas_retries.fetch_add(1, Ordering::Relaxed);
                    cur = actual;
                }
            }
        }
    }

    /// Upper-level refill search: reserve a subtree for `node`,
    /// preferring partially-filled over fully-free ones and same-node
    /// over remote ones (same-node stealing before crossing).
    fn find_and_reserve(&self, node: usize) -> Option<usize> {
        for d in 0..self.nodes {
            let n = (node + d) % self.nodes;
            let (lo, hi) = self.node_subtrees(n);
            for want_partial in [true, false] {
                for s in lo..hi {
                    if self.try_reserve(s, want_partial) {
                        if d > 0 {
                            self.cross_node.fetch_add(1, Ordering::Relaxed);
                        }
                        return Some(s);
                    }
                }
            }
        }
        None
    }

    /// Publish subtree `s` as `l`'s reservation, releasing whatever the
    /// slot held before (drained subtree, or a racing install by a
    /// thread sharing the slot — either way the old subtree returns to
    /// the reservable pool).
    fn install(&self, l: &Local, s: usize) {
        let prev = l.reserved.swap(s + 1, Ordering::AcqRel);
        if prev != 0 {
            self.roots[prev - 1].state.fetch_and(!RESERVED, Ordering::AcqRel);
        }
        l.cursor.store(0, Ordering::Relaxed);
        self.reservations.fetch_add(1, Ordering::Relaxed);
    }

    /// Allocate one block from an explicit core slot with an explicit
    /// node hint (llfree's `get(core)` shape; the trait's `alloc` is
    /// this with the thread's hashed core and its home node).
    pub fn alloc_core_on(&self, core: usize, node: usize) -> Result<BlockId> {
        if node >= self.nodes {
            return Err(Error::Config(format!(
                "node hint {node} out of range (pool has {} nodes)",
                self.nodes
            )));
        }
        let l = &self.locals[core % self.locals.len()];
        let active = self.note_active(l);

        // Fast path: one CAS inside the reserved subtree. The node hint
        // steers *refills*; a live reservation is sticky by design
        // (re-searching per alloc would thrash the upper level).
        let r = l.reserved.load(Ordering::Acquire);
        if r != 0 {
            if let Some(id) = self.claim_one(r - 1, l.cursor.load(Ordering::Relaxed)) {
                l.cursor.store(id as usize / 64 % WORDS_PER_SUBTREE, Ordering::Relaxed);
                self.record_allocs(1);
                return Ok(BlockId(id));
            }
        }

        // Refill: reserve a fresh subtree — but only while reservation
        // pays. Once active cores outnumber subtrees, installing more
        // reservations just fences cores out of each other's space
        // (adaptive reservation under thread-count growth).
        let nsub = self.roots.len();
        if nsub >= 2 && active <= nsub {
            // Two rounds: a freshly reserved subtree can be drained by a
            // handoff before our first claim lands.
            for _ in 0..2 {
                let Some(s) = self.find_and_reserve(node) else { break };
                self.install(l, s);
                if let Some(id) = self.claim_one(s, 0) {
                    l.cursor.store(id as usize / 64 % WORDS_PER_SUBTREE, Ordering::Relaxed);
                    self.record_allocs(1);
                    return Ok(BlockId(id));
                }
            }
        }

        // Shared fallback (handoff): claim anywhere a block remains,
        // inside other cores' reservations included — same-node
        // subtrees first, then crossing. A zero count proves a subtree
        // empty (frees raise counts before publishing bits), so the
        // skip is sound.
        for d in 0..self.nodes {
            let n = (node + d) % self.nodes;
            let (lo, hi) = self.node_subtrees(n);
            for s in lo..hi {
                let st = self.roots[s].state.load(Ordering::Acquire);
                if st & COUNT_MASK == 0 {
                    continue;
                }
                if let Some(id) = self.claim_one(s, 0) {
                    if st & RESERVED != 0 {
                        // A handoff proper: we claimed inside another
                        // core's reservation.
                        self.handoffs.fetch_add(1, Ordering::Relaxed);
                    }
                    if d > 0 {
                        self.cross_node.fetch_add(1, Ordering::Relaxed);
                    }
                    self.record_allocs(1);
                    return Ok(BlockId(id));
                }
            }
        }
        self.failed_allocs.fetch_add(1, Ordering::Relaxed);
        Err(Error::OutOfMemory {
            requested: 1,
            free: 0,
            capacity: self.arena.capacity(),
        })
    }

    /// Claim up to `want` blocks from subtree `s`, word-granular (≤ 64
    /// blocks per CAS). Bulk path: ignores reservations by design.
    fn claim_batch(&self, s: usize, want: usize, out: &mut Vec<u32>) -> usize {
        let mut got = 0;
        for j in 0..WORDS_PER_SUBTREE {
            if got >= want {
                break;
            }
            let word = &self.fields[s].words[j];
            loop {
                let cur = word.load(Ordering::Relaxed);
                if cur == 0 {
                    break;
                }
                let take = (cur.count_ones() as usize).min(want - got);
                // Mask of the `take` lowest set bits of `cur`.
                let mut mask = 0u64;
                let mut m = cur;
                for _ in 0..take {
                    let b = m & m.wrapping_neg();
                    mask |= b;
                    m ^= b;
                }
                match word.compare_exchange_weak(
                    cur,
                    cur & !mask,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        self.roots[s].state.fetch_sub(take as u32, Ordering::AcqRel);
                        let base = ((s * WORDS_PER_SUBTREE + j) * 64) as u32;
                        let mut left = mask;
                        while left != 0 {
                            let bit = left.trailing_zeros();
                            out.push(base + bit);
                            left &= left - 1;
                        }
                        got += take;
                        break;
                    }
                    Err(_) => {
                        self.cas_retries.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        got
    }

    /// Return a claimed bit and its subtree count without touching
    /// statistics (rollback path). Count first, bit second — the same
    /// ordering as `free`, so counts never understate free space.
    fn release_bit(&self, id: u32) {
        let i = id as usize;
        self.roots[i / SUBTREE_BLOCKS]
            .state
            .fetch_add(1, Ordering::AcqRel);
        self.word(i / 64).fetch_or(1u64 << (i % 64), Ordering::AcqRel);
    }

    fn record_allocs(&self, n: usize) {
        let live = self.allocated.fetch_add(n, Ordering::AcqRel) + n;
        self.peak.fetch_max(live, Ordering::AcqRel);
        self.total_allocs.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn bounds_check(&self, id: BlockId, offset: usize, len: usize) -> Result<()> {
        if !BlockAlloc::is_live(self, id) {
            return Err(Error::InvalidBlock(id));
        }
        self.arena.check_span(offset, len)
    }
}

impl BlockAlloc for TwoLevelAllocator {
    fn alloc(&self) -> Result<BlockId> {
        let core = self.current_core();
        self.alloc_core_on(core, self.home_node(core))
    }

    fn alloc_many(&self, n: usize) -> Result<Vec<BlockId>> {
        let core = self.current_core();
        let node = self.home_node(core);
        let mut ids: Vec<u32> = Vec::with_capacity(n);
        'scan: for d in 0..self.nodes {
            let nd = (node + d) % self.nodes;
            let (lo, hi) = self.node_subtrees(nd);
            for s in lo..hi {
                if ids.len() >= n {
                    break 'scan;
                }
                if self.roots[s].state.load(Ordering::Acquire) & COUNT_MASK == 0 {
                    continue;
                }
                let got = self.claim_batch(s, n - ids.len(), &mut ids);
                if d > 0 && got > 0 {
                    self.cross_node.fetch_add(got as u64, Ordering::Relaxed);
                }
            }
        }
        if ids.len() < n {
            // All-or-nothing: roll the partial claim back, leak nothing.
            let got = ids.len();
            for id in ids {
                self.release_bit(id);
            }
            self.failed_allocs.fetch_add(1, Ordering::Relaxed);
            return Err(Error::OutOfMemory {
                requested: n,
                free: got,
                capacity: self.arena.capacity(),
            });
        }
        self.record_allocs(n);
        Ok(ids.into_iter().map(BlockId).collect())
    }

    fn alloc_zeroed(&self) -> Result<BlockId> {
        let id = BlockAlloc::alloc(self)?;
        // SAFETY: id is live and exclusively ours until returned.
        unsafe { self.arena.zero_block(id) };
        Ok(id)
    }

    /// Lowest-id free block in `[lo, hi)`: ascending word scan with the
    /// shared span mask, exactly the sharded allocator's placement
    /// semantics. Bypasses reservations (placement is the point);
    /// subtree counts are kept consistent.
    fn alloc_in_span(&self, lo: usize, hi: usize) -> Result<BlockId> {
        let hi = hi.min(self.arena.capacity());
        for w in lo / 64..hi.div_ceil(64) {
            let first = w * 64;
            let mask = span_word_mask(w, lo, hi);
            let word = self.word(w);
            loop {
                let cur = word.load(Ordering::Relaxed);
                let avail = cur & mask;
                if avail == 0 {
                    break;
                }
                let bit = avail.trailing_zeros();
                if word
                    .compare_exchange_weak(
                        cur,
                        cur & !(1u64 << bit),
                        Ordering::AcqRel,
                        Ordering::Relaxed,
                    )
                    .is_ok()
                {
                    self.roots[w / WORDS_PER_SUBTREE]
                        .state
                        .fetch_sub(1, Ordering::AcqRel);
                    self.record_allocs(1);
                    return Ok(BlockId((first + bit as usize) as u32));
                }
                self.cas_retries.fetch_add(1, Ordering::Relaxed);
            }
        }
        // A full span is an expected probe miss for the compactor, not
        // pool exhaustion — don't count a failed alloc.
        Err(Error::OutOfMemory {
            requested: 1,
            free: 0,
            capacity: self.arena.capacity(),
        })
    }

    /// One span per subtree — mmd's fragmentation telemetry and
    /// rebalancing become subtree-granular for free, which is exactly
    /// the occupancy signal the upper level maintains.
    fn shard_spans(&self) -> Vec<(usize, usize)> {
        let cap = self.arena.capacity();
        (0..self.roots.len())
            .map(|s| (s * SUBTREE_BLOCKS, ((s + 1) * SUBTREE_BLOCKS).min(cap)))
            .collect()
    }

    fn live_snapshot(&self, out: &mut Vec<u64>) {
        out.clear();
        let cap = self.arena.capacity();
        let nwords = cap.div_ceil(64);
        out.reserve(nwords);
        for w in 0..nwords {
            // Bitfields hold the FREE bitmap; invert and mask the tail
            // so bits past the capacity read as not-allocated.
            let mut live = !self.word(w).load(Ordering::Acquire);
            let first = w * 64;
            if cap - first < 64 {
                live &= (1u64 << (cap - first)) - 1;
            }
            out.push(live);
        }
    }

    fn free(&self, id: BlockId) -> Result<()> {
        let i = id.0 as usize;
        if i >= self.arena.capacity() {
            return Err(Error::InvalidBlock(id));
        }
        let (w, b) = (i / 64, 1u64 << (i % 64));
        // Cheap pre-check: an already-free bit is a double free; reject
        // without touching any state.
        if self.word(w).load(Ordering::Acquire) & b != 0 {
            return Err(Error::InvalidBlock(id));
        }
        let s = i / SUBTREE_BLOCKS;
        // Retire from the live count BEFORE publishing the free bit
        // (allocated must never exceed capacity), and raise the subtree
        // count BEFORE the bit too (counts must never understate free
        // space — a zero count is the handoff path's proof of
        // emptiness). Both are undone if we lose a double-free race.
        self.allocated.fetch_sub(1, Ordering::AcqRel);
        self.roots[s].state.fetch_add(1, Ordering::AcqRel);
        let prev = self.word(w).fetch_or(b, Ordering::AcqRel);
        if prev & b != 0 {
            self.roots[s].state.fetch_sub(1, Ordering::AcqRel);
            self.allocated.fetch_add(1, Ordering::AcqRel);
            return Err(Error::InvalidBlock(id));
        }
        self.total_frees.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn block_size(&self) -> usize {
        self.arena.block_size()
    }

    fn capacity(&self) -> usize {
        self.arena.capacity()
    }

    fn free_blocks(&self) -> usize {
        self.arena.capacity() - self.allocated.load(Ordering::Acquire)
    }

    fn is_live(&self, id: BlockId) -> bool {
        let i = id.0 as usize;
        if i >= self.arena.capacity() {
            return false;
        }
        self.word(i / 64).load(Ordering::Acquire) & (1u64 << (i % 64)) == 0
    }

    fn stats(&self) -> AllocStats {
        let mut s = AllocStats {
            allocated: self.allocated.load(Ordering::Acquire),
            peak: self.peak.load(Ordering::Acquire),
            total_allocs: self.total_allocs.load(Ordering::Relaxed),
            total_frees: self.total_frees.load(Ordering::Relaxed),
            failed_allocs: self.failed_allocs.load(Ordering::Relaxed),
            ..AllocStats::default()
        };
        self.epoch.fill_alloc_stats(&mut s);
        s
    }

    fn contention(&self) -> ContentionStats {
        ContentionStats {
            steals: self.handoffs.load(Ordering::Relaxed),
            refills: self.reservations.load(Ordering::Relaxed),
            cas_retries: self.cas_retries.load(Ordering::Relaxed),
        }
    }

    fn epoch(&self) -> &ArenaEpoch {
        &self.epoch
    }

    unsafe fn block_ptr(&self, id: BlockId) -> *mut u8 {
        self.arena.block_ptr(id)
    }

    fn write(&self, id: BlockId, offset: usize, data: &[u8]) -> Result<()> {
        self.bounds_check(id, offset, data.len())?;
        // SAFETY: bounds checked; caller owns the live block.
        unsafe { self.arena.copy_in(id, offset, data) };
        Ok(())
    }

    fn read(&self, id: BlockId, offset: usize, out: &mut [u8]) -> Result<()> {
        self.bounds_check(id, offset, out.len())?;
        // SAFETY: bounds checked; caller owns the live block.
        unsafe { self.arena.copy_out(id, offset, out) };
        Ok(())
    }
}

impl std::fmt::Debug for TwoLevelAllocator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TwoLevelAllocator")
            .field("block_size", &self.arena.block_size())
            .field("capacity", &self.arena.capacity())
            .field("subtrees", &self.roots.len())
            .field("nodes", &self.nodes)
            .field("cores", &self.locals.len())
            .field("allocated", &self.allocated.load(Ordering::Acquire))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Per-subtree free counts must match the bitfield popcounts when
    /// the pool is quiescent — the counter discipline's ground truth.
    fn assert_counts_exact(a: &TwoLevelAllocator) {
        for s in 0..a.subtree_count() {
            let pop: u32 = a.fields[s]
                .words
                .iter()
                .map(|w| w.load(Ordering::Acquire).count_ones())
                .sum();
            let count = a.roots[s].state.load(Ordering::Acquire) & COUNT_MASK;
            assert_eq!(count, pop, "subtree {s} count drifted from bitmap");
        }
    }

    #[test]
    fn alloc_free_roundtrip() {
        let a = TwoLevelAllocator::new(1024, 640).unwrap();
        let b = a.alloc().unwrap();
        assert!(a.is_live(b));
        assert_eq!(a.free_blocks(), 639);
        a.free(b).unwrap();
        assert!(!a.is_live(b));
        assert_eq!(a.free_blocks(), 640);
        assert_counts_exact(&a);
    }

    #[test]
    fn exhaustion_errors_and_counts() {
        let a = TwoLevelAllocator::new(1024, 70).unwrap();
        let all: Vec<_> = (0..70).map(|_| a.alloc().unwrap()).collect();
        assert!(matches!(a.alloc(), Err(Error::OutOfMemory { .. })));
        assert_eq!(a.stats().failed_allocs, 1);
        assert_eq!(a.free_blocks(), 0);
        for b in all {
            a.free(b).unwrap();
        }
        assert_eq!(a.free_blocks(), 70);
        assert_counts_exact(&a);
    }

    #[test]
    fn double_free_rejected() {
        let a = TwoLevelAllocator::new(1024, 64).unwrap();
        let b = a.alloc().unwrap();
        a.free(b).unwrap();
        assert!(matches!(a.free(b), Err(Error::InvalidBlock(_))));
        assert_eq!(a.free_blocks(), 64);
        assert_counts_exact(&a);
    }

    #[test]
    fn foreign_block_rejected() {
        let a = TwoLevelAllocator::new(1024, 8).unwrap();
        assert!(matches!(a.free(BlockId(99)), Err(Error::InvalidBlock(_))));
        assert!(matches!(a.free(BlockId(3)), Err(Error::InvalidBlock(_))));
    }

    #[test]
    fn alloc_many_all_or_nothing() {
        let a = TwoLevelAllocator::new(1024, 600).unwrap();
        let keep = a.alloc_many(590).unwrap();
        assert!(a.alloc_many(11).is_err());
        assert_eq!(a.free_blocks(), 10, "rollback leaked blocks");
        assert_counts_exact(&a);
        let rest = a.alloc_many(10).unwrap();
        assert_eq!(rest.len(), 10);
        for b in keep.into_iter().chain(rest) {
            a.free(b).unwrap();
        }
        assert_eq!(a.free_blocks(), 600);
        assert_counts_exact(&a);
    }

    #[test]
    fn alloc_many_returns_distinct_blocks() {
        let a = TwoLevelAllocator::new(1024, 520).unwrap();
        let mut ids: Vec<u32> = a.alloc_many(520).unwrap().iter().map(|b| b.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 520);
    }

    #[test]
    fn write_read_roundtrip() {
        let a = TwoLevelAllocator::new(1024, 16).unwrap();
        let b = a.alloc().unwrap();
        a.write(b, 11, &[7, 8, 9]).unwrap();
        let mut out = [0u8; 3];
        a.read(b, 11, &mut out).unwrap();
        assert_eq!(out, [7, 8, 9]);
        a.free(b).unwrap();
        assert!(a.write(b, 0, &[1]).is_err(), "write to freed block");
    }

    #[test]
    fn invalid_config_rejected() {
        assert!(TwoLevelAllocator::with_topology(1024, 64, 0, 1).is_err());
        assert!(TwoLevelAllocator::with_topology(1024, 64, 1, 0).is_err());
        // 600 blocks = 2 subtrees; 3 nodes cannot each own one.
        assert!(TwoLevelAllocator::with_topology(1024, 600, 3, 4).is_err());
        assert!(TwoLevelAllocator::with_topology(1024, 600, 2, 4).is_ok());
    }

    #[test]
    fn capacity_not_multiple_of_64_is_exact() {
        let a = TwoLevelAllocator::new(1024, 100).unwrap();
        let all = a.alloc_many(100).unwrap();
        assert!(a.alloc().is_err());
        assert!(all.iter().all(|b| (b.0 as usize) < 100));
        assert_counts_exact(&a);
    }

    #[test]
    fn capacity_not_multiple_of_subtree_is_exact() {
        // 600 = 512 + 88: the tail subtree is partial.
        let a = TwoLevelAllocator::new(1024, 600).unwrap();
        assert_eq!(a.subtree_count(), 2);
        assert_eq!(a.subtree_occupancy(1), (0, 88));
        let mut got = 0;
        while a.alloc().is_ok() {
            got += 1;
        }
        assert_eq!(got, 600);
        assert_eq!(a.free_blocks(), 0);
    }

    #[test]
    fn fast_path_stays_in_reserved_subtree() {
        let a = TwoLevelAllocator::with_topology(1024, 2048, 1, 2).unwrap();
        let ids: Vec<_> = (0..100).map(|_| a.alloc_core_on(0, 0).unwrap()).collect();
        let s0 = ids[0].0 as usize / SUBTREE_BLOCKS;
        assert!(
            ids.iter().all(|b| b.0 as usize / SUBTREE_BLOCKS == s0),
            "fast path left the reserved subtree"
        );
        assert_eq!(a.placement_stats().reservations, 1);
        assert_eq!(a.reserved_subtree_of(0), Some(s0));
    }

    #[test]
    fn refill_reserves_next_subtree_on_drain() {
        let a = TwoLevelAllocator::with_topology(1024, 1024, 1, 1).unwrap();
        for _ in 0..SUBTREE_BLOCKS + 1 {
            a.alloc_core_on(0, 0).unwrap();
        }
        let p = a.placement_stats();
        assert_eq!(p.reservations, 2, "drain must refill the reservation");
        assert_eq!(a.reserved_subtree_of(0), Some(1));
        assert_eq!(p.handoffs, 0);
    }

    #[test]
    fn numa_same_node_before_crossing() {
        // 4 subtrees over 2 nodes: node 0 owns blocks 0..1024.
        let a = TwoLevelAllocator::with_topology(1024, 2048, 2, 2).unwrap();
        let ids: Vec<_> = (0..1025).map(|_| a.alloc_core_on(0, 0).unwrap()).collect();
        assert!(
            ids[..1024].iter().all(|b| (b.0 as usize) < 1024),
            "crossed nodes while the home node had space"
        );
        assert!(ids[1024].0 as usize >= 1024);
        let p = a.placement_stats();
        assert!(p.cross_node > 0, "the 1025th alloc crossed nodes");
        assert_eq!(a.node_of_block(ids[0]), 0);
        assert_eq!(a.node_of_block(ids[1024]), 1);
    }

    #[test]
    fn handoff_claims_inside_foreign_reservation() {
        // Core 0 reserves subtree 0; core 1 reserves and drains subtree
        // 1, then must hand off into core 0's reservation rather than
        // report OOM.
        let a = TwoLevelAllocator::with_topology(1024, 1024, 1, 2).unwrap();
        a.alloc_core_on(0, 0).unwrap();
        assert_eq!(a.reserved_subtree_of(0), Some(0));
        let mut core1 = Vec::new();
        for _ in 0..SUBTREE_BLOCKS {
            core1.push(a.alloc_core_on(1, 0).unwrap());
        }
        assert!(
            core1.iter().all(|b| b.0 as usize >= SUBTREE_BLOCKS),
            "core 1 should have reserved the unreserved subtree"
        );
        let b = a.alloc_core_on(1, 0).unwrap();
        assert!((b.0 as usize) < SUBTREE_BLOCKS, "handoff must use subtree 0");
        let p = a.placement_stats();
        assert!(p.handoffs > 0);
        assert!(a.contention().steals > 0, "handoffs surface as steals");
    }

    #[test]
    fn reservation_goes_shared_when_cores_outnumber_subtrees() {
        // 2 subtrees, 4 cores: the 3rd and 4th active cores must not
        // install reservations (adaptive shared mode).
        let a = TwoLevelAllocator::with_topology(1024, 1024, 1, 4).unwrap();
        for core in 0..4 {
            a.alloc_core_on(core, 0).unwrap();
        }
        let p = a.placement_stats();
        assert_eq!(p.reservations, 2, "only the first two cores reserve");
        assert!(p.handoffs >= 2, "late cores go through the shared path");
    }

    #[test]
    fn alloc_in_span_takes_lowest_in_range() {
        let a = TwoLevelAllocator::new(1024, 640).unwrap();
        let all = a.alloc_many(640).unwrap();
        for b in &all {
            if (b.0 as usize) >= 600 || (b.0 as usize) % 3 == 0 {
                a.free(*b).unwrap();
            }
        }
        let b = a.alloc_in_span(100, 200).unwrap();
        assert_eq!(b.0, 102, "lowest free multiple of 3 in [100, 200)");
        assert!(a.alloc_in_span(103, 105).is_err(), "full span must miss");
        assert_eq!(a.stats().failed_allocs, 0, "span misses aren't failures");
        assert_counts_exact(&a);
    }

    #[test]
    fn shard_spans_are_subtree_granular() {
        let a = TwoLevelAllocator::new(1024, 1100).unwrap();
        assert_eq!(
            a.shard_spans(),
            vec![(0, 512), (512, 1024), (1024, 1100)]
        );
        let one = TwoLevelAllocator::new(1024, 96).unwrap();
        assert_eq!(one.shard_spans(), vec![(0, 96)]);
    }

    #[test]
    fn live_snapshot_matches_is_live() {
        let a = TwoLevelAllocator::new(1024, 700).unwrap();
        let mut rng = crate::testutil::Rng::new(42);
        let mut live = Vec::new();
        for _ in 0..400 {
            if rng.chance(0.4) && !live.is_empty() {
                let i = rng.range(0, live.len());
                let b: BlockId = live.swap_remove(i);
                a.free(b).unwrap();
            } else if let Ok(b) = a.alloc() {
                live.push(b);
            }
        }
        let mut snap = Vec::new();
        a.live_snapshot(&mut snap);
        assert_eq!(snap.len(), 700usize.div_ceil(64));
        for i in 0..700u32 {
            let bit = snap[i as usize / 64] >> (i % 64) & 1 == 1;
            assert_eq!(bit, a.is_live(BlockId(i)), "snapshot disagrees at {i}");
        }
        assert_counts_exact(&a);
    }

    #[test]
    fn peak_tracks_high_water() {
        let a = TwoLevelAllocator::new(1024, 64).unwrap();
        let blocks = a.alloc_many(40).unwrap();
        for b in &blocks[..30] {
            a.free(*b).unwrap();
        }
        assert_eq!(a.stats().allocated, 10);
        assert_eq!(a.stats().peak, 40);
    }

    #[test]
    fn blocks_are_zeroed_via_alloc_zeroed() {
        let a = TwoLevelAllocator::new(1024, 8).unwrap();
        let b = a.alloc().unwrap();
        a.write(b, 0, &[0xAB; 16]).unwrap();
        a.free(b).unwrap();
        let b2 = a.alloc_zeroed().unwrap();
        let mut out = [0xFFu8; 16];
        a.read(b2, 0, &mut out).unwrap();
        assert_eq!(out, [0u8; 16]);
    }

    #[test]
    fn concurrent_alloc_free_conserves() {
        use std::sync::Arc;
        let a = Arc::new(TwoLevelAllocator::with_topology(1024, 1024, 2, 8).unwrap());
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::testutil::Rng::new(t + 1);
                let mut held = Vec::new();
                for _ in 0..2000 {
                    if rng.chance(0.5) && !held.is_empty() {
                        let i = rng.range(0, held.len());
                        let b = held.swap_remove(i);
                        a.free(b).unwrap();
                    } else if let Ok(b) = a.alloc() {
                        held.push(b);
                    }
                }
                for b in held {
                    a.free(b).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.stats().allocated, 0);
        assert_eq!(a.free_blocks(), 1024);
        assert_counts_exact(&a);
    }
}
