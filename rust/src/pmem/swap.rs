//! Swapping under application control (paper §2, Table 1 "Swapping":
//! *"machinery for migrating objects between memory pages can also move
//! objects between memory and disk, under application control"*).
//!
//! Block-granular swap: [`SwapPool`] evicts a block's 32 KB payload to a
//! backing file and frees the physical block; faulting it back allocates
//! a fresh block (not necessarily the same one — physical addresses are
//! not stable across swap, which is fine because the tree/pointer
//! patching machinery from [`crate::pmem::migrate`] already handles
//! moves). There is no page fault handler: the *application* decides
//! what to evict and when to fault, which is the paper's whole point.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::pmem::{BlockAlloc, BlockAllocator, BlockId};

/// A stable handle for swapped-out contents.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SwapSlot(u64);

/// Swap statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SwapStats {
    /// Blocks evicted to disk.
    pub evictions: u64,
    /// Blocks faulted back in.
    pub faults: u64,
    /// Slots currently on disk.
    pub resident_slots: usize,
}

struct Inner {
    file: File,
    /// Free slot indices in the file (reused before extending).
    free_slots: Vec<u64>,
    next_slot: u64,
    live: HashMap<u64, ()>,
    stats: SwapStats,
}

/// Block-granular swap file over any [`BlockAlloc`] pool.
pub struct SwapPool<'a, A: BlockAlloc = BlockAllocator> {
    alloc: &'a A,
    inner: Mutex<Inner>,
}

impl<'a, A: BlockAlloc> SwapPool<'a, A> {
    /// Create a swap pool backed by a file at `path` (truncated).
    pub fn new(alloc: &'a A, path: &std::path::Path) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(SwapPool {
            alloc,
            inner: Mutex::new(Inner {
                file,
                free_slots: Vec::new(),
                next_slot: 0,
                live: HashMap::new(),
                stats: SwapStats::default(),
            }),
        })
    }

    /// Swap pool backed by an anonymous temp file.
    pub fn anonymous(alloc: &'a A) -> Result<Self> {
        let path = std::env::temp_dir().join(format!(
            "nvm-swap-{}-{:x}",
            std::process::id(),
            alloc as *const _ as usize
        ));
        let pool = Self::new(alloc, &path)?;
        // Unlink immediately; the fd keeps it alive (unix).
        let _ = std::fs::remove_file(&path);
        Ok(pool)
    }

    /// Evict `block`: write its payload to disk, free the physical
    /// block, return the slot handle.
    pub fn evict(&self, block: BlockId) -> Result<SwapSlot> {
        if !self.alloc.is_live(block) {
            return Err(Error::InvalidBlock(block));
        }
        let bs = self.alloc.block_size();
        let mut buf = vec![0u8; bs];
        self.alloc.read(block, 0, &mut buf)?;
        let mut g = self.inner.lock().unwrap();
        let slot = g.free_slots.pop().unwrap_or_else(|| {
            let s = g.next_slot;
            g.next_slot += 1;
            s
        });
        g.file.seek(SeekFrom::Start(slot * bs as u64))?;
        g.file.write_all(&buf)?;
        g.live.insert(slot, ());
        g.stats.evictions += 1;
        g.stats.resident_slots = g.live.len();
        drop(g);
        self.alloc.free(block)?;
        // Eviction is a relocation (memory -> disk): any cached
        // translation to `block` is dead, so shoot down arena-wide.
        self.alloc.epoch().bump();
        Ok(SwapSlot(slot))
    }

    /// Fault `slot` back in: allocate a fresh block, read the payload,
    /// release the slot. Returns the (new) physical block.
    pub fn fault(&self, slot: SwapSlot) -> Result<BlockId> {
        let bs = self.alloc.block_size();
        let mut buf = vec![0u8; bs];
        {
            let mut g = self.inner.lock().unwrap();
            if g.live.remove(&slot.0).is_none() {
                return Err(Error::Artifact(format!("swap slot {} not resident", slot.0)));
            }
            g.file.seek(SeekFrom::Start(slot.0 * bs as u64))?;
            g.file.read_exact(&mut buf)?;
            g.free_slots.push(slot.0);
            g.stats.faults += 1;
            g.stats.resident_slots = g.live.len();
        }
        let fresh = self.alloc.alloc()?;
        self.alloc.write(fresh, 0, &buf)?;
        // No epoch bump here: the relocation's shootdown happened at
        // evict() (that is when the old translation died); `fresh` is a
        // brand-new block no cache has ever seen, so faulting in cannot
        // invalidate anything.
        Ok(fresh)
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> SwapStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn evict_fault_roundtrip() {
        let a = BlockAllocator::new(4096, 4).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        a.write(b, 10, b"hello swap").unwrap();
        let before = a.stats().allocated;
        let slot = swap.evict(b).unwrap();
        assert_eq!(a.stats().allocated, before - 1, "physical block freed");
        let nb = swap.fault(slot).unwrap();
        let mut out = [0u8; 10];
        a.read(nb, 10, &mut out).unwrap();
        assert_eq!(&out, b"hello swap");
    }

    #[test]
    fn evict_bumps_the_arena_epoch_fault_does_not() {
        let a = BlockAllocator::new(4096, 4).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        let e0 = a.epoch().current();
        let slot = swap.evict(b).unwrap();
        assert_eq!(a.epoch().current(), e0 + 1, "evict must shoot down");
        let nb = swap.fault(slot).unwrap();
        assert_eq!(
            a.epoch().current(),
            e0 + 1,
            "fault allocates a never-cached block; bumping would only cause spurious flushes"
        );
        a.free(nb).unwrap();
    }

    #[test]
    fn double_fault_rejected() {
        let a = BlockAllocator::new(4096, 4).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        let slot = swap.evict(b).unwrap();
        swap.fault(slot).unwrap();
        assert!(swap.fault(slot).is_err());
    }

    #[test]
    fn eviction_extends_memory_capacity() {
        // A 4-block pool hosts 16 blocks' worth of data via swap — the
        // paper's "application-controlled" overcommit.
        let a = BlockAllocator::new(1024, 4).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let mut slots = Vec::new();
        for i in 0..16u32 {
            let b = a.alloc().unwrap();
            a.write(b, 0, &i.to_le_bytes()).unwrap();
            slots.push(swap.evict(b).unwrap());
        }
        assert_eq!(a.stats().allocated, 0);
        for (i, slot) in slots.into_iter().enumerate() {
            let b = swap.fault(slot).unwrap();
            let mut out = [0u8; 4];
            a.read(b, 0, &mut out).unwrap();
            assert_eq!(u32::from_le_bytes(out), i as u32);
            a.free(b).unwrap();
        }
        assert_eq!(swap.stats().faults, 16);
        assert_eq!(swap.stats().resident_slots, 0);
    }

    #[test]
    fn slots_are_reused() {
        let a = BlockAllocator::new(1024, 2).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        for _ in 0..10 {
            let b = a.alloc().unwrap();
            let s = swap.evict(b).unwrap();
            let b2 = swap.fault(s).unwrap();
            a.free(b2).unwrap();
        }
        let g = swap.inner.lock().unwrap();
        assert!(g.next_slot <= 2, "slots must be recycled, used {}", g.next_slot);
    }

    #[test]
    fn prop_swap_preserves_random_contents() {
        forall(15, |g| {
            let a = BlockAllocator::new(1024, 8).unwrap();
            let swap = SwapPool::anonymous(&a).unwrap();
            let n = g.usize_in(1, 8);
            let mut pairs = Vec::new();
            for _ in 0..n {
                let data: Vec<u8> = g.vec(1024, |g| g.usize_in(0, 255) as u8);
                let b = a.alloc().unwrap();
                a.write(b, 0, &data).unwrap();
                pairs.push((swap.evict(b).unwrap(), data));
            }
            g.rng().shuffle(&mut pairs);
            for (slot, data) in pairs {
                let b = swap.fault(slot).unwrap();
                let mut out = vec![0u8; 1024];
                a.read(b, 0, &mut out).unwrap();
                assert_eq!(out, data);
                a.free(b).unwrap();
            }
        });
    }
}
