//! Swapping under application control (paper §2, Table 1 "Swapping":
//! *"machinery for migrating objects between memory pages can also move
//! objects between memory and disk, under application control"*).
//!
//! Block-granular swap: [`SwapPool`] evicts a block's 32 KB payload to a
//! backing file and frees the physical block; faulting it back allocates
//! a fresh block (not necessarily the same one — physical addresses are
//! not stable across swap, which is fine because the tree/pointer
//! patching machinery from [`crate::pmem::migrate`] already handles
//! moves). There is no page fault handler: the *application* decides
//! what to evict and when to fault, which is the paper's whole point.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::sync::{Condvar, Mutex};

use crate::error::{Error, Result};
use crate::pmem::{BlockAlloc, BlockAllocator, BlockId};

/// The storage a [`SwapPool`] stashes payloads into, abstracted so
/// tests can inject I/O failures at exact points ([`crate::testutil`]'s
/// `FailingBacking`) and prove the pool's failure-atomicity claims —
/// the production backing is a plain [`FileBacking`]. Offsets are byte
/// positions (`slot * block_size`); each call is one logical I/O.
pub trait SwapBacking: Send {
    /// Write `data` at byte offset `off` (extending the store).
    fn write_at(&mut self, off: u64, data: &[u8]) -> std::io::Result<()>;

    /// Fill `out` from byte offset `off`; short reads are errors.
    fn read_at(&mut self, off: u64, out: &mut [u8]) -> std::io::Result<()>;
}

/// The default [`SwapBacking`]: a seek-and-IO file.
pub struct FileBacking(File);

impl SwapBacking for FileBacking {
    fn write_at(&mut self, off: u64, data: &[u8]) -> std::io::Result<()> {
        self.0.seek(SeekFrom::Start(off))?;
        self.0.write_all(data)
    }

    fn read_at(&mut self, off: u64, out: &mut [u8]) -> std::io::Result<()> {
        self.0.seek(SeekFrom::Start(off))?;
        self.0.read_exact(out)
    }
}

/// A stable handle for swapped-out contents.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SwapSlot(u64);

impl SwapSlot {
    /// The raw slot index — what the per-leaf swap words and the typed
    /// fault errors carry (crate-internal: slot handles stay opaque to
    /// library users).
    #[inline]
    pub(crate) fn raw(self) -> u64 {
        self.0
    }

    /// Rebuild a handle from a raw index previously taken with
    /// [`SwapSlot::raw`] (crate-internal).
    #[inline]
    pub(crate) fn from_raw(raw: u64) -> Self {
        SwapSlot(raw)
    }
}

/// Swap statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SwapStats {
    /// Blocks evicted to disk.
    pub evictions: u64,
    /// Blocks faulted back in.
    pub faults: u64,
    /// Fault calls that found the slot's I/O already in flight and
    /// waited on the peer instead of issuing a duplicate read.
    pub coalesced: u64,
    /// Slots currently on disk.
    pub resident_slots: usize,
}

/// Per-slot state machine: a resident slot holds a payload on disk; a
/// slot whose fault I/O is in flight is *claimed* — concurrent faults
/// for it park on the pool's condvar and coalesce onto the one read
/// (the duplicate either reports the peer's completion or, if the peer
/// failed, inherits the claim and retries the I/O itself).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotState {
    Resident,
    FaultInFlight,
}

/// Slot bookkeeping, deliberately separate from the backing store: the
/// `meta` mutex is only ever held for map/counter updates, while the
/// `io` mutex is held across actual backing reads/writes — so state
/// transitions (and in particular the [`SlotState::FaultInFlight`]
/// claim/park protocol) stay observable while an I/O is in flight.
struct Meta {
    /// Free slot indices in the backing (reused before extending).
    free_slots: Vec<u64>,
    next_slot: u64,
    live: HashMap<u64, SlotState>,
    stats: SwapStats,
}

/// Block-granular swap over any [`BlockAlloc`] pool and any
/// [`SwapBacking`] store (a file by default).
pub struct SwapPool<'a, A: BlockAlloc = BlockAllocator, B: SwapBacking = FileBacking> {
    alloc: &'a A,
    io: Mutex<B>,
    meta: Mutex<Meta>,
    /// Signalled on every fault completion (success or failure) so
    /// coalesced waiters re-examine the slot.
    cv: Condvar,
}

impl<'a, A: BlockAlloc> SwapPool<'a, A> {
    /// Create a swap pool backed by a file at `path` (truncated).
    pub fn new(alloc: &'a A, path: &std::path::Path) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(Self::with_backing(alloc, FileBacking(file)))
    }

    /// Swap pool backed by an anonymous temp file.
    pub fn anonymous(alloc: &'a A) -> Result<Self> {
        let path = std::env::temp_dir().join(format!(
            "nvm-swap-{}-{:x}",
            std::process::id(),
            alloc as *const _ as usize
        ));
        let pool = Self::new(alloc, &path)?;
        // Unlink immediately; the fd keeps it alive (unix).
        let _ = std::fs::remove_file(&path);
        Ok(pool)
    }
}

impl<'a, A: BlockAlloc, B: SwapBacking> SwapPool<'a, A, B> {
    /// Swap pool over an explicit backing store (how the
    /// fault-injection tests thread a failing double through the real
    /// eviction/fault paths).
    pub fn with_backing(alloc: &'a A, backing: B) -> Self {
        SwapPool {
            alloc,
            io: Mutex::new(backing),
            meta: Mutex::new(Meta {
                free_slots: Vec::new(),
                next_slot: 0,
                live: HashMap::new(),
                stats: SwapStats::default(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Write `block`'s payload into a (new or recycled) swap slot and
    /// record it resident. Shared by both eviction forms; does not
    /// dispose of the physical block.
    ///
    /// Failure-atomic: on a backing write error the picked slot returns
    /// to the free list (it is in neither `live` nor `free_slots` at
    /// failure time), nothing is recorded resident, no counter moves,
    /// and the caller keeps the (untouched) physical block — a retried
    /// eviction reuses the same slot.
    fn stash(&self, block: BlockId) -> Result<u64> {
        if !self.alloc.is_live(block) {
            return Err(Error::InvalidBlock(block));
        }
        let bs = self.alloc.block_size();
        let mut buf = vec![0u8; bs];
        self.alloc.read(block, 0, &mut buf)?;
        // Claim a slot under `meta`, write under `io`: the slot is in
        // neither `live` nor `free_slots` during the write, so no
        // concurrent evict or fault can touch it, and the unpublished
        // handle means no fault for it can arrive before we record it.
        let slot = {
            let mut m = self.meta.lock().unwrap();
            m.free_slots.pop().unwrap_or_else(|| {
                let s = m.next_slot;
                m.next_slot += 1;
                s
            })
        };
        let wrote = self.io.lock().unwrap().write_at(slot * bs as u64, &buf);
        if let Err(e) = wrote {
            // Failure-atomic like `fault`: return the slot to the free
            // list instead of leaking it, so retried evictions reuse it.
            self.meta.lock().unwrap().free_slots.push(slot);
            return Err(e.into());
        }
        let mut m = self.meta.lock().unwrap();
        m.live.insert(slot, SlotState::Resident);
        m.stats.evictions += 1;
        m.stats.resident_slots = m.live.len();
        Ok(slot)
    }

    /// Evict `block`: write its payload to disk, free the physical
    /// block, return the slot handle.
    ///
    /// The free is immediate, so no concurrent reader may hold a cached
    /// translation into `block` (the [`crate::trees::TreeArray::migrate_leaf`]
    /// contract); under live epoch-registered readers use
    /// [`SwapPool::evict_deferred`].
    pub fn evict(&self, block: BlockId) -> Result<SwapSlot> {
        let slot = self.stash(block)?;
        self.alloc.free(block)?;
        // Eviction is a relocation (memory -> disk): any cached
        // translation to `block` is dead, so shoot down arena-wide.
        self.alloc.epoch().bump();
        Ok(SwapSlot(slot))
    }

    /// [`SwapPool::evict`] under **live concurrent readers**: the
    /// payload goes to disk, but the physical block is *retired* into
    /// the arena epoch's limbo list instead of freed — it returns to
    /// the pool only once every registered reader has pinned past the
    /// eviction ([`crate::pmem::ArenaEpoch::try_reclaim`]), so a read
    /// already in flight through a stale cached translation still
    /// dereferences stable bytes. This is to `evict` what
    /// `migrate_leaf_concurrent` is to `migrate_leaf`, and the eviction
    /// hook the [`crate::mmd`] daemon drives.
    pub fn evict_deferred(&self, block: BlockId) -> Result<SwapSlot> {
        let slot = self.stash(block)?;
        // Bump first (shootdown: post-eviction readers revalidate), then
        // park the block in limbo stamped with the post-move epoch.
        let e = self.alloc.epoch().bump();
        self.alloc.epoch().retire(block, e);
        Ok(SwapSlot(slot))
    }

    /// Fault `slot` back in: allocate a fresh block, read the payload,
    /// release the slot. Returns the (new) physical block.
    ///
    /// The block is allocated *before* the slot is consumed: if the
    /// pool is exhausted the fault fails cleanly and the slot stays
    /// resident (retry after freeing memory), instead of losing the
    /// payload.
    ///
    /// **Coalescing**: a fault for a slot whose I/O is already in
    /// flight ([`SlotState::FaultInFlight`]) does not issue a second
    /// read — it parks on the pool's condvar until the peer completes.
    /// If the peer succeeded, the duplicate returns an error (the slot
    /// is gone; its payload now lives in the *peer's* block — callers
    /// on the tree fault path re-check the leaf's swap word and find it
    /// restored). If the peer failed, the waiter inherits the claim and
    /// retries the I/O itself.
    pub fn fault(&self, slot: SwapSlot) -> Result<BlockId> {
        let bs = self.alloc.block_size();
        // Claim the slot (or coalesce on a peer's in-flight fault).
        let mut coalesced = false;
        {
            let mut m = self.meta.lock().unwrap();
            loop {
                match m.live.get(&slot.0) {
                    Some(SlotState::Resident) => {
                        m.live.insert(slot.0, SlotState::FaultInFlight);
                        break;
                    }
                    Some(SlotState::FaultInFlight) => {
                        if !coalesced {
                            m.stats.coalesced += 1;
                            coalesced = true;
                        }
                        m = self.cv.wait(m).unwrap();
                    }
                    None => {
                        return Err(Error::Artifact(if coalesced {
                            format!("swap slot {} faulted in by a concurrent fault", slot.0)
                        } else {
                            format!("swap slot {} not resident", slot.0)
                        }));
                    }
                }
            }
        }
        // The claim is ours: every exit below must either complete the
        // fault (slot removed) or revert the slot to Resident, and must
        // notify the condvar so coalesced waiters re-examine it.
        let fresh = match self.alloc.alloc() {
            Ok(f) => f,
            Err(e) => {
                self.meta.lock().unwrap().live.insert(slot.0, SlotState::Resident);
                self.cv.notify_all();
                return Err(e);
            }
        };
        let mut buf = vec![0u8; bs];
        let read = self.io.lock().unwrap().read_at(slot.0 * bs as u64, &mut buf);
        if let Err(e) = read {
            // I/O failure: keep the slot resident, free the block.
            let _ = self.alloc.free(fresh);
            self.meta.lock().unwrap().live.insert(slot.0, SlotState::Resident);
            self.cv.notify_all();
            return Err(e.into());
        }
        {
            let mut m = self.meta.lock().unwrap();
            let claimed = m.live.remove(&slot.0);
            debug_assert_eq!(claimed, Some(SlotState::FaultInFlight));
            m.free_slots.push(slot.0);
            m.stats.faults += 1;
            m.stats.resident_slots = m.live.len();
        }
        self.cv.notify_all();
        self.alloc.write(fresh, 0, &buf)?;
        // No epoch bump here: the relocation's shootdown happened at
        // evict() (that is when the old translation died); `fresh` is a
        // brand-new block no cache has ever seen, so faulting in cannot
        // invalidate anything.
        Ok(fresh)
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> SwapStats {
        self.meta.lock().unwrap().stats
    }

    /// Run one non-blocking epoch-reclaim pass over the pool's arena.
    /// The fault path allocates before reading; when the arena is full
    /// of *limbo* blocks (evicted-but-unreclaimed), this is what turns
    /// an `OutOfMemory` fault into a retryable condition — the fault
    /// queue calls it between OOM retries.
    pub fn reclaim(&self) -> usize {
        self.alloc.epoch().try_reclaim(self.alloc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn evict_fault_roundtrip() {
        let a = BlockAllocator::new(4096, 4).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        a.write(b, 10, b"hello swap").unwrap();
        let before = a.stats().allocated;
        let slot = swap.evict(b).unwrap();
        assert_eq!(a.stats().allocated, before - 1, "physical block freed");
        let nb = swap.fault(slot).unwrap();
        let mut out = [0u8; 10];
        a.read(nb, 10, &mut out).unwrap();
        assert_eq!(&out, b"hello swap");
    }

    #[test]
    fn evict_bumps_the_arena_epoch_fault_does_not() {
        let a = BlockAllocator::new(4096, 4).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        let e0 = a.epoch().current();
        let slot = swap.evict(b).unwrap();
        assert_eq!(a.epoch().current(), e0 + 1, "evict must shoot down");
        let nb = swap.fault(slot).unwrap();
        assert_eq!(
            a.epoch().current(),
            e0 + 1,
            "fault allocates a never-cached block; bumping would only cause spurious flushes"
        );
        a.free(nb).unwrap();
    }

    #[test]
    fn double_fault_rejected() {
        let a = BlockAllocator::new(4096, 4).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        let slot = swap.evict(b).unwrap();
        swap.fault(slot).unwrap();
        assert!(swap.fault(slot).is_err());
    }

    #[test]
    fn concurrent_faults_on_one_slot_coalesce_to_one_io() {
        // N threads race to fault the same slot: exactly one wins the
        // payload (one I/O, one fresh block), the rest either park on
        // the FaultInFlight claim or arrive after completion — in every
        // interleaving they get a typed error, never a duplicate block
        // or a lost payload.
        let a = BlockAllocator::new(1024, 8).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        a.write(b, 0, b"one copy").unwrap();
        let slot = swap.evict(b).unwrap();
        let wins: Vec<Option<BlockId>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| s.spawn(|| swap.fault(slot).ok()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let winners: Vec<BlockId> = wins.into_iter().flatten().collect();
        assert_eq!(winners.len(), 1, "exactly one fault may win the slot");
        assert_eq!(swap.stats().faults, 1);
        assert_eq!(swap.stats().resident_slots, 0);
        let mut out = [0u8; 8];
        a.read(winners[0], 0, &mut out).unwrap();
        assert_eq!(&out, b"one copy");
        a.free(winners[0]).unwrap();
        assert_eq!(a.stats().allocated, 0, "losing faults must not leak blocks");
    }

    #[test]
    fn eviction_extends_memory_capacity() {
        // A 4-block pool hosts 16 blocks' worth of data via swap — the
        // paper's "application-controlled" overcommit.
        let a = BlockAllocator::new(1024, 4).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let mut slots = Vec::new();
        for i in 0..16u32 {
            let b = a.alloc().unwrap();
            a.write(b, 0, &i.to_le_bytes()).unwrap();
            slots.push(swap.evict(b).unwrap());
        }
        assert_eq!(a.stats().allocated, 0);
        for (i, slot) in slots.into_iter().enumerate() {
            let b = swap.fault(slot).unwrap();
            let mut out = [0u8; 4];
            a.read(b, 0, &mut out).unwrap();
            assert_eq!(u32::from_le_bytes(out), i as u32);
            a.free(b).unwrap();
        }
        assert_eq!(swap.stats().faults, 16);
        assert_eq!(swap.stats().resident_slots, 0);
    }

    #[test]
    fn slots_are_reused() {
        let a = BlockAllocator::new(1024, 2).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        for _ in 0..10 {
            let b = a.alloc().unwrap();
            let s = swap.evict(b).unwrap();
            let b2 = swap.fault(s).unwrap();
            a.free(b2).unwrap();
        }
        let m = swap.meta.lock().unwrap();
        assert!(m.next_slot <= 2, "slots must be recycled, used {}", m.next_slot);
    }

    #[test]
    fn evict_fault_roundtrip_sharded_allocator() {
        use crate::pmem::ShardedAllocator;
        let a = ShardedAllocator::with_shards(4096, 8, 2).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        a.write(b, 10, b"sharded swap").unwrap();
        let before = a.stats().allocated;
        let slot = swap.evict(b).unwrap();
        assert_eq!(a.stats().allocated, before - 1, "physical block freed");
        assert!(!a.is_live(b));
        let nb = swap.fault(slot).unwrap();
        let mut out = [0u8; 12];
        a.read(nb, 10, &mut out).unwrap();
        assert_eq!(&out, b"sharded swap");
        a.free(nb).unwrap();
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn deferred_evict_waits_for_pinned_reader() {
        // The satellite scenario: a registered reader pinned *before*
        // the eviction may still dereference the evicted block through
        // a cached translation, so evict_deferred must park it in limbo
        // until the reader quiesces — and the bytes must stay intact in
        // the meantime.
        let a = BlockAllocator::new(4096, 4).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let reader = a.epoch().register();
        reader.pin();
        let b = a.alloc().unwrap();
        a.write(b, 0, b"cold leaf").unwrap();
        let live = a.stats().allocated;
        let e0 = a.epoch().current();
        let slot = swap.evict_deferred(b).unwrap();
        assert_eq!(a.epoch().current(), e0 + 1, "deferred evict must shoot down");
        assert!(a.is_live(b), "block must stay allocated while the reader is stale");
        assert_eq!(a.stats().limbo, 1);
        assert_eq!(a.epoch().try_reclaim(&a), 0, "pinned reader blocks reclaim");
        // The stale translation still reads stable bytes.
        let mut out = [0u8; 9];
        a.read(b, 0, &mut out).unwrap();
        assert_eq!(&out, b"cold leaf");
        // Reader quiesces: the block returns to the pool.
        reader.pin();
        assert_eq!(a.epoch().try_reclaim(&a), 1);
        assert_eq!(a.stats().allocated, live - 1);
        // The payload faults back regardless.
        let nb = swap.fault(slot).unwrap();
        a.read(nb, 0, &mut out).unwrap();
        assert_eq!(&out, b"cold leaf");
        a.free(nb).unwrap();
    }

    #[test]
    fn deferred_evict_under_sharded_allocator_and_view_reader() {
        // End-to-end with a real revalidating reader: a TreeView holds a
        // cached translation over one tree while an *unrelated* block in
        // the same pool is deferred-evicted; the view pins, flushes, and
        // keeps verifying, and the evicted block reclaims only after.
        use crate::pmem::ShardedAllocator;
        use crate::trees::TreeArray;
        let a = ShardedAllocator::with_shards(1024, 64, 2).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let mut tree: TreeArray<u32, ShardedAllocator> = TreeArray::new(&a, 256 * 2).unwrap();
        let data: Vec<u32> = (0..512u32).collect();
        tree.copy_from_slice(&data).unwrap();
        let mut view = tree.view();
        assert_eq!(view.get(5).unwrap(), data[5]); // pins + caches leaf 0
        let cold = a.alloc().unwrap();
        a.write(cold, 0, b"victim").unwrap();
        let slot = swap.evict_deferred(cold).unwrap();
        assert_eq!(a.epoch().try_reclaim(&a), 0, "view pinned pre-eviction");
        // The view's next access pins the post-eviction epoch (flushing
        // its TLB), unblocking the reclaim.
        assert_eq!(view.get(5).unwrap(), data[5]);
        assert!(view.tlb_stats().invalidations >= 1, "shootdown must flush");
        assert_eq!(a.epoch().try_reclaim(&a), 1);
        let nb = swap.fault(slot).unwrap();
        let mut out = [0u8; 6];
        a.read(nb, 0, &mut out).unwrap();
        assert_eq!(&out, b"victim");
        a.free(nb).unwrap();
    }

    #[test]
    fn fault_on_exhausted_pool_keeps_the_slot_resident() {
        let a = BlockAllocator::new(1024, 1).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let b = a.alloc().unwrap();
        a.write(b, 0, b"keep me").unwrap();
        let slot = swap.evict(b).unwrap();
        // Exhaust the pool, then fault: must fail without consuming the
        // slot's payload.
        let hog = a.alloc().unwrap();
        assert!(matches!(swap.fault(slot), Err(Error::OutOfMemory { .. })));
        assert_eq!(swap.stats().resident_slots, 1, "slot must survive the failed fault");
        a.free(hog).unwrap();
        let nb = swap.fault(slot).unwrap();
        let mut out = [0u8; 7];
        a.read(nb, 0, &mut out).unwrap();
        assert_eq!(&out, b"keep me");
    }

    #[test]
    fn prop_swap_preserves_random_contents_sharded() {
        use crate::pmem::ShardedAllocator;
        forall(10, |g| {
            let a = ShardedAllocator::with_shards(1024, 8, 2).unwrap();
            let swap = SwapPool::anonymous(&a).unwrap();
            let n = g.usize_in(1, 8);
            let mut pairs = Vec::new();
            for _ in 0..n {
                let data: Vec<u8> = g.vec(1024, |g| g.usize_in(0, 255) as u8);
                let b = a.alloc().unwrap();
                a.write(b, 0, &data).unwrap();
                pairs.push((swap.evict(b).unwrap(), data));
            }
            g.rng().shuffle(&mut pairs);
            for (slot, data) in pairs {
                let b = swap.fault(slot).unwrap();
                let mut out = vec![0u8; 1024];
                a.read(b, 0, &mut out).unwrap();
                assert_eq!(out, data);
                a.free(b).unwrap();
            }
            assert_eq!(a.stats().allocated, 0);
        });
    }

    // ---- fault injection (FailingBacking) ----
    //
    // The happy-path tests above assume the failure-atomicity the docs
    // claim; these inject backing I/O errors at exact points and assert
    // it actually holds.

    #[test]
    fn failed_stash_rolls_the_slot_back() {
        use crate::testutil::FailingBacking;
        let a = BlockAllocator::new(1024, 4).unwrap();
        let (backing, ctl) = FailingBacking::new();
        let swap = SwapPool::with_backing(&a, backing);
        let b = a.alloc().unwrap();
        a.write(b, 0, b"precious").unwrap();
        let e0 = a.epoch().current();
        ctl.fail_nth(1);
        assert!(swap.evict(b).is_err(), "injected write fault must surface");
        // Failure-atomicity: block untouched and live, nothing resident,
        // no counter moved, no shootdown fired.
        assert!(a.is_live(b));
        let mut out = [0u8; 8];
        a.read(b, 0, &mut out).unwrap();
        assert_eq!(&out, b"precious");
        assert_eq!(swap.stats().evictions, 0);
        assert_eq!(swap.stats().resident_slots, 0);
        assert_eq!(a.epoch().current(), e0, "failed evict must not bump the epoch");
        // Slot rollback: the retry reuses the slot instead of leaking it.
        let slot = swap.evict(b).unwrap();
        assert_eq!(
            swap.meta.lock().unwrap().next_slot,
            1,
            "failed stash leaked its slot"
        );
        let nb = swap.fault(slot).unwrap();
        a.read(nb, 0, &mut out).unwrap();
        assert_eq!(&out, b"precious");
        a.free(nb).unwrap();
    }

    #[test]
    fn failed_deferred_evict_retires_nothing() {
        use crate::testutil::FailingBacking;
        let a = BlockAllocator::new(1024, 4).unwrap();
        let (backing, ctl) = FailingBacking::new();
        let swap = SwapPool::with_backing(&a, backing);
        let reader = a.epoch().register();
        reader.pin();
        let b = a.alloc().unwrap();
        let e0 = a.epoch().current();
        ctl.fail_nth(1);
        assert!(swap.evict_deferred(b).is_err());
        assert_eq!(a.epoch().limbo_len(), 0, "failed evict must not retire the block");
        assert_eq!(a.epoch().current(), e0, "failed evict must not shoot down");
        assert!(a.is_live(b), "caller keeps the block on failure");
        a.free(b).unwrap();
    }

    #[test]
    fn failed_fault_keeps_the_slot_resident_and_frees_the_block() {
        use crate::testutil::FailingBacking;
        let a = BlockAllocator::new(1024, 2).unwrap();
        let (backing, ctl) = FailingBacking::new();
        let swap = SwapPool::with_backing(&a, backing);
        let b = a.alloc().unwrap();
        a.write(b, 0, b"survives").unwrap();
        let slot = swap.evict(b).unwrap();
        assert_eq!(a.stats().allocated, 0);
        ctl.fail_nth(1);
        assert!(swap.fault(slot).is_err(), "injected read fault must surface");
        // Failure-atomicity: slot stays resident, the speculative block
        // went back to the pool, no fault counted.
        assert_eq!(swap.stats().resident_slots, 1);
        assert_eq!(swap.stats().faults, 0);
        assert_eq!(a.stats().allocated, 0, "failed fault must free its speculative block");
        // The retry succeeds with the payload intact.
        let nb = swap.fault(slot).unwrap();
        let mut out = [0u8; 8];
        a.read(nb, 0, &mut out).unwrap();
        assert_eq!(&out, b"survives");
        a.free(nb).unwrap();
    }

    #[test]
    fn prop_random_io_faults_never_lose_payloads() {
        use crate::testutil::FailingBacking;
        forall(15, |g| {
            let a = BlockAllocator::new(256, 8).unwrap();
            let (backing, ctl) = FailingBacking::new();
            let swap = SwapPool::with_backing(&a, backing);
            let n = g.usize_in(1, 6);
            let mut slots = Vec::new();
            for _ in 0..n {
                let data: Vec<u8> = g.vec(256, |g| g.usize_in(0, 255) as u8);
                let b = a.alloc().unwrap();
                a.write(b, 0, &data).unwrap();
                if g.bool(0.5) {
                    ctl.fail_nth(1);
                }
                // One injected failure at most (fail_nth disarms after
                // firing), so a single retry must always succeed.
                let slot = match swap.evict(b) {
                    Ok(s) => s,
                    Err(_) => swap.evict(b).expect("retry after injected fault"),
                };
                slots.push((slot, data));
            }
            g.rng().shuffle(&mut slots);
            for (slot, data) in slots {
                if g.bool(0.5) {
                    ctl.fail_nth(1);
                }
                let b = match swap.fault(slot) {
                    Ok(b) => b,
                    Err(_) => swap.fault(slot).expect("retry after injected fault"),
                };
                let mut out = vec![0u8; 256];
                a.read(b, 0, &mut out).unwrap();
                assert_eq!(out, data, "payload corrupted across injected faults");
                a.free(b).unwrap();
            }
            assert_eq!(a.stats().allocated, 0);
        });
    }

    #[test]
    fn prop_swap_preserves_random_contents() {
        forall(15, |g| {
            let a = BlockAllocator::new(1024, 8).unwrap();
            let swap = SwapPool::anonymous(&a).unwrap();
            let n = g.usize_in(1, 8);
            let mut pairs = Vec::new();
            for _ in 0..n {
                let data: Vec<u8> = g.vec(1024, |g| g.usize_in(0, 255) as u8);
                let b = a.alloc().unwrap();
                a.write(b, 0, &data).unwrap();
                pairs.push((swap.evict(b).unwrap(), data));
            }
            g.rng().shuffle(&mut pairs);
            for (slot, data) in pairs {
                let b = swap.fault(slot).unwrap();
                let mut out = vec![0u8; 1024];
                a.read(b, 0, &mut out).unwrap();
                assert_eq!(out, data);
                a.free(b).unwrap();
            }
        });
    }
}
