//! The shared arena: one stable heap allocation carved into fixed-size
//! blocks. Both allocator policies embed an [`Arena`], so the unsafe
//! surface (raw pointer arithmetic, bounds checks, block copies, the
//! alloc/dealloc lifecycle) is written and audited exactly once.

use std::alloc::{alloc_zeroed, dealloc, Layout};

use crate::error::{Error, Result};
use crate::pmem::BlockId;

/// A contiguous zero-initialized allocation of `capacity` blocks of
/// `block_size` bytes each. The arena validates geometry, owns the
/// memory, and provides the raw block accessors; *which* blocks are
/// live is the embedding allocator's business.
///
/// Alignment guarantee (load-bearing, see [`crate::trees::Pod`]): the
/// backing allocation is aligned to `block_size`, so every block starts
/// at a `block_size`-aligned address and any power-of-two-sized element
/// placed at a multiple of its size within a block is naturally aligned
/// — consumers may use aligned `read`/`write`, not the `_unaligned`
/// variants.
pub(crate) struct Arena {
    ptr: *mut u8,
    layout: Layout,
    block_size: usize,
    capacity: usize,
}

// SAFETY: the pointer is stable for the arena's lifetime and the unsafe
// accessors require the caller (the embedding allocator) to guarantee
// exclusive ownership of each live block, so concurrent access to
// distinct blocks never aliases.
unsafe impl Send for Arena {}
unsafe impl Sync for Arena {}

impl Arena {
    /// Validate geometry and allocate the zeroed backing memory.
    ///
    /// `block_size` must be a power of two ≥ 256 (the paper uses 32 KB;
    /// the ablation sweeps 8–128 KB).
    pub(crate) fn new(block_size: usize, capacity_blocks: usize) -> Result<Self> {
        if !block_size.is_power_of_two() || block_size < 256 {
            return Err(Error::Config(format!(
                "block_size {block_size} must be a power of two >= 256"
            )));
        }
        if capacity_blocks == 0 || capacity_blocks > u32::MAX as usize {
            return Err(Error::Config(format!(
                "capacity_blocks {capacity_blocks} out of range"
            )));
        }
        let bytes = block_size.checked_mul(capacity_blocks).ok_or_else(|| {
            Error::Config(format!(
                "arena size {block_size} B x {capacity_blocks} blocks overflows usize"
            ))
        })?;
        let layout = Layout::from_size_align(bytes, block_size)
            .map_err(|e| Error::Config(e.to_string()))?;
        // SAFETY: layout is non-zero-sized and valid.
        let ptr = unsafe { alloc_zeroed(layout) };
        if ptr.is_null() {
            return Err(Error::Config(format!("arena allocation of {bytes} bytes failed")));
        }
        Ok(Arena {
            ptr,
            layout,
            block_size,
            capacity: capacity_blocks,
        })
    }

    /// Block size in bytes.
    #[inline]
    pub(crate) fn block_size(&self) -> usize {
        self.block_size
    }

    /// Capacity in blocks.
    #[inline]
    pub(crate) fn capacity(&self) -> usize {
        self.capacity
    }

    /// Overflow-safe check that `[offset, offset + len)` lies within one
    /// block.
    #[inline]
    pub(crate) fn check_span(&self, offset: usize, len: usize) -> Result<()> {
        match offset.checked_add(len) {
            Some(end) if end <= self.block_size => Ok(()),
            _ => Err(Error::IndexOutOfBounds {
                index: offset.saturating_add(len),
                len: self.block_size,
            }),
        }
    }

    /// Raw pointer to the block's first byte.
    ///
    /// # Safety
    /// `id` must be in range and the caller must uphold exclusive
    /// ownership of the block's data.
    #[inline]
    pub(crate) unsafe fn block_ptr(&self, id: BlockId) -> *mut u8 {
        debug_assert!((id.0 as usize) < self.capacity);
        self.ptr.add(id.0 as usize * self.block_size)
    }

    /// Copy `data` into the block at `offset`.
    ///
    /// # Safety
    /// As [`Arena::block_ptr`], plus the span must have been validated
    /// with [`Arena::check_span`].
    #[inline]
    pub(crate) unsafe fn copy_in(&self, id: BlockId, offset: usize, data: &[u8]) {
        std::ptr::copy_nonoverlapping(data.as_ptr(), self.block_ptr(id).add(offset), data.len());
    }

    /// Copy bytes out of the block at `offset`.
    ///
    /// # Safety
    /// As [`Arena::copy_in`].
    #[inline]
    pub(crate) unsafe fn copy_out(&self, id: BlockId, offset: usize, out: &mut [u8]) {
        std::ptr::copy_nonoverlapping(self.block_ptr(id).add(offset), out.as_mut_ptr(), out.len());
    }

    /// Zero the whole block.
    ///
    /// # Safety
    /// As [`Arena::block_ptr`].
    #[inline]
    pub(crate) unsafe fn zero_block(&self, id: BlockId) {
        std::ptr::write_bytes(self.block_ptr(id), 0, self.block_size);
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        // SAFETY: `ptr` was allocated with exactly this layout.
        unsafe { dealloc(self.ptr, self.layout) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_validation() {
        assert!(Arena::new(3000, 4).is_err());
        assert!(Arena::new(128, 4).is_err());
        assert!(Arena::new(4096, 0).is_err());
        assert!(Arena::new(4096, 4).is_ok());
    }

    #[test]
    fn total_size_overflow_rejected() {
        // Each factor passes its individual check, but the product
        // wraps usize; must be an error, not a tiny arena that makes
        // block_ptr arithmetic out-of-bounds.
        assert!(Arena::new(1usize << 40, 1usize << 30).is_err());
    }

    #[test]
    fn check_span_rejects_overflowing_ranges() {
        let a = Arena::new(4096, 1).unwrap();
        assert!(a.check_span(0, 4096).is_ok());
        assert!(a.check_span(4095, 1).is_ok());
        assert!(a.check_span(4093, 4).is_err());
        // The wrap case: offset + len overflows usize; must reject, not
        // wrap around and pass.
        assert!(a.check_span(usize::MAX - 7, 16).is_err());
        assert!(a.check_span(usize::MAX, usize::MAX).is_err());
    }

    #[test]
    fn blocks_start_zeroed_and_copy_roundtrips() {
        let a = Arena::new(4096, 2).unwrap();
        let mut out = [0xFFu8; 8];
        // SAFETY: ids in range; single-threaded exclusive access.
        unsafe {
            a.copy_out(BlockId(1), 0, &mut out);
            assert_eq!(out, [0u8; 8]);
            a.copy_in(BlockId(1), 100, &[1, 2, 3]);
            a.copy_out(BlockId(1), 100, &mut out[..3]);
            assert_eq!(&out[..3], &[1, 2, 3]);
            a.zero_block(BlockId(1));
            a.copy_out(BlockId(1), 100, &mut out[..3]);
            assert_eq!(&out[..3], &[0, 0, 0]);
        }
    }
}
