//! Physical memory protection without address translation (paper §2,
//! Table 1 "Protection": *"hardware support for physical memory
//! protection and OS support for using these features"*).
//!
//! Models an MPU/PMP-style per-block permission table, the hardware the
//! paper expects to replace page-table permission bits (cf. RISC-V PMP
//! and tagged-memory schemes like Hyperflow [5] / CHERI [4], which the
//! paper cites as evidence that protection can be divorced from
//! translation). Granularity is the allocation block, so the table is
//! one word per 32 KB — far smaller than a page table, with no reach
//! limit and no walker.
//!
//! [`ProtectionDomain`]s play the role of address-space IDs: each block
//! is owned by one domain with per-domain R/W/X bits, and a
//! [`CheckedMem`] view enforces them on every access.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::pmem::{BlockAlloc, BlockAllocator, BlockId};

/// Access permissions on a block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Perms {
    /// Loads allowed.
    pub read: bool,
    /// Stores allowed.
    pub write: bool,
    /// Instruction fetch allowed.
    pub exec: bool,
}

impl Perms {
    /// Read-only.
    pub const R: Perms = Perms { read: true, write: false, exec: false };
    /// Read-write (the default data permission).
    pub const RW: Perms = Perms { read: true, write: true, exec: false };
    /// Read-execute (code).
    pub const RX: Perms = Perms { read: true, write: false, exec: true };
    /// No access.
    pub const NONE: Perms = Perms { read: false, write: false, exec: false };

    #[inline]
    fn bits(self) -> u64 {
        (self.read as u64) | (self.write as u64) << 1 | (self.exec as u64) << 2
    }

    #[inline]
    fn from_bits(b: u64) -> Perms {
        Perms {
            read: b & 1 != 0,
            write: b & 2 != 0,
            exec: b & 4 != 0,
        }
    }
}

/// A protection domain (process/compartment id). Domain 0 is the
/// "kernel" and passes every check.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProtectionDomain(pub u16);

/// Kernel domain: bypasses checks (it programs the table).
pub const KERNEL: ProtectionDomain = ProtectionDomain(0);

/// The per-block protection table.
///
/// One packed word per block: `[owner:16 | perms:3]`, atomically
/// updated so concurrent domains can be checked lock-free — matching
/// the hardware the paper envisions (a flat SRAM/CAM consulted in
/// parallel with the cache access, no walk, no TLB).
pub struct ProtectionTable {
    entries: Vec<AtomicU64>,
}

const OWNER_SHIFT: u32 = 3;

impl ProtectionTable {
    /// A table for `blocks` blocks; everything starts owned by KERNEL
    /// with no user access.
    pub fn new(blocks: usize) -> Self {
        ProtectionTable {
            entries: (0..blocks).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Grant `domain` the given permissions on `block` (kernel op).
    pub fn grant(&self, block: BlockId, domain: ProtectionDomain, perms: Perms) -> Result<()> {
        let e = self
            .entries
            .get(block.0 as usize)
            .ok_or(Error::InvalidBlock(block))?;
        e.store((domain.0 as u64) << OWNER_SHIFT | perms.bits(), Ordering::Release);
        Ok(())
    }

    /// Revoke all access to `block` (returns it to KERNEL/none).
    pub fn revoke(&self, block: BlockId) -> Result<()> {
        self.grant(block, KERNEL, Perms::NONE)
    }

    /// Revoke `block` **iff** it is still owned by `domain` — one
    /// compare-exchange, so an ownership transfer racing this call
    /// either wins entirely (the revoke refuses with a typed
    /// [`Error::Protection`]) or loses entirely (the block returns to
    /// KERNEL/none). The lookup-then-revoke sequence this replaces had
    /// a window where a stale owner's revoke could clobber a grant the
    /// kernel made in between.
    pub fn revoke_if_owner(&self, block: BlockId, domain: ProtectionDomain) -> Result<()> {
        let e = self
            .entries
            .get(block.0 as usize)
            .ok_or(Error::InvalidBlock(block))?;
        let mut cur = e.load(Ordering::Acquire);
        loop {
            if ProtectionDomain((cur >> OWNER_SHIFT) as u16) != domain {
                return Err(Error::Protection {
                    block,
                    domain: domain.0,
                    write: true,
                    exec: false,
                });
            }
            match e.compare_exchange_weak(cur, 0, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return Ok(()),
                Err(now) => cur = now,
            }
        }
    }

    /// Owner and permissions of `block`.
    pub fn lookup(&self, block: BlockId) -> Result<(ProtectionDomain, Perms)> {
        let e = self
            .entries
            .get(block.0 as usize)
            .ok_or(Error::InvalidBlock(block))?;
        let v = e.load(Ordering::Acquire);
        Ok((
            ProtectionDomain((v >> OWNER_SHIFT) as u16),
            Perms::from_bits(v),
        ))
    }

    /// Check an access by `domain`. Kernel always passes. Returns the
    /// denied permission on failure.
    #[inline]
    pub fn check(
        &self,
        block: BlockId,
        domain: ProtectionDomain,
        write: bool,
        exec: bool,
    ) -> Result<()> {
        if domain == KERNEL {
            return Ok(());
        }
        let (owner, perms) = self.lookup(block)?;
        let ok = owner == domain
            && ((!write && !exec && perms.read)
                || (write && perms.write)
                || (exec && perms.exec));
        if ok {
            Ok(())
        } else {
            Err(Error::Protection {
                block,
                domain: domain.0,
                write,
                exec,
            })
        }
    }
}

/// A domain-scoped memory view: every read/write is permission-checked
/// against the table before touching the allocator (the software
/// equivalent of the PMP check the paper's hardware would do in the
/// load/store pipeline).
pub struct CheckedMem<'a, A: BlockAlloc = BlockAllocator> {
    alloc: &'a A,
    table: &'a ProtectionTable,
    domain: ProtectionDomain,
}

impl<'a, A: BlockAlloc> CheckedMem<'a, A> {
    /// A view for `domain`.
    pub fn new(alloc: &'a A, table: &'a ProtectionTable, domain: ProtectionDomain) -> Self {
        CheckedMem { alloc, table, domain }
    }

    /// Checked write.
    pub fn write(&self, block: BlockId, offset: usize, data: &[u8]) -> Result<()> {
        self.table.check(block, self.domain, true, false)?;
        self.alloc.write(block, offset, data)
    }

    /// Checked read.
    pub fn read(&self, block: BlockId, offset: usize, out: &mut [u8]) -> Result<()> {
        self.table.check(block, self.domain, false, false)?;
        self.alloc.read(block, offset, out)
    }

    /// Allocate a block owned by this domain with `perms`.
    pub fn alloc(&self, perms: Perms) -> Result<BlockId> {
        let b = self.alloc.alloc()?;
        self.table.grant(b, self.domain, perms)?;
        Ok(b)
    }

    /// Free a block (must be owned by this domain). The ownership
    /// check and the revoke are one atomic step
    /// ([`ProtectionTable::revoke_if_owner`]), so a concurrent
    /// ownership transfer cannot slip between them and be clobbered by
    /// a stale free.
    pub fn free(&self, block: BlockId) -> Result<()> {
        if self.domain == KERNEL {
            self.table.revoke(block)?;
        } else {
            self.table.revoke_if_owner(block, self.domain)?;
        }
        self.alloc.free(block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    fn setup() -> (BlockAllocator, ProtectionTable) {
        let a = BlockAllocator::new(4096, 64).unwrap();
        let t = ProtectionTable::new(64);
        (a, t)
    }

    #[test]
    fn owner_can_rw_others_cannot() {
        let (a, t) = setup();
        let alice = CheckedMem::new(&a, &t, ProtectionDomain(1));
        let bob = CheckedMem::new(&a, &t, ProtectionDomain(2));
        let b = alice.alloc(Perms::RW).unwrap();
        alice.write(b, 0, &[1, 2, 3]).unwrap();
        let mut out = [0u8; 3];
        alice.read(b, 0, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3]);
        assert!(matches!(
            bob.read(b, 0, &mut out),
            Err(Error::Protection { .. })
        ));
        assert!(matches!(
            bob.write(b, 0, &[9]),
            Err(Error::Protection { .. })
        ));
    }

    #[test]
    fn read_only_blocks_reject_writes() {
        let (a, t) = setup();
        let d = CheckedMem::new(&a, &t, ProtectionDomain(3));
        let b = d.alloc(Perms::R).unwrap();
        let mut out = [0u8; 1];
        d.read(b, 0, &mut out).unwrap();
        assert!(matches!(d.write(b, 0, &[1]), Err(Error::Protection { .. })));
    }

    #[test]
    fn exec_permission_checked() {
        let (a, t) = setup();
        let b = a.alloc().unwrap();
        t.grant(b, ProtectionDomain(4), Perms::RX).unwrap();
        t.check(b, ProtectionDomain(4), false, true).unwrap();
        t.grant(b, ProtectionDomain(4), Perms::RW).unwrap();
        assert!(t.check(b, ProtectionDomain(4), false, true).is_err());
    }

    #[test]
    fn kernel_bypasses() {
        let (a, t) = setup();
        let d = CheckedMem::new(&a, &t, ProtectionDomain(5));
        let b = d.alloc(Perms::NONE).unwrap();
        let k = CheckedMem::new(&a, &t, KERNEL);
        k.write(b, 0, &[7]).unwrap();
        let mut out = [0u8; 1];
        k.read(b, 0, &mut out).unwrap();
        assert_eq!(out, [7]);
    }

    #[test]
    fn revoke_cuts_access() {
        let (a, t) = setup();
        let d = CheckedMem::new(&a, &t, ProtectionDomain(6));
        let b = d.alloc(Perms::RW).unwrap();
        t.revoke(b).unwrap();
        let mut out = [0u8; 1];
        assert!(d.read(b, 0, &mut out).is_err());
    }

    #[test]
    fn cannot_free_foreign_block() {
        let (a, t) = setup();
        let alice = CheckedMem::new(&a, &t, ProtectionDomain(1));
        let bob = CheckedMem::new(&a, &t, ProtectionDomain(2));
        let b = alice.alloc(Perms::RW).unwrap();
        assert!(bob.free(b).is_err());
        alice.free(b).unwrap();
    }

    #[test]
    fn free_owner_check_is_atomic() {
        let (a, t) = setup();
        let alice = CheckedMem::new(&a, &t, ProtectionDomain(1));
        let b = alice.alloc(Perms::RW).unwrap();
        // Ownership transfers (kernel op) between alice's last access
        // and her stale free: the conditional revoke must refuse
        // instead of clobbering bob's grant.
        t.grant(b, ProtectionDomain(2), Perms::RW).unwrap();
        assert!(matches!(alice.free(b), Err(Error::Protection { .. })));
        assert_eq!(t.lookup(b).unwrap().0, ProtectionDomain(2), "grant survived stale free");
        let bob = CheckedMem::new(&a, &t, ProtectionDomain(2));
        bob.free(b).unwrap();
    }

    #[test]
    fn grant_revoke_racing_checked_access_stress() {
        let a = BlockAllocator::new(4096, 64).unwrap();
        let t = ProtectionTable::new(64);
        let blocks: Vec<BlockId> = (0..4).map(|_| a.alloc().unwrap()).collect();
        for &b in &blocks {
            a.write(b, 0, &[0xAB; 8]).unwrap();
        }
        const D: ProtectionDomain = ProtectionDomain(7);
        let live = AtomicU64::new(3);
        let oks = AtomicU64::new(0);
        let denies = AtomicU64::new(0);
        std::thread::scope(|s| {
            // The "kernel" flips each block between granted-to-D and
            // revoked for as long as any reader is still hammering
            // checked accesses — the race spans the readers' whole
            // workload.
            s.spawn(|| {
                let mut i = 0u64;
                while live.load(Ordering::Acquire) > 0 {
                    let b = blocks[((i >> 1) as usize) % blocks.len()];
                    if i & 1 == 0 {
                        t.grant(b, D, Perms::RW).unwrap();
                    } else {
                        t.revoke(b).unwrap();
                    }
                    i += 1;
                }
            });
            for _ in 0..3 {
                s.spawn(|| {
                    let mem = CheckedMem::new(&a, &t, D);
                    let mut buf = [0u8; 8];
                    for _ in 0..2_000 {
                        for &b in &blocks {
                            // A racing access must land on exactly one
                            // of the two programmed states — the packed
                            // word moves owner and perms together.
                            match mem.read(b, 0, &mut buf) {
                                Ok(()) => {
                                    assert_eq!(buf, [0xAB; 8]);
                                    oks.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(Error::Protection { domain, .. }) => {
                                    assert_eq!(domain, D.0);
                                    denies.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(e) => panic!("unexpected error under race: {e:?}"),
                            }
                            let (owner, perms) = t.lookup(b).unwrap();
                            assert!(
                                (owner == D && perms == Perms::RW)
                                    || (owner == KERNEL && perms == Perms::NONE),
                                "torn protection word: owner {owner:?} perms {perms:?}"
                            );
                        }
                    }
                    live.fetch_sub(1, Ordering::Release);
                });
            }
        });
        assert!(oks.load(Ordering::Relaxed) > 0, "race never saw a granted window");
        assert!(denies.load(Ordering::Relaxed) > 0, "race never saw a revoked window");
    }

    #[test]
    fn prop_isolation_between_random_domains() {
        forall(30, |g| {
            let (a, t) = setup();
            let n_domains = g.usize_in(2, 6) as u16;
            let mut owned: Vec<(BlockId, u16)> = Vec::new();
            for _ in 0..g.usize_in(1, 40) {
                let dom = 1 + g.usize_in(0, (n_domains - 2) as usize) as u16;
                let view = CheckedMem::new(&a, &t, ProtectionDomain(dom));
                if let Ok(b) = view.alloc(Perms::RW) {
                    owned.push((b, dom));
                }
            }
            // Every block is accessible to its owner and nobody else.
            for &(b, dom) in &owned {
                let mut buf = [0u8; 1];
                for d in 1..=n_domains {
                    let view = CheckedMem::new(&a, &t, ProtectionDomain(d));
                    let r = view.read(b, 0, &mut buf);
                    assert_eq!(r.is_ok(), d == dom, "block {b:?} domain {d} owner {dom}");
                }
            }
        });
    }
}
