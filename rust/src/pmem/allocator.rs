//! The fixed-size block allocator (the paper's §3 OS memory manager).

use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::pmem::alloc_trait::{AllocStats, BlockAlloc};
use crate::pmem::arena::Arena;
use crate::pmem::epoch::ArenaEpoch;
use crate::pmem::BlockId;

struct Inner {
    /// LIFO free list (freshly freed blocks are reused first — warm in
    /// cache, the policy a real block-grained OS allocator would use).
    free: Vec<u32>,
    /// One bit per block: currently allocated?
    live: Vec<u64>,
    stats: AllocStats,
}

impl Inner {
    #[inline]
    fn is_live(&self, id: u32) -> bool {
        (self.live[(id / 64) as usize] >> (id % 64)) & 1 == 1
    }
    #[inline]
    fn set_live(&mut self, id: u32, v: bool) {
        let (w, b) = ((id / 64) as usize, id % 64);
        if v {
            self.live[w] |= 1 << b;
        } else {
            self.live[w] &= !(1 << b);
        }
    }
}

/// Fixed-size physical block allocator over one stable arena.
///
/// Thread-safe: the free list is behind a mutex; block *data* access is
/// lock-free because each live block is exclusively owned by its
/// allocating data structure (the crate-internal raw APIs uphold this).
pub struct BlockAllocator {
    arena: Arena,
    inner: Mutex<Inner>,
    epoch: ArenaEpoch,
}

impl BlockAllocator {
    /// Create a pool of `capacity_blocks` blocks of `block_size` bytes.
    ///
    /// `block_size` must be a power of two ≥ 256 (the paper uses 32 KB;
    /// the ablation sweeps 8–128 KB).
    pub fn new(block_size: usize, capacity_blocks: usize) -> Result<Self> {
        let arena = Arena::new(block_size, capacity_blocks)?;
        // Free list initialized high→low so allocation order is 0,1,2,…
        let free: Vec<u32> = (0..capacity_blocks as u32).rev().collect();
        Ok(BlockAllocator {
            arena,
            inner: Mutex::new(Inner {
                free,
                live: vec![0u64; capacity_blocks.div_ceil(64)],
                stats: AllocStats::default(),
            }),
            epoch: ArenaEpoch::new(),
        })
    }

    /// Pool with the paper's 32 KB blocks covering `bytes` of memory.
    pub fn with_capacity_bytes(bytes: usize) -> Result<Self> {
        Self::new(crate::BLOCK_SIZE, bytes.div_ceil(crate::BLOCK_SIZE).max(1))
    }

    /// Allocate one (zero-initialized on first use) block.
    pub fn alloc(&self) -> Result<BlockId> {
        let mut g = self.inner.lock().unwrap();
        match g.free.pop() {
            Some(id) => {
                g.set_live(id, true);
                g.stats.allocated += 1;
                g.stats.total_allocs += 1;
                g.stats.peak = g.stats.peak.max(g.stats.allocated);
                Ok(BlockId(id))
            }
            None => {
                g.stats.failed_allocs += 1;
                Err(Error::OutOfMemory {
                    requested: 1,
                    free: 0,
                    capacity: self.arena.capacity(),
                })
            }
        }
    }

    /// Allocate `n` blocks (all-or-nothing).
    pub fn alloc_many(&self, n: usize) -> Result<Vec<BlockId>> {
        let mut g = self.inner.lock().unwrap();
        if g.free.len() < n {
            g.stats.failed_allocs += 1;
            return Err(Error::OutOfMemory {
                requested: n,
                free: g.free.len(),
                capacity: self.arena.capacity(),
            });
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let id = g.free.pop().unwrap();
            g.set_live(id, true);
            out.push(BlockId(id));
        }
        g.stats.allocated += n;
        g.stats.total_allocs += n as u64;
        g.stats.peak = g.stats.peak.max(g.stats.allocated);
        Ok(out)
    }

    /// Allocate a block and zero its contents.
    pub fn alloc_zeroed(&self) -> Result<BlockId> {
        let id = self.alloc()?;
        // SAFETY: id is live and exclusively ours until returned.
        unsafe { self.arena.zero_block(id) };
        Ok(id)
    }

    /// Allocate the lowest-id free block in `[lo, hi)` — the placement
    /// hook background compaction uses (see [`BlockAlloc::alloc_in_span`]).
    /// Unlike the LIFO `alloc`, this scans the live bitmap, so it pays
    /// O(capacity/64) under the lock plus an O(free) free-list patch;
    /// fine for the daemon's paced moves.
    pub fn alloc_in_span(&self, lo: usize, hi: usize) -> Result<BlockId> {
        let hi = hi.min(self.arena.capacity());
        let mut g = self.inner.lock().unwrap();
        let mut found = None;
        for w in lo / 64..hi.div_ceil(64) {
            // Free bits of this word, masked to [lo, hi). Bits past the
            // capacity are never set in `live`, but hi <= capacity masks
            // them out of `!live` anyway.
            let free_bits = !g.live[w] & crate::pmem::alloc_trait::span_word_mask(w, lo, hi);
            if free_bits != 0 {
                found = Some(w * 64 + free_bits.trailing_zeros() as usize);
                break;
            }
        }
        match found {
            Some(id) => {
                let pos = g
                    .free
                    .iter()
                    .position(|&x| x as usize == id)
                    .expect("free list and live bitmap must agree");
                g.free.swap_remove(pos);
                g.set_live(id as u32, true);
                g.stats.allocated += 1;
                g.stats.total_allocs += 1;
                g.stats.peak = g.stats.peak.max(g.stats.allocated);
                Ok(BlockId(id as u32))
            }
            None => Err(Error::OutOfMemory {
                // A full span is an *expected* probe miss for the
                // compactor ("is there a free block below this leaf?"),
                // not pool exhaustion — don't count a failed alloc.
                requested: 1,
                free: 0,
                capacity: self.arena.capacity(),
            }),
        }
    }

    /// Snapshot the live bitmap (bit set = allocated); see
    /// [`BlockAlloc::live_snapshot`].
    pub fn live_snapshot(&self, out: &mut Vec<u64>) {
        let g = self.inner.lock().unwrap();
        out.clear();
        out.extend_from_slice(&g.live);
    }

    /// Return a block to the pool. Double frees are rejected.
    pub fn free(&self, id: BlockId) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if id.0 as usize >= self.arena.capacity() || !g.is_live(id.0) {
            return Err(Error::InvalidBlock(id));
        }
        g.set_live(id.0, false);
        g.free.push(id.0);
        g.stats.allocated -= 1;
        g.stats.total_frees += 1;
        Ok(())
    }

    /// Block size in bytes.
    #[inline]
    pub fn block_size(&self) -> usize {
        self.arena.block_size()
    }

    /// Pool capacity in blocks.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.arena.capacity()
    }

    /// Free blocks remaining.
    pub fn free_blocks(&self) -> usize {
        self.inner.lock().unwrap().free.len()
    }

    /// Snapshot of allocation statistics (reclamation health — limbo
    /// depth, reclaim latency — mirrored from the pool's epoch).
    pub fn stats(&self) -> AllocStats {
        let mut s = self.inner.lock().unwrap().stats;
        self.epoch.fill_alloc_stats(&mut s);
        s
    }

    /// Is `id` currently allocated?
    pub fn is_live(&self, id: BlockId) -> bool {
        (id.0 as usize) < self.arena.capacity() && self.inner.lock().unwrap().is_live(id.0)
    }

    /// Raw pointer to the block's first byte.
    ///
    /// # Safety
    /// `id` must be live and the caller must uphold exclusive ownership
    /// of the block's data (no two holders of the same live block).
    #[inline]
    pub(crate) unsafe fn block_ptr(&self, id: BlockId) -> *mut u8 {
        self.arena.block_ptr(id)
    }

    /// Copy bytes into a block (safe, bounds-checked API).
    pub fn write(&self, id: BlockId, offset: usize, data: &[u8]) -> Result<()> {
        self.check(id, offset, data.len())?;
        // SAFETY: span checked; exclusive ownership per contract.
        unsafe { self.arena.copy_in(id, offset, data) };
        Ok(())
    }

    /// Copy bytes out of a block (safe, bounds-checked API).
    pub fn read(&self, id: BlockId, offset: usize, out: &mut [u8]) -> Result<()> {
        self.check(id, offset, out.len())?;
        // SAFETY: span checked.
        unsafe { self.arena.copy_out(id, offset, out) };
        Ok(())
    }

    /// The pool's shared relocation epoch (see [`crate::pmem::epoch`]).
    #[inline]
    pub fn epoch(&self) -> &ArenaEpoch {
        &self.epoch
    }

    fn check(&self, id: BlockId, offset: usize, len: usize) -> Result<()> {
        if !self.is_live(id) {
            return Err(Error::InvalidBlock(id));
        }
        self.arena.check_span(offset, len)
    }
}

/// The trait impl delegates to the inherent methods, so concrete users
/// keep their API and generic users (`TreeArray<T, A>`, `SplitStack<A>`,
/// the workloads) see the same behaviour through [`BlockAlloc`].
impl BlockAlloc for BlockAllocator {
    fn alloc(&self) -> Result<BlockId> {
        BlockAllocator::alloc(self)
    }

    fn alloc_many(&self, n: usize) -> Result<Vec<BlockId>> {
        BlockAllocator::alloc_many(self, n)
    }

    fn alloc_zeroed(&self) -> Result<BlockId> {
        BlockAllocator::alloc_zeroed(self)
    }

    fn alloc_in_span(&self, lo: usize, hi: usize) -> Result<BlockId> {
        BlockAllocator::alloc_in_span(self, lo, hi)
    }

    fn live_snapshot(&self, out: &mut Vec<u64>) {
        BlockAllocator::live_snapshot(self, out)
    }

    fn free(&self, id: BlockId) -> Result<()> {
        BlockAllocator::free(self, id)
    }

    fn block_size(&self) -> usize {
        BlockAllocator::block_size(self)
    }

    fn capacity(&self) -> usize {
        BlockAllocator::capacity(self)
    }

    fn free_blocks(&self) -> usize {
        BlockAllocator::free_blocks(self)
    }

    fn is_live(&self, id: BlockId) -> bool {
        BlockAllocator::is_live(self, id)
    }

    fn stats(&self) -> AllocStats {
        BlockAllocator::stats(self)
    }

    fn epoch(&self) -> &ArenaEpoch {
        BlockAllocator::epoch(self)
    }

    unsafe fn block_ptr(&self, id: BlockId) -> *mut u8 {
        BlockAllocator::block_ptr(self, id)
    }

    fn write(&self, id: BlockId, offset: usize, data: &[u8]) -> Result<()> {
        BlockAllocator::write(self, id, offset, data)
    }

    fn read(&self, id: BlockId, offset: usize, out: &mut [u8]) -> Result<()> {
        BlockAllocator::read(self, id, offset, out)
    }
}

impl std::fmt::Debug for BlockAllocator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "BlockAllocator {{ block_size: {}, capacity: {}, allocated: {} }}",
            self.arena.block_size(),
            self.arena.capacity(),
            s.allocated
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn alloc_free_roundtrip() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        let b = a.alloc().unwrap();
        assert!(a.is_live(b));
        a.free(b).unwrap();
        assert!(!a.is_live(b));
    }

    #[test]
    fn exhaustion_errors() {
        let a = BlockAllocator::new(4096, 2).unwrap();
        let _b1 = a.alloc().unwrap();
        let _b2 = a.alloc().unwrap();
        assert!(matches!(a.alloc(), Err(Error::OutOfMemory { .. })));
        assert_eq!(a.stats().failed_allocs, 1);
    }

    #[test]
    fn double_free_rejected() {
        let a = BlockAllocator::new(4096, 2).unwrap();
        let b = a.alloc().unwrap();
        a.free(b).unwrap();
        assert!(matches!(a.free(b), Err(Error::InvalidBlock(_))));
    }

    #[test]
    fn foreign_block_rejected() {
        let a = BlockAllocator::new(4096, 2).unwrap();
        assert!(matches!(a.free(BlockId(99)), Err(Error::InvalidBlock(_))));
    }

    #[test]
    fn alloc_many_all_or_nothing() {
        let a = BlockAllocator::new(4096, 4).unwrap();
        let _one = a.alloc().unwrap();
        assert!(a.alloc_many(4).is_err());
        assert_eq!(a.free_blocks(), 3); // nothing leaked by the failure
        let three = a.alloc_many(3).unwrap();
        assert_eq!(three.len(), 3);
    }

    #[test]
    fn write_read_roundtrip() {
        let a = BlockAllocator::new(4096, 2).unwrap();
        let b = a.alloc().unwrap();
        a.write(b, 100, &[1, 2, 3, 4]).unwrap();
        let mut out = [0u8; 4];
        a.read(b, 100, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3, 4]);
    }

    #[test]
    fn write_oob_rejected() {
        let a = BlockAllocator::new(4096, 2).unwrap();
        let b = a.alloc().unwrap();
        assert!(a.write(b, 4093, &[1, 2, 3, 4]).is_err());
        // Offsets that would wrap the address computation are rejected
        // by the overflow-safe span check, not UB.
        assert!(a.write(b, usize::MAX - 2, &[1, 2, 3, 4]).is_err());
        let mut out = [0u8; 4];
        assert!(a.read(b, usize::MAX - 2, &mut out).is_err());
    }

    #[test]
    fn invalid_block_size_rejected() {
        assert!(BlockAllocator::new(3000, 4).is_err());
        assert!(BlockAllocator::new(128, 4).is_err());
        assert!(BlockAllocator::new(4096, 0).is_err());
    }

    #[test]
    fn peak_tracks_high_water() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        let bs = a.alloc_many(5).unwrap();
        for b in &bs[..3] {
            a.free(*b).unwrap();
        }
        let _x = a.alloc().unwrap();
        assert_eq!(a.stats().peak, 5);
        assert_eq!(a.stats().allocated, 3);
    }

    #[test]
    fn blocks_are_zeroed_initially() {
        let a = BlockAllocator::new(4096, 2).unwrap();
        let b = a.alloc().unwrap();
        let mut out = [0xFFu8; 16];
        a.read(b, 0, &mut out).unwrap();
        assert_eq!(out, [0u8; 16]);
    }

    #[test]
    fn prop_alloc_free_conservation() {
        forall(50, |g| {
            let cap = g.usize_in(1, 64);
            let a = BlockAllocator::new(4096, cap).unwrap();
            let mut live = Vec::new();
            for _ in 0..g.usize_in(0, 200) {
                if g.bool(0.5) && !live.is_empty() {
                    let i = g.usize_in(0, live.len() - 1);
                    let b: BlockId = live.swap_remove(i);
                    a.free(b).unwrap();
                } else if let Ok(b) = a.alloc() {
                    live.push(b);
                }
                // Invariant: allocated + free == capacity, always.
                assert_eq!(a.stats().allocated + a.free_blocks(), cap);
                assert_eq!(a.stats().allocated, live.len());
            }
        });
    }

    #[test]
    fn prop_distinct_blocks_never_alias() {
        forall(25, |g| {
            let cap = g.usize_in(2, 32);
            let a = BlockAllocator::new(4096, cap).unwrap();
            let blocks = a.alloc_many(cap).unwrap();
            // Write a distinct pattern to each block; verify no bleed.
            for (i, b) in blocks.iter().enumerate() {
                a.write(*b, 0, &[i as u8; 64]).unwrap();
            }
            for (i, b) in blocks.iter().enumerate() {
                let mut out = [0u8; 64];
                a.read(*b, 0, &mut out).unwrap();
                assert_eq!(out, [i as u8; 64]);
            }
        });
    }

    #[test]
    fn alloc_in_span_takes_lowest_in_range() {
        let a = BlockAllocator::new(4096, 130).unwrap();
        let all = a.alloc_many(130).unwrap();
        // Free blocks 3, 70 and 128 (spanning three bitmap words).
        for &i in &[3usize, 70, 128] {
            a.free(all[i]).unwrap();
        }
        assert_eq!(a.alloc_in_span(0, 130).unwrap(), BlockId(3));
        assert_eq!(a.alloc_in_span(64, 130).unwrap(), BlockId(70));
        assert!(a.alloc_in_span(0, 128).is_err(), "3 and 70 retaken");
        assert_eq!(a.alloc_in_span(0, 130).unwrap(), BlockId(128));
        assert!(a.alloc_in_span(0, 130).is_err(), "pool full again");
        assert_eq!(a.stats().allocated, 130, "span allocs must be counted");
        for b in all {
            if a.is_live(b) {
                a.free(b).unwrap();
            }
        }
    }

    #[test]
    fn live_snapshot_matches_is_live() {
        let a = BlockAllocator::new(4096, 70).unwrap();
        let blocks = a.alloc_many(70).unwrap();
        for b in blocks.iter().skip(1).step_by(3) {
            a.free(*b).unwrap();
        }
        let mut snap = Vec::new();
        a.live_snapshot(&mut snap);
        assert_eq!(snap.len(), 2);
        for i in 0..70u32 {
            let bit = (snap[(i / 64) as usize] >> (i % 64)) & 1 == 1;
            assert_eq!(bit, a.is_live(BlockId(i)), "block {i}");
        }
        // Bits past the capacity stay clear.
        assert_eq!(snap[1] >> 6, 0);
    }

    #[test]
    fn concurrent_alloc_free() {
        let a = std::sync::Arc::new(BlockAllocator::new(4096, 1024).unwrap());
        let mut handles = Vec::new();
        for t in 0..8 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                let mut mine = Vec::new();
                for i in 0..200 {
                    if (i + t) % 3 == 0 && !mine.is_empty() {
                        a.free(mine.pop().unwrap()).unwrap();
                    } else if let Ok(b) = a.alloc() {
                        mine.push(b);
                    }
                }
                for b in mine {
                    a.free(b).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.stats().allocated, 0);
        assert_eq!(a.free_blocks(), 1024);
    }
}
