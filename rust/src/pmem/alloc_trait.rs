//! The allocator abstraction: every consumer of physical blocks (trees,
//! stacks, regions, workloads, the coordinator) is generic over
//! [`BlockAlloc`], so the paper's "OS memory manager" is a pluggable
//! policy. Three implementations ship:
//!
//! * [`crate::pmem::BlockAllocator`] — the original single-mutex LIFO
//!   free list (simple, strictly ordered, the §3 baseline).
//! * [`crate::pmem::ShardedAllocator`] — per-shard atomic free bitmaps
//!   with cross-shard stealing, for multi-threaded workloads where one
//!   lock would serialize the hot path.
//! * [`crate::pmem::TwoLevelAllocator`] — the llfree-style two-level
//!   design: a lower level of 512-block subtrees with cache-line
//!   bitfields, an upper level of packed subtree roots with CPU-local
//!   subtree *reservation* (common path = one CAS, no search) and
//!   NUMA-aware subtree binding.
//!
//! # Placement and NUMA
//!
//! The portable trait deliberately has **no node hint on `alloc`** —
//! most callers (trees, stacks, workloads) don't know or care where a
//! block lands, and a hint every implementation must ignore is worse
//! than none. Placement enters through two narrower doors:
//!
//! * **Policy-directed placement** is `alloc_in_span`: the daemon
//!   ([`crate::mmd`]) chooses *where* by choosing the span. The spans
//!   come from `shard_spans`, which is also the **placement
//!   granularity contract**: each reported span is the unit the
//!   allocator places within (the whole pool for the mutex baseline,
//!   a lock shard for the sharded allocator, a 512-block subtree for
//!   the two-level allocator), so occupancy telemetry, compaction and
//!   rebalancing automatically operate at the allocator's own
//!   granularity.
//! * **Topology-directed placement** is allocator-specific surface:
//!   [`crate::pmem::TwoLevelAllocator::alloc_on`] /
//!   [`TwoLevelAllocator::alloc_core_on`](crate::pmem::TwoLevelAllocator::alloc_core_on)
//!   take a NUMA-node hint and prefer same-node subtrees (stealing
//!   within the node before crossing it). Code that wants node-aware
//!   placement takes the concrete type; code that doesn't stays on the
//!   trait.

use crate::error::Result;
use crate::pmem::epoch::ArenaEpoch;
use crate::pmem::BlockId;

/// Allocation statistics (also the fragmentation story of §3: external
/// fragmentation is impossible by construction — every free block can
/// satisfy every request — so the classical numbers are counts; the
/// *placement* fragmentation a compactor cares about lives in
/// [`crate::mmd::FragSampler`]). The reclamation fields mirror the
/// pool's [`crate::pmem::ArenaEpoch`] so `stats()` alone shows
/// reclamation health without constructing a daemon.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocStats {
    /// Blocks currently allocated.
    pub allocated: usize,
    /// High-water mark of simultaneously allocated blocks.
    pub peak: usize,
    /// Total successful `alloc` calls over the allocator's lifetime.
    pub total_allocs: u64,
    /// Total successful `free` calls.
    pub total_frees: u64,
    /// Failed allocations (pool exhausted).
    pub failed_allocs: u64,
    /// Blocks currently parked in the epoch's limbo list (retired by a
    /// concurrent relocation/eviction, waiting for readers to quiesce).
    pub limbo: usize,
    /// Blocks retired into limbo over the pool's lifetime.
    pub retired: u64,
    /// Retired blocks returned to the pool so far.
    pub reclaimed: u64,
    /// Cumulative epochs reclaimed blocks waited in limbo (divide by
    /// `reclaimed` for the mean reclaim latency in epochs).
    pub reclaim_lag: u64,
}

impl AllocStats {
    /// Mean epochs a reclaimed block waited in limbo (0 when nothing
    /// has been reclaimed yet).
    pub fn mean_reclaim_lag(&self) -> f64 {
        if self.reclaimed == 0 {
            0.0
        } else {
            self.reclaim_lag as f64 / self.reclaimed as f64
        }
    }
}

/// Mask of the bits of bitmap word `w` (block ids `w*64 .. w*64+64`)
/// that fall inside the block-id span `[lo, hi)`. The one copy of the
/// boundary arithmetic both allocators' `alloc_in_span` scans share;
/// callers iterate `w` over `lo / 64 .. hi.div_ceil(64)` (so
/// `w * 64 < hi` always holds here).
pub(crate) fn span_word_mask(w: usize, lo: usize, hi: usize) -> u64 {
    let first = w * 64;
    let mut mask = !0u64;
    if lo > first {
        mask &= !0u64 << (lo - first);
    }
    if hi - first < 64 {
        mask &= (1u64 << (hi - first)) - 1;
    }
    mask
}

/// Contention counters for concurrent allocators. The mutex baseline
/// reports zeros; [`crate::pmem::ShardedAllocator`] counts the events
/// its scaling story hinges on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ContentionStats {
    /// Allocations served from a non-home shard (the local shard was
    /// dry and the block was stolen from a neighbor).
    pub steals: u64,
    /// Cursor refills: full rescans of a shard's bitmap after the
    /// forward scan from the cursor hint found nothing.
    pub refills: u64,
    /// Compare-and-swap attempts that lost a race and retried.
    pub cas_retries: u64,
}

/// A fixed-size physical block allocator over one stable arena
/// (the paper's §3 OS memory manager).
///
/// # Contract
///
/// * Blocks are `block_size()` bytes, zero-initialized on first use.
/// * `alloc`/`alloc_many`/`free` are safe to call from many threads.
/// * A live block is exclusively owned by its allocating holder; the
///   allocator never hands one block to two owners.
/// * `free` rejects double frees and foreign ids.
/// * `alloc_many` is all-or-nothing: on failure nothing is leaked.
pub trait BlockAlloc: Send + Sync {
    /// Allocate one block.
    fn alloc(&self) -> Result<BlockId>;

    /// Allocate `n` blocks (all-or-nothing).
    fn alloc_many(&self, n: usize) -> Result<Vec<BlockId>>;

    /// Allocate a block and zero its contents (freed blocks may hold
    /// stale data; fresh arena blocks are already zero).
    fn alloc_zeroed(&self) -> Result<BlockId>;

    /// Allocate the **lowest-id** free block whose id lies in
    /// `[lo, hi)` (`hi` is clamped to the capacity). This is the
    /// placement hook compaction and rebalancing policies use
    /// ([`crate::mmd`]): ordinary `alloc` optimizes for speed and
    /// thread affinity, `alloc_in_span` for *where* the block lands —
    /// sinking relocated leaves toward the bottom of the pool (or into
    /// a chosen shard's range) so free space consolidates. Slower than
    /// `alloc` (a bitmap scan); meant for the daemon's paced moves, not
    /// the hot path.
    fn alloc_in_span(&self, lo: usize, hi: usize) -> Result<BlockId>;

    /// The block-id span `[lo, hi)` of each placement unit.
    /// Single-shard designs (the mutex baseline) report one span
    /// covering the pool; [`crate::pmem::ShardedAllocator`] reports its
    /// per-shard bitmap ranges; [`crate::pmem::TwoLevelAllocator`]
    /// reports its 512-block subtrees — so fragmentation telemetry and
    /// rebalancing ([`crate::mmd`]) reason at whatever granularity the
    /// allocator actually places at.
    fn shard_spans(&self) -> Vec<(usize, usize)> {
        vec![(0, self.capacity())]
    }

    /// Snapshot the pool's live bitmap into `out` (bit set = block
    /// allocated; one `u64` per 64 blocks, `capacity.div_ceil(64)`
    /// words, bits past the capacity zero). The fragmentation-telemetry
    /// primitive: cheap (atomic word loads, or one short lock for the
    /// mutex baseline) and safe to call while allocation proceeds — the
    /// snapshot is a consistent-enough sample, not a fence.
    fn live_snapshot(&self, out: &mut Vec<u64>);

    /// Return a block to the pool. Double frees are rejected.
    fn free(&self, id: BlockId) -> Result<()>;

    /// Block size in bytes.
    fn block_size(&self) -> usize;

    /// Pool capacity in blocks.
    fn capacity(&self) -> usize;

    /// Free blocks remaining.
    fn free_blocks(&self) -> usize;

    /// Is `id` currently allocated?
    fn is_live(&self, id: BlockId) -> bool;

    /// Snapshot of allocation statistics.
    fn stats(&self) -> AllocStats;

    /// Snapshot of contention counters (zeros for uncontended designs).
    fn contention(&self) -> ContentionStats {
        ContentionStats::default()
    }

    /// The pool's shared relocation epoch: bumped on *every* block move
    /// in this pool (tree leaf migration, [`crate::pmem::Relocator`],
    /// [`crate::pmem::SwapPool`]), so translation caches over any
    /// structure in the arena can revalidate with one load, and
    /// concurrent readers can coordinate deferred reclamation. See
    /// [`crate::pmem::epoch`].
    fn epoch(&self) -> &ArenaEpoch;

    /// Raw pointer to the block's first byte.
    ///
    /// # Safety
    /// `id` must be live and the caller must uphold exclusive ownership
    /// of the block's data (no two holders of the same live block).
    unsafe fn block_ptr(&self, id: BlockId) -> *mut u8;

    /// Copy bytes into a block (safe, bounds-checked API).
    fn write(&self, id: BlockId, offset: usize, data: &[u8]) -> Result<()>;

    /// Copy bytes out of a block (safe, bounds-checked API).
    fn read(&self, id: BlockId, offset: usize, out: &mut [u8]) -> Result<()>;
}

#[cfg(test)]
mod tests {
    use super::span_word_mask;

    #[test]
    fn span_word_mask_edges() {
        // Full word strictly inside the span.
        assert_eq!(span_word_mask(1, 0, 256), !0u64);
        // lo inside the word: bits below lo cleared.
        assert_eq!(span_word_mask(0, 3, 256), !0u64 << 3);
        // hi inside the word: bits at/above hi cleared.
        assert_eq!(span_word_mask(0, 0, 5), (1u64 << 5) - 1);
        // lo and hi inside the SAME word: both masks apply.
        assert_eq!(span_word_mask(0, 3, 5), 0b11000);
        // hi exactly at the word boundary keeps the full word.
        assert_eq!(span_word_mask(0, 0, 64), !0u64);
        // Degenerate span within one word: no bits.
        assert_eq!(span_word_mask(0, 5, 5), 0);
    }
}
