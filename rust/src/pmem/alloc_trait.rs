//! The allocator abstraction: every consumer of physical blocks (trees,
//! stacks, regions, workloads, the coordinator) is generic over
//! [`BlockAlloc`], so the paper's "OS memory manager" is a pluggable
//! policy. Two implementations ship:
//!
//! * [`crate::pmem::BlockAllocator`] — the original single-mutex LIFO
//!   free list (simple, strictly ordered, the §3 baseline).
//! * [`crate::pmem::ShardedAllocator`] — per-shard atomic free bitmaps
//!   with cross-shard stealing (llfree-style), for multi-threaded
//!   workloads where one lock would serialize the hot path.

use crate::error::Result;
use crate::pmem::epoch::ArenaEpoch;
use crate::pmem::BlockId;

/// Allocation statistics (also the fragmentation story of §3: external
/// fragmentation is impossible by construction — every free block can
/// satisfy every request — so the only interesting numbers are counts).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocStats {
    /// Blocks currently allocated.
    pub allocated: usize,
    /// High-water mark of simultaneously allocated blocks.
    pub peak: usize,
    /// Total successful `alloc` calls over the allocator's lifetime.
    pub total_allocs: u64,
    /// Total successful `free` calls.
    pub total_frees: u64,
    /// Failed allocations (pool exhausted).
    pub failed_allocs: u64,
}

/// Contention counters for concurrent allocators. The mutex baseline
/// reports zeros; [`crate::pmem::ShardedAllocator`] counts the events
/// its scaling story hinges on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ContentionStats {
    /// Allocations served from a non-home shard (the local shard was
    /// dry and the block was stolen from a neighbor).
    pub steals: u64,
    /// Cursor refills: full rescans of a shard's bitmap after the
    /// forward scan from the cursor hint found nothing.
    pub refills: u64,
    /// Compare-and-swap attempts that lost a race and retried.
    pub cas_retries: u64,
}

/// A fixed-size physical block allocator over one stable arena
/// (the paper's §3 OS memory manager).
///
/// # Contract
///
/// * Blocks are `block_size()` bytes, zero-initialized on first use.
/// * `alloc`/`alloc_many`/`free` are safe to call from many threads.
/// * A live block is exclusively owned by its allocating holder; the
///   allocator never hands one block to two owners.
/// * `free` rejects double frees and foreign ids.
/// * `alloc_many` is all-or-nothing: on failure nothing is leaked.
pub trait BlockAlloc: Send + Sync {
    /// Allocate one block.
    fn alloc(&self) -> Result<BlockId>;

    /// Allocate `n` blocks (all-or-nothing).
    fn alloc_many(&self, n: usize) -> Result<Vec<BlockId>>;

    /// Allocate a block and zero its contents (freed blocks may hold
    /// stale data; fresh arena blocks are already zero).
    fn alloc_zeroed(&self) -> Result<BlockId>;

    /// Return a block to the pool. Double frees are rejected.
    fn free(&self, id: BlockId) -> Result<()>;

    /// Block size in bytes.
    fn block_size(&self) -> usize;

    /// Pool capacity in blocks.
    fn capacity(&self) -> usize;

    /// Free blocks remaining.
    fn free_blocks(&self) -> usize;

    /// Is `id` currently allocated?
    fn is_live(&self, id: BlockId) -> bool;

    /// Snapshot of allocation statistics.
    fn stats(&self) -> AllocStats;

    /// Snapshot of contention counters (zeros for uncontended designs).
    fn contention(&self) -> ContentionStats {
        ContentionStats::default()
    }

    /// The pool's shared relocation epoch: bumped on *every* block move
    /// in this pool (tree leaf migration, [`crate::pmem::Relocator`],
    /// [`crate::pmem::SwapPool`]), so translation caches over any
    /// structure in the arena can revalidate with one load, and
    /// concurrent readers can coordinate deferred reclamation. See
    /// [`crate::pmem::epoch`].
    fn epoch(&self) -> &ArenaEpoch;

    /// Raw pointer to the block's first byte.
    ///
    /// # Safety
    /// `id` must be live and the caller must uphold exclusive ownership
    /// of the block's data (no two holders of the same live block).
    unsafe fn block_ptr(&self, id: BlockId) -> *mut u8;

    /// Copy bytes into a block (safe, bounds-checked API).
    fn write(&self, id: BlockId, offset: usize, data: &[u8]) -> Result<()>;

    /// Copy bytes out of a block (safe, bounds-checked API).
    fn read(&self, id: BlockId, offset: usize, out: &mut [u8]) -> Result<()>;
}
