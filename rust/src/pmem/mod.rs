//! Physical memory management without virtual memory (paper §3).
//!
//! The OS model of the paper: memory is segmented into fixed-size blocks
//! (32 KB in all experiments) which are the *minimum and maximum*
//! allocation unit — there is no abstraction of large contiguous regions,
//! and nothing is translated. "Physical addresses" here are offsets into a
//! single fixed arena, so a block's address never changes and arithmetic
//! on addresses is meaningful, exactly as on a machine without paging.
//!
//! * [`BlockAlloc`] — the allocator abstraction every consumer (trees,
//!   stacks, regions, workloads, coordinator) is generic over.
//! * [`BlockAllocator`] — the baseline fixed-block pool: one mutex, one
//!   LIFO free list.
//! * [`ShardedAllocator`] — the scalable pool: per-shard atomic free
//!   bitmaps with cross-shard stealing (lock-free hot path).
//! * [`TwoLevelAllocator`] — the llfree-style two-level pool: per-subtree
//!   cache-line bitfields under a packed array of subtree roots, with
//!   CPU-local subtree reservation and NUMA-aware placement (see
//!   [`twolevel`]).
//! * [`SlabPool`] — small-object slab classes carved inside single
//!   blocks (the `RbTree` node pool's backing; see [`slab`]).
//! * [`Region`] — a convenience view over a *logical* sequence of blocks
//!   (what a large `malloc` becomes in this world).
//! * [`ArenaEpoch`] — the pool's shared relocation epoch: one counter
//!   bumped by every block move (tree migration, [`Relocator`],
//!   [`SwapPool`]) that translation caches revalidate against, plus the
//!   quiescent-state deferred reclamation concurrent readers need (see
//!   [`epoch`]).
//! * [`TenantRegistry`] / [`QuotaAlloc`] — the multi-tenant policy
//!   layer: per-tenant block quotas with soft-pressure / hard-failure
//!   watermarks, charged at the allocator boundary, with per-tenant
//!   swap routing and degraded-state scoping in [`FaultQueue`] (see
//!   [`tenant`]).
//!
//! The [`crate::mmd`] daemon drives this layer in the background:
//! [`BlockAlloc::live_snapshot`] / [`BlockAlloc::shard_spans`] feed its
//! fragmentation telemetry, [`BlockAlloc::alloc_in_span`] gives its
//! compactor placement control, and [`SwapPool::evict_deferred`] is its
//! reader-safe eviction hook.

pub mod alloc_trait;
mod allocator;
mod arena;
mod block;
pub mod epoch;
pub mod faultq;
pub mod migrate;
pub mod protect;
mod region;
mod sharded;
pub mod slab;
pub mod swap;
pub mod tenant;
pub mod twolevel;

pub use alloc_trait::{AllocStats, BlockAlloc, ContentionStats};
pub use allocator::BlockAllocator;
pub use block::BlockId;
pub use epoch::{ArenaEpoch, EpochStats, ReaderSlot};
pub use faultq::{
    FaultQueue, FaultQueueConfig, FaultStats, LeafFaulter, PrefetchGate, SwapService, TenantFaulter,
};
pub use migrate::Relocator;
pub use protect::{CheckedMem, Perms, ProtectionDomain, ProtectionTable, KERNEL};
pub use region::Region;
pub use sharded::ShardedAllocator;
pub use slab::{SlabPool, SlabStats, SlotAddr};
pub use tenant::{
    QuotaAlloc, Tenant, TenantConfig, TenantRegistry, TenantSnapshot, DEFAULT_TENANT,
};
pub use twolevel::{PlacementStats, TwoLevelAllocator, SUBTREE_BLOCKS};
pub use swap::{FileBacking, SwapBacking, SwapPool, SwapSlot, SwapStats};
