//! Physical memory management without virtual memory (paper §3).
//!
//! The OS model of the paper: memory is segmented into fixed-size blocks
//! (32 KB in all experiments) which are the *minimum and maximum*
//! allocation unit — there is no abstraction of large contiguous regions,
//! and nothing is translated. "Physical addresses" here are offsets into a
//! single fixed arena, so a block's address never changes and arithmetic
//! on addresses is meaningful, exactly as on a machine without paging.
//!
//! * [`BlockAllocator`] — the fixed-block pool with a LIFO free list.
//! * [`Region`] — a convenience view over a *logical* sequence of blocks
//!   (what a large `malloc` becomes in this world).

mod allocator;
mod block;
pub mod migrate;
pub mod protect;
mod region;
pub mod swap;

pub use allocator::{AllocStats, BlockAllocator};
pub use block::BlockId;
pub use migrate::Relocator;
pub use protect::{CheckedMem, Perms, ProtectionDomain, ProtectionTable, KERNEL};
pub use region::Region;
pub use swap::SwapPool;
