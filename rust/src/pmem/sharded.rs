//! Sharded lock-free block allocator (llfree-rs idiom).
//!
//! The single-mutex [`crate::pmem::BlockAllocator`] serializes every
//! alloc/free — exactly where the paper argues software memory
//! management must be cheap (§3). This allocator removes the lock:
//!
//! * The arena's free state is one **atomic bitmap** (one bit per block,
//!   1 = free), partitioned into per-shard word ranges.
//! * Threads get **shard affinity** by thread-id hash, so uncontended
//!   allocation touches only the home shard's words (word-level CAS,
//!   no global state).
//! * Each shard keeps a **cursor** hint; a full rescan after the hint
//!   runs dry is counted as a `refill`.
//! * When a shard is empty the thread **steals** from neighbor shards
//!   (next-shard order). `alloc_many` steals in word-granular batches:
//!   up to 64 blocks per CAS.
//! * Frees return a block to its home word, so shards replenish in
//!   place and stolen capacity drifts back over time.
//!
//! Per-shard contention counters (steals, refills, CAS retries)
//! aggregate into [`ContentionStats`] next to the usual [`AllocStats`].

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::error::{Error, Result};
use crate::pmem::alloc_trait::{AllocStats, BlockAlloc, ContentionStats};
use crate::pmem::arena::Arena;
use crate::pmem::epoch::ArenaEpoch;
use crate::pmem::BlockId;

/// Monotonic thread token source for shard affinity.
static NEXT_THREAD_TOKEN: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread token, assigned on first allocator use by this thread.
    static THREAD_TOKEN: usize = NEXT_THREAD_TOKEN.fetch_add(1, Ordering::Relaxed);
}

/// This thread's allocator-affinity token (shared by every concurrent
/// allocator in the crate so a thread keeps one identity across pools).
#[inline]
pub(crate) fn thread_token() -> usize {
    THREAD_TOKEN.with(|t| *t)
}

/// splitmix64 finalizer: spreads consecutive thread tokens across shards.
#[inline]
pub(crate) fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One shard: a word range of the global bitmap plus local counters.
struct Shard {
    /// First bitmap word owned by this shard (inclusive).
    lo: usize,
    /// One past the last bitmap word owned by this shard.
    hi: usize,
    /// Word index where the next scan starts (absolute, in [lo, hi)).
    cursor: AtomicUsize,
    steals: AtomicU64,
    refills: AtomicU64,
    cas_retries: AtomicU64,
}

impl Shard {
    fn new(lo: usize, hi: usize) -> Self {
        Shard {
            lo,
            hi,
            cursor: AtomicUsize::new(lo),
            steals: AtomicU64::new(0),
            refills: AtomicU64::new(0),
            cas_retries: AtomicU64::new(0),
        }
    }

    #[inline]
    fn span(&self) -> usize {
        self.hi - self.lo
    }
}

/// Sharded lock-free fixed-size block allocator over one stable arena.
///
/// Block ownership is transferred through bitmap CAS/fetch_or with
/// AcqRel ordering, so a block's data accesses are ordered across the
/// free → realloc handoff and live blocks never alias.
pub struct ShardedAllocator {
    arena: Arena,
    /// Free bitmap: bit set = block free. Bits past `capacity` in the
    /// last word start cleared and can never be set (free() bounds-checks
    /// ids), so they are never handed out.
    words: Vec<AtomicU64>,
    shards: Vec<Shard>,
    allocated: AtomicUsize,
    peak: AtomicUsize,
    total_allocs: AtomicU64,
    total_frees: AtomicU64,
    failed_allocs: AtomicU64,
    epoch: ArenaEpoch,
}

impl ShardedAllocator {
    /// Create a pool of `capacity_blocks` blocks of `block_size` bytes
    /// with a shard count derived from available parallelism.
    ///
    /// `block_size` must be a power of two ≥ 256 (the paper uses 32 KB).
    pub fn new(block_size: usize, capacity_blocks: usize) -> Result<Self> {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::with_shards(block_size, capacity_blocks, threads.min(64))
    }

    /// Create a pool with an explicit shard count (clamped to at least 1
    /// and at most one shard per bitmap word).
    pub fn with_shards(block_size: usize, capacity_blocks: usize, nshards: usize) -> Result<Self> {
        let arena = Arena::new(block_size, capacity_blocks)?;
        let nwords = capacity_blocks.div_ceil(64);
        let mut words = Vec::with_capacity(nwords);
        for w in 0..nwords {
            let first = w * 64;
            let in_range = capacity_blocks - first; // > 0 by construction
            let word = if in_range >= 64 {
                u64::MAX
            } else {
                (1u64 << in_range) - 1
            };
            words.push(AtomicU64::new(word));
        }
        let nshards = nshards.clamp(1, nwords);
        // Balanced split: shard s owns words [s*n/k, (s+1)*n/k). With
        // nshards <= nwords every shard gets at least one word — a
        // ceil-divided split would leave trailing shards empty and turn
        // every allocation by threads homed there into a phantom
        // "steal".
        let shards = (0..nshards)
            .map(|s| Shard::new(s * nwords / nshards, (s + 1) * nwords / nshards))
            .collect();
        Ok(ShardedAllocator {
            arena,
            words,
            shards,
            allocated: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            total_allocs: AtomicU64::new(0),
            total_frees: AtomicU64::new(0),
            failed_allocs: AtomicU64::new(0),
            epoch: ArenaEpoch::new(),
        })
    }

    /// Pool with the paper's 32 KB blocks covering `bytes` of memory.
    pub fn with_capacity_bytes(bytes: usize) -> Result<Self> {
        Self::new(crate::BLOCK_SIZE, bytes.div_ceil(crate::BLOCK_SIZE).max(1))
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// This thread's home shard (stable per thread, hashed token).
    #[inline]
    fn home_shard(&self) -> usize {
        let token = THREAD_TOKEN.with(|t| *t);
        (mix(token as u64) % self.shards.len() as u64) as usize
    }

    /// Claim one free bit in `shard`. Lock-free: word-level CAS; lost
    /// races are counted and retried on the fresh word value.
    fn try_claim_in_shard(&self, shard: &Shard) -> Option<u32> {
        let span = shard.span();
        if span == 0 {
            return None;
        }
        let start = shard.cursor.load(Ordering::Relaxed).clamp(shard.lo, shard.hi - 1);
        let mut counted_refill = false;
        for k in 0..span {
            let w = shard.lo + (start - shard.lo + k) % span;
            if k > 0 && w == shard.lo && !counted_refill {
                counted_refill = true;
                shard.refills.fetch_add(1, Ordering::Relaxed);
            }
            loop {
                let cur = self.words[w].load(Ordering::Relaxed);
                if cur == 0 {
                    break;
                }
                let bit = cur.trailing_zeros();
                let new = cur & !(1u64 << bit);
                match self.words[w].compare_exchange_weak(
                    cur,
                    new,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        shard.cursor.store(w, Ordering::Relaxed);
                        return Some((w * 64 + bit as usize) as u32);
                    }
                    Err(_) => {
                        shard.cas_retries.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        None
    }

    /// Claim up to `want` bits from `shard` in word-granular batches
    /// (one CAS can take up to 64 blocks). Returns how many were taken;
    /// claimed ids are appended to `out`.
    fn claim_batch_in_shard(&self, shard: &Shard, want: usize, out: &mut Vec<u32>) -> usize {
        let span = shard.span();
        if span == 0 || want == 0 {
            return 0;
        }
        let start = shard.cursor.load(Ordering::Relaxed).clamp(shard.lo, shard.hi - 1);
        let mut got = 0usize;
        for k in 0..span {
            if got >= want {
                break;
            }
            let w = shard.lo + (start - shard.lo + k) % span;
            loop {
                let cur = self.words[w].load(Ordering::Relaxed);
                if cur == 0 {
                    break;
                }
                let take = (want - got).min(cur.count_ones() as usize);
                // Mask of the `take` lowest set bits of `cur`.
                let mut mask = 0u64;
                let mut rest = cur;
                for _ in 0..take {
                    let b = rest.trailing_zeros();
                    mask |= 1u64 << b;
                    rest &= !(1u64 << b);
                }
                match self.words[w].compare_exchange_weak(
                    cur,
                    cur & !mask,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let mut m = mask;
                        while m != 0 {
                            let b = m.trailing_zeros();
                            out.push((w * 64 + b as usize) as u32);
                            m &= !(1u64 << b);
                        }
                        got += take;
                        shard.cursor.store(w, Ordering::Relaxed);
                        break;
                    }
                    Err(_) => {
                        shard.cas_retries.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        got
    }

    /// Release a claimed bit without touching statistics (rollback path).
    fn release_bit(&self, id: u32) {
        let (w, b) = ((id / 64) as usize, 1u64 << (id % 64));
        self.words[w].fetch_or(b, Ordering::AcqRel);
    }

    fn record_allocs(&self, n: usize) {
        let live = self.allocated.fetch_add(n, Ordering::AcqRel) + n;
        self.peak.fetch_max(live, Ordering::AcqRel);
        self.total_allocs.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn bounds_check(&self, id: BlockId, offset: usize, len: usize) -> Result<()> {
        if !BlockAlloc::is_live(self, id) {
            return Err(Error::InvalidBlock(id));
        }
        self.arena.check_span(offset, len)
    }
}

impl BlockAlloc for ShardedAllocator {
    fn alloc(&self) -> Result<BlockId> {
        let home = self.home_shard();
        let n = self.shards.len();
        for k in 0..n {
            let si = (home + k) % n;
            if let Some(id) = self.try_claim_in_shard(&self.shards[si]) {
                if k > 0 {
                    self.shards[home].steals.fetch_add(1, Ordering::Relaxed);
                }
                self.record_allocs(1);
                return Ok(BlockId(id));
            }
        }
        self.failed_allocs.fetch_add(1, Ordering::Relaxed);
        Err(Error::OutOfMemory {
            requested: 1,
            free: 0,
            capacity: self.arena.capacity(),
        })
    }

    fn alloc_many(&self, n: usize) -> Result<Vec<BlockId>> {
        let home = self.home_shard();
        let nsh = self.shards.len();
        let mut ids: Vec<u32> = Vec::with_capacity(n);
        for k in 0..nsh {
            if ids.len() >= n {
                break;
            }
            let got = self.claim_batch_in_shard(&self.shards[(home + k) % nsh], n - ids.len(), &mut ids);
            if k > 0 && got > 0 {
                self.shards[home].steals.fetch_add(got as u64, Ordering::Relaxed);
            }
        }
        if ids.len() < n {
            // All-or-nothing: roll the partial claim back, leak nothing.
            let got = ids.len();
            for id in ids {
                self.release_bit(id);
            }
            self.failed_allocs.fetch_add(1, Ordering::Relaxed);
            return Err(Error::OutOfMemory {
                requested: n,
                free: got,
                capacity: self.arena.capacity(),
            });
        }
        self.record_allocs(n);
        Ok(ids.into_iter().map(BlockId).collect())
    }

    fn alloc_zeroed(&self) -> Result<BlockId> {
        let id = BlockAlloc::alloc(self)?;
        // SAFETY: id is live and exclusively ours until returned.
        unsafe { self.arena.zero_block(id) };
        Ok(id)
    }

    /// Lowest-id free block in `[lo, hi)`: a lock-free ascending bitmap
    /// scan (word-level CAS, same ownership-transfer ordering as the
    /// shard paths). Ignores shard affinity by design — placement is
    /// the point ([`BlockAlloc::alloc_in_span`]); contention met here is
    /// not counted against any shard's counters.
    fn alloc_in_span(&self, lo: usize, hi: usize) -> Result<BlockId> {
        let hi = hi.min(self.arena.capacity());
        for w in lo / 64..hi.div_ceil(64) {
            let first = w * 64;
            let mask = crate::pmem::alloc_trait::span_word_mask(w, lo, hi);
            loop {
                let cur = self.words[w].load(Ordering::Relaxed);
                let avail = cur & mask;
                if avail == 0 {
                    break;
                }
                let bit = avail.trailing_zeros();
                if self.words[w]
                    .compare_exchange_weak(
                        cur,
                        cur & !(1u64 << bit),
                        Ordering::AcqRel,
                        Ordering::Relaxed,
                    )
                    .is_ok()
                {
                    self.record_allocs(1);
                    return Ok(BlockId((first + bit as usize) as u32));
                }
            }
        }
        // A full span is an expected probe miss for the compactor, not
        // pool exhaustion — don't count a failed alloc.
        Err(Error::OutOfMemory {
            requested: 1,
            free: 0,
            capacity: self.arena.capacity(),
        })
    }

    fn shard_spans(&self) -> Vec<(usize, usize)> {
        let cap = self.arena.capacity();
        self.shards
            .iter()
            .map(|s| (s.lo * 64, (s.hi * 64).min(cap)))
            .collect()
    }

    fn live_snapshot(&self, out: &mut Vec<u64>) {
        out.clear();
        out.reserve(self.words.len());
        let cap = self.arena.capacity();
        for (w, word) in self.words.iter().enumerate() {
            // `words` is the FREE bitmap; invert and mask the tail so
            // bits past the capacity read as not-allocated.
            let mut live = !word.load(Ordering::Acquire);
            let first = w * 64;
            if cap - first < 64 {
                live &= (1u64 << (cap - first)) - 1;
            }
            out.push(live);
        }
    }

    fn free(&self, id: BlockId) -> Result<()> {
        let i = id.0 as usize;
        if i >= self.arena.capacity() {
            return Err(Error::InvalidBlock(id));
        }
        let (w, b) = (i / 64, 1u64 << (i % 64));
        // Cheap pre-check: an already-free bit is a double free; reject
        // without touching any state.
        if self.words[w].load(Ordering::Acquire) & b != 0 {
            return Err(Error::InvalidBlock(id));
        }
        // Retire from the live count BEFORE publishing the free bit: the
        // instant the bit is visible, another thread may re-allocate the
        // block and increment `allocated`, which must never exceed
        // capacity (free_blocks() is capacity - allocated). A transient
        // under-count on this side is harmless.
        self.allocated.fetch_sub(1, Ordering::AcqRel);
        let prev = self.words[w].fetch_or(b, Ordering::AcqRel);
        if prev & b != 0 {
            // Lost a double-free race (both callers saw the bit clear);
            // the other free won and fetch_or was a no-op here. Undo the
            // speculative decrement.
            self.allocated.fetch_add(1, Ordering::AcqRel);
            return Err(Error::InvalidBlock(id));
        }
        self.total_frees.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn block_size(&self) -> usize {
        self.arena.block_size()
    }

    fn capacity(&self) -> usize {
        self.arena.capacity()
    }

    fn free_blocks(&self) -> usize {
        self.arena.capacity() - self.allocated.load(Ordering::Acquire)
    }

    fn is_live(&self, id: BlockId) -> bool {
        let i = id.0 as usize;
        if i >= self.arena.capacity() {
            return false;
        }
        let (w, b) = (i / 64, 1u64 << (i % 64));
        self.words[w].load(Ordering::Acquire) & b == 0
    }

    fn stats(&self) -> AllocStats {
        let mut s = AllocStats {
            allocated: self.allocated.load(Ordering::Acquire),
            peak: self.peak.load(Ordering::Acquire),
            total_allocs: self.total_allocs.load(Ordering::Relaxed),
            total_frees: self.total_frees.load(Ordering::Relaxed),
            failed_allocs: self.failed_allocs.load(Ordering::Relaxed),
            ..AllocStats::default()
        };
        self.epoch.fill_alloc_stats(&mut s);
        s
    }

    fn contention(&self) -> ContentionStats {
        let mut c = ContentionStats::default();
        for s in &self.shards {
            c.steals += s.steals.load(Ordering::Relaxed);
            c.refills += s.refills.load(Ordering::Relaxed);
            c.cas_retries += s.cas_retries.load(Ordering::Relaxed);
        }
        c
    }

    fn epoch(&self) -> &ArenaEpoch {
        &self.epoch
    }

    unsafe fn block_ptr(&self, id: BlockId) -> *mut u8 {
        self.arena.block_ptr(id)
    }

    fn write(&self, id: BlockId, offset: usize, data: &[u8]) -> Result<()> {
        self.bounds_check(id, offset, data.len())?;
        // SAFETY: span checked; exclusive ownership per contract.
        unsafe { self.arena.copy_in(id, offset, data) };
        Ok(())
    }

    fn read(&self, id: BlockId, offset: usize, out: &mut [u8]) -> Result<()> {
        self.bounds_check(id, offset, out.len())?;
        // SAFETY: span checked.
        unsafe { self.arena.copy_out(id, offset, out) };
        Ok(())
    }
}

impl std::fmt::Debug for ShardedAllocator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = BlockAlloc::stats(self);
        write!(
            f,
            "ShardedAllocator {{ block_size: {}, capacity: {}, shards: {}, allocated: {} }}",
            self.arena.block_size(),
            self.arena.capacity(),
            self.shards.len(),
            s.allocated
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sharded(cap: usize, shards: usize) -> ShardedAllocator {
        ShardedAllocator::with_shards(4096, cap, shards).unwrap()
    }

    #[test]
    fn alloc_free_roundtrip() {
        let a = sharded(256, 4);
        let b = a.alloc().unwrap();
        assert!(a.is_live(b));
        a.free(b).unwrap();
        assert!(!a.is_live(b));
        assert_eq!(a.stats().allocated, 0);
        assert_eq!(a.free_blocks(), 256);
    }

    #[test]
    fn exhaustion_errors_and_counts() {
        let a = sharded(2, 1);
        let _b1 = a.alloc().unwrap();
        let _b2 = a.alloc().unwrap();
        assert!(matches!(a.alloc(), Err(Error::OutOfMemory { .. })));
        assert_eq!(a.stats().failed_allocs, 1);
    }

    #[test]
    fn double_free_rejected() {
        let a = sharded(8, 2);
        let b = a.alloc().unwrap();
        a.free(b).unwrap();
        assert!(matches!(a.free(b), Err(Error::InvalidBlock(_))));
        assert_eq!(a.stats().total_frees, 1);
    }

    #[test]
    fn foreign_block_rejected() {
        let a = sharded(8, 2);
        assert!(matches!(a.free(BlockId(99)), Err(Error::InvalidBlock(_))));
    }

    #[test]
    fn alloc_many_all_or_nothing() {
        let a = sharded(4, 2);
        let _one = a.alloc().unwrap();
        assert!(a.alloc_many(4).is_err());
        assert_eq!(a.free_blocks(), 3, "failed alloc_many must leak nothing");
        let three = a.alloc_many(3).unwrap();
        assert_eq!(three.len(), 3);
        assert_eq!(a.free_blocks(), 0);
    }

    #[test]
    fn alloc_many_returns_distinct_blocks() {
        let a = sharded(300, 8);
        let blocks = a.alloc_many(300).unwrap();
        let mut ids: Vec<u32> = blocks.iter().map(|b| b.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 300, "every block handed out exactly once");
    }

    #[test]
    fn write_read_roundtrip() {
        let a = sharded(4, 2);
        let b = a.alloc().unwrap();
        a.write(b, 100, &[1, 2, 3, 4]).unwrap();
        let mut out = [0u8; 4];
        a.read(b, 100, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3, 4]);
        assert!(a.write(b, 4093, &[1, 2, 3, 4]).is_err());
        // Wrapping offsets are rejected by the overflow-safe span check.
        assert!(a.write(b, usize::MAX - 2, &[1, 2, 3, 4]).is_err());
    }

    #[test]
    fn invalid_config_rejected() {
        assert!(ShardedAllocator::new(3000, 4).is_err());
        assert!(ShardedAllocator::new(128, 4).is_err());
        assert!(ShardedAllocator::new(4096, 0).is_err());
    }

    #[test]
    fn no_shard_is_ever_empty() {
        // Uneven word/shard ratios must still give every shard at least
        // one bitmap word, or threads homed there lose all affinity.
        for (cap, shards) in [(1120usize, 8usize), (70, 2), (65, 4), (64, 64), (300, 7)] {
            let a = ShardedAllocator::with_shards(4096, cap, shards).unwrap();
            for s in &a.shards {
                assert!(s.span() > 0, "empty shard at cap={cap} shards={shards}");
            }
            // And the ranges tile the bitmap exactly.
            assert_eq!(a.shards.first().unwrap().lo, 0);
            assert_eq!(a.shards.last().unwrap().hi, a.words.len());
            for w in a.shards.windows(2) {
                assert_eq!(w[0].hi, w[1].lo);
            }
        }
    }

    #[test]
    fn blocks_are_zeroed_initially() {
        let a = sharded(2, 1);
        let b = a.alloc().unwrap();
        let mut out = [0xFFu8; 16];
        a.read(b, 0, &mut out).unwrap();
        assert_eq!(out, [0u8; 16]);
    }

    #[test]
    fn capacity_not_multiple_of_64_is_exact() {
        // 70 blocks: the second bitmap word has only 6 valid bits; the
        // allocator must hand out exactly 70 distinct blocks.
        let a = sharded(70, 2);
        let blocks = a.alloc_many(70).unwrap();
        assert!(a.alloc().is_err());
        let mut ids: Vec<u32> = blocks.iter().map(|b| b.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 70);
        assert!(ids.iter().all(|&i| (i as usize) < 70));
    }

    #[test]
    fn stealing_crosses_shards() {
        // 2 shards; drain everything from one thread. Whatever the home
        // shard is, the far half must be reachable (steals observed or
        // everything served locally from a single shard is impossible
        // with 128 blocks in 2x64-block shards).
        let a = sharded(128, 2);
        let blocks: Vec<_> = (0..128).map(|_| a.alloc().unwrap()).collect();
        assert_eq!(a.stats().allocated, 128);
        assert!(a.contention().steals > 0, "cross-shard steals must occur");
        for b in blocks {
            a.free(b).unwrap();
        }
        assert_eq!(a.free_blocks(), 128);
    }

    #[test]
    fn peak_tracks_high_water() {
        let a = sharded(8, 2);
        let bs = a.alloc_many(5).unwrap();
        for b in &bs[..3] {
            a.free(*b).unwrap();
        }
        let _x = a.alloc().unwrap();
        assert_eq!(a.stats().peak, 5);
        assert_eq!(a.stats().allocated, 3);
    }

    #[test]
    fn alloc_in_span_takes_lowest_in_range() {
        let a = sharded(130, 2);
        let all = a.alloc_many(130).unwrap();
        // Claim order is shard-affine, not id order: free ids by value.
        for want in [3u32, 70, 128] {
            let b = all.iter().copied().find(|b| b.0 == want).unwrap();
            a.free(b).unwrap();
        }
        assert_eq!(a.alloc_in_span(0, 130).unwrap(), BlockId(3));
        assert_eq!(a.alloc_in_span(64, 130).unwrap(), BlockId(70));
        assert!(a.alloc_in_span(0, 128).is_err(), "3 and 70 retaken");
        assert_eq!(a.alloc_in_span(0, 130).unwrap(), BlockId(128));
        assert!(a.alloc_in_span(0, 130).is_err(), "pool full again");
        assert_eq!(a.stats().allocated, 130, "span allocs must be counted");
    }

    #[test]
    fn shard_spans_tile_the_pool() {
        let a = sharded(300, 3); // 5 words split 1/2/2
        let spans = a.shard_spans();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans.first().unwrap().0, 0);
        assert_eq!(spans.last().unwrap().1, 300, "last span clamps to capacity");
        for w in spans.windows(2) {
            assert_eq!(w[0].1, w[1].0, "spans must tile without gaps");
        }
    }

    #[test]
    fn live_snapshot_matches_is_live() {
        let a = sharded(70, 2);
        let blocks = a.alloc_many(70).unwrap();
        for b in blocks.iter().skip(1).step_by(3) {
            a.free(*b).unwrap();
        }
        let mut snap = Vec::new();
        a.live_snapshot(&mut snap);
        assert_eq!(snap.len(), 2);
        for i in 0..70u32 {
            let bit = (snap[(i / 64) as usize] >> (i % 64)) & 1 == 1;
            assert_eq!(bit, a.is_live(BlockId(i)), "block {i}");
        }
        assert_eq!(snap[1] >> 6, 0, "bits past capacity must read free");
    }

    #[test]
    fn concurrent_alloc_free_conserves() {
        let a = std::sync::Arc::new(sharded(1024, 8));
        let mut handles = Vec::new();
        for t in 0..8 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                let mut mine = Vec::new();
                for i in 0..400 {
                    if (i + t) % 3 == 0 && !mine.is_empty() {
                        a.free(mine.pop().unwrap()).unwrap();
                    } else if let Ok(b) = a.alloc() {
                        mine.push(b);
                    }
                }
                for b in mine {
                    a.free(b).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.stats().allocated, 0);
        assert_eq!(a.free_blocks(), 1024);
        assert_eq!(a.stats().total_allocs, a.stats().total_frees);
    }
}
