//! Logical multi-block regions: what a large `malloc` becomes when the OS
//! only hands out fixed blocks. A [`Region`] is a *logical* byte range
//! spread over physically unrelated blocks — the degenerate "depth-1 list"
//! flavor of discontiguous allocation, used by the split stack and the
//! batcher. (Indexed access at scale wants [`crate::trees::TreeArray`].)

use crate::error::Result;
use crate::pmem::{BlockAlloc, BlockAllocator, BlockId};

/// A logical byte range backed by a sequence of blocks.
pub struct Region<'a, A: BlockAlloc = BlockAllocator> {
    alloc: &'a A,
    blocks: Vec<BlockId>,
    len: usize,
}

impl<'a, A: BlockAlloc> Region<'a, A> {
    /// Allocate a region of at least `len` bytes.
    pub fn new(alloc: &'a A, len: usize) -> Result<Self> {
        let bs = alloc.block_size();
        let nblocks = len.div_ceil(bs).max(1);
        let blocks = alloc.alloc_many(nblocks)?;
        Ok(Region { alloc, blocks, len })
    }

    /// Logical length in bytes.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if the region has zero logical bytes.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Backing blocks, in logical order.
    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Write `data` at logical `offset`, spanning blocks as needed.
    pub fn write(&self, offset: usize, data: &[u8]) -> Result<()> {
        self.bounds(offset, data.len())?;
        let bs = self.alloc.block_size();
        let mut off = offset;
        let mut rest = data;
        while !rest.is_empty() {
            let (blk, in_off) = (off / bs, off % bs);
            let take = rest.len().min(bs - in_off);
            self.alloc.write(self.blocks[blk], in_off, &rest[..take])?;
            off += take;
            rest = &rest[take..];
        }
        Ok(())
    }

    /// Read `out.len()` bytes from logical `offset`.
    pub fn read(&self, offset: usize, out: &mut [u8]) -> Result<()> {
        self.bounds(offset, out.len())?;
        let bs = self.alloc.block_size();
        let mut off = offset;
        let mut rest = &mut out[..];
        while !rest.is_empty() {
            let (blk, in_off) = (off / bs, off % bs);
            let take = rest.len().min(bs - in_off);
            let (head, tail) = rest.split_at_mut(take);
            self.alloc.read(self.blocks[blk], in_off, head)?;
            off += take;
            rest = tail;
        }
        Ok(())
    }

    fn bounds(&self, offset: usize, len: usize) -> Result<()> {
        if offset + len > self.len {
            return Err(crate::Error::IndexOutOfBounds {
                index: offset + len,
                len: self.len,
            });
        }
        Ok(())
    }
}

impl<A: BlockAlloc> Drop for Region<'_, A> {
    fn drop(&mut self) {
        for b in &self.blocks {
            let _ = self.alloc.free(*b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn spans_block_boundaries() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        let r = Region::new(&a, 3 * 4096).unwrap();
        let data: Vec<u8> = (0..255).collect();
        r.write(4096 - 100, &data).unwrap(); // crosses block 0 -> 1
        let mut out = vec![0u8; 255];
        r.read(4096 - 100, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn frees_blocks_on_drop() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        {
            let _r = Region::new(&a, 4 * 4096).unwrap();
            assert_eq!(a.stats().allocated, 4);
        }
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn oob_rejected() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        let r = Region::new(&a, 100).unwrap();
        assert!(r.write(90, &[0u8; 20]).is_err());
    }

    #[test]
    fn prop_region_rw_matches_vec() {
        forall(40, |g| {
            let a = BlockAllocator::new(1024, 64).unwrap();
            let len = g.usize_in(1, 16 * 1024);
            let r = Region::new(&a, len).unwrap();
            let mut model = vec![0u8; len];
            for _ in 0..g.usize_in(0, 20) {
                let off = g.usize_in(0, len - 1);
                let n = g.usize_in(0, len - off);
                let data: Vec<u8> = g.vec(n, |g| g.usize_in(0, 255) as u8);
                r.write(off, &data).unwrap();
                model[off..off + n].copy_from_slice(&data);
            }
            let mut out = vec![0u8; len];
            r.read(0, &mut out).unwrap();
            assert_eq!(out, model);
        });
    }
}
