//! Relocation / migration without address translation (paper §2,
//! Table 1 "Relocation / Migration").
//!
//! With virtual memory, the OS migrates a page by remapping it; with
//! physical addressing, *software* must move the data and patch the
//! pointers. The paper's observation: managed runtimes already do this,
//! and arrays-as-trees make it nearly free for large arrays — a leaf can
//! move anywhere as long as its single parent slot is patched (this is
//! exactly the CARAT [12] limitation the paper says trees ameliorate).
//!
//! [`Relocator`] implements block-granular migration over the allocator
//! with a forwarding table (the software analogue of CARAT's patching
//! pass), plus first-class leaf migration for [`TreeArray`].

use std::collections::HashMap;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::pmem::{BlockAlloc, BlockAllocator, BlockId};
use crate::trees::{Pod, TreeArray};

/// Statistics of migration activity.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MigrateStats {
    /// Blocks migrated.
    pub migrations: u64,
    /// Bytes copied.
    pub bytes_copied: u64,
}

/// Block migrator with a forwarding table.
pub struct Relocator<'a, A: BlockAlloc = BlockAllocator> {
    alloc: &'a A,
    /// old block -> new block, for pointer-patching passes.
    forwards: Mutex<HashMap<BlockId, BlockId>>,
    stats: Mutex<MigrateStats>,
}

impl<'a, A: BlockAlloc> Relocator<'a, A> {
    /// New relocator over `alloc`.
    pub fn new(alloc: &'a A) -> Self {
        Relocator {
            alloc,
            forwards: Mutex::new(HashMap::new()),
            stats: Mutex::new(MigrateStats::default()),
        }
    }

    /// Move `block`'s contents into a freshly allocated block; frees the
    /// old block and records a forwarding entry. Returns the new block.
    pub fn migrate(&self, block: BlockId) -> Result<BlockId> {
        if !self.alloc.is_live(block) {
            return Err(Error::InvalidBlock(block));
        }
        let fresh = self.alloc.alloc()?;
        let bs = self.alloc.block_size();
        let mut buf = vec![0u8; bs];
        self.alloc.read(block, 0, &mut buf)?;
        self.alloc.write(fresh, 0, &buf)?;
        self.alloc.free(block)?;
        let mut fwd = self.forwards.lock().unwrap();
        // `fresh` is a live block again: any stale forwarding entry
        // keyed by its (recycled) id is dead — removing it keeps the
        // forwarding graph acyclic (the allocator's LIFO free list
        // recycles ids quickly, so migrate(migrate(b)) can hand back b).
        fwd.remove(&fresh);
        fwd.insert(block, fresh);
        drop(fwd);
        let mut s = self.stats.lock().unwrap();
        s.migrations += 1;
        s.bytes_copied += bs as u64;
        Ok(fresh)
    }

    /// Resolve a (possibly stale) block id through the forwarding table.
    pub fn resolve(&self, block: BlockId) -> BlockId {
        let fwd = self.forwards.lock().unwrap();
        let mut cur = block;
        // Chase forwarding chains (migrate-of-migrate). The graph is
        // kept acyclic by `migrate`, and the hop bound makes resolve
        // total even against future invariant bugs.
        for _ in 0..=fwd.len() {
            match fwd.get(&cur) {
                Some(&next) => cur = next,
                None => break,
            }
        }
        cur
    }

    /// Drop forwarding entries (after a patching pass has rewritten all
    /// stale pointers).
    pub fn clear_forwards(&self) {
        self.forwards.lock().unwrap().clear();
    }

    /// Migration statistics.
    pub fn stats(&self) -> MigrateStats {
        *self.stats.lock().unwrap()
    }
}

impl<'a, T: Pod, A: BlockAlloc> TreeArray<'a, T, A> {
    /// Migrate leaf `leaf_idx` to a fresh block, patching the parent
    /// pointer — the tree-native relocation the paper describes (only
    /// one pointer names a leaf, so no global patching pass is needed).
    ///
    /// Takes `&mut self`, so the borrow checker rules out outstanding
    /// [`TreeArray::leaf_slice`] borrows (which pin a leaf's *location*
    /// and would dangle into the freed block). To move a leaf under a
    /// live [`Cursor`](crate::trees::Cursor) — which revalidates via the
    /// generation counter and is safe to coexist with — use
    /// [`TreeArray::migrate_leaf_shared`].
    pub fn migrate_leaf(&mut self, leaf_idx: usize) -> Result<BlockId> {
        // SAFETY: `&mut self` proves no leaf slice (or any other borrow
        // of the tree) is live across the move.
        unsafe { self.migrate_leaf_shared(leaf_idx) }
    }

    /// [`TreeArray::migrate_leaf`] through `&self`: location metadata is
    /// interior-mutable so leaves can move *under live cursors* — the
    /// tree's generation counter is bumped and cursors/TLBs revalidate
    /// on their next access (see [`TreeArray`]'s relocation docs).
    ///
    /// # Safety
    /// Raw leaf slices cannot revalidate, so the caller must ensure no
    /// [`TreeArray::leaf_slice`] / [`TreeArray::leaf_slice_mut`] borrow
    /// of the tree (including the `&[T]` handed to
    /// [`TreeArray::for_each_leaf_run`]'s callback) is live across the
    /// call — the moving leaf's block is freed and may be recycled and
    /// rewritten while such a slice still points at it. The caller must
    /// also ensure no *other thread* accesses the tree during the move
    /// (the same single-writer contract as
    /// [`crate::pmem::BlockAlloc::block_ptr`]).
    pub unsafe fn migrate_leaf_shared(&self, leaf_idx: usize) -> Result<BlockId> {
        if leaf_idx >= self.nleaves() {
            return Err(Error::IndexOutOfBounds {
                index: leaf_idx,
                len: self.nleaves(),
            });
        }
        // SAFETY: forwarded verbatim — the caller upholds this fn's
        // identical contract.
        unsafe { self.relocate_leaf_impl(leaf_idx) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn migrate_preserves_contents() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        let r = Relocator::new(&a);
        let b = a.alloc().unwrap();
        a.write(b, 100, b"payload").unwrap();
        let nb = r.migrate(b).unwrap();
        assert_ne!(b, nb);
        let mut out = [0u8; 7];
        a.read(nb, 100, &mut out).unwrap();
        assert_eq!(&out, b"payload");
        assert!(!a.is_live(b));
        assert_eq!(r.stats().migrations, 1);
    }

    #[test]
    fn forwarding_chains_resolve() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        let r = Relocator::new(&a);
        let b0 = a.alloc().unwrap();
        let b1 = r.migrate(b0).unwrap();
        let b2 = r.migrate(b1).unwrap();
        assert_eq!(r.resolve(b0), b2);
        assert_eq!(r.resolve(b1), b2);
        r.clear_forwards();
        assert_eq!(r.resolve(b0), b0); // stale ids no longer forwarded
    }

    #[test]
    fn migrate_dead_block_rejected() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        let r = Relocator::new(&a);
        let b = a.alloc().unwrap();
        a.free(b).unwrap();
        assert!(r.migrate(b).is_err());
    }

    #[test]
    fn tree_leaf_migration_is_transparent() {
        let a = BlockAllocator::new(1024, 256).unwrap();
        let n = 256 * 5 + 7;
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).collect();
        t.copy_from_slice(&data).unwrap();
        for leaf in 0..t.nleaves() {
            t.migrate_leaf(leaf).unwrap();
        }
        assert_eq!(t.to_vec(), data, "contents survive migrating every leaf");
        // Naive and iterator paths both see the new locations.
        assert_eq!(t.get(300).unwrap(), 300);
        assert_eq!(t.iter().last().unwrap(), n as u32 - 1);
    }

    #[test]
    fn prop_random_leaf_migrations_preserve_array() {
        forall(20, |g| {
            let a = BlockAllocator::new(1024, 1 << 12).unwrap();
            let n = g.usize_in(1, 256 * 64);
            let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
            let data: Vec<u32> = (0..n).map(|_| g.rng().next_u32()).collect();
            t.copy_from_slice(&data).unwrap();
            let live_before = a.stats().allocated;
            for _ in 0..g.usize_in(0, 20) {
                let leaf = g.usize_in(0, t.nleaves() - 1);
                t.migrate_leaf(leaf).unwrap();
            }
            assert_eq!(t.to_vec(), data);
            assert_eq!(a.stats().allocated, live_before, "no block leak");
        });
    }
}
