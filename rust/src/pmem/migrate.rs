//! Relocation / migration without address translation (paper §2,
//! Table 1 "Relocation / Migration").
//!
//! With virtual memory, the OS migrates a page by remapping it; with
//! physical addressing, *software* must move the data and patch the
//! pointers. The paper's observation: managed runtimes already do this,
//! and arrays-as-trees make it nearly free for large arrays — a leaf can
//! move anywhere as long as its single parent slot is patched (this is
//! exactly the CARAT [12] limitation the paper says trees ameliorate).
//!
//! [`Relocator`] implements block-granular migration over the allocator
//! with a forwarding table (the software analogue of CARAT's patching
//! pass), plus first-class leaf migration for [`TreeArray`].

use std::collections::HashMap;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::pmem::{BlockAlloc, BlockAllocator, BlockId};
use crate::trees::{Pod, TreeArray};

/// Statistics of migration activity.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MigrateStats {
    /// Blocks migrated.
    pub migrations: u64,
    /// Bytes copied.
    pub bytes_copied: u64,
}

/// Block migrator with a forwarding table.
pub struct Relocator<'a, A: BlockAlloc = BlockAllocator> {
    alloc: &'a A,
    /// old block -> new block, for pointer-patching passes.
    forwards: Mutex<HashMap<BlockId, BlockId>>,
    stats: Mutex<MigrateStats>,
}

impl<'a, A: BlockAlloc> Relocator<'a, A> {
    /// New relocator over `alloc`.
    pub fn new(alloc: &'a A) -> Self {
        Relocator {
            alloc,
            forwards: Mutex::new(HashMap::new()),
            stats: Mutex::new(MigrateStats::default()),
        }
    }

    /// Move `block`'s contents into a freshly allocated block; frees the
    /// old block and records a forwarding entry. Returns the new block.
    pub fn migrate(&self, block: BlockId) -> Result<BlockId> {
        if !self.alloc.is_live(block) {
            return Err(Error::InvalidBlock(block));
        }
        let fresh = self.alloc.alloc()?;
        let bs = self.alloc.block_size();
        let mut buf = vec![0u8; bs];
        self.alloc.read(block, 0, &mut buf)?;
        self.alloc.write(fresh, 0, &buf)?;
        self.alloc.free(block)?;
        // Arena-wide shootdown: the freed block may back someone's
        // cached translation (e.g. a cursor over a tree in this pool);
        // bumping the pool epoch makes every cache revalidate.
        self.alloc.epoch().bump();
        let mut fwd = self.forwards.lock().unwrap();
        // `fresh` is a live block again: any stale forwarding entry
        // keyed by its (recycled) id is dead — removing it keeps the
        // forwarding graph acyclic (the allocator's LIFO free list
        // recycles ids quickly, so migrate(migrate(b)) can hand back b).
        fwd.remove(&fresh);
        fwd.insert(block, fresh);
        drop(fwd);
        let mut s = self.stats.lock().unwrap();
        s.migrations += 1;
        s.bytes_copied += bs as u64;
        Ok(fresh)
    }

    /// Resolve a (possibly stale) block id through the forwarding table.
    pub fn resolve(&self, block: BlockId) -> BlockId {
        let fwd = self.forwards.lock().unwrap();
        let mut cur = block;
        // Chase forwarding chains (migrate-of-migrate). The graph is
        // kept acyclic by `migrate`, and the hop bound makes resolve
        // total even against future invariant bugs.
        for _ in 0..=fwd.len() {
            match fwd.get(&cur) {
                Some(&next) => cur = next,
                None => break,
            }
        }
        cur
    }

    /// Drop forwarding entries (after a patching pass has rewritten all
    /// stale pointers).
    pub fn clear_forwards(&self) {
        self.forwards.lock().unwrap().clear();
    }

    /// Migration statistics.
    pub fn stats(&self) -> MigrateStats {
        *self.stats.lock().unwrap()
    }
}

impl<'a, T: Pod, A: BlockAlloc> TreeArray<'a, T, A> {
    /// Migrate leaf `leaf_idx` to a fresh block, patching the parent
    /// pointer — the tree-native relocation the paper describes (only
    /// one pointer names a leaf, so no global patching pass is needed).
    ///
    /// Takes `&mut self`, so the borrow checker rules out outstanding
    /// [`TreeArray::leaf_slice`] borrows (which pin a leaf's *location*
    /// and would dangle into the freed block). To move a leaf under a
    /// live [`Cursor`](crate::trees::Cursor) — which revalidates via the
    /// generation counter and is safe to coexist with — use
    /// [`TreeArray::migrate_leaf_shared`].
    pub fn migrate_leaf(&mut self, leaf_idx: usize) -> Result<BlockId> {
        // SAFETY: `&mut self` proves no leaf slice (or any other borrow
        // of the tree) is live across the move.
        unsafe { self.migrate_leaf_shared(leaf_idx) }
    }

    /// [`TreeArray::migrate_leaf`] under **live concurrent readers**:
    /// the displaced block is not freed but *retired* into the arena
    /// epoch's limbo list ([`crate::pmem::ArenaEpoch`]), and returns to
    /// the pool only after every registered reader has pinned the
    /// post-move epoch — so a read in flight on another thread can
    /// still dereference the old location safely (it holds the same
    /// bytes, and cannot be recycled underneath the reader). Every
    /// pointer patch is atomic, so concurrent walks never tear.
    ///
    /// The caller (or anyone) must eventually run
    /// [`crate::pmem::ArenaEpoch::try_reclaim`] /
    /// [`crate::pmem::ArenaEpoch::synchronize`] on the pool to drain
    /// limbo, or displaced blocks accumulate until the allocator drops.
    ///
    /// The move takes the leaf's **seqlock** (the per-leaf sequence
    /// word [`crate::trees::TreeWriter`] writes under): the copy waits
    /// for an in-flight write of that leaf and vice versa, so a leaf is
    /// never simultaneously written and moved, and writers acquiring
    /// after the move re-translate to the fresh block.
    ///
    /// # Safety
    /// * No [`TreeArray::leaf_slice`]-style raw slice of the tree may be
    ///   live across the call (slices cannot revalidate), on any thread.
    /// * Concurrent access from other threads is allowed **only**
    ///   through epoch-registered revalidating accessors:
    ///   [`crate::trees::TreeView`] readers,
    ///   [`crate::trees::TreeWriter`] seqlock writers, or a custom
    ///   reader following the [`crate::pmem::ReaderSlot`] pin protocol.
    ///   Cursors and the direct `get`/`set` paths do not pin the epoch
    ///   (nor seq-check) and must stay on this thread.
    /// * At most one migration of this tree in flight. Data writes
    ///   during the move are allowed **only** through
    ///   [`crate::trees::TreeWriter`] (the seqlock serializes them
    ///   against the copy); any other write path would tear.
    pub unsafe fn migrate_leaf_concurrent(&self, leaf_idx: usize) -> Result<BlockId> {
        if leaf_idx >= self.nleaves() {
            return Err(Error::IndexOutOfBounds {
                index: leaf_idx,
                len: self.nleaves(),
            });
        }
        // SAFETY: forwarded — the caller upholds the contract above,
        // which is this fn's contract with `defer_free == true`.
        unsafe { self.relocate_leaf_impl(leaf_idx, true, None) }
    }

    /// [`TreeArray::migrate_leaf_concurrent`] with a **caller-chosen
    /// destination block** — the placement-directed form background
    /// compaction uses ([`crate::mmd`]): the daemon allocates `dest`
    /// low in the pool (or inside a chosen shard) via
    /// [`crate::pmem::BlockAlloc::alloc_in_span`] and sinks the leaf
    /// into it, which is what consolidates free space instead of just
    /// shuffling it.
    ///
    /// On success ownership of `dest` transfers to the tree and the
    /// displaced block is retired into limbo (same deferred-reclaim
    /// protocol). On error (out-of-bounds leaf) the tree is untouched
    /// and the caller keeps `dest` — free it or reuse it.
    ///
    /// # Safety
    /// The full [`TreeArray::migrate_leaf_concurrent`] contract, plus:
    /// `dest` is a live block exclusively owned by the caller and not
    /// referenced by any tree.
    pub unsafe fn migrate_leaf_concurrent_to(
        &self,
        leaf_idx: usize,
        dest: BlockId,
    ) -> Result<BlockId> {
        if leaf_idx >= self.nleaves() {
            return Err(Error::IndexOutOfBounds {
                index: leaf_idx,
                len: self.nleaves(),
            });
        }
        // SAFETY: forwarded — the caller upholds the contract above.
        unsafe { self.relocate_leaf_impl(leaf_idx, true, Some(dest)) }
    }

    /// [`TreeArray::migrate_leaf`] through `&self`: location metadata is
    /// interior-mutable so leaves can move *under live cursors* — the
    /// tree's generation counter is bumped and cursors/TLBs revalidate
    /// on their next access (see [`TreeArray`]'s relocation docs).
    ///
    /// # Safety
    /// Raw leaf slices cannot revalidate, so the caller must ensure no
    /// [`TreeArray::leaf_slice`] / [`TreeArray::leaf_slice_mut`] borrow
    /// of the tree (including the `&[T]` handed to
    /// [`TreeArray::for_each_leaf_run`]'s callback) is live across the
    /// call — the moving leaf's block is freed and may be recycled and
    /// rewritten while such a slice still points at it. The caller must
    /// also ensure no *other thread* accesses the tree during the move
    /// (the same single-writer contract as
    /// [`crate::pmem::BlockAlloc::block_ptr`]).
    pub unsafe fn migrate_leaf_shared(&self, leaf_idx: usize) -> Result<BlockId> {
        if leaf_idx >= self.nleaves() {
            return Err(Error::IndexOutOfBounds {
                index: leaf_idx,
                len: self.nleaves(),
            });
        }
        // SAFETY: forwarded verbatim — the caller upholds this fn's
        // identical contract (immediate free: no concurrent readers).
        unsafe { self.relocate_leaf_impl(leaf_idx, false, None) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn migrate_preserves_contents() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        let r = Relocator::new(&a);
        let b = a.alloc().unwrap();
        a.write(b, 100, b"payload").unwrap();
        let nb = r.migrate(b).unwrap();
        assert_ne!(b, nb);
        let mut out = [0u8; 7];
        a.read(nb, 100, &mut out).unwrap();
        assert_eq!(&out, b"payload");
        assert!(!a.is_live(b));
        assert_eq!(r.stats().migrations, 1);
    }

    #[test]
    fn forwarding_chains_resolve() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        let r = Relocator::new(&a);
        let b0 = a.alloc().unwrap();
        let b1 = r.migrate(b0).unwrap();
        let b2 = r.migrate(b1).unwrap();
        assert_eq!(r.resolve(b0), b2);
        assert_eq!(r.resolve(b1), b2);
        r.clear_forwards();
        assert_eq!(r.resolve(b0), b0); // stale ids no longer forwarded
    }

    #[test]
    fn migrate_dead_block_rejected() {
        let a = BlockAllocator::new(4096, 8).unwrap();
        let r = Relocator::new(&a);
        let b = a.alloc().unwrap();
        a.free(b).unwrap();
        assert!(r.migrate(b).is_err());
    }

    #[test]
    fn tree_leaf_migration_is_transparent() {
        let a = BlockAllocator::new(1024, 256).unwrap();
        let n = 256 * 5 + 7;
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).collect();
        t.copy_from_slice(&data).unwrap();
        for leaf in 0..t.nleaves() {
            t.migrate_leaf(leaf).unwrap();
        }
        assert_eq!(t.to_vec(), data, "contents survive migrating every leaf");
        // Naive and iterator paths both see the new locations.
        assert_eq!(t.get(300).unwrap(), 300);
        assert_eq!(t.iter().last().unwrap(), n as u32 - 1);
    }

    #[test]
    fn relocator_bumps_arena_epoch_and_flushes_foreign_caches() {
        // Cross-structure shootdown: a Relocator moving a block the
        // tree does not own must still flush the tree's cursor caches
        // (the cursor cannot know the moved block wasn't one of its
        // translations). Generation counters alone would miss this —
        // this is exactly what the arena epoch generalizes.
        let a = BlockAllocator::new(1024, 256).unwrap();
        let n = 256 * 4;
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).collect();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.cursor();
        assert_eq!(c.seek(10), data[10]); // leaf 0 cached + in TLB
        let e0 = a.epoch().current();
        let r = Relocator::new(&a);
        let foreign = a.alloc().unwrap();
        let moved = r.migrate(foreign).unwrap();
        assert_eq!(a.epoch().current(), e0 + 1, "Relocator must bump the epoch");
        assert_eq!(c.seek(10), data[10]);
        assert!(
            c.tlb_stats().invalidations >= 1,
            "foreign move must flush the cursor TLB: {:?}",
            c.tlb_stats()
        );
        a.free(moved).unwrap();
    }

    #[test]
    fn migrate_leaf_concurrent_defers_the_free() {
        let a = BlockAllocator::new(1024, 256).unwrap();
        let n = 256 * 3;
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).map(|i| i ^ 0xABCD).collect();
        t.copy_from_slice(&data).unwrap();
        let live = a.stats().allocated;
        let g0 = t.generation();
        let e0 = a.epoch().current();
        // SAFETY: no raw slices, no concurrent access at all here.
        let fresh = unsafe { t.migrate_leaf_concurrent(1) }.unwrap();
        assert!(a.is_live(fresh));
        assert_eq!(t.generation(), g0 + 1);
        assert_eq!(a.epoch().current(), e0 + 1);
        // Old block parked in limbo, still counted allocated.
        assert_eq!(a.stats().allocated, live + 1, "displaced block must not be freed yet");
        assert_eq!(a.epoch().limbo_len(), 1);
        assert_eq!(t.to_vec(), data);
        // No readers registered: reclaim drains immediately.
        assert_eq!(a.epoch().synchronize(&a), 1);
        assert_eq!(a.stats().allocated, live);
        assert!(unsafe { t.migrate_leaf_concurrent(99) }.is_err(), "oob leaf");
    }

    #[test]
    fn migrate_leaf_concurrent_to_lands_on_the_chosen_block() {
        let a = BlockAllocator::new(1024, 256).unwrap();
        let n = 256 * 3;
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(7919)).collect();
        t.copy_from_slice(&data).unwrap();
        let dest = a.alloc_in_span(0, 256).unwrap();
        // SAFETY: no readers, no raw slices; dest freshly allocated.
        let got = unsafe { t.migrate_leaf_concurrent_to(1, dest) }.unwrap();
        assert_eq!(got, dest);
        assert_eq!(t.leaf_block(1), dest, "leaf must live on the chosen block");
        assert_eq!(t.to_vec(), data);
        // OOB leaf: tree untouched, caller keeps dest.
        let spare = a.alloc().unwrap();
        assert!(unsafe { t.migrate_leaf_concurrent_to(99, spare) }.is_err());
        assert!(a.is_live(spare), "failed migrate must not consume dest");
        a.free(spare).unwrap();
        a.epoch().synchronize(&a);
    }

    #[test]
    fn tree_teardown_reclaims_limbo() {
        // Satellite: blocks retired by migrate_leaf_concurrent used to
        // stay in limbo until an *explicit* try_reclaim/synchronize;
        // teardown now runs a non-blocking reclaim pass so the pool's
        // free count returns to baseline without one.
        let a = BlockAllocator::new(1024, 256).unwrap();
        assert_eq!(a.stats().allocated, 0);
        {
            let t: TreeArray<u32> = TreeArray::new(&a, 256 * 3).unwrap();
            // SAFETY: no readers, no raw slices, single thread.
            unsafe { t.migrate_leaf_concurrent(0) }.unwrap();
            unsafe { t.migrate_leaf_concurrent(1) }.unwrap();
            assert_eq!(a.epoch().limbo_len(), 2);
        } // drop: frees the tree's blocks, then drains limbo
        assert_eq!(a.epoch().limbo_len(), 0, "teardown must drain limbo");
        assert_eq!(a.stats().allocated, 0, "free count must return to baseline");
    }

    #[test]
    fn prop_random_leaf_migrations_preserve_array() {
        forall(20, |g| {
            let a = BlockAllocator::new(1024, 1 << 12).unwrap();
            let n = g.usize_in(1, 256 * 64);
            let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
            let data: Vec<u32> = (0..n).map(|_| g.rng().next_u32()).collect();
            t.copy_from_slice(&data).unwrap();
            let live_before = a.stats().allocated;
            for _ in 0..g.usize_in(0, 20) {
                let leaf = g.usize_in(0, t.nleaves() - 1);
                t.migrate_leaf(leaf).unwrap();
            }
            assert_eq!(t.to_vec(), data);
            assert_eq!(a.stats().allocated, live_before, "no block leak");
        });
    }
}
