//! Block identifiers.

/// Identifier of one fixed-size physical block.
///
/// The block's simulated physical address is
/// `id.0 as u64 * block_size as u64`; block 0 starts at physical 0.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

impl BlockId {
    /// Simulated physical byte address of the block's first byte.
    #[inline]
    pub fn phys_addr(self, block_size: usize) -> u64 {
        self.0 as u64 * block_size as u64
    }
}

impl std::fmt::Debug for BlockId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Block#{}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phys_addr_is_linear() {
        assert_eq!(BlockId(0).phys_addr(32 * 1024), 0);
        assert_eq!(BlockId(3).phys_addr(32 * 1024), 3 * 32 * 1024);
    }
}
