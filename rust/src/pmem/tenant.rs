//! Multi-tenant physical-memory policy: per-tenant block quotas with
//! soft/hard watermarks, OOM backpressure, and the per-tenant state the
//! fault-containment machinery keys on.
//!
//! The paper's premise is software-managed physical memory for
//! *colocated* workloads; Cichlid and the Virtual Block Interface both
//! argue that per-application policy is the point of dropping hardware
//! translation. This module is that policy layer:
//!
//! * [`TenantRegistry`] — admission/departure ledger. Each tenant owns
//!   a [`ProtectionDomain`] (the isolation boundary
//!   [`crate::pmem::CheckedMem`] enforces), a block quota with **soft**
//!   and **hard** watermarks, and an mmd budget *share* (its weight
//!   when the daemon splits an eviction budget across tenants).
//! * [`QuotaAlloc`] — wraps any [`BlockAlloc`] and charges/credits the
//!   tenant's atomic usage counter on every alloc/free. Crossing the
//!   soft watermark marks the tenant **pressured** (the mmd daemon
//!   preferentially evicts that tenant's cold leaves); crossing the
//!   hard watermark fails the allocation with the typed
//!   [`Error::QuotaExceeded`] — backpressure on *that tenant only*,
//!   never arena-wide failure. The pool may still be mostly free.
//!
//! # Quota = physical residency
//!
//! `used` counts the tenant's *resident* physical blocks, so eviction
//! genuinely relieves pressure:
//!
//! * alloc/free through the tenant's [`QuotaAlloc`] charge/credit.
//! * mmd **relocation** is quota-neutral (one uncharged alloc + one
//!   uncredited free per move, ownership continuous).
//! * **Eviction** of a tenant leaf credits the tenant
//!   ([`TenantRegistry::evict_credited`], called by the tenant-aware
//!   compactor pass) — the payload now lives in swap, not DRAM.
//! * **Fault-in** charges it back ([`TenantRegistry::fault_charged`],
//!   called by the fault queue on a successful tenant fault). A demand
//!   fault charges *unchecked* — it may transiently push a tenant over
//!   its hard quota, because wedging a reader that touches its own data
//!   is worse than brief overshoot; only new allocations backpressure.
//!
//! # Degraded scoping
//!
//! Each tenant carries its own sticky `degraded` flag, mirrored by the
//! [`crate::pmem::FaultQueue`] when that tenant's backing exhausts a
//! retry budget (and cleared by its next successful fault-in). One
//! tenant's dead backing parks *its* leaves; every other tenant keeps
//! faulting normally. There is no global degraded state.

use std::sync::atomic::{AtomicBool, AtomicU16, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::pmem::epoch::ArenaEpoch;
use crate::pmem::protect::ProtectionDomain;
use crate::pmem::{AllocStats, BlockAlloc, BlockId, ContentionStats};
use crate::telemetry::metrics::MetricSource;
use crate::telemetry::stat::LogHistogram;

/// The implicit tenant of tenant-unaware code paths: registrations and
/// fault requests that never name a tenant run as tenant 0 (the
/// "kernel" tenant, matching [`crate::pmem::KERNEL`]'s domain 0).
/// [`TenantRegistry::admit`] assigns real tenants ids from 1.
pub const DEFAULT_TENANT: u16 = 0;

/// Admission parameters for one tenant. Quotas are in blocks of the
/// pool the tenant's [`QuotaAlloc`] wraps.
#[derive(Clone, Copy, Debug)]
pub struct TenantConfig {
    /// Soft watermark: allocations beyond this succeed but mark the
    /// tenant *pressured* (the mmd daemon preferentially evicts its
    /// cold leaves until usage drops back under).
    pub soft_quota: usize,
    /// Hard watermark: allocations that would exceed this fail with
    /// [`Error::QuotaExceeded`]. Must be >= `soft_quota`.
    pub hard_quota: usize,
    /// mmd budget share: this tenant's weight when the daemon splits a
    /// per-tick eviction budget across tenants (see
    /// [`crate::mmd::Compactor`]'s tenant-aware passes). 0 is
    /// normalized to 1.
    pub share: u32,
}

impl TenantConfig {
    /// A tenant with the given watermarks and a share of 1.
    pub fn new(soft_quota: usize, hard_quota: usize) -> Self {
        TenantConfig {
            soft_quota,
            hard_quota,
            share: 1,
        }
    }
}

/// Interior state of one tenant; shared by every [`Tenant`] handle.
struct TenantState {
    id: u16,
    domain: ProtectionDomain,
    soft: usize,
    hard: usize,
    share: u32,
    /// Resident physical blocks charged to this tenant.
    used: AtomicUsize,
    /// High-water mark of `used`.
    peak: AtomicUsize,
    /// Sticky over-soft-quota marker; cleared when usage drops back.
    pressured: AtomicBool,
    /// Sticky per-tenant swap-degraded flag (this tenant's backing
    /// exhausted a fault retry budget; cleared by its next success).
    degraded: AtomicBool,
    /// Allocations rejected at the hard watermark.
    quota_failures: AtomicU64,
    /// Leaves of this tenant's trees evicted by the daemon.
    evictions: AtomicU64,
    /// Successful fault-ins on this tenant's behalf.
    faults: AtomicU64,
    /// Per-op latency histogram (ns) — the tenant's SLO surface.
    /// Workloads feed it via [`Tenant::record_latency_ns`] (typically
    /// sampled); `MmdReport` rows carry its p50/p99.
    lat: Mutex<LogHistogram>,
}

/// A cheap cloneable handle to one admitted tenant. All state is
/// atomic; handles stay valid after the tenant departs the registry
/// (late frees through a surviving [`QuotaAlloc`] still credit it).
#[derive(Clone)]
pub struct Tenant(Arc<TenantState>);

impl Tenant {
    /// The tenant's id (assigned by [`TenantRegistry::admit`], from 1).
    pub fn id(&self) -> u16 {
        self.0.id
    }

    /// The protection domain this tenant's checked accesses run under.
    pub fn domain(&self) -> ProtectionDomain {
        self.0.domain
    }

    /// Resident blocks currently charged to the tenant.
    pub fn used(&self) -> usize {
        self.0.used.load(Ordering::Acquire)
    }

    /// The (soft, hard) quota watermarks in blocks.
    pub fn quota(&self) -> (usize, usize) {
        (self.0.soft, self.0.hard)
    }

    /// The tenant's mmd budget share.
    pub fn share(&self) -> u32 {
        self.0.share.max(1)
    }

    /// Is the tenant over its soft watermark (eviction preference)?
    pub fn pressured(&self) -> bool {
        self.0.pressured.load(Ordering::Relaxed)
    }

    /// Is the tenant's swap backing marked degraded?
    pub fn degraded(&self) -> bool {
        self.0.degraded.load(Ordering::Relaxed)
    }

    /// Allocations this tenant had rejected at the hard watermark.
    pub fn quota_failures(&self) -> u64 {
        self.0.quota_failures.load(Ordering::Relaxed)
    }

    /// Charge `n` blocks against the quota. Over-hard fails (and rolls
    /// the charge back); over-soft succeeds and marks the tenant
    /// pressured.
    fn charge(&self, n: usize) -> Result<()> {
        let s = &*self.0;
        let prev = s.used.fetch_add(n, Ordering::AcqRel);
        let now = prev + n;
        if now > s.hard {
            s.used.fetch_sub(n, Ordering::AcqRel);
            s.quota_failures.fetch_add(1, Ordering::Relaxed);
            return Err(Error::QuotaExceeded {
                tenant: s.id,
                used: prev,
                quota: s.hard,
            });
        }
        s.peak.fetch_max(now, Ordering::Relaxed);
        if now > s.soft {
            s.pressured.store(true, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Charge without the hard-watermark check (demand fault-in of data
    /// the tenant already owns — backpressure applies to new
    /// allocations, never to reading back evicted state).
    fn charge_unchecked(&self, n: usize) {
        let s = &*self.0;
        let now = s.used.fetch_add(n, Ordering::AcqRel) + n;
        s.peak.fetch_max(now, Ordering::Relaxed);
        if now > s.soft {
            s.pressured.store(true, Ordering::Relaxed);
        }
    }

    /// Credit `n` blocks back; clears the pressured marker once usage
    /// is back under the soft watermark.
    fn credit(&self, n: usize) {
        let s = &*self.0;
        let prev = s.used.fetch_sub(n, Ordering::AcqRel);
        debug_assert!(prev >= n, "tenant {} credit underflow", s.id);
        if prev.saturating_sub(n) <= s.soft {
            s.pressured.store(false, Ordering::Relaxed);
        }
    }

    /// Record one operation latency (ns) into the tenant's SLO
    /// histogram. Callers on hot paths sample (every Nth op) — the
    /// log-scale histogram itself is cheap, but this takes a mutex.
    pub fn record_latency_ns(&self, ns: u64) {
        self.0.lat.lock().unwrap().record(ns);
    }

    /// The tenant's SLO histogram, merged out (so callers can build
    /// cross-phase aggregates without holding the lock).
    pub fn latency_hist(&self) -> LogHistogram {
        self.0.lat.lock().unwrap().clone()
    }

    /// One row of per-tenant observability (quota, pressure, faults,
    /// SLO percentiles — the `MmdReport` surfaces these).
    pub fn snapshot(&self) -> TenantSnapshot {
        let s = &*self.0;
        let (lat_ops, p50_us, p99_us) = {
            let lat = s.lat.lock().unwrap();
            (
                lat.count(),
                lat.percentile(0.50) as f64 / 1e3,
                lat.percentile(0.99) as f64 / 1e3,
            )
        };
        TenantSnapshot {
            tenant: s.id,
            domain: s.domain.0,
            used: s.used.load(Ordering::Acquire),
            peak: s.peak.load(Ordering::Relaxed),
            soft_quota: s.soft,
            hard_quota: s.hard,
            share: s.share.max(1),
            pressured: s.pressured.load(Ordering::Relaxed),
            degraded: s.degraded.load(Ordering::Relaxed),
            quota_failures: s.quota_failures.load(Ordering::Relaxed),
            evictions: s.evictions.load(Ordering::Relaxed),
            faults: s.faults.load(Ordering::Relaxed),
            lat_ops,
            p50_us,
            p99_us,
        }
    }
}

/// A point-in-time copy of one tenant's counters (a `MmdReport` row).
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantSnapshot {
    /// Tenant id.
    pub tenant: u16,
    /// The tenant's protection-domain id.
    pub domain: u16,
    /// Resident blocks charged right now.
    pub used: usize,
    /// High-water mark of `used`.
    pub peak: usize,
    /// Soft (pressure) watermark.
    pub soft_quota: usize,
    /// Hard (backpressure) watermark.
    pub hard_quota: usize,
    /// mmd budget share.
    pub share: u32,
    /// Over the soft watermark right now?
    pub pressured: bool,
    /// Swap backing marked degraded?
    pub degraded: bool,
    /// Allocations rejected at the hard watermark.
    pub quota_failures: u64,
    /// Daemon evictions of this tenant's leaves.
    pub evictions: u64,
    /// Successful fault-ins for this tenant.
    pub faults: u64,
    /// Latencies recorded into the SLO histogram (0 = no SLO data).
    pub lat_ops: u64,
    /// SLO median op latency in µs (0 with no SLO data).
    pub p50_us: f64,
    /// SLO tail (p99) op latency in µs (0 with no SLO data).
    pub p99_us: f64,
}

impl MetricSource for TenantSnapshot {
    fn metric_prefix(&self) -> &'static str {
        "tenant"
    }

    fn emit(&self, out: &mut dyn FnMut(&str, f64)) {
        out("used", self.used as f64);
        out("peak", self.peak as f64);
        out("soft_quota", self.soft_quota as f64);
        out("hard_quota", self.hard_quota as f64);
        out("share", self.share as f64);
        out("pressured", self.pressured as u8 as f64);
        out("degraded", self.degraded as u8 as f64);
        out("quota_failures", self.quota_failures as f64);
        out("evictions", self.evictions as f64);
        out("faults", self.faults as f64);
        out("lat_ops", self.lat_ops as f64);
        out("p50_us", self.p50_us);
        out("p99_us", self.p99_us);
    }
}

/// The tenant ledger: admission, departure, and the per-tenant lookups
/// the allocator wrapper, fault queue, and mmd daemon share.
pub struct TenantRegistry {
    tenants: Mutex<Vec<Tenant>>,
    next_id: AtomicU16,
}

impl TenantRegistry {
    /// An empty registry. Ids are assigned from 1
    /// ([`DEFAULT_TENANT`] = 0 stays the implicit kernel tenant).
    pub fn new() -> Self {
        TenantRegistry {
            tenants: Mutex::new(Vec::new()),
            next_id: AtomicU16::new(1),
        }
    }

    /// Admit a tenant: assigns the next id, derives its protection
    /// domain (`ProtectionDomain(id)` — ids start at 1, so no tenant
    /// ever lands on [`crate::pmem::KERNEL`]), and returns its handle.
    pub fn admit(&self, cfg: TenantConfig) -> Tenant {
        assert!(
            cfg.soft_quota <= cfg.hard_quota,
            "soft quota {} must not exceed hard quota {}",
            cfg.soft_quota,
            cfg.hard_quota
        );
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        assert!(id != 0, "tenant id space exhausted");
        let t = Tenant(Arc::new(TenantState {
            id,
            domain: ProtectionDomain(id),
            soft: cfg.soft_quota,
            hard: cfg.hard_quota,
            share: cfg.share,
            used: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            pressured: AtomicBool::new(false),
            degraded: AtomicBool::new(false),
            quota_failures: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            lat: Mutex::new(LogHistogram::new()),
        }));
        self.tenants.lock().unwrap().push(t.clone());
        t
    }

    /// Look a tenant up by id (`None` after departure or for
    /// [`DEFAULT_TENANT`]).
    pub fn get(&self, id: u16) -> Option<Tenant> {
        self.tenants.lock().unwrap().iter().find(|t| t.id() == id).cloned()
    }

    /// Tenant departure: drop the registry's handle. Outstanding
    /// [`Tenant`] handles (and any [`QuotaAlloc`] built on them) stay
    /// valid — late frees still credit the departed tenant — but the
    /// daemon stops budgeting for it. Returns the handle so callers can
    /// assert the tenant left nothing behind.
    pub fn remove(&self, id: u16) -> Option<Tenant> {
        let mut ts = self.tenants.lock().unwrap();
        let pos = ts.iter().position(|t| t.id() == id)?;
        Some(ts.remove(pos))
    }

    /// Admitted tenants right now.
    pub fn len(&self) -> usize {
        self.tenants.lock().unwrap().len()
    }

    /// Is the registry empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tenants currently over their soft watermark.
    pub fn pressured_count(&self) -> usize {
        self.tenants.lock().unwrap().iter().filter(|t| t.pressured()).count()
    }

    /// Is `id` over its soft watermark? (Unknown ids are not.)
    pub fn pressured(&self, id: u16) -> bool {
        self.get(id).map(|t| t.pressured()).unwrap_or(false)
    }

    /// Is `id`'s backing marked degraded? (Unknown ids are not.)
    pub fn degraded(&self, id: u16) -> bool {
        self.get(id).map(|t| t.degraded()).unwrap_or(false)
    }

    /// Mirror a fault queue's per-tenant degraded verdict onto the
    /// tenant's flag. No-op for unknown ids.
    pub fn set_degraded(&self, id: u16, degraded: bool) {
        if let Some(t) = self.get(id) {
            t.0.degraded.store(degraded, Ordering::Relaxed);
        }
    }

    /// Are *all* admitted tenants degraded (and at least one admitted)?
    /// The daemon reads this as "swap wholly unavailable".
    pub fn all_degraded(&self) -> bool {
        let ts = self.tenants.lock().unwrap();
        !ts.is_empty() && ts.iter().all(|t| t.degraded())
    }

    /// Sum of all admitted tenants' shares (>= 1 per tenant).
    pub fn share_total(&self) -> u64 {
        self.tenants.lock().unwrap().iter().map(|t| t.share() as u64).sum()
    }

    /// Record a successful fault-in on `id`'s behalf: counts it and
    /// charges the faulted block unchecked (see the module docs —
    /// reading your own data back never backpressures). No-op for
    /// unknown ids, so tenant-unaware queues cost nothing.
    pub fn fault_charged(&self, id: u16) {
        if let Some(t) = self.get(id) {
            t.0.faults.fetch_add(1, Ordering::Relaxed);
            t.charge_unchecked(1);
        }
    }

    /// Record a daemon eviction of one of `id`'s leaves: counts it and
    /// credits the block back (the payload now lives in swap). No-op
    /// for unknown ids.
    pub fn evict_credited(&self, id: u16) {
        if let Some(t) = self.get(id) {
            t.0.evictions.fetch_add(1, Ordering::Relaxed);
            t.credit(1);
        }
    }

    /// Snapshot every admitted tenant's counters, id-ascending (the
    /// `MmdReport`'s per-tenant rows).
    pub fn rows(&self) -> Vec<TenantSnapshot> {
        let mut rows: Vec<TenantSnapshot> =
            self.tenants.lock().unwrap().iter().map(|t| t.snapshot()).collect();
        rows.sort_by_key(|r| r.tenant);
        rows
    }
}

impl Default for TenantRegistry {
    fn default() -> Self {
        TenantRegistry::new()
    }
}

/// A per-tenant view of a shared pool: every allocation charges the
/// tenant's quota and every free credits it, with the backpressure
/// semantics described in the module docs. Implements the full
/// [`BlockAlloc`] surface, so trees/stacks/workloads built over a
/// `QuotaAlloc` are tenant-metered without knowing it.
pub struct QuotaAlloc<'a, A: BlockAlloc> {
    inner: &'a A,
    tenant: Tenant,
}

impl<'a, A: BlockAlloc> QuotaAlloc<'a, A> {
    /// Meter `inner` against `tenant`'s quota.
    pub fn new(inner: &'a A, tenant: Tenant) -> Self {
        QuotaAlloc { inner, tenant }
    }

    /// The metered tenant.
    pub fn tenant(&self) -> &Tenant {
        &self.tenant
    }

    /// The wrapped pool.
    pub fn inner(&self) -> &'a A {
        self.inner
    }

    fn charged<T>(&self, n: usize, f: impl FnOnce() -> Result<T>) -> Result<T> {
        self.tenant.charge(n)?;
        match f() {
            Ok(v) => Ok(v),
            Err(e) => {
                // The pool refused after the quota said yes: roll the
                // charge back so quota never exceeds real ownership.
                self.tenant.credit(n);
                Err(e)
            }
        }
    }
}

impl<A: BlockAlloc> BlockAlloc for QuotaAlloc<'_, A> {
    fn alloc(&self) -> Result<BlockId> {
        self.charged(1, || self.inner.alloc())
    }

    fn alloc_many(&self, n: usize) -> Result<Vec<BlockId>> {
        self.charged(n, || self.inner.alloc_many(n))
    }

    fn alloc_zeroed(&self) -> Result<BlockId> {
        self.charged(1, || self.inner.alloc_zeroed())
    }

    fn alloc_in_span(&self, lo: usize, hi: usize) -> Result<BlockId> {
        self.charged(1, || self.inner.alloc_in_span(lo, hi))
    }

    fn shard_spans(&self) -> Vec<(usize, usize)> {
        self.inner.shard_spans()
    }

    fn live_snapshot(&self, out: &mut Vec<u64>) {
        self.inner.live_snapshot(out);
    }

    fn free(&self, id: BlockId) -> Result<()> {
        self.inner.free(id)?;
        self.tenant.credit(1);
        Ok(())
    }

    fn block_size(&self) -> usize {
        self.inner.block_size()
    }

    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn free_blocks(&self) -> usize {
        self.inner.free_blocks()
    }

    fn is_live(&self, id: BlockId) -> bool {
        self.inner.is_live(id)
    }

    fn stats(&self) -> AllocStats {
        self.inner.stats()
    }

    fn contention(&self) -> ContentionStats {
        self.inner.contention()
    }

    fn epoch(&self) -> &ArenaEpoch {
        self.inner.epoch()
    }

    unsafe fn block_ptr(&self, id: BlockId) -> *mut u8 {
        // SAFETY: forwarded verbatim; the caller's obligations are the
        // inner allocator's.
        unsafe { self.inner.block_ptr(id) }
    }

    fn write(&self, id: BlockId, offset: usize, data: &[u8]) -> Result<()> {
        self.inner.write(id, offset, data)
    }

    fn read(&self, id: BlockId, offset: usize, out: &mut [u8]) -> Result<()> {
        self.inner.read(id, offset, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;

    #[test]
    fn charge_credit_and_watermarks() {
        let reg = TenantRegistry::new();
        let t = reg.admit(TenantConfig::new(2, 4));
        assert_eq!(t.id(), 1);
        assert_eq!(t.domain(), ProtectionDomain(1));
        t.charge(2).unwrap();
        assert!(!t.pressured(), "at the soft watermark is not over it");
        t.charge(1).unwrap();
        assert!(t.pressured(), "over soft marks pressured");
        t.charge(1).unwrap();
        match t.charge(1) {
            Err(Error::QuotaExceeded { tenant, used, quota }) => {
                assert_eq!((tenant, used, quota), (1, 4, 4));
            }
            other => panic!("expected QuotaExceeded, got {other:?}"),
        }
        assert_eq!(t.used(), 4, "failed charge must roll back");
        assert_eq!(t.quota_failures(), 1);
        t.credit(2);
        assert!(!t.pressured(), "credit under soft clears pressure");
        t.credit(2);
        assert_eq!(t.used(), 0);
    }

    #[test]
    fn quota_alloc_meters_a_real_pool() {
        let a = BlockAllocator::new(256, 16).unwrap();
        let reg = TenantRegistry::new();
        let t = reg.admit(TenantConfig::new(2, 3));
        let qa = QuotaAlloc::new(&a, t.clone());
        let b1 = qa.alloc().unwrap();
        let b2 = qa.alloc_zeroed().unwrap();
        let b3 = qa.alloc().unwrap();
        assert_eq!(t.used(), 3);
        assert!(t.pressured());
        // Hard watermark: typed failure, pool untouched.
        let live_before = a.stats().allocated;
        assert!(matches!(qa.alloc(), Err(Error::QuotaExceeded { tenant: 1, .. })));
        assert_eq!(a.stats().allocated, live_before, "rejected alloc must not touch the pool");
        assert!(a.free_blocks() > 0, "backpressure, not pool exhaustion");
        qa.free(b3).unwrap();
        assert!(!t.pressured(), "freeing under soft clears pressure");
        qa.free(b1).unwrap();
        qa.free(b2).unwrap();
        assert_eq!(t.used(), 0);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn pool_failure_rolls_the_charge_back() {
        let a = BlockAllocator::new(256, 2).unwrap();
        let reg = TenantRegistry::new();
        let t = reg.admit(TenantConfig::new(8, 8));
        let qa = QuotaAlloc::new(&a, t.clone());
        let held = qa.alloc_many(2).unwrap();
        assert_eq!(t.used(), 2);
        // Quota allows it, the pool is dry: OutOfMemory surfaces and
        // the speculative charge is credited back.
        assert!(matches!(qa.alloc(), Err(Error::OutOfMemory { .. })));
        assert_eq!(t.used(), 2);
        // All-or-nothing alloc_many rolls back the same way.
        assert!(qa.alloc_many(3).is_err());
        assert_eq!(t.used(), 2);
        for b in held {
            qa.free(b).unwrap();
        }
        assert_eq!(t.used(), 0);
    }

    #[test]
    fn registry_admission_departure_and_rows() {
        let reg = TenantRegistry::new();
        let t1 = reg.admit(TenantConfig::new(4, 8));
        let t2 = reg.admit(TenantConfig {
            soft_quota: 2,
            hard_quota: 4,
            share: 3,
        });
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.share_total(), 4);
        assert!(reg.get(t2.id()).is_some());
        t1.charge(5).unwrap();
        assert_eq!(reg.pressured_count(), 1);
        let rows = reg.rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].tenant, t1.id());
        assert!(rows[0].pressured && rows[0].used == 5);
        assert_eq!(rows[1].share, 3);
        // Departure: handle stays usable, registry forgets the tenant.
        let gone = reg.remove(t1.id()).unwrap();
        assert_eq!(reg.len(), 1);
        assert!(reg.get(t1.id()).is_none());
        gone.credit(5);
        assert_eq!(gone.used(), 0);
    }

    #[test]
    fn fault_and_evict_adjust_residency() {
        let reg = TenantRegistry::new();
        let t = reg.admit(TenantConfig::new(2, 2));
        t.charge(2).unwrap();
        // Eviction credits: pressure relief is the point.
        reg.evict_credited(t.id());
        assert_eq!(t.used(), 1);
        // Fault-in charges back, unchecked even at the hard watermark.
        reg.fault_charged(t.id());
        reg.fault_charged(t.id());
        assert_eq!(t.used(), 3, "demand fault-in never backpressures");
        assert!(t.pressured());
        let snap = t.snapshot();
        assert_eq!((snap.evictions, snap.faults), (1, 2));
        // Unknown ids are silent no-ops (tenant-unaware paths).
        reg.fault_charged(99);
        reg.evict_credited(99);
        reg.set_degraded(99, true);
        assert!(!reg.degraded(99));
    }

    #[test]
    fn degraded_scoping_is_per_tenant() {
        let reg = TenantRegistry::new();
        let t1 = reg.admit(TenantConfig::new(4, 8));
        let t2 = reg.admit(TenantConfig::new(4, 8));
        reg.set_degraded(t1.id(), true);
        assert!(reg.degraded(t1.id()));
        assert!(!reg.degraded(t2.id()), "one tenant's dead backing is its own");
        assert!(!reg.all_degraded());
        reg.set_degraded(t2.id(), true);
        assert!(reg.all_degraded());
        reg.set_degraded(t1.id(), false);
        assert!(!reg.all_degraded());
    }
}
