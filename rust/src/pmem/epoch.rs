//! The arena-wide relocation epoch: cross-tree translation shootdown
//! plus quiescent-state block reclamation.
//!
//! PR 2's generation counters are *per tree*: a cursor over tree A
//! revalidates when A's own leaves move, but a [`crate::pmem::Relocator`]
//! or [`crate::pmem::SwapPool`] moving blocks elsewhere in the same pool
//! leaves A's counter untouched while still recycling physical blocks a
//! cached translation may point at. The epoch generalizes the scheme to
//! the whole arena, the way the Virtual Block Interface argues
//! translation state should work: per-client caches over shared
//! metadata, invalidated by one cheap counter instead of an IPI storm.
//!
//! Every [`crate::pmem::BlockAlloc`] pool owns one [`ArenaEpoch`]. Two
//! protocols run over it:
//!
//! 1. **Shootdown** — *any* relocation in the pool
//!    ([`crate::trees::TreeArray::migrate_leaf`] and friends,
//!    `Relocator::migrate`, `SwapPool::evict`/`fault`) bumps the epoch.
//!    Translation caches ([`crate::trees::Cursor`],
//!    [`crate::trees::TreeView`]) snapshot the epoch and flush wholesale
//!    on mismatch — conservative (a move in tree B flushes views of
//!    tree A) but O(1) to publish and impossible to forget, exactly the
//!    trade hardware TLB shootdown makes in the other direction.
//!
//! 2. **Quiescent-state reclamation** — concurrent readers cannot use
//!    "check a counter on the next access" alone: a block freed *while a
//!    read is in flight* may be recycled and scribbled under the
//!    reader's feet. So readers **register** a slot and **pin** the
//!    current epoch before every translation; a concurrent relocation
//!    ([`crate::trees::TreeArray::migrate_leaf_concurrent`]) does not
//!    free the displaced block but **retires** it into a limbo list
//!    tagged with the post-move epoch. [`ArenaEpoch::try_reclaim`] frees
//!    a retired block only once every registered reader has pinned an
//!    epoch at or past the retirement point (or gone offline) — by then
//!    no reader can hold a pre-move translation, because pinning a newer
//!    epoch flushes its caches before any further dereference. This is
//!    QSBR (RCU's userspace cousin, the llfree-rs idiom applied to
//!    translation instead of allocation): readers pay two uncontended
//!    atomic ops per pin, writers pay the wait.
//!
//! The scheme is cooperative: a registered reader that stops pinning
//! (without dropping its slot) stalls reclamation — limbo grows but
//! nothing is unsafe. [`crate::trees::TreeView`] pins on every access
//! and deregisters on drop, so view-based readers always make progress.
//! [`crate::trees::TreeWriter`] registers and pins exactly like a
//! reader: its read paths and cached translations are covered by the
//! same quiescence argument (its *writes* are protected by the per-leaf
//! seqlock instead — a write only ever lands on a leaf's current block).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::pmem::alloc_trait::BlockAlloc;
use crate::pmem::BlockId;

/// Slot value of a reader that is not currently reading: reclamation
/// never waits on an offline reader.
pub const OFFLINE: u64 = u64::MAX;

/// Counter snapshot of one [`ArenaEpoch`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EpochStats {
    /// Current epoch value (total relocations in the pool's lifetime).
    pub epoch: u64,
    /// Registered reader slots.
    pub readers: usize,
    /// Blocks retired into limbo over the pool's lifetime.
    pub retired: u64,
    /// Retired blocks returned to the pool so far.
    pub reclaimed: u64,
    /// Blocks currently in limbo (retired, not yet reclaimable).
    pub limbo: usize,
    /// Cumulative epochs reclaimed blocks waited in limbo — the
    /// reclaim-latency counter (divide by `reclaimed` for the mean).
    pub reclaim_lag: u64,
    /// Reader pins performed ([`ReaderSlot::pin`] calls) over the
    /// pool's lifetime.
    pub pins: u64,
    /// Pins *avoided* by batched pinning: an N-access batch path pins
    /// once and reports N-1 here ([`ReaderSlot::record_saved_pins`]).
    /// `pins + saved_pins` is what per-access pinning would have cost.
    pub saved_pins: u64,
}

impl EpochStats {
    /// Mean epochs a reclaimed block waited in limbo (0 when nothing
    /// has been reclaimed yet).
    pub fn mean_reclaim_lag(&self) -> f64 {
        if self.reclaimed == 0 {
            0.0
        } else {
            self.reclaim_lag as f64 / self.reclaimed as f64
        }
    }
}

/// The shared relocation epoch of one block pool. See the module docs
/// for the shootdown and reclamation protocols.
pub struct ArenaEpoch {
    /// Bumped once per relocation, after all pointers are patched.
    global: AtomicU64,
    /// Registered reader slots. Each holds the epoch its reader last
    /// pinned, or [`OFFLINE`].
    readers: Mutex<Vec<Arc<AtomicU64>>>,
    /// Retired blocks awaiting quiescence: `(block, retire_epoch)`.
    limbo: Mutex<Vec<(BlockId, u64)>>,
    retired_total: AtomicU64,
    reclaimed_total: AtomicU64,
    /// Sum over reclaimed blocks of (reclaim epoch - retire epoch).
    lag_total: AtomicU64,
    /// Pins performed / pins amortized away by batch paths.
    pins_total: AtomicU64,
    saved_pins_total: AtomicU64,
}

impl ArenaEpoch {
    /// A fresh epoch at 0 with no readers and an empty limbo list.
    pub fn new() -> Self {
        ArenaEpoch {
            global: AtomicU64::new(0),
            readers: Mutex::new(Vec::new()),
            limbo: Mutex::new(Vec::new()),
            retired_total: AtomicU64::new(0),
            reclaimed_total: AtomicU64::new(0),
            lag_total: AtomicU64::new(0),
            pins_total: AtomicU64::new(0),
            saved_pins_total: AtomicU64::new(0),
        }
    }

    /// Current epoch. Caches compare this against their snapshot and
    /// flush on mismatch (the shootdown check).
    #[inline]
    pub fn current(&self) -> u64 {
        self.global.load(Ordering::Acquire)
    }

    /// Publish one relocation: bump the epoch *after* every pointer is
    /// patched, so a reader observing the new value observes a
    /// consistent translation structure. Returns the new epoch.
    ///
    /// `SeqCst`: the reclamation argument (see [`ReaderSlot::pin`])
    /// needs bumps, slot stores, and slot samples to sit in one total
    /// order.
    #[inline]
    pub fn bump(&self) -> u64 {
        self.global.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Register a reader slot (initially [`OFFLINE`]). The slot
    /// deregisters itself on drop.
    pub fn register(&self) -> ReaderSlot<'_> {
        let slot = Arc::new(AtomicU64::new(OFFLINE));
        self.readers.lock().unwrap().push(slot.clone());
        ReaderSlot { epoch: self, slot }
    }

    /// Retire a displaced block: it stays allocated (so it cannot be
    /// recycled) until [`ArenaEpoch::try_reclaim`] proves no reader can
    /// still hold a translation into it.
    pub fn retire(&self, block: BlockId, retire_epoch: u64) {
        self.limbo.lock().unwrap().push((block, retire_epoch));
        self.retired_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Oldest epoch any registered reader may still be reading at
    /// ([`OFFLINE`] when every reader is offline or none exist).
    fn min_reader_epoch(&self) -> u64 {
        self.readers
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.load(Ordering::SeqCst))
            .min()
            .unwrap_or(OFFLINE)
    }

    /// Free every retired block all readers have quiesced past,
    /// returning how many went back to the pool. Non-blocking: blocks
    /// some reader may still reference stay in limbo.
    ///
    /// `alloc` must be the pool this epoch belongs to (the one whose
    /// relocations retired the blocks).
    pub fn try_reclaim<A: BlockAlloc + ?Sized>(&self, alloc: &A) -> usize {
        // Take the limbo lock BEFORE sampling reader slots: a retirement
        // is visible in limbo only after its epoch bump (retire() runs
        // after bump()), so sampling second guarantees that for every
        // entry `r` considered here, a reader racing its online
        // transition either confirmed a pin >= r (it synchronized with
        // the bump and sees the patched pointers — cannot reach the
        // retired block) or its slot store < r was already visible to
        // this sample (we keep the block). Sampling before reading
        // limbo would let a just-pinned reader at `e < r` be missed.
        let mut limbo = self.limbo.lock().unwrap();
        if limbo.is_empty() {
            return 0;
        }
        let safe = self.min_reader_epoch();
        let now = self.current();
        let before = limbo.len();
        limbo.retain(|&(block, retire_epoch)| {
            if retire_epoch <= safe {
                let freed = alloc.free(block);
                debug_assert!(freed.is_ok(), "reclaiming retired block failed: {freed:?}");
                // Reclaim latency: how many epochs the block sat in
                // limbo before readers quiesced past it.
                self.lag_total
                    .fetch_add(now.saturating_sub(retire_epoch), Ordering::Relaxed);
                false
            } else {
                true
            }
        });
        let freed = before - limbo.len();
        self.reclaimed_total.fetch_add(freed as u64, Ordering::Relaxed);
        freed
    }

    /// Block until the limbo list drains (readers keep pinning, so each
    /// pass frees what has quiesced). Returns the number reclaimed.
    ///
    /// Livelock caveat: a registered reader that never pins again and is
    /// never dropped stalls this forever — the cooperative contract in
    /// the module docs.
    pub fn synchronize<A: BlockAlloc + ?Sized>(&self, alloc: &A) -> usize {
        let mut total = 0;
        loop {
            total += self.try_reclaim(alloc);
            if self.limbo.lock().unwrap().is_empty() {
                return total;
            }
            std::thread::yield_now();
        }
    }

    /// Blocks currently in limbo.
    pub fn limbo_len(&self) -> usize {
        self.limbo.lock().unwrap().len()
    }

    /// Counter snapshot.
    pub fn stats(&self) -> EpochStats {
        EpochStats {
            epoch: self.current(),
            readers: self.readers.lock().unwrap().len(),
            retired: self.retired_total.load(Ordering::Relaxed),
            reclaimed: self.reclaimed_total.load(Ordering::Relaxed),
            limbo: self.limbo_len(),
            reclaim_lag: self.lag_total.load(Ordering::Relaxed),
            pins: self.pins_total.load(Ordering::Relaxed),
            saved_pins: self.saved_pins_total.load(Ordering::Relaxed),
        }
    }

    /// Mirror the reclamation counters into an [`crate::pmem::AllocStats`]
    /// (both allocators call this from `stats()` so limbo depth and
    /// reclaim latency surface next to the allocation counters).
    pub(crate) fn fill_alloc_stats(&self, s: &mut crate::pmem::AllocStats) {
        s.limbo = self.limbo_len();
        s.retired = self.retired_total.load(Ordering::Relaxed);
        s.reclaimed = self.reclaimed_total.load(Ordering::Relaxed);
        s.reclaim_lag = self.lag_total.load(Ordering::Relaxed);
    }
}

impl Default for ArenaEpoch {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for ArenaEpoch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "ArenaEpoch {{ epoch: {}, readers: {}, limbo: {} }}",
            s.epoch, s.readers, s.limbo
        )
    }
}

/// One reader's registration with an [`ArenaEpoch`].
///
/// The slot holds the epoch its owner last pinned. Reclamation treats
/// the owner as potentially holding translations obtained at that epoch
/// until a newer one is pinned (or the slot goes [`OFFLINE`] /
/// drops). Owned by [`crate::trees::TreeView`]; usable directly by any
/// custom reader that wants the same guarantee.
pub struct ReaderSlot<'e> {
    epoch: &'e ArenaEpoch,
    slot: Arc<AtomicU64>,
}

impl ReaderSlot<'_> {
    /// Pin the current epoch: publish "I may hold translations obtained
    /// at epoch `e`" *before* performing them. Returns `e` so the caller
    /// can flush its caches when the value moved since its last pin —
    /// the flush must happen before the caller dereferences anything,
    /// which is what makes a slot value of `e` proof of quiescence for
    /// blocks retired before `e`.
    ///
    /// Store-confirm loop: publishing `e` and then re-reading the
    /// global closes the online-transition race. Without the confirm, a
    /// reader coming back from [`OFFLINE`] could load epoch `e`, a
    /// relocation could retire a block at `e+1` and a reclaimer sample
    /// the slot while it still reads `OFFLINE` (the store not yet
    /// visible) — freeing a block this reader is about to dereference
    /// through a still-cached translation. With the confirm, a
    /// successful pin at `e` means the store was in place before any
    /// bump past `e`, so a reclaimer deciding the fate of a block
    /// retired at `r > e` (it samples slots only after `r` is visible
    /// in limbo, i.e. after `bump() -> r`) must observe this slot at
    /// `e < r` and keep the block; and for `r <= e` the confirming
    /// read synchronized with `bump() -> r`, so the caller sees the
    /// patched pointers (and flushes stale cache state first).
    #[inline]
    pub fn pin(&self) -> u64 {
        self.epoch.pins_total.fetch_add(1, Ordering::Relaxed);
        loop {
            let e = self.epoch.global.load(Ordering::SeqCst);
            self.slot.store(e, Ordering::SeqCst);
            if self.epoch.global.load(Ordering::SeqCst) == e {
                return e;
            }
        }
    }

    /// Credit `n` pins amortized away by a batch path: a caller that
    /// pinned once for an N-access batch reports N-1 here, so
    /// [`EpochStats::pins`] + [`EpochStats::saved_pins`] is the cost
    /// per-access pinning would have paid. Pure accounting — no effect
    /// on the reclamation protocol.
    #[inline]
    pub fn record_saved_pins(&self, n: u64) {
        if n > 0 {
            self.epoch.saved_pins_total.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Go offline: reclamation stops waiting on this reader until its
    /// next [`ReaderSlot::pin`]. Call between bursts of reads when the
    /// reader idles with translations it promises not to use.
    #[inline]
    pub fn unpin(&self) {
        self.slot.store(OFFLINE, Ordering::SeqCst);
    }

    /// The epoch this slot is registered with.
    pub fn arena_epoch(&self) -> &ArenaEpoch {
        self.epoch
    }
}

impl Drop for ReaderSlot<'_> {
    fn drop(&mut self) {
        let mut readers = self.epoch.readers.lock().unwrap();
        if let Some(i) = readers.iter().position(|s| Arc::ptr_eq(s, &self.slot)) {
            readers.swap_remove(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;

    #[test]
    fn bump_and_current() {
        let e = ArenaEpoch::new();
        assert_eq!(e.current(), 0);
        assert_eq!(e.bump(), 1);
        assert_eq!(e.bump(), 2);
        assert_eq!(e.current(), 2);
    }

    #[test]
    fn reclaim_without_readers_is_immediate() {
        let a = BlockAllocator::new(1024, 8).unwrap();
        let b = a.alloc().unwrap();
        let e = a.epoch();
        let re = e.bump();
        e.retire(b, re);
        assert!(a.is_live(b), "retired blocks stay allocated");
        assert_eq!(e.limbo_len(), 1);
        assert_eq!(e.try_reclaim(&a), 1);
        assert!(!a.is_live(b));
        assert_eq!(e.stats().reclaimed, 1);
    }

    #[test]
    fn pinned_reader_blocks_reclaim_until_it_advances() {
        let a = BlockAllocator::new(1024, 8).unwrap();
        let e = a.epoch();
        let reader = e.register();
        reader.pin(); // reading at epoch 0
        let b = a.alloc().unwrap();
        let re = e.bump(); // relocation happens at epoch 1
        e.retire(b, re);
        // The reader pinned epoch 0 < 1: it may still hold a translation
        // into `b`, so nothing can be freed.
        assert_eq!(e.try_reclaim(&a), 0);
        assert!(a.is_live(b));
        // Reader quiesces (pins the new epoch, flushing its caches
        // first per the contract) -> the block is reclaimable.
        reader.pin();
        assert_eq!(e.try_reclaim(&a), 1);
        assert!(!a.is_live(b));
    }

    #[test]
    fn offline_and_dropped_readers_never_stall_reclaim() {
        let a = BlockAllocator::new(1024, 8).unwrap();
        let e = a.epoch();
        let r1 = e.register();
        r1.pin();
        let r2 = e.register();
        r2.pin();
        let b = a.alloc().unwrap();
        let re = e.bump();
        e.retire(b, re);
        assert_eq!(e.try_reclaim(&a), 0, "two stale readers");
        r1.unpin(); // offline: ignored
        assert_eq!(e.try_reclaim(&a), 0, "r2 still stale");
        drop(r2); // deregistered
        assert_eq!(e.try_reclaim(&a), 1);
        assert_eq!(e.stats().readers, 1, "r1 still registered");
    }

    #[test]
    fn reclaim_lag_measures_epochs_in_limbo() {
        let a = BlockAllocator::new(1024, 8).unwrap();
        let e = a.epoch();
        // Immediate reclaim: retired and reclaimed at the same epoch.
        let b1 = a.alloc().unwrap();
        e.retire(b1, e.bump());
        assert_eq!(e.try_reclaim(&a), 1);
        assert_eq!(e.stats().reclaim_lag, 0);
        // Two more relocations happen before b2 is reclaimed: lag 2.
        let b2 = a.alloc().unwrap();
        e.retire(b2, e.bump());
        e.bump();
        e.bump();
        assert_eq!(e.try_reclaim(&a), 1);
        let s = e.stats();
        assert_eq!(s.reclaim_lag, 2);
        assert!((s.mean_reclaim_lag() - 1.0).abs() < 1e-9, "2 lag / 2 reclaimed");
        // And the allocator surfaces the same numbers in AllocStats.
        let alloc_stats = a.stats();
        assert_eq!(alloc_stats.reclaimed, 2);
        assert_eq!(alloc_stats.reclaim_lag, 2);
        assert_eq!(alloc_stats.limbo, 0);
    }

    #[test]
    fn pin_accounting_tracks_batching() {
        let e = ArenaEpoch::new();
        let r = e.register();
        r.pin();
        r.pin();
        r.record_saved_pins(7); // an 8-access batch that pinned once
        r.record_saved_pins(0); // no-op
        let s = e.stats();
        assert_eq!(s.pins, 2);
        assert_eq!(s.saved_pins, 7);
    }

    #[test]
    fn reclaim_is_per_retire_epoch() {
        let a = BlockAllocator::new(1024, 8).unwrap();
        let e = a.epoch();
        let r = e.register();
        let b1 = a.alloc().unwrap();
        e.retire(b1, e.bump());
        r.pin(); // quiesced past b1's retirement...
        let b2 = a.alloc().unwrap();
        e.retire(b2, e.bump()); // ...but not b2's
        assert_eq!(e.try_reclaim(&a), 1, "only b1 reclaimable");
        assert!(!a.is_live(b1));
        assert!(a.is_live(b2));
        r.pin();
        assert_eq!(e.synchronize(&a), 1);
        assert_eq!(e.limbo_len(), 0);
    }
}
