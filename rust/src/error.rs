//! Crate-wide error type (hand-rolled Display/Error impls: the offline
//! build has no `thiserror`).

/// Errors surfaced by the nvm library.
#[derive(Debug)]
pub enum Error {
    /// The physical block pool has no free blocks left.
    OutOfMemory {
        /// Blocks requested by the failing call.
        requested: usize,
        /// Blocks currently free.
        free: usize,
        /// Total pool capacity in blocks.
        capacity: usize,
    },

    /// A block handle was used after being freed, or double-freed.
    InvalidBlock(crate::pmem::BlockId),

    /// Element index out of bounds for a tree array.
    IndexOutOfBounds {
        /// Offending index.
        index: usize,
        /// Array length.
        len: usize,
    },

    /// Requested array cannot be represented at the given node geometry.
    TooLarge {
        /// Requested length.
        len: usize,
        /// Maximum representable length.
        max: usize,
        /// Maximum supported depth.
        max_depth: u32,
    },

    /// A stack frame larger than the stack block size was requested.
    FrameTooLarge {
        /// Requested frame size.
        frame: usize,
        /// Maximum frame payload per block.
        payload: usize,
    },

    /// Split-stack machine popped an empty stack.
    StackUnderflow,

    /// A permission-checked access was denied by the protection table.
    Protection {
        /// The block whose check failed.
        block: crate::pmem::BlockId,
        /// Offending domain id.
        domain: u16,
        /// Was it a write?
        write: bool,
        /// Was it an instruction fetch?
        exec: bool,
    },

    /// The block is swapped out and must be faulted in first.
    SwappedOut(crate::pmem::BlockId),

    /// A tenant's allocation would exceed its hard block quota
    /// ([`crate::pmem::QuotaAlloc`]). Backpressure, not pool
    /// exhaustion: the arena still has free blocks, this tenant has
    /// spent its share. The tenant can free blocks and retry; no other
    /// tenant is affected.
    QuotaExceeded {
        /// The tenant whose quota is exhausted.
        tenant: u16,
        /// Blocks the tenant holds right now.
        used: usize,
        /// The tenant's hard quota in blocks.
        quota: usize,
    },

    /// A swap fault-in exhausted its retries against a failing backing
    /// store — the fault queue's permanent-failure escalation
    /// ([`crate::pmem::FaultQueue`]). The payload is still resident in
    /// its slot; the fault may be retried once the backing recovers.
    SwapFaultFailed {
        /// The swap slot whose payload could not be read back.
        slot: u64,
        /// I/O attempts made before giving up.
        attempts: u32,
    },

    /// An artifact file is missing or malformed.
    Artifact(String),

    /// Invalid experiment / CLI configuration.
    Config(String),

    /// XLA / PJRT runtime failure.
    Xla(String),

    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::OutOfMemory {
                requested,
                free,
                capacity,
            } => write!(
                f,
                "out of physical memory: {requested} blocks requested, {free} free (capacity {capacity})"
            ),
            Error::InvalidBlock(b) => write!(f, "invalid block handle {b:?} (freed or foreign)"),
            Error::IndexOutOfBounds { index, len } => {
                write!(f, "index {index} out of bounds for tree array of length {len}")
            }
            Error::TooLarge { len, max, max_depth } => write!(
                f,
                "array of {len} elements exceeds max tree capacity {max} (depth {max_depth})"
            ),
            Error::FrameTooLarge { frame, payload } => write!(
                f,
                "frame of {frame} bytes exceeds stack block payload {payload} bytes"
            ),
            Error::StackUnderflow => write!(f, "stack underflow"),
            Error::Protection {
                block,
                domain,
                write,
                exec,
            } => {
                let verb = if *exec {
                    "executing"
                } else if *write {
                    "writing"
                } else {
                    "reading"
                };
                write!(f, "protection fault: domain {domain} {verb} {block:?}")
            }
            Error::QuotaExceeded { tenant, used, quota } => write!(
                f,
                "tenant {tenant} over hard quota: {used} blocks used of {quota} allowed"
            ),
            Error::SwappedOut(b) => write!(f, "block {b:?} is swapped out"),
            Error::SwapFaultFailed { slot, attempts } => write!(
                f,
                "swap fault-in of slot {slot} failed permanently after {attempts} attempts"
            ),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_informative() {
        let e = Error::OutOfMemory {
            requested: 3,
            free: 1,
            capacity: 8,
        };
        let s = e.to_string();
        assert!(s.contains('3') && s.contains("capacity 8"), "{s}");
        assert!(Error::StackUnderflow.to_string().contains("underflow"));
        let q = Error::QuotaExceeded {
            tenant: 7,
            used: 12,
            quota: 10,
        };
        let s = q.to_string();
        assert!(s.contains("tenant 7") && s.contains("12") && s.contains("10"), "{s}");
    }

    #[test]
    fn protection_verbs() {
        let mk = |write, exec| Error::Protection {
            block: crate::pmem::BlockId(1),
            domain: 2,
            write,
            exec,
        };
        assert!(mk(false, false).to_string().contains("reading"));
        assert!(mk(true, false).to_string().contains("writing"));
        assert!(mk(false, true).to_string().contains("executing"));
    }

    #[test]
    fn io_error_converts() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(matches!(e, Error::Io(_)));
    }
}
