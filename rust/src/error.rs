//! Crate-wide error type.

/// Errors surfaced by the nvm library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// The physical block pool has no free blocks left.
    #[error("out of physical memory: {requested} blocks requested, {free} free (capacity {capacity})")]
    OutOfMemory {
        /// Blocks requested by the failing call.
        requested: usize,
        /// Blocks currently free.
        free: usize,
        /// Total pool capacity in blocks.
        capacity: usize,
    },

    /// A block handle was used after being freed, or double-freed.
    #[error("invalid block handle {0:?} (freed or foreign)")]
    InvalidBlock(crate::pmem::BlockId),

    /// Element index out of bounds for a tree array.
    #[error("index {index} out of bounds for tree array of length {len}")]
    IndexOutOfBounds {
        /// Offending index.
        index: usize,
        /// Array length.
        len: usize,
    },

    /// Requested array cannot be represented at the given node geometry.
    #[error("array of {len} elements exceeds max tree capacity {max} (depth {max_depth})")]
    TooLarge {
        /// Requested length.
        len: usize,
        /// Maximum representable length.
        max: usize,
        /// Maximum supported depth.
        max_depth: u32,
    },

    /// A stack frame larger than the stack block size was requested.
    #[error("frame of {frame} bytes exceeds stack block payload {payload} bytes")]
    FrameTooLarge {
        /// Requested frame size.
        frame: usize,
        /// Maximum frame payload per block.
        payload: usize,
    },

    /// Split-stack machine popped an empty stack.
    #[error("stack underflow")]
    StackUnderflow,

    /// A permission-checked access was denied by the protection table.
    #[error("protection fault: domain {domain} {} {block:?}", if *exec { "executing" } else if *write { "writing" } else { "reading" })]
    Protection {
        /// The block whose check failed.
        block: crate::pmem::BlockId,
        /// Offending domain id.
        domain: u16,
        /// Was it a write?
        write: bool,
        /// Was it an instruction fetch?
        exec: bool,
    },

    /// The block is swapped out and must be faulted in first.
    #[error("block {0:?} is swapped out")]
    SwappedOut(crate::pmem::BlockId),

    /// An artifact file is missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Invalid experiment / CLI configuration.
    #[error("config error: {0}")]
    Config(String),

    /// XLA / PJRT runtime failure.
    #[error("xla error: {0}")]
    Xla(String),

    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
