//! Test utilities: a deterministic RNG, a minimal property-testing
//! harness (`proptest_lite`), the differential op oracle
//! ([`diffops`]), and fault-injection doubles ([`fault`]).
//!
//! The offline build environment has no `proptest`/`rand` crates, so the
//! crate ships its own splitmix64/xoshiro-based generator and a tiny
//! property runner with input shrinking. Benches reuse [`Rng`] for
//! workload generation so experiments are reproducible bit-for-bit.
//! See `TESTING.md` for how these tiers fit together.

pub mod diffops;
pub mod fault;
pub mod proptest_lite;

pub use diffops::DiffOutcome;
pub use fault::{AllocFailControl, FailControl, FailingAlloc, FailingBacking};
pub use proptest_lite::{forall, Gen};

/// Deterministic 64-bit RNG (splitmix64 seeded xoshiro256**).
///
/// Not cryptographic; statistically solid for workload generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create an RNG from a seed; equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64-bit value (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's method; `bound > 0`).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// `n` distinct indices in `[0, bound)` (n must be ≤ bound).
    pub fn distinct(&mut self, n: usize, bound: usize) -> Vec<usize> {
        assert!(n <= bound);
        if n * 4 >= bound {
            let mut all: Vec<usize> = (0..bound).collect();
            self.shuffle(&mut all);
            all.truncate(n);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(n);
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let v = self.below(bound as u64) as usize;
            if seen.insert(v) {
                out.push(v);
            }
        }
        out
    }
}

/// Build a **deliberately fragmented** pool hosting one tree: allocate
/// the whole pool, free a strided set of blocks, land the tree's
/// `leaves` leaf blocks (+ its root) exactly in those holes, then
/// release the rest — live blocks end up sprinkled every
/// `capacity / (leaves + 1)` ids, shredding free space into short runs.
/// The shared setup of the mmd compaction tests, the
/// `fragmentation-churn` experiment, and the `ablation_compaction`
/// bench; `fill(i)` supplies element `i`, and the returned mirror is
/// the reference the tree must keep matching.
///
/// Requirements: an empty pool, `u64` leaf capacity `block_size / 8`,
/// `leaves + 1 <= capacity / 2` (so the stride is at least 2), and
/// `leaves` within one interior node's fanout (depth 2). The tree is
/// returned with its flat leaf table built (the serving configuration
/// the concurrent experiments use).
pub fn fragmented_tree<A: crate::pmem::BlockAlloc>(
    a: &A,
    leaves: usize,
    fill: impl Fn(u64) -> u64,
) -> (crate::trees::TreeArray<'_, u64, A>, Vec<u64>) {
    use crate::trees::TreeArray;
    let cap = a.capacity();
    assert_eq!(a.stats().allocated, 0, "fragmented_tree wants an empty pool");
    let elems = leaves * (a.block_size() / 8);
    let all = a.alloc_many(cap).expect("fill pool");
    let total = leaves + 1; // leaves + root (depth 2)
    let stride = cap / total;
    assert!(stride >= 2, "need room to perforate: {cap} blocks / {total} tree blocks");
    let mut scratch = Vec::new();
    for (i, b) in all.into_iter().enumerate() {
        if i % stride == 0 && i / stride < total {
            a.free(b).expect("perforate");
        } else {
            scratch.push(b);
        }
    }
    let mut tree: TreeArray<u64, A> = TreeArray::new(a, elems).expect("strided tree");
    let mirror: Vec<u64> = (0..elems as u64).map(fill).collect();
    tree.copy_from_slice(&mirror).expect("fill tree");
    tree.enable_flat_table();
    let _ = tree.get(0); // build the flat table before sharing
    for b in scratch {
        a.free(b).expect("release scratch");
    }
    (tree, mirror)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn distinct_has_no_dups() {
        let mut r = Rng::new(11);
        let v = r.distinct(100, 1000);
        let set: std::collections::HashSet<_> = v.iter().collect();
        assert_eq!(set.len(), 100);
        let v2 = r.distinct(50, 60); // dense path
        let set2: std::collections::HashSet<_> = v2.iter().collect();
        assert_eq!(set2.len(), 50);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
