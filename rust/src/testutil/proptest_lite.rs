//! Minimal property-based testing: random case generation with greedy
//! shrinking, in the spirit of proptest/quickcheck (neither crate is
//! reachable in the offline build).
//!
//! ```
//! use nvm::testutil::proptest_lite::{forall, Gen};
//!
//! forall(200, |g| {
//!     let n = g.usize_in(0, 1000);
//!     let doubled = n * 2;
//!     assert!(doubled % 2 == 0, "n={n}");
//! });
//! ```

use super::Rng;

/// Per-case generator handle. Records sizes so failures can shrink.
pub struct Gen {
    rng: Rng,
    /// Scale factor in (0, 1]; shrinking retries with smaller scales.
    scale: f64,
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Gen {
            rng: Rng::new(seed),
            scale,
        }
    }

    /// Access the underlying RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// usize in `[lo, hi]`, biased smaller while shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.scale).ceil() as usize;
        lo + self.rng.below(span as u64 + 1) as usize
    }

    /// u64 in `[0, bound)`.
    pub fn u64_below(&mut self, bound: u64) -> u64 {
        self.rng.below(bound)
    }

    /// f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.f32_range(lo, hi)
    }

    /// Bernoulli.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vec of `len` items drawn from `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Choose uniformly from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Run `prop` against `cases` random cases. On panic, retry the failing
/// seed at smaller scales (shrinking) and report the smallest failure.
///
/// Panics (failing the enclosing test) if any case fails.
pub fn forall(cases: u32, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    // Fixed base seed: reproducible CI. Override with NVM_PROPTEST_SEED.
    let base: u64 = std::env::var("NVM_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let outcome = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        });
        if outcome.is_err() {
            // Shrink: rerun the same seed with progressively smaller
            // scales; the smallest still-failing scale is the report.
            let mut smallest = 1.0f64;
            for &scale in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                let fails = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, scale);
                    prop(&mut g);
                })
                .is_err();
                if fails {
                    smallest = scale;
                } else {
                    break;
                }
            }
            // Re-raise at the smallest failing scale with context.
            eprintln!(
                "proptest_lite: case {case} failed (seed={seed:#x}, shrunk scale={smallest}); \
                 rerun with NVM_PROPTEST_SEED={base}"
            );
            let mut g = Gen::new(seed, smallest);
            prop(&mut g); // panics again, surfacing the assertion
            unreachable!("property failed under catch_unwind but passed on rerun");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(50, |g| {
            let n = g.usize_in(0, 100);
            assert!(n <= 100);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        forall(50, |g| {
            let n = g.usize_in(0, 100);
            assert!(n < 90, "found large n={n}");
        });
    }

    #[test]
    fn vec_gen_len() {
        forall(20, |g| {
            let len = g.usize_in(0, 32);
            let v = g.vec(len, |g| g.f32_in(0.0, 1.0));
            assert_eq!(v.len(), len);
        });
    }
}
