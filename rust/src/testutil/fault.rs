//! Fault-injection doubles for the swap backing.
//!
//! [`FailingBacking`] implements [`SwapBacking`] over an in-memory
//! byte store and fails the N-th subsequent I/O on command, so tests
//! can hit `SwapPool`'s error paths at exact points and assert the
//! failure-atomicity the happy-path tests merely assume: a failed
//! `stash` must roll its slot back, a failed `fault` must keep the
//! payload resident. (It doubles as a fast in-memory backing for
//! high-case-count suites — the differential harness — where creating
//! one temp file per case would dominate the runtime.)

use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::pmem::SwapBacking;

/// Remote control for a [`FailingBacking`] that has been moved into a
/// `SwapPool`: arm faults and observe I/O counts from the test body.
#[derive(Clone)]
pub struct FailControl {
    /// I/Os until the next injected failure; 0 = disarmed.
    arm: Arc<AtomicU64>,
    /// Total I/O calls observed.
    ops: Arc<AtomicU64>,
}

impl FailControl {
    /// Fail the `n`-th I/O from now (`1` = the very next call), then
    /// disarm — exactly one failure per arming.
    pub fn fail_nth(&self, n: u64) {
        assert!(n > 0, "fail_nth counts from 1");
        self.arm.store(n, Ordering::Relaxed);
    }

    /// Cancel a pending injected failure.
    pub fn disarm(&self) {
        self.arm.store(0, Ordering::Relaxed);
    }

    /// Total backing I/Os performed so far (including the failed ones).
    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }
}

/// An in-memory [`SwapBacking`] whose I/Os can be made to fail on
/// command via the paired [`FailControl`].
pub struct FailingBacking {
    data: Vec<u8>,
    arm: Arc<AtomicU64>,
    ops: Arc<AtomicU64>,
}

impl FailingBacking {
    /// A fresh backing (no failure armed) plus its control handle.
    pub fn new() -> (Self, FailControl) {
        let arm = Arc::new(AtomicU64::new(0));
        let ops = Arc::new(AtomicU64::new(0));
        let ctl = FailControl {
            arm: arm.clone(),
            ops: ops.clone(),
        };
        (
            FailingBacking {
                data: Vec::new(),
                arm,
                ops,
            },
            ctl,
        )
    }

    /// Count one I/O; error if the armed countdown hits it.
    fn tick(&self) -> io::Result<()> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let a = self.arm.load(Ordering::Relaxed);
        if a > 0 {
            self.arm.store(a - 1, Ordering::Relaxed);
            if a == 1 {
                return Err(io::Error::new(io::ErrorKind::Other, "injected swap I/O fault"));
            }
        }
        Ok(())
    }
}

impl SwapBacking for FailingBacking {
    fn write_at(&mut self, off: u64, data: &[u8]) -> io::Result<()> {
        self.tick()?;
        let off = off as usize;
        if self.data.len() < off + data.len() {
            self.data.resize(off + data.len(), 0);
        }
        self.data[off..off + data.len()].copy_from_slice(data);
        Ok(())
    }

    fn read_at(&mut self, off: u64, out: &mut [u8]) -> io::Result<()> {
        self.tick()?;
        let off = off as usize;
        if self.data.len() < off + out.len() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "read past the end of the swap backing",
            ));
        }
        out.copy_from_slice(&self.data[off..off + out.len()]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nth_io_fails_then_recovers() {
        let (mut b, ctl) = FailingBacking::new();
        b.write_at(0, &[1, 2, 3]).unwrap();
        ctl.fail_nth(2); // next is ok, the one after fails
        let mut out = [0u8; 3];
        b.read_at(0, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3]);
        assert!(b.read_at(0, &mut out).is_err(), "armed I/O must fail");
        b.read_at(0, &mut out).unwrap(); // disarmed after one failure
        assert_eq!(ctl.ops(), 4);
    }

    #[test]
    fn short_reads_are_errors() {
        let (mut b, _ctl) = FailingBacking::new();
        b.write_at(4, &[9; 4]).unwrap();
        let mut out = [0u8; 16];
        assert!(b.read_at(0, &mut out).is_err());
        let mut ok = [0u8; 8];
        b.read_at(0, &mut ok).unwrap();
        assert_eq!(&ok[4..], &[9; 4]);
    }
}
