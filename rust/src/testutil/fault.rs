//! Fault-injection doubles for the swap backing and the allocator.
//!
//! [`FailingAlloc`] wraps any [`BlockAlloc`] and injects typed
//! [`Error::OutOfMemory`] failures on the allocation paths with the
//! same fail-nth / fail-for / fail-always vocabulary, so tests can
//! drive allocator-exhaustion error paths (tree growth, swap fault-in
//! destinations, slab refill) at exact call indices without actually
//! draining a pool.
//!
//! [`FailingBacking`] implements [`SwapBacking`] over an in-memory
//! byte store and injects failures and delays on command, so tests can
//! hit `SwapPool`/`FaultQueue` error paths at exact points and assert
//! the failure-atomicity the happy-path tests merely assume: a failed
//! `stash` must roll its slot back, a failed `fault` must keep the
//! payload resident. (It doubles as a fast in-memory backing for
//! high-case-count suites — the differential harness — where creating
//! one temp file per case would dominate the runtime.)
//!
//! # Async completion-ordering faults
//!
//! Four injection modes cover the fault queue's state machine:
//!
//! * [`FailControl::fail_nth`] — **fail-then-succeed-on-retry**: one
//!   transient error; the queue's retry must recover the payload.
//! * [`FailControl::fail_for`] — a burst of `n` consecutive failures
//!   (drives multi-retry backoff sequences short of escalation).
//! * [`FailControl::fail_always`] — **permanent failure** until
//!   [`FailControl::disarm`]: the queue must escalate to the typed
//!   `SwapFaultFailed` and mark itself degraded, never wedge.
//! * [`FailControl::delay_nth`] / [`FailControl::delay_all`] —
//!   **delay**: stall chosen I/Os. Because the pool serializes backing
//!   calls under one I/O mutex, completions cannot literally pass each
//!   other *inside* the backing; reordering is induced one level up
//!   and that is where it matters — a delayed or failing-then-retried
//!   request completes *after* requests issued later (retry backoff
//!   reorders), which is exactly the window the coalescing and
//!   adopt-under-seqlock protocols must survive.

use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::pmem::epoch::ArenaEpoch;
use crate::pmem::{AllocStats, BlockAlloc, BlockId, ContentionStats, SwapBacking};

/// Remote control for a [`FailingBacking`] that has been moved into a
/// `SwapPool`: arm faults/delays and observe I/O counts from the test
/// body.
#[derive(Clone)]
pub struct FailControl {
    /// I/Os until the next injected failure; 0 = disarmed.
    arm: Arc<AtomicU64>,
    /// Consecutive I/Os to fail starting now (`u64::MAX` = permanent).
    burst: Arc<AtomicU64>,
    /// I/Os until the one-shot delay fires; 0 = disarmed.
    delay_arm: Arc<AtomicU64>,
    /// One-shot delay length in nanoseconds (with `delay_arm`).
    delay_once_ns: Arc<AtomicU64>,
    /// Delay applied to *every* I/O, in nanoseconds; 0 = none.
    delay_all_ns: Arc<AtomicU64>,
    /// Total I/O calls observed.
    ops: Arc<AtomicU64>,
}

impl FailControl {
    /// Fail the `n`-th I/O from now (`1` = the very next call), then
    /// disarm — exactly one failure per arming.
    pub fn fail_nth(&self, n: u64) {
        assert!(n > 0, "fail_nth counts from 1");
        self.arm.store(n, Ordering::Relaxed);
    }

    /// Fail the next `n` I/Os (a transient burst: long enough to force
    /// several retries, short enough to stay under an escalation
    /// budget — or over it, the test's choice).
    pub fn fail_for(&self, n: u64) {
        self.burst.store(n, Ordering::Relaxed);
    }

    /// Fail every I/O until [`FailControl::disarm`] — the permanent
    /// backing failure the escalation path is built for.
    pub fn fail_always(&self) {
        self.burst.store(u64::MAX, Ordering::Relaxed);
    }

    /// Stall the `n`-th I/O from now by `delay` (then disarm). With a
    /// concurrent second request this induces completion reordering:
    /// the delayed request finishes after later-issued ones.
    pub fn delay_nth(&self, n: u64, delay: Duration) {
        assert!(n > 0, "delay_nth counts from 1");
        self.delay_once_ns.store(delay.as_nanos() as u64, Ordering::Relaxed);
        self.delay_arm.store(n, Ordering::Relaxed);
    }

    /// Stall every I/O by `delay` (a uniformly slow device) until
    /// cleared with `delay_all(Duration::ZERO)`.
    pub fn delay_all(&self, delay: Duration) {
        self.delay_all_ns.store(delay.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Cancel every pending injected failure and delay.
    pub fn disarm(&self) {
        self.arm.store(0, Ordering::Relaxed);
        self.burst.store(0, Ordering::Relaxed);
        self.delay_arm.store(0, Ordering::Relaxed);
        self.delay_all_ns.store(0, Ordering::Relaxed);
    }

    /// Total backing I/Os performed so far (including the failed ones).
    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }
}

/// An in-memory [`SwapBacking`] whose I/Os can be made to fail or
/// stall on command via the paired [`FailControl`].
pub struct FailingBacking {
    data: Vec<u8>,
    ctl: FailControl,
}

impl FailingBacking {
    /// A fresh backing (nothing armed) plus its control handle.
    pub fn new() -> (Self, FailControl) {
        let ctl = FailControl {
            arm: Arc::new(AtomicU64::new(0)),
            burst: Arc::new(AtomicU64::new(0)),
            delay_arm: Arc::new(AtomicU64::new(0)),
            delay_once_ns: Arc::new(AtomicU64::new(0)),
            delay_all_ns: Arc::new(AtomicU64::new(0)),
            ops: Arc::new(AtomicU64::new(0)),
        };
        (
            FailingBacking {
                data: Vec::new(),
                ctl: ctl.clone(),
            },
            ctl,
        )
    }

    /// Count one I/O; apply any armed delay, then any armed failure.
    /// (Plain load/store countdowns are race-free in practice: the
    /// pool's I/O mutex serializes every backing call.)
    fn tick(&self) -> io::Result<()> {
        let ctl = &self.ctl;
        ctl.ops.fetch_add(1, Ordering::Relaxed);
        let da = ctl.delay_arm.load(Ordering::Relaxed);
        if da > 0 {
            ctl.delay_arm.store(da - 1, Ordering::Relaxed);
            if da == 1 {
                let ns = ctl.delay_once_ns.load(Ordering::Relaxed);
                std::thread::sleep(Duration::from_nanos(ns));
            }
        }
        let all_ns = ctl.delay_all_ns.load(Ordering::Relaxed);
        if all_ns > 0 {
            std::thread::sleep(Duration::from_nanos(all_ns));
        }
        let b = ctl.burst.load(Ordering::Relaxed);
        if b > 0 {
            if b != u64::MAX {
                ctl.burst.store(b - 1, Ordering::Relaxed);
            }
            return Err(io::Error::new(io::ErrorKind::Other, "injected swap I/O fault (burst)"));
        }
        let a = ctl.arm.load(Ordering::Relaxed);
        if a > 0 {
            ctl.arm.store(a - 1, Ordering::Relaxed);
            if a == 1 {
                return Err(io::Error::new(io::ErrorKind::Other, "injected swap I/O fault"));
            }
        }
        Ok(())
    }
}

impl SwapBacking for FailingBacking {
    fn write_at(&mut self, off: u64, data: &[u8]) -> io::Result<()> {
        self.tick()?;
        let off = off as usize;
        if self.data.len() < off + data.len() {
            self.data.resize(off + data.len(), 0);
        }
        self.data[off..off + data.len()].copy_from_slice(data);
        Ok(())
    }

    fn read_at(&mut self, off: u64, out: &mut [u8]) -> io::Result<()> {
        self.tick()?;
        let off = off as usize;
        if self.data.len() < off + out.len() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "read past the end of the swap backing",
            ));
        }
        out.copy_from_slice(&self.data[off..off + out.len()]);
        Ok(())
    }
}

/// Remote control for a [`FailingAlloc`]: arm allocation failures and
/// observe allocation-attempt counts from the test body. The same
/// fail-nth / fail-for / fail-always vocabulary as [`FailControl`],
/// minus delays (allocation is CPU-side; there is no device to stall).
#[derive(Clone)]
pub struct AllocFailControl {
    /// Allocation calls until the next injected failure; 0 = disarmed.
    arm: Arc<AtomicU64>,
    /// Consecutive calls to fail starting now (`u64::MAX` = permanent).
    burst: Arc<AtomicU64>,
    /// Total allocation calls observed (failed ones included).
    ops: Arc<AtomicU64>,
}

impl AllocFailControl {
    /// Fail the `n`-th allocation from now (`1` = the very next call),
    /// then disarm — exactly one failure per arming.
    pub fn fail_nth(&self, n: u64) {
        assert!(n > 0, "fail_nth counts from 1");
        self.arm.store(n, Ordering::Relaxed);
    }

    /// Fail the next `n` allocations (a transient OOM burst — long
    /// enough to force retry/reclaim paths, short enough to recover).
    pub fn fail_for(&self, n: u64) {
        self.burst.store(n, Ordering::Relaxed);
    }

    /// Fail every allocation until [`AllocFailControl::disarm`] — the
    /// pool is "full" no matter what the caller does.
    pub fn fail_always(&self) {
        self.burst.store(u64::MAX, Ordering::Relaxed);
    }

    /// Cancel every pending injected failure.
    pub fn disarm(&self) {
        self.arm.store(0, Ordering::Relaxed);
        self.burst.store(0, Ordering::Relaxed);
    }

    /// Total allocation calls so far (including the failed ones;
    /// `alloc_many` counts as one call).
    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }
}

/// A [`BlockAlloc`] wrapper whose *allocation* paths (`alloc`,
/// `alloc_many`, `alloc_zeroed`, `alloc_in_span`) fail on command with
/// the typed [`Error::OutOfMemory`] the real pool returns when
/// exhausted — carrying the inner pool's true `free`/`capacity`, so an
/// injected OOM is indistinguishable from a real one to the code under
/// test. Everything else (free, reads, writes, telemetry, the epoch)
/// forwards untouched: an injected failure must never corrupt pool
/// state, only deny new blocks.
///
/// This is the allocator-side sibling of [`FailingBacking`]: together
/// they let the differential oracle drive every typed error path —
/// swap I/O faults *and* allocation failure — against one mirror.
pub struct FailingAlloc<'a, A: BlockAlloc> {
    inner: &'a A,
    ctl: AllocFailControl,
}

impl<'a, A: BlockAlloc> FailingAlloc<'a, A> {
    /// Wrap `inner` (nothing armed) and return the control handle.
    pub fn new(inner: &'a A) -> (Self, AllocFailControl) {
        let ctl = AllocFailControl {
            arm: Arc::new(AtomicU64::new(0)),
            burst: Arc::new(AtomicU64::new(0)),
            ops: Arc::new(AtomicU64::new(0)),
        };
        (
            FailingAlloc {
                inner,
                ctl: ctl.clone(),
            },
            ctl,
        )
    }

    /// Count one allocation call; inject an armed failure. The error
    /// mirrors [`Error::OutOfMemory`] from a genuinely empty pool.
    fn tick(&self, requested: usize) -> Result<()> {
        let ctl = &self.ctl;
        ctl.ops.fetch_add(1, Ordering::Relaxed);
        let b = ctl.burst.load(Ordering::Relaxed);
        if b > 0 {
            if b != u64::MAX {
                ctl.burst.store(b - 1, Ordering::Relaxed);
            }
            return Err(Error::OutOfMemory {
                requested,
                free: self.inner.free_blocks(),
                capacity: self.inner.capacity(),
            });
        }
        let a = ctl.arm.load(Ordering::Relaxed);
        if a > 0 {
            ctl.arm.store(a - 1, Ordering::Relaxed);
            if a == 1 {
                return Err(Error::OutOfMemory {
                    requested,
                    free: self.inner.free_blocks(),
                    capacity: self.inner.capacity(),
                });
            }
        }
        Ok(())
    }
}

impl<A: BlockAlloc> BlockAlloc for FailingAlloc<'_, A> {
    fn alloc(&self) -> Result<BlockId> {
        self.tick(1)?;
        self.inner.alloc()
    }

    fn alloc_many(&self, n: usize) -> Result<Vec<BlockId>> {
        self.tick(n)?;
        self.inner.alloc_many(n)
    }

    fn alloc_zeroed(&self) -> Result<BlockId> {
        self.tick(1)?;
        self.inner.alloc_zeroed()
    }

    fn alloc_in_span(&self, lo: usize, hi: usize) -> Result<BlockId> {
        self.tick(1)?;
        self.inner.alloc_in_span(lo, hi)
    }

    fn shard_spans(&self) -> Vec<(usize, usize)> {
        self.inner.shard_spans()
    }

    fn live_snapshot(&self, out: &mut Vec<u64>) {
        self.inner.live_snapshot(out)
    }

    fn free(&self, id: BlockId) -> Result<()> {
        self.inner.free(id)
    }

    fn block_size(&self) -> usize {
        self.inner.block_size()
    }

    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn free_blocks(&self) -> usize {
        self.inner.free_blocks()
    }

    fn is_live(&self, id: BlockId) -> bool {
        self.inner.is_live(id)
    }

    fn stats(&self) -> AllocStats {
        self.inner.stats()
    }

    fn contention(&self) -> ContentionStats {
        self.inner.contention()
    }

    fn epoch(&self) -> &ArenaEpoch {
        self.inner.epoch()
    }

    unsafe fn block_ptr(&self, id: BlockId) -> *mut u8 {
        // SAFETY: forwarded verbatim; the wrapper adds no aliasing.
        unsafe { self.inner.block_ptr(id) }
    }

    fn write(&self, id: BlockId, offset: usize, data: &[u8]) -> Result<()> {
        self.inner.write(id, offset, data)
    }

    fn read(&self, id: BlockId, offset: usize, out: &mut [u8]) -> Result<()> {
        self.inner.read(id, offset, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nth_io_fails_then_recovers() {
        let (mut b, ctl) = FailingBacking::new();
        b.write_at(0, &[1, 2, 3]).unwrap();
        ctl.fail_nth(2); // next is ok, the one after fails
        let mut out = [0u8; 3];
        b.read_at(0, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3]);
        assert!(b.read_at(0, &mut out).is_err(), "armed I/O must fail");
        b.read_at(0, &mut out).unwrap(); // disarmed after one failure
        assert_eq!(ctl.ops(), 4);
    }

    #[test]
    fn burst_fails_consecutively_then_recovers() {
        let (mut b, ctl) = FailingBacking::new();
        b.write_at(0, &[7; 4]).unwrap();
        let mut out = [0u8; 4];
        ctl.fail_for(2);
        assert!(b.read_at(0, &mut out).is_err());
        assert!(b.read_at(0, &mut out).is_err());
        b.read_at(0, &mut out).unwrap();
        assert_eq!(out, [7; 4]);
    }

    #[test]
    fn fail_always_holds_until_disarm() {
        let (mut b, ctl) = FailingBacking::new();
        b.write_at(0, &[3; 2]).unwrap();
        ctl.fail_always();
        let mut out = [0u8; 2];
        for _ in 0..5 {
            assert!(b.read_at(0, &mut out).is_err());
        }
        ctl.disarm();
        b.read_at(0, &mut out).unwrap();
        assert_eq!(out, [3; 2]);
    }

    #[test]
    fn delays_fire_and_clear() {
        let (mut b, ctl) = FailingBacking::new();
        b.write_at(0, &[1]).unwrap();
        let mut out = [0u8; 1];
        ctl.delay_nth(1, Duration::from_millis(3));
        let t0 = std::time::Instant::now();
        b.read_at(0, &mut out).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(3), "one-shot delay must stall");
        let t1 = std::time::Instant::now();
        b.read_at(0, &mut out).unwrap();
        assert!(t1.elapsed() < Duration::from_millis(3), "one-shot delay must disarm");
        ctl.delay_all(Duration::from_millis(3));
        let t2 = std::time::Instant::now();
        b.read_at(0, &mut out).unwrap();
        assert!(t2.elapsed() >= Duration::from_millis(3));
        ctl.disarm();
    }

    #[test]
    fn failing_alloc_injects_typed_oom_and_recovers() {
        use crate::pmem::BlockAllocator;
        let pool = BlockAllocator::new(1024, 8).unwrap();
        let (a, ctl) = FailingAlloc::new(&pool);
        let b0 = a.alloc().unwrap();
        ctl.fail_nth(2); // next call ok, the one after fails
        let b1 = a.alloc().unwrap();
        match a.alloc() {
            Err(Error::OutOfMemory {
                requested,
                free,
                capacity,
            }) => {
                assert_eq!(requested, 1);
                assert_eq!(capacity, 8);
                assert_eq!(free, 6, "injected OOM must report the pool's real state");
            }
            other => panic!("expected injected OutOfMemory, got {other:?}"),
        }
        // Disarmed after one failure; pool state is uncorrupted.
        let b2 = a.alloc_zeroed().unwrap();
        assert_eq!(a.stats().allocated, 3);
        ctl.fail_for(2);
        assert!(matches!(a.alloc_many(3), Err(Error::OutOfMemory { requested: 3, .. })));
        assert!(a.alloc_in_span(0, 8).is_err());
        let b3 = a.alloc().unwrap(); // burst over
        assert_eq!(ctl.ops(), 7);
        ctl.fail_always();
        for _ in 0..4 {
            assert!(a.alloc().is_err());
        }
        ctl.disarm();
        for b in [b0, b1, b2, b3] {
            a.free(b).unwrap();
        }
        assert_eq!(pool.stats().allocated, 0);
        assert_eq!(
            pool.stats().failed_allocs,
            0,
            "injected failures must never reach the inner pool"
        );
    }

    #[test]
    fn short_reads_are_errors() {
        let (mut b, _ctl) = FailingBacking::new();
        b.write_at(4, &[9; 4]).unwrap();
        let mut out = [0u8; 16];
        assert!(b.read_at(0, &mut out).is_err());
        let mut ok = [0u8; 8];
        b.read_at(0, &mut ok).unwrap();
        assert_eq!(&ok[4..], &[9; 4]);
    }
}
