//! The differential oracle: randomized operation sequences executed
//! against a [`TreeArray`] and a plain `Vec<u64>` mirror in lockstep.
//!
//! Every public access path the tree offers — scalar get/set, the
//! batched APIs, [`crate::trees::TreeWriter`] seqlock writes,
//! [`crate::trees::TreeView`] reads, safe and concurrent leaf
//! migration, swap eviction/restore through the
//! [`crate::trees::CompactTarget`] entry points, and **software page
//! faults** (view/writer accesses landing on evicted leaves, served by
//! an installed [`FaultQueue`]) — is driven by one seeded op stream
//! while the mirror records the intended contents. Any divergence (a
//! lost write, a stale translation, a torn copy, a restore landing on
//! the wrong leaf, a fault-in adopting the wrong payload) surfaces as
//! a mismatch, and [`crate::testutil::forall`]'s shrinking re-runs the
//! failing seed at smaller scales. Swap I/O runs over the in-memory
//! [`FailingBacking`], with faults injected at random
//! eviction/fault-in points so the error paths' failure-atomicity and
//! the queue's retry path are part of the oracle, not a separate
//! suite. Allocation runs through a [`FailingAlloc`], and a dedicated
//! arm injects typed [`Error::OutOfMemory`] on migration, restore, and
//! demand-fault destination allocations — the allocator-exhaustion
//! error paths must surface typed errors and leave the mirror intact,
//! under the same oracle.
//!
//! Shared via `testutil` so the integration suite
//! (`rust/tests/differential.rs`) can run the same cases under both
//! allocator policies, and future structures can bolt their own ops on.

use std::time::Duration;

use crate::error::Error;
use crate::pmem::{BlockAlloc, FaultQueue, FaultQueueConfig, SwapPool};
use crate::testutil::fault::{FailingAlloc, FailingBacking};
use crate::testutil::proptest_lite::Gen;
use crate::trees::{CompactTarget, TreeArray};

/// What one differential case exercised — returned so suites can
/// assert, in aggregate, that the interesting ops actually ran instead
/// of the generator silently starving them.
#[derive(Clone, Copy, Debug, Default)]
pub struct DiffOutcome {
    /// Ops executed (of any kind).
    pub ops: usize,
    /// Elements written (all write paths).
    pub writes: usize,
    /// Elements written through the seqlock [`crate::trees::TreeWriter`].
    pub writer_writes: usize,
    /// Leaf migrations (safe + concurrent forms).
    pub migrations: usize,
    /// Successful leaf evictions to swap.
    pub evictions: usize,
    /// Successful restores (fault + adopt) through the daemon-style
    /// [`CompactTarget::restore_leaf`] path, including the final drain.
    pub restores: usize,
    /// Leaves faulted back in by an accessor hitting them (the
    /// view/writer software-page-fault hooks).
    pub hook_faults: usize,
    /// Injected swap I/O faults survived (error path taken, state
    /// verified intact — including transient failures the fault
    /// queue's retry path absorbed).
    pub injected_faults: usize,
    /// Injected allocator OOM failures survived (typed
    /// [`Error::OutOfMemory`] surfaced on migrate/restore/demand-fault
    /// destination allocation; mirror verified intact).
    pub injected_oom: usize,
}

/// Pick a leaf by residency: `parked == false` draws from the resident
/// (not swapped out) leaves, `parked == true` from the evicted ones —
/// read straight off the tree's swap words, the authoritative ledger.
/// Returns `None` when the requested set is empty. The plain
/// `TreeArray` accessors (no fault hook) must avoid parked leaves;
/// eviction targets resident ones; restore and the demand-fault arm
/// target parked ones.
fn pick_leaf<A: BlockAlloc>(g: &mut Gen, tree: &TreeArray<u64, A>, parked: bool) -> Option<usize> {
    let set: Vec<usize> = (0..tree.nleaves())
        .filter(|&l| tree.leaf_swapped(l) == parked)
        .collect();
    if set.is_empty() {
        None
    } else {
        Some(*g.choose(&set))
    }
}

/// Pick an element index inside a leaf of the requested residency.
/// Returns `None` when no such leaf exists.
fn index_in<A: BlockAlloc>(
    g: &mut Gen,
    tree: &TreeArray<u64, A>,
    n: usize,
    leaf_cap: usize,
    parked: bool,
) -> Option<usize> {
    let leaf = pick_leaf(g, tree, parked)?;
    let lo = leaf * leaf_cap;
    let hi = (lo + leaf_cap).min(n);
    Some(g.usize_in(lo, hi - 1))
}

/// Run one differential case against `a`. The case builds its own
/// tree, mirror, and in-memory swap; on return the pool is empty again
/// (the case asserts it).
pub fn run_case<A: BlockAlloc>(a: &A, g: &mut Gen) -> DiffOutcome {
    let mut out = DiffOutcome::default();
    let leaf_cap = a.block_size() / 8;
    let n = g.usize_in(1, leaf_cap * 24);
    // Every allocation the case makes goes through the failing wrapper
    // so the OOM-injection arm can deny exactly one chosen allocation
    // (migration destination, restore destination, fault-in block)
    // without draining the pool.
    let (fa, alloc_ctl) = FailingAlloc::new(a);
    let fa = &fa;
    let mut tree: TreeArray<u64, FailingAlloc<A>> = TreeArray::new(fa, n).expect("diff tree");
    let mut mirror = vec![0u64; n];
    if g.bool(0.5) {
        tree.enable_flat_table();
    }
    // Seed some contents through the bulk path.
    for slot in mirror.iter_mut() {
        *slot = g.rng().next_u64();
    }
    tree.copy_from_slice(&mirror).expect("seed");

    let (backing, fault_ctl) = FailingBacking::new();
    let swap = SwapPool::with_backing(fa, backing);
    // Demand faults run through a real FaultQueue (inline mode) so the
    // retry/backoff machinery sits inside the oracle's loop.
    let fq = FaultQueue::new(
        &swap,
        FaultQueueConfig {
            max_retries: 3,
            backoff_base: Duration::from_micros(50),
            backoff_cap: Duration::from_micros(400),
            ..FaultQueueConfig::default()
        },
    );
    // SAFETY: cleared at the end of this case, before `fq` drops.
    unsafe { tree.install_faulter(&fq) };

    let nops = g.usize_in(1, 120);
    for _ in 0..nops {
        out.ops += 1;
        match g.usize_in(0, 13) {
            // -- plain scalar access --------------------------------
            0 | 1 => {
                if let Some(i) = index_in(g, &tree, n, leaf_cap, false) {
                    if g.bool(0.5) {
                        let v = g.rng().next_u64();
                        tree.set(i, v).expect("set");
                        mirror[i] = v;
                        out.writes += 1;
                    } else {
                        assert_eq!(tree.get(i).expect("get"), mirror[i], "scalar get diverged at {i}");
                    }
                }
            }
            // -- batched access -------------------------------------
            2 => {
                let b = g.usize_in(1, 64);
                let mut idxs = Vec::with_capacity(b);
                for _ in 0..b {
                    match index_in(g, &tree, n, leaf_cap, false) {
                        Some(i) => idxs.push(i),
                        None => break,
                    }
                }
                if !idxs.is_empty() {
                    let got = tree.get_batch(&idxs).expect("get_batch");
                    for (k, &i) in idxs.iter().enumerate() {
                        assert_eq!(got[k], mirror[i], "get_batch diverged at {i}");
                    }
                }
            }
            3 => {
                let b = g.usize_in(1, 64);
                let mut idxs = Vec::new();
                let mut vals = Vec::new();
                for _ in 0..b {
                    match index_in(g, &tree, n, leaf_cap, false) {
                        Some(i) => {
                            idxs.push(i);
                            vals.push(g.rng().next_u64());
                        }
                        None => break,
                    }
                }
                if !idxs.is_empty() {
                    tree.set_batch(&idxs, &vals).expect("set_batch");
                    // Stable grouping = last-write-wins in batch order.
                    for (k, &i) in idxs.iter().enumerate() {
                        mirror[i] = vals[k];
                    }
                    out.writes += idxs.len();
                }
            }
            4 => {
                let b = g.usize_in(1, 64);
                let mut idxs = Vec::new();
                let mut keys = Vec::new();
                for _ in 0..b {
                    match index_in(g, &tree, n, leaf_cap, false) {
                        Some(i) => {
                            idxs.push(i);
                            keys.push(g.rng().next_u64());
                        }
                        None => break,
                    }
                }
                if !idxs.is_empty() {
                    tree.update_batch(&idxs, |pos, v| *v ^= keys[pos]).expect("update_batch");
                    for (k, &i) in idxs.iter().enumerate() {
                        mirror[i] ^= keys[k];
                    }
                    out.writes += idxs.len();
                }
            }
            // -- seqlock writer -------------------------------------
            5 | 6 => {
                // SAFETY: single thread; the writer is the only
                // accessor until it drops at the end of this arm.
                let mut w = unsafe { tree.writer() };
                for _ in 0..g.usize_in(1, 24) {
                    if let Some(i) = index_in(g, &tree, n, leaf_cap, false) {
                        match g.usize_in(0, 2) {
                            0 => {
                                let v = g.rng().next_u64();
                                w.set(i, v).expect("writer set");
                                mirror[i] = v;
                            }
                            1 => {
                                let k = g.rng().next_u64();
                                w.update(i, |v| v.wrapping_add(k)).expect("writer update");
                                mirror[i] = mirror[i].wrapping_add(k);
                            }
                            _ => {
                                assert_eq!(
                                    w.get(i).expect("writer get"),
                                    mirror[i],
                                    "writer get diverged at {i}"
                                );
                                continue;
                            }
                        }
                        out.writes += 1;
                        out.writer_writes += 1;
                    }
                }
            }
            // -- view reads -----------------------------------------
            7 => {
                let mut v = tree.view();
                let b = g.usize_in(1, 64);
                let mut idxs = Vec::new();
                for _ in 0..b {
                    match index_in(g, &tree, n, leaf_cap, false) {
                        Some(i) => idxs.push(i),
                        None => break,
                    }
                }
                if !idxs.is_empty() {
                    let got = v.get_batch(&idxs).expect("view get_batch");
                    for (k, &i) in idxs.iter().enumerate() {
                        assert_eq!(got[k], mirror[i], "view batch read diverged at {i}");
                    }
                    let spot = idxs[0];
                    assert_eq!(v.get(spot).expect("view get"), mirror[spot]);
                    assert_eq!(v.seq_retries(), 0, "no writers live: the bracket must not retry");
                }
            }
            // -- relocation -----------------------------------------
            8 => {
                if let Some(leaf) = pick_leaf(g, &tree, false) {
                    if g.bool(0.5) {
                        tree.migrate_leaf(leaf).expect("migrate_leaf");
                    } else {
                        // SAFETY: single thread, no raw slices live.
                        unsafe { tree.migrate_leaf_concurrent(leaf) }.expect("migrate concurrent");
                        if g.bool(0.5) {
                            a.epoch().try_reclaim(a);
                        }
                    }
                    out.migrations += 1;
                }
            }
            // -- eviction -------------------------------------------
            9 => {
                if let Some(leaf) = pick_leaf(g, &tree, false) {
                    let inject = g.bool(0.15);
                    if inject {
                        fault_ctl.fail_nth(1);
                    }
                    // SAFETY: every accessor in this case is
                    // fault-capable (hooked view/writer, or filtered to
                    // resident leaves) and the faulter is installed.
                    match unsafe { CompactTarget::evict_leaf(&tree, leaf, &swap) } {
                        Ok(_) => {
                            assert!(tree.leaf_swapped(leaf));
                            out.evictions += 1;
                        }
                        Err(_) => {
                            assert!(inject, "uninjected eviction failed");
                            out.injected_faults += 1;
                            // Failure-atomic: the leaf must still serve.
                            assert!(!tree.leaf_swapped(leaf));
                            let lo = leaf * leaf_cap;
                            assert_eq!(tree.get(lo).expect("get after failed evict"), mirror[lo]);
                        }
                    }
                }
            }
            // -- software page fault: access a parked leaf ----------
            10 => {
                if let Some(i) = index_in(g, &tree, n, leaf_cap, true) {
                    let inject = g.bool(0.3);
                    if inject {
                        // Transient: the queue's first read fails, the
                        // retry serves the payload.
                        fault_ctl.fail_nth(1);
                        out.injected_faults += 1;
                    }
                    let retries0 = fq.stats().retries;
                    match g.usize_in(0, 2) {
                        0 => {
                            let mut v = tree.view();
                            assert_eq!(
                                v.get(i).expect("view demand fault"),
                                mirror[i],
                                "fault-in served wrong bytes at {i}"
                            );
                            out.hook_faults += v.faults() as usize;
                        }
                        1 => {
                            // SAFETY: single thread; sole accessor
                            // until it drops at the end of this arm.
                            let mut w = unsafe { tree.writer() };
                            let val = g.rng().next_u64();
                            w.set(i, val).expect("writer demand fault");
                            mirror[i] = val;
                            out.writes += 1;
                            out.writer_writes += 1;
                            out.hook_faults += w.faults() as usize;
                        }
                        _ => {
                            // Bulk path: faults *every* parked leaf.
                            let mut v = tree.view();
                            assert_eq!(v.to_vec(), mirror, "to_vec fault-in diverged");
                            out.hook_faults += v.faults() as usize;
                        }
                    }
                    if inject {
                        assert!(
                            fq.stats().retries > retries0,
                            "injected transient fault must go through the retry path"
                        );
                    }
                }
            }
            // -- injected allocator OOM -----------------------------
            11 => {
                match g.usize_in(0, 2) {
                    0 => {
                        // Migration destination allocation fails: the
                        // typed error surfaces and the leaf keeps
                        // serving from its old block.
                        if let Some(leaf) = pick_leaf(g, &tree, false) {
                            alloc_ctl.fail_nth(1);
                            let res = tree.migrate_leaf(leaf);
                            alloc_ctl.disarm();
                            match res {
                                Err(Error::OutOfMemory { .. }) => {
                                    out.injected_oom += 1;
                                    let lo = leaf * leaf_cap;
                                    assert_eq!(
                                        tree.get(lo).expect("get after failed migrate"),
                                        mirror[lo],
                                        "failed migration corrupted leaf {leaf}"
                                    );
                                }
                                other => panic!("armed migrate must fail typed: {other:?}"),
                            }
                        }
                    }
                    1 => {
                        // Restore destination allocation fails: typed
                        // error, the payload stays parked (the drain
                        // brings it home later).
                        if let Some(leaf) = pick_leaf(g, &tree, true) {
                            alloc_ctl.fail_nth(1);
                            let res = CompactTarget::restore_leaf(&tree, leaf, &swap);
                            alloc_ctl.disarm();
                            match res {
                                Err(Error::OutOfMemory { .. }) => {
                                    out.injected_oom += 1;
                                    assert!(
                                        tree.leaf_swapped(leaf),
                                        "failed restore must leave leaf {leaf} parked"
                                    );
                                }
                                other => panic!("armed restore must fail typed: {other:?}"),
                            }
                        }
                    }
                    _ => {
                        // Demand fault with a transient OOM: the
                        // queue's retry path reclaims and re-allocates;
                        // the read still serves the right bytes.
                        if let Some(i) = index_in(g, &tree, n, leaf_cap, true) {
                            alloc_ctl.fail_nth(1);
                            out.injected_oom += 1;
                            let mut v = tree.view();
                            assert_eq!(
                                v.get(i).expect("view demand fault under OOM"),
                                mirror[i],
                                "OOM-retried fault-in served wrong bytes at {i}"
                            );
                            out.hook_faults += v.faults() as usize;
                            drop(v);
                            alloc_ctl.disarm();
                        }
                    }
                }
            }
            // -- restore --------------------------------------------
            _ => {
                if let Some(leaf) = pick_leaf(g, &tree, true) {
                    let inject = g.bool(0.15);
                    if inject {
                        fault_ctl.fail_nth(1);
                    }
                    match CompactTarget::restore_leaf(&tree, leaf, &swap) {
                        Ok(restored) => {
                            assert!(restored, "single thread: nobody else could restore it");
                            out.restores += 1;
                            let lo = leaf * leaf_cap;
                            assert_eq!(
                                tree.get(lo).expect("get after restore"),
                                mirror[lo],
                                "restore landed wrong bytes on leaf {leaf}"
                            );
                        }
                        Err(_) => {
                            assert!(inject, "uninjected fault failed");
                            out.injected_faults += 1;
                            // Failure-atomic: the payload stays parked.
                            assert!(tree.leaf_swapped(leaf));
                        }
                    }
                }
            }
        }
    }

    // Drain: restore every parked leaf, then the full-contents oracle.
    fault_ctl.disarm();
    for leaf in 0..tree.nleaves() {
        if tree.leaf_swapped(leaf) {
            let restored = CompactTarget::restore_leaf(&tree, leaf, &swap).expect("final restore");
            assert!(restored);
            out.restores += 1;
        }
    }
    assert_eq!(tree.swapped_leaves(), 0, "drain left parked leaves");
    assert_eq!(tree.to_vec(), mirror, "final contents diverged from the mirror");
    let mut view = tree.view();
    assert_eq!(view.to_vec(), mirror, "view drain diverged from the mirror");
    drop(view);
    tree.clear_faulter();
    a.epoch().synchronize(a);
    assert_eq!(a.epoch().limbo_len(), 0, "case left blocks in limbo");
    drop(tree);
    assert_eq!(a.stats().allocated, 0, "case leaked blocks");
    out
}
