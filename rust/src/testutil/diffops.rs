//! The differential oracle: randomized operation sequences executed
//! against a [`TreeArray`] and a plain `Vec<u64>` mirror in lockstep.
//!
//! Every public access path the tree offers — scalar get/set, the
//! batched APIs, [`crate::trees::TreeWriter`] seqlock writes,
//! [`crate::trees::TreeView`] reads, safe and concurrent leaf
//! migration, and swap eviction/restore through the
//! [`crate::trees::CompactTarget`] adoption hooks — is driven by one
//! seeded op stream while the mirror records the intended contents.
//! Any divergence (a lost write, a stale translation, a torn copy, a
//! restore landing on the wrong leaf) surfaces as a mismatch, and
//! [`crate::testutil::forall`]'s shrinking re-runs the failing seed at
//! smaller scales. Swap I/O runs over the in-memory
//! [`FailingBacking`], with faults injected at random eviction/fault
//! points so the error paths' failure-atomicity is part of the oracle,
//! not a separate suite.
//!
//! Shared via `testutil` so the integration suite
//! (`rust/tests/differential.rs`) can run the same cases under both
//! allocator policies, and future structures can bolt their own ops on.

use crate::pmem::{BlockAlloc, SwapPool, SwapSlot};
use crate::testutil::fault::FailingBacking;
use crate::testutil::proptest_lite::Gen;
use crate::trees::{CompactTarget, TreeArray};

/// What one differential case exercised — returned so suites can
/// assert, in aggregate, that the interesting ops actually ran instead
/// of the generator silently starving them.
#[derive(Clone, Copy, Debug, Default)]
pub struct DiffOutcome {
    /// Ops executed (of any kind).
    pub ops: usize,
    /// Elements written (all write paths).
    pub writes: usize,
    /// Elements written through the seqlock [`crate::trees::TreeWriter`].
    pub writer_writes: usize,
    /// Leaf migrations (safe + concurrent forms).
    pub migrations: usize,
    /// Successful leaf evictions to swap.
    pub evictions: usize,
    /// Successful restores (fault + adopt).
    pub restores: usize,
    /// Injected swap I/O faults survived (error path taken, state
    /// verified intact).
    pub injected_faults: usize,
}

/// Pick a leaf by residency: `parked == false` draws from the resident
/// (not swapped out) leaves, `parked == true` from the evicted ones.
/// Returns `None` when the requested set is empty. The one residency
/// filter every op arm shares — access ops, relocation, and eviction
/// must all avoid parked leaves, restore must hit one.
fn pick_leaf(g: &mut Gen, evicted: &[Option<SwapSlot>], parked: bool) -> Option<usize> {
    let set: Vec<usize> = (0..evicted.len())
        .filter(|&l| evicted[l].is_some() == parked)
        .collect();
    if set.is_empty() {
        None
    } else {
        Some(*g.choose(&set))
    }
}

/// Pick an element index whose leaf is resident (not swapped out).
/// Returns `None` when every leaf is evicted.
fn resident_index(g: &mut Gen, n: usize, leaf_cap: usize, evicted: &[Option<SwapSlot>]) -> Option<usize> {
    let leaf = pick_leaf(g, evicted, false)?;
    let lo = leaf * leaf_cap;
    let hi = (lo + leaf_cap).min(n);
    Some(g.usize_in(lo, hi - 1))
}

/// Run one differential case against `a`. The case builds its own
/// tree, mirror, and in-memory swap; on return the pool is empty again
/// (the case asserts it).
pub fn run_case<A: BlockAlloc>(a: &A, g: &mut Gen) -> DiffOutcome {
    let mut out = DiffOutcome::default();
    let leaf_cap = a.block_size() / 8;
    let n = g.usize_in(1, leaf_cap * 24);
    let mut tree: TreeArray<u64, A> = TreeArray::new(a, n).expect("diff tree");
    let mut mirror = vec![0u64; n];
    if g.bool(0.5) {
        tree.enable_flat_table();
    }
    // Seed some contents through the bulk path.
    for slot in mirror.iter_mut() {
        *slot = g.rng().next_u64();
    }
    tree.copy_from_slice(&mirror).expect("seed");

    let (backing, fault_ctl) = FailingBacking::new();
    let swap = SwapPool::with_backing(a, backing);
    let mut evicted: Vec<Option<SwapSlot>> = vec![None; tree.nleaves()];

    let nops = g.usize_in(1, 120);
    for _ in 0..nops {
        out.ops += 1;
        match g.usize_in(0, 11) {
            // -- plain scalar access --------------------------------
            0 | 1 => {
                if let Some(i) = resident_index(g, n, leaf_cap, &evicted) {
                    if g.bool(0.5) {
                        let v = g.rng().next_u64();
                        tree.set(i, v).expect("set");
                        mirror[i] = v;
                        out.writes += 1;
                    } else {
                        assert_eq!(tree.get(i).expect("get"), mirror[i], "scalar get diverged at {i}");
                    }
                }
            }
            // -- batched access -------------------------------------
            2 => {
                let b = g.usize_in(1, 64);
                let mut idxs = Vec::with_capacity(b);
                for _ in 0..b {
                    match resident_index(g, n, leaf_cap, &evicted) {
                        Some(i) => idxs.push(i),
                        None => break,
                    }
                }
                if !idxs.is_empty() {
                    let got = tree.get_batch(&idxs).expect("get_batch");
                    for (k, &i) in idxs.iter().enumerate() {
                        assert_eq!(got[k], mirror[i], "get_batch diverged at {i}");
                    }
                }
            }
            3 => {
                let b = g.usize_in(1, 64);
                let mut idxs = Vec::new();
                let mut vals = Vec::new();
                for _ in 0..b {
                    match resident_index(g, n, leaf_cap, &evicted) {
                        Some(i) => {
                            idxs.push(i);
                            vals.push(g.rng().next_u64());
                        }
                        None => break,
                    }
                }
                if !idxs.is_empty() {
                    tree.set_batch(&idxs, &vals).expect("set_batch");
                    // Stable grouping = last-write-wins in batch order.
                    for (k, &i) in idxs.iter().enumerate() {
                        mirror[i] = vals[k];
                    }
                    out.writes += idxs.len();
                }
            }
            4 => {
                let b = g.usize_in(1, 64);
                let mut idxs = Vec::new();
                let mut keys = Vec::new();
                for _ in 0..b {
                    match resident_index(g, n, leaf_cap, &evicted) {
                        Some(i) => {
                            idxs.push(i);
                            keys.push(g.rng().next_u64());
                        }
                        None => break,
                    }
                }
                if !idxs.is_empty() {
                    tree.update_batch(&idxs, |pos, v| *v ^= keys[pos]).expect("update_batch");
                    for (k, &i) in idxs.iter().enumerate() {
                        mirror[i] ^= keys[k];
                    }
                    out.writes += idxs.len();
                }
            }
            // -- seqlock writer -------------------------------------
            5 | 6 => {
                // SAFETY: single thread; the writer is the only
                // accessor until it drops at the end of this arm.
                let mut w = unsafe { tree.writer() };
                for _ in 0..g.usize_in(1, 24) {
                    if let Some(i) = resident_index(g, n, leaf_cap, &evicted) {
                        match g.usize_in(0, 2) {
                            0 => {
                                let v = g.rng().next_u64();
                                w.set(i, v).expect("writer set");
                                mirror[i] = v;
                            }
                            1 => {
                                let k = g.rng().next_u64();
                                w.update(i, |v| v.wrapping_add(k)).expect("writer update");
                                mirror[i] = mirror[i].wrapping_add(k);
                            }
                            _ => {
                                assert_eq!(
                                    w.get(i).expect("writer get"),
                                    mirror[i],
                                    "writer get diverged at {i}"
                                );
                                continue;
                            }
                        }
                        out.writes += 1;
                        out.writer_writes += 1;
                    }
                }
            }
            // -- view reads -----------------------------------------
            7 => {
                let mut v = tree.view();
                let b = g.usize_in(1, 64);
                let mut idxs = Vec::new();
                for _ in 0..b {
                    match resident_index(g, n, leaf_cap, &evicted) {
                        Some(i) => idxs.push(i),
                        None => break,
                    }
                }
                if !idxs.is_empty() {
                    let got = v.get_batch(&idxs).expect("view get_batch");
                    for (k, &i) in idxs.iter().enumerate() {
                        assert_eq!(got[k], mirror[i], "view batch read diverged at {i}");
                    }
                    let spot = idxs[0];
                    assert_eq!(v.get(spot).expect("view get"), mirror[spot]);
                    assert_eq!(v.seq_retries(), 0, "no writers live: the bracket must not retry");
                }
            }
            // -- relocation -----------------------------------------
            8 => {
                if let Some(leaf) = pick_leaf(g, &evicted, false) {
                    if g.bool(0.5) {
                        tree.migrate_leaf(leaf).expect("migrate_leaf");
                    } else {
                        // SAFETY: single thread, no raw slices live.
                        unsafe { tree.migrate_leaf_concurrent(leaf) }.expect("migrate concurrent");
                        if g.bool(0.5) {
                            a.epoch().try_reclaim(a);
                        }
                    }
                    out.migrations += 1;
                }
            }
            // -- eviction -------------------------------------------
            9 => {
                if let Some(leaf) = pick_leaf(g, &evicted, false) {
                    let block = tree.leaf_block(leaf);
                    let inject = g.bool(0.15);
                    if inject {
                        fault_ctl.fail_nth(1);
                    }
                    match swap.evict(block) {
                        Ok(slot) => {
                            evicted[leaf] = Some(slot);
                            out.evictions += 1;
                        }
                        Err(_) => {
                            assert!(inject, "uninjected eviction failed");
                            out.injected_faults += 1;
                            // Failure-atomic: the leaf must still serve.
                            let lo = leaf * leaf_cap;
                            assert_eq!(tree.get(lo).expect("get after failed evict"), mirror[lo]);
                        }
                    }
                }
            }
            // -- restore --------------------------------------------
            _ => {
                if let Some(leaf) = pick_leaf(g, &evicted, true) {
                    let slot = evicted[leaf].take().expect("parked leaf has a slot");
                    let inject = g.bool(0.15);
                    if inject {
                        fault_ctl.fail_nth(1);
                    }
                    match swap.fault(slot) {
                        Ok(fresh) => {
                            // SAFETY: no accessor since the eviction;
                            // fresh holds the leaf's bytes and is ours.
                            unsafe { CompactTarget::adopt_leaf_block(&tree, leaf, fresh) };
                            out.restores += 1;
                            let lo = leaf * leaf_cap;
                            assert_eq!(
                                tree.get(lo).expect("get after restore"),
                                mirror[lo],
                                "restore landed wrong bytes on leaf {leaf}"
                            );
                        }
                        Err(_) => {
                            assert!(inject, "uninjected fault failed");
                            out.injected_faults += 1;
                            // Failure-atomic: the payload stays parked.
                            evicted[leaf] = Some(slot);
                        }
                    }
                }
            }
        }
    }

    // Drain: restore every parked leaf, then the full-contents oracle.
    fault_ctl.disarm();
    for leaf in 0..evicted.len() {
        if let Some(slot) = evicted[leaf].take() {
            let fresh = swap.fault(slot).expect("final restore");
            // SAFETY: no accessor since the eviction.
            unsafe { CompactTarget::adopt_leaf_block(&tree, leaf, fresh) };
            out.restores += 1;
        }
    }
    assert_eq!(tree.to_vec(), mirror, "final contents diverged from the mirror");
    let mut view = tree.view();
    assert_eq!(view.to_vec(), mirror, "view drain diverged from the mirror");
    drop(view);
    a.epoch().synchronize(a);
    assert_eq!(a.epoch().limbo_len(), 0, "case left blocks in limbo");
    drop(tree);
    assert_eq!(a.stats().allocated, 0, "case leaked blocks");
    out
}
