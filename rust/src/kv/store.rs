//! The keyspace: a `BTreeMap` index over revision-stamped value cells
//! in a [`TreeArray`].
//!
//! ## Cell protocol
//!
//! The tree is carved into fixed `cell_words` runs of `u64` words, one
//! value per cell, never straddling a leaf (`leaf_cap % cell_words ==
//! 0` is enforced). Word 0 is the **revision stamp**, word 1 the value
//! length in bytes, the rest the LE-packed payload. A cell is always
//! written by exactly one `set_batch` call, and [`TreeWriter`] commits
//! a same-leaf batch under one seqlock hold, so a concurrent
//! [`TreeView::get_batch`] over the cell's indices returns either the
//! cell's old contents or its new contents — never a mix.
//!
//! ## Out-of-place commit
//!
//! Every put goes to a *fresh* cell:
//!
//! 1. under the index lock: pop a free cell, take a globally unique
//!    revision;
//! 2. outside the lock: write stamp + length + payload through the
//!    seqlock writer — this is where write faults on evicted leaves
//!    are taken, off the index's critical path;
//! 3. under the index lock again: point the key at the new cell and
//!    return the old cell (if any) to the free list.
//!
//! Readers snapshot the key's `(cell, rev)` under the lock, read the
//! cell lock-free, and accept the value only when the stamp equals the
//! snapshotted revision; a mismatch means the cell was recycled by a
//! later put, so the reader re-resolves. Revisions are never reused,
//! which makes the stamp ABA-proof: a stale-but-matching stamp can
//! only mean the cell still holds exactly the snapshotted value.
//!
//! Two concurrent puts to the same key each write their own cell and
//! race only on commit order: the last phase-3 lock holder wins, even
//! if its revision is numerically older. Within one client connection
//! operations are strictly ordered, which is the consistency pallas-kv
//! promises (per-key last-committer-wins, reads linearize at their
//! index snapshot).

use std::collections::{BTreeMap, VecDeque};
use std::ops::Bound;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::pmem::{BlockAlloc, BlockAllocator};
use crate::trees::{TreeArray, TreeView, TreeWriter};

/// Reserved words per cell ahead of the payload: revision stamp +
/// byte length.
const CELL_HEADER_WORDS: usize = 2;

/// What happened, for watchers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A key was created or overwritten.
    Put,
    /// A key was removed.
    Delete,
}

/// One entry in the watch ring.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KvEvent {
    /// Position in the global event sequence (dense, starts at 0).
    pub seq: u64,
    /// Put or delete.
    pub kind: EventKind,
    /// The key.
    pub key: Vec<u8>,
    /// The revision the mutation committed (for a delete: the fresh
    /// revision of the deletion itself, not the dead entry's).
    pub rev: u64,
}

/// One `watch` reply: the retained events at or after the requested
/// sequence number.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WatchBatch {
    /// Matching events in sequence order (bounded by the caller's
    /// `max`).
    pub events: Vec<KvEvent>,
    /// Oldest sequence number still in the ring. When this is greater
    /// than the requested `from_seq`, the ring overflowed and the
    /// watcher missed events — it must re-sync with a full range scan.
    pub first_seq_available: u64,
    /// Where to resume: one past the last returned event, or the end
    /// of the ring when nothing matched.
    pub next_seq: u64,
}

/// Bounded, oldest-dropped event ring (the "watch-lite" half of etcd's
/// watch: replay within a window, detectable loss beyond it).
struct EventRing {
    buf: VecDeque<KvEvent>,
    cap: usize,
    /// Sequence number the next pushed event receives.
    next_seq: u64,
    /// Sequence number of the oldest retained event (== `next_seq`
    /// when empty).
    first_seq: u64,
}

impl EventRing {
    fn new(cap: usize) -> Self {
        EventRing { buf: VecDeque::new(), cap, next_seq: 0, first_seq: 0 }
    }

    fn push(&mut self, kind: EventKind, key: &[u8], rev: u64) {
        let seq = self.next_seq;
        self.next_seq += 1;
        if self.cap == 0 {
            self.first_seq = self.next_seq;
            return;
        }
        self.buf.push_back(KvEvent { seq, kind, key: key.to_vec(), rev });
        if self.buf.len() > self.cap {
            self.buf.pop_front();
        }
        self.first_seq = self.buf.front().map(|e| e.seq).unwrap_or(self.next_seq);
    }
}

/// Index entry: where the key's current value lives.
#[derive(Clone, Copy, Debug)]
struct Slot {
    /// Cell number (tree words `cell * cell_words ..`).
    cell: u32,
    /// Revision stamped into the cell's word 0.
    rev: u64,
    /// Value length in bytes (duplicated in the cell's word 1).
    len: u32,
}

/// The mutex-protected half: key index, cell free list, revision
/// counter, event ring.
struct KvIndex {
    map: BTreeMap<Vec<u8>, Slot>,
    free: Vec<u32>,
    /// Next revision to hand out. Starts at 1 so 0 (the zero-filled
    /// tree's stamp) never matches a real revision.
    next_rev: u64,
    events: EventRing,
}

/// Operation counters, all monotonically increasing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvCounters {
    /// Committed puts.
    pub puts: u64,
    /// Point reads (hit or miss).
    pub gets: u64,
    /// Deletes that removed a key.
    pub deletes: u64,
    /// Range scans.
    pub scans: u64,
    /// Stamp-mismatch retries on the read path (a reader raced a cell
    /// recycle and re-resolved).
    pub read_retries: u64,
}

/// The shared keyspace. Create with [`KvStore::new`], then give each
/// serving thread its own [`KvHandler`] via [`KvStore::handler`].
pub struct KvStore<'t, 'a, A: BlockAlloc = BlockAllocator> {
    tree: &'t TreeArray<'a, u64, A>,
    cell_words: usize,
    ncells: usize,
    max_val: usize,
    index: Mutex<KvIndex>,
    puts: AtomicU64,
    gets: AtomicU64,
    deletes: AtomicU64,
    scans: AtomicU64,
    read_retries: AtomicU64,
}

impl<'t, 'a, A: BlockAlloc> KvStore<'t, 'a, A> {
    /// Wrap `tree` as a keyspace of `tree.len() / cell_words` cells,
    /// retaining up to `event_cap` watch events.
    ///
    /// `cell_words` must be at least `CELL_HEADER_WORDS + 1` and must
    /// divide the tree's leaf capacity, so no cell straddles a leaf
    /// (the seqlock-atomicity argument above needs that). The tree
    /// must be freshly zero-filled ([`TreeArray::new`] guarantees it)
    /// so no stale stamp can collide with a real revision.
    ///
    /// # Safety
    ///
    /// While the store exists, the tree may only be accessed through
    /// this store's handlers (plus read-only views and the mmd
    /// relocation/eviction machinery, which coordinate through leaf
    /// seqlocks). The store hands each [`KvHandler`] a seqlock
    /// [`TreeWriter`] under the [`TreeArray::writer`] contract; cell
    /// reservation through the index is what keeps those writers from
    /// ever racing on the same words.
    pub unsafe fn new(
        tree: &'t TreeArray<'a, u64, A>,
        cell_words: usize,
        event_cap: usize,
    ) -> Result<Self> {
        if cell_words < CELL_HEADER_WORDS + 1 {
            return Err(Error::Config(format!(
                "kv: cell_words {cell_words} leaves no payload room (need >= {})",
                CELL_HEADER_WORDS + 1
            )));
        }
        let leaf_cap = tree.geo.leaf_cap;
        if leaf_cap % cell_words != 0 {
            return Err(Error::Config(format!(
                "kv: cell_words {cell_words} must divide the leaf capacity {leaf_cap} \
                 so cells never straddle leaves"
            )));
        }
        let ncells = tree.len() / cell_words;
        if ncells == 0 {
            return Err(Error::Config("kv: tree too small for a single cell".into()));
        }
        // Pop from the back: cells are handed out lowest-first, which
        // keeps a lightly-loaded keyspace dense in the low leaves.
        let free: Vec<u32> = (0..ncells as u32).rev().collect();
        Ok(KvStore {
            tree,
            cell_words,
            ncells,
            max_val: (cell_words - CELL_HEADER_WORDS) * 8,
            index: Mutex::new(KvIndex {
                map: BTreeMap::new(),
                free,
                next_rev: 1,
                events: EventRing::new(event_cap),
            }),
            puts: AtomicU64::new(0),
            gets: AtomicU64::new(0),
            deletes: AtomicU64::new(0),
            scans: AtomicU64::new(0),
            read_retries: AtomicU64::new(0),
        })
    }

    /// A per-thread serving handle (own [`TreeView`] + [`TreeWriter`],
    /// own translation caches).
    pub fn handler<'s>(&'s self) -> KvHandler<'s, 't, 'a, A> {
        KvHandler {
            store: self,
            view: self.tree.view(),
            // SAFETY: the KvStore::new contract — all mutation goes
            // through handlers, and the index's cell reservation keeps
            // concurrent writers on disjoint words.
            writer: unsafe { self.tree.writer() },
            idxs: Vec::with_capacity(self.cell_words),
            vals: Vec::with_capacity(self.cell_words),
        }
    }

    /// Largest value (in bytes) a cell can hold.
    pub fn max_value_len(&self) -> usize {
        self.max_val
    }

    /// Total cell capacity (the keyspace can hold at most this many
    /// live keys, minus cells transiently reserved by in-flight puts).
    pub fn capacity(&self) -> usize {
        self.ncells
    }

    /// Live key count.
    pub fn len(&self) -> usize {
        self.index.lock().unwrap().map.len()
    }

    /// True when no keys are live.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot the operation counters.
    pub fn counters(&self) -> KvCounters {
        KvCounters {
            puts: self.puts.load(Ordering::Relaxed),
            gets: self.gets.load(Ordering::Relaxed),
            deletes: self.deletes.load(Ordering::Relaxed),
            scans: self.scans.load(Ordering::Relaxed),
            read_retries: self.read_retries.load(Ordering::Relaxed),
        }
    }

    /// Retained events at or after `from_seq`, up to `max` of them.
    /// Pure index operation, so it lives on the store (any thread may
    /// call it without a handler).
    pub fn watch(&self, from_seq: u64, max: usize) -> WatchBatch {
        let ix = self.index.lock().unwrap();
        let events: Vec<KvEvent> = ix
            .events
            .buf
            .iter()
            .filter(|e| e.seq >= from_seq)
            .take(max)
            .cloned()
            .collect();
        let next_seq = events
            .last()
            .map(|e| e.seq + 1)
            .unwrap_or_else(|| from_seq.max(ix.events.next_seq));
        WatchBatch {
            events,
            first_seq_available: ix.events.first_seq,
            next_seq,
        }
    }
}

/// A serving handle: the store plus thread-local tree accessors. Not
/// `Sync` — create one per serving thread with [`KvStore::handler`].
pub struct KvHandler<'s, 't, 'a, A: BlockAlloc> {
    store: &'s KvStore<'t, 'a, A>,
    view: TreeView<'t, 'a, u64, A>,
    writer: TreeWriter<'t, 'a, u64, A>,
    /// Scratch: the current cell's word indices.
    idxs: Vec<usize>,
    /// Scratch: the current cell's outgoing words.
    vals: Vec<u64>,
}

impl<'s, 't, 'a, A: BlockAlloc> KvHandler<'s, 't, 'a, A> {
    /// The store this handler serves.
    pub fn store(&self) -> &'s KvStore<'t, 'a, A> {
        self.store
    }

    /// Unpin this handler's epoch slots. Call before blocking (e.g. on
    /// an empty request queue) so reclamation never waits on an idle
    /// handler; the next operation re-pins automatically.
    pub fn park(&self) {
        self.view.park();
        self.writer.park();
    }

    /// Demand faults this handler's accessors took (evicted leaves
    /// paged back in on its read/write path).
    pub fn faults(&self) -> u64 {
        self.view.faults() + self.writer.faults()
    }

    fn fill_idxs(&mut self, cell: u32) {
        let base = cell as usize * self.store.cell_words;
        self.idxs.clear();
        self.idxs.extend(base..base + self.store.cell_words);
    }

    /// Read `cell`'s words seqlock-atomically (the whole cell is one
    /// leaf run, so the bracket covers it).
    fn read_cell(&mut self, cell: u32) -> Result<Vec<u64>> {
        self.fill_idxs(cell);
        self.view.get_batch(&self.idxs)
    }

    /// Stamp + write `cell` in one seqlock-held batch.
    fn write_cell(&mut self, cell: u32, rev: u64, value: &[u8]) -> Result<()> {
        self.fill_idxs(cell);
        self.vals.clear();
        self.vals.push(rev);
        self.vals.push(value.len() as u64);
        for chunk in value.chunks(8) {
            let mut b = [0u8; 8];
            b[..chunk.len()].copy_from_slice(chunk);
            self.vals.push(u64::from_le_bytes(b));
        }
        // Zero-pad so recycled cells never leak a previous value's
        // tail bytes into a longer successor.
        self.vals.resize(self.store.cell_words, 0);
        let (idxs, vals) = (&self.idxs, &self.vals);
        self.writer.set_batch(idxs, vals)
    }

    /// Point read: the value and its revision, or `None` for a missing
    /// key.
    pub fn get(&mut self, key: &[u8]) -> Result<Option<(Vec<u8>, u64)>> {
        self.store.gets.fetch_add(1, Ordering::Relaxed);
        loop {
            let slot = {
                let ix = self.store.index.lock().unwrap();
                match ix.map.get(key) {
                    None => return Ok(None),
                    Some(s) => *s,
                }
            };
            let words = self.read_cell(slot.cell)?;
            if words[0] == slot.rev && words[1] == slot.len as u64 {
                return Ok(Some((unpack(&words[CELL_HEADER_WORDS..], slot.len as usize), slot.rev)));
            }
            // The cell was recycled by a later put between our index
            // snapshot and the read; re-resolve from the index.
            self.store.read_retries.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Create or overwrite `key`, returning the committed revision.
    pub fn put(&mut self, key: &[u8], value: &[u8]) -> Result<u64> {
        if key.is_empty() {
            return Err(Error::Config("kv: empty key".into()));
        }
        if value.len() > self.store.max_val {
            return Err(Error::Config(format!(
                "kv: value of {} bytes exceeds the {}-byte cell payload",
                value.len(),
                self.store.max_val
            )));
        }
        // Phase 1: reserve a fresh cell and revision.
        let (cell, rev) = {
            let mut ix = self.store.index.lock().unwrap();
            let cell = ix.free.pop().ok_or_else(|| {
                Error::Config(format!("kv: keyspace full ({} cells)", self.store.ncells))
            })?;
            let rev = ix.next_rev;
            ix.next_rev += 1;
            (cell, rev)
        };
        // Phase 2: write the cell outside the lock (write faults on an
        // evicted leaf land here, off the index's critical path).
        if let Err(e) = self.write_cell(cell, rev, value) {
            // Roll the reservation back; the failed cell's contents
            // are unreferenced garbage either way.
            self.store.index.lock().unwrap().free.push(cell);
            return Err(e);
        }
        // Phase 3: commit.
        let mut ix = self.store.index.lock().unwrap();
        let old = ix.map.insert(
            key.to_vec(),
            Slot { cell, rev, len: value.len() as u32 },
        );
        if let Some(o) = old {
            ix.free.push(o.cell);
        }
        ix.events.push(EventKind::Put, key, rev);
        drop(ix);
        self.store.puts.fetch_add(1, Ordering::Relaxed);
        Ok(rev)
    }

    /// Remove `key`, returning the revision of the entry it removed
    /// (or `None` when the key was absent).
    pub fn delete(&mut self, key: &[u8]) -> Result<Option<u64>> {
        let mut ix = self.store.index.lock().unwrap();
        match ix.map.remove(key) {
            None => Ok(None),
            Some(s) => {
                ix.free.push(s.cell);
                let rev = ix.next_rev;
                ix.next_rev += 1;
                ix.events.push(EventKind::Delete, key, rev);
                drop(ix);
                self.store.deletes.fetch_add(1, Ordering::Relaxed);
                Ok(Some(s.rev))
            }
        }
    }

    /// Keys in `[start, end)` (all keys from `start` when `end` is
    /// empty), at most `limit` of them (unlimited when 0), as
    /// `(key, value, rev)` triples in key order.
    ///
    /// The key set is snapshotted under the index lock; values are
    /// then read lock-free with per-entry stamp validation. Entries
    /// deleted between snapshot and read are dropped, so fewer than
    /// `limit` rows can come back even when more keys matched.
    pub fn range(
        &mut self,
        start: &[u8],
        end: &[u8],
        limit: usize,
    ) -> Result<Vec<(Vec<u8>, Vec<u8>, u64)>> {
        self.store.scans.fetch_add(1, Ordering::Relaxed);
        if !end.is_empty() && end <= start {
            return Ok(Vec::new());
        }
        let snap: Vec<(Vec<u8>, Slot)> = {
            let ix = self.store.index.lock().unwrap();
            let upper = if end.is_empty() {
                Bound::Unbounded
            } else {
                Bound::Excluded(end.to_vec())
            };
            let iter = ix
                .map
                .range((Bound::Included(start.to_vec()), upper))
                .map(|(k, s)| (k.clone(), *s));
            if limit == 0 {
                iter.collect()
            } else {
                iter.take(limit).collect()
            }
        };
        let mut out = Vec::with_capacity(snap.len());
        for (key, mut slot) in snap {
            loop {
                let words = self.read_cell(slot.cell)?;
                if words[0] == slot.rev && words[1] == slot.len as u64 {
                    out.push((
                        key,
                        unpack(&words[CELL_HEADER_WORDS..], slot.len as usize),
                        slot.rev,
                    ));
                    break;
                }
                self.store.read_retries.fetch_add(1, Ordering::Relaxed);
                match self.store.index.lock().unwrap().map.get(&key) {
                    // Deleted since the snapshot: drop the row.
                    None => break,
                    Some(s) => slot = *s,
                }
            }
        }
        Ok(out)
    }
}

/// Unpack `len` bytes from LE-packed words.
fn unpack(words: &[u64], len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(words.len() * 8);
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out.truncate(len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;

    /// 4 KB blocks → 512 u64 per leaf; 16-word cells → 32 cells/leaf.
    fn harness() -> (BlockAllocator, usize) {
        (BlockAllocator::new(4096, 64).unwrap(), 16)
    }

    #[test]
    fn crud_roundtrip() {
        let (alloc, cw) = harness();
        let tree = TreeArray::<u64, _>::new(&alloc, 4 * 512).unwrap();
        let store = unsafe { KvStore::new(&tree, cw, 64) }.unwrap();
        let mut h = store.handler();

        assert_eq!(h.get(b"missing").unwrap(), None);
        let r1 = h.put(b"alpha", b"one").unwrap();
        let r2 = h.put(b"beta", b"two-two").unwrap();
        assert!(r2 > r1);
        assert_eq!(h.get(b"alpha").unwrap(), Some((b"one".to_vec(), r1)));
        assert_eq!(h.get(b"beta").unwrap(), Some((b"two-two".to_vec(), r2)));

        // Overwrite bumps the revision and frees the old cell.
        let r3 = h.put(b"alpha", b"ONE!").unwrap();
        assert!(r3 > r2);
        assert_eq!(h.get(b"alpha").unwrap(), Some((b"ONE!".to_vec(), r3)));

        assert_eq!(h.delete(b"alpha").unwrap(), Some(r3));
        assert_eq!(h.delete(b"alpha").unwrap(), None);
        assert_eq!(h.get(b"alpha").unwrap(), None);
        assert_eq!(store.len(), 1);

        let c = store.counters();
        assert_eq!(c.puts, 3);
        assert_eq!(c.deletes, 1);
        assert_eq!(c.read_retries, 0);
    }

    #[test]
    fn empty_and_max_len_values() {
        let (alloc, cw) = harness();
        let tree = TreeArray::<u64, _>::new(&alloc, 2 * 512).unwrap();
        let store = unsafe { KvStore::new(&tree, cw, 8) }.unwrap();
        let mut h = store.handler();
        assert_eq!(store.max_value_len(), (cw - 2) * 8);

        h.put(b"empty", b"").unwrap();
        assert_eq!(h.get(b"empty").unwrap().unwrap().0, b"".to_vec());

        let fat = vec![0xA5u8; store.max_value_len()];
        h.put(b"fat", &fat).unwrap();
        assert_eq!(h.get(b"fat").unwrap().unwrap().0, fat);

        let too_fat = vec![0u8; store.max_value_len() + 1];
        assert!(h.put(b"nope", &too_fat).is_err());
        assert!(h.put(b"", b"x").is_err());
    }

    #[test]
    fn recycled_cells_do_not_leak_previous_tails() {
        let (alloc, cw) = harness();
        let tree = TreeArray::<u64, _>::new(&alloc, 512).unwrap();
        let store = unsafe { KvStore::new(&tree, cw, 8) }.unwrap();
        let mut h = store.handler();
        // Long value, delete, then a short value likely reuses the cell.
        h.put(b"k", &vec![0xFFu8; store.max_value_len()]).unwrap();
        h.delete(b"k").unwrap();
        h.put(b"k", b"ab").unwrap();
        assert_eq!(h.get(b"k").unwrap().unwrap().0, b"ab".to_vec());
    }

    #[test]
    fn keyspace_full_is_typed_and_recoverable() {
        let (alloc, _) = harness();
        // One leaf of 512 words at 128-word cells: exactly 4 cells.
        let tree = TreeArray::<u64, _>::new(&alloc, 512).unwrap();
        let store = unsafe { KvStore::new(&tree, 128, 8) }.unwrap();
        assert_eq!(store.capacity(), 4);
        let mut h = store.handler();
        for i in 0..4u8 {
            h.put(&[i + 1], b"v").unwrap();
        }
        assert!(matches!(h.put(b"overflow", b"v"), Err(Error::Config(_))));
        // Deleting frees a cell and the keyspace accepts writes again.
        h.delete(&[1]).unwrap();
        h.put(b"overflow", b"v").unwrap();
        assert_eq!(store.len(), 4);
    }

    #[test]
    fn range_bounds_and_limit() {
        let (alloc, cw) = harness();
        let tree = TreeArray::<u64, _>::new(&alloc, 2 * 512).unwrap();
        let store = unsafe { KvStore::new(&tree, cw, 8) }.unwrap();
        let mut h = store.handler();
        for k in [b"a", b"b", b"c", b"d", b"e"] {
            h.put(k, k).unwrap();
        }
        let rows = h.range(b"b", b"e", 0).unwrap();
        assert_eq!(
            rows.iter().map(|(k, _, _)| k.clone()).collect::<Vec<_>>(),
            vec![b"b".to_vec(), b"c".to_vec(), b"d".to_vec()]
        );
        for (k, v, _) in &rows {
            assert_eq!(k, v);
        }
        // Limit truncates in key order.
        let rows = h.range(b"a", b"", 2).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, b"a".to_vec());
        // Inverted or empty window: no rows, no error.
        assert!(h.range(b"e", b"b", 0).unwrap().is_empty());
        assert!(h.range(b"c", b"c", 0).unwrap().is_empty());
        // Open upper bound reaches the last key.
        assert_eq!(h.range(b"e", b"", 0).unwrap().len(), 1);
    }

    #[test]
    fn watch_ring_replays_and_drops_oldest() {
        let (alloc, cw) = harness();
        let tree = TreeArray::<u64, _>::new(&alloc, 2 * 512).unwrap();
        let store = unsafe { KvStore::new(&tree, cw, 4) }.unwrap();
        let mut h = store.handler();
        h.put(b"a", b"1").unwrap(); // seq 0
        h.put(b"b", b"2").unwrap(); // seq 1
        h.delete(b"a").unwrap(); // seq 2

        let w = store.watch(0, 100);
        assert_eq!(w.first_seq_available, 0);
        assert_eq!(w.next_seq, 3);
        assert_eq!(w.events.len(), 3);
        assert_eq!(w.events[0].kind, EventKind::Put);
        assert_eq!(w.events[2].kind, EventKind::Delete);
        assert_eq!(w.events[2].key, b"a".to_vec());
        // Revisions in the stream are strictly increasing.
        assert!(w.events.windows(2).all(|p| p[1].rev > p[0].rev));

        // Overflow the 4-slot ring: oldest events fall off and the
        // loss is detectable via first_seq_available.
        for i in 0..6u8 {
            h.put(&[b'x', i], b"v").unwrap(); // seqs 3..=8
        }
        let w = store.watch(0, 100);
        assert!(w.first_seq_available > 0, "ring must have dropped seq 0");
        assert_eq!(w.events.len(), 4);
        assert_eq!(w.events.last().unwrap().seq, 8);
        assert_eq!(w.next_seq, 9);

        // max bounds the batch; next_seq resumes mid-ring.
        let w1 = store.watch(w.first_seq_available, 2);
        assert_eq!(w1.events.len(), 2);
        let w2 = store.watch(w1.next_seq, 100);
        assert_eq!(w2.events.len(), 2);
        // Asking beyond the end returns an empty batch, not an error.
        let w3 = store.watch(w2.next_seq, 100);
        assert!(w3.events.is_empty());
        assert_eq!(w3.next_seq, w2.next_seq);
    }

    #[test]
    fn config_errors_are_typed() {
        let (alloc, _) = harness();
        let tree = TreeArray::<u64, _>::new(&alloc, 512).unwrap();
        // 7 does not divide 512.
        assert!(unsafe { KvStore::new(&tree, 7, 8) }.is_err());
        // No payload room.
        assert!(unsafe { KvStore::new(&tree, 2, 8) }.is_err());
    }

    #[test]
    fn concurrent_handlers_share_the_store() {
        let alloc = BlockAllocator::new(4096, 64).unwrap();
        // 32 leaves -> 1024 cells, comfortably above the ~408 distinct
        // keys the four threads write.
        let tree = TreeArray::<u64, _>::new(&alloc, 32 * 512).unwrap();
        let store = unsafe { KvStore::new(&tree, 16, 1024) }.unwrap();
        let commits = AtomicU64::new(0);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let (store, commits) = (&store, &commits);
                s.spawn(move || {
                    let mut h = store.handler();
                    for i in 0..200u64 {
                        // Half the keys are shared across threads, so
                        // same-key put races and read-retries happen.
                        let k = if i % 2 == 0 { i % 16 } else { t * 1000 + i };
                        let key = k.to_be_bytes();
                        let rev = h.put(&key, &k.to_le_bytes()).unwrap();
                        commits.fetch_add((rev > 0) as u64, Ordering::Relaxed);
                        let (v, _) = h.get(&key).unwrap().expect("just wrote it");
                        // Shared keys always hold SOME thread's write of
                        // the same k, and k determines the value.
                        assert_eq!(v, k.to_le_bytes().to_vec());
                    }
                    h.park();
                });
            }
        });
        assert_eq!(commits.load(Ordering::Relaxed), 4 * 200);
        // Every key readable at the end; free list + live cells add up.
        let mut h = store.handler();
        let rows = h.range(b"", b"", 0).unwrap();
        assert_eq!(rows.len(), store.len());
        drop(h);
        drop(store);
        drop(tree);
        assert_eq!(alloc.stats().allocated, 0);
    }
}
