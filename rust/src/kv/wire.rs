//! Length-prefixed binary codec for [`Request`]/[`Response`].
//!
//! One tag byte, little-endian fixed-width integers, `u32`
//! length-prefixed byte strings. Decoding is a checked cursor: any
//! truncation, unknown tag, or trailing garbage is a typed `Err` —
//! never a panic and never a partial value — so a server can feed it
//! hostile bytes. The TCP transport ([`super::net`]) frames these
//! encodings; they also make deterministic replay logs.

use super::store::{EventKind, KvEvent};
use super::transport::{Request, Response};

/// Codec result: the error is a human-readable reason.
pub type WireResult<T> = std::result::Result<T, String>;

const REQ_GET: u8 = 1;
const REQ_PUT: u8 = 2;
const REQ_DELETE: u8 = 3;
const REQ_RANGE: u8 = 4;
const REQ_WATCH: u8 = 5;

const RESP_VALUE: u8 = 1;
const RESP_COMMITTED: u8 = 2;
const RESP_DELETED: u8 = 3;
const RESP_ENTRIES: u8 = 4;
const RESP_EVENTS: u8 = 5;
const RESP_ERROR: u8 = 6;

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn put_opt_u64(out: &mut Vec<u8>, v: Option<u64>) {
    match v {
        None => out.push(0),
        Some(v) => {
            out.push(1);
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Encode a request.
pub fn encode_request(r: &Request) -> Vec<u8> {
    let mut out = Vec::new();
    match r {
        Request::Get { key } => {
            out.push(REQ_GET);
            put_bytes(&mut out, key);
        }
        Request::Put { key, value } => {
            out.push(REQ_PUT);
            put_bytes(&mut out, key);
            put_bytes(&mut out, value);
        }
        Request::Delete { key } => {
            out.push(REQ_DELETE);
            put_bytes(&mut out, key);
        }
        Request::Range { start, end, limit } => {
            out.push(REQ_RANGE);
            put_bytes(&mut out, start);
            put_bytes(&mut out, end);
            out.extend_from_slice(&limit.to_le_bytes());
        }
        Request::Watch { from_seq, max } => {
            out.push(REQ_WATCH);
            out.extend_from_slice(&from_seq.to_le_bytes());
            out.extend_from_slice(&max.to_le_bytes());
        }
    }
    out
}

/// Encode a response.
pub fn encode_response(r: &Response) -> Vec<u8> {
    let mut out = Vec::new();
    match r {
        Response::Value { value, rev } => {
            out.push(RESP_VALUE);
            match value {
                None => out.push(0),
                Some(v) => {
                    out.push(1);
                    put_bytes(&mut out, v);
                }
            }
            out.extend_from_slice(&rev.to_le_bytes());
        }
        Response::Committed { rev } => {
            out.push(RESP_COMMITTED);
            out.extend_from_slice(&rev.to_le_bytes());
        }
        Response::Deleted { rev } => {
            out.push(RESP_DELETED);
            put_opt_u64(&mut out, *rev);
        }
        Response::Entries { entries } => {
            out.push(RESP_ENTRIES);
            out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
            for (k, v, rev) in entries {
                put_bytes(&mut out, k);
                put_bytes(&mut out, v);
                out.extend_from_slice(&rev.to_le_bytes());
            }
        }
        Response::Events { events, first_seq_available, next_seq } => {
            out.push(RESP_EVENTS);
            out.extend_from_slice(&(events.len() as u32).to_le_bytes());
            for e in events {
                out.extend_from_slice(&e.seq.to_le_bytes());
                out.push(match e.kind {
                    EventKind::Put => 0,
                    EventKind::Delete => 1,
                });
                put_bytes(&mut out, &e.key);
                out.extend_from_slice(&e.rev.to_le_bytes());
            }
            out.extend_from_slice(&first_seq_available.to_le_bytes());
            out.extend_from_slice(&next_seq.to_le_bytes());
        }
        Response::Error { message } => {
            out.push(RESP_ERROR);
            put_bytes(&mut out, message.as_bytes());
        }
    }
    out
}

/// Bounds-checked read cursor over untrusted bytes.
struct Cursor<'b> {
    b: &'b [u8],
    i: usize,
}

impl<'b> Cursor<'b> {
    fn new(b: &'b [u8]) -> Self {
        Cursor { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> WireResult<&'b [u8]> {
        let end = self.i.checked_add(n).ok_or("length overflow")?;
        let s = self.b.get(self.i..end).ok_or("truncated message")?;
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> WireResult<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> WireResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> WireResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes(&mut self) -> WireResult<Vec<u8>> {
        let len = self.u32()? as usize;
        Ok(self.take(len)?.to_vec())
    }

    fn opt_u64(&mut self) -> WireResult<Option<u64>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u64()?)),
            f => Err(format!("bad option flag {f}")),
        }
    }

    fn done(&self) -> WireResult<()> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes", self.b.len() - self.i))
        }
    }
}

/// Decode a request; rejects truncation, unknown tags, and trailing
/// bytes.
pub fn decode_request(b: &[u8]) -> WireResult<Request> {
    let mut c = Cursor::new(b);
    let req = match c.u8()? {
        REQ_GET => Request::Get { key: c.bytes()? },
        REQ_PUT => Request::Put { key: c.bytes()?, value: c.bytes()? },
        REQ_DELETE => Request::Delete { key: c.bytes()? },
        REQ_RANGE => Request::Range { start: c.bytes()?, end: c.bytes()?, limit: c.u32()? },
        REQ_WATCH => Request::Watch { from_seq: c.u64()?, max: c.u32()? },
        t => return Err(format!("unknown request tag {t}")),
    };
    c.done()?;
    Ok(req)
}

/// Decode a response; same guarantees as [`decode_request`].
pub fn decode_response(b: &[u8]) -> WireResult<Response> {
    let mut c = Cursor::new(b);
    let resp = match c.u8()? {
        RESP_VALUE => {
            let value = match c.u8()? {
                0 => None,
                1 => Some(c.bytes()?),
                f => Err(format!("bad option flag {f}"))?,
            };
            Response::Value { value, rev: c.u64()? }
        }
        RESP_COMMITTED => Response::Committed { rev: c.u64()? },
        RESP_DELETED => Response::Deleted { rev: c.opt_u64()? },
        RESP_ENTRIES => {
            let n = c.u32()?;
            let mut entries = Vec::new();
            for _ in 0..n {
                let k = c.bytes()?;
                let v = c.bytes()?;
                let rev = c.u64()?;
                entries.push((k, v, rev));
            }
            Response::Entries { entries }
        }
        RESP_EVENTS => {
            let n = c.u32()?;
            let mut events = Vec::new();
            for _ in 0..n {
                let seq = c.u64()?;
                let kind = match c.u8()? {
                    0 => EventKind::Put,
                    1 => EventKind::Delete,
                    k => return Err(format!("bad event kind {k}")),
                };
                let key = c.bytes()?;
                let rev = c.u64()?;
                events.push(KvEvent { seq, kind, key, rev });
            }
            Response::Events {
                events,
                first_seq_available: c.u64()?,
                next_seq: c.u64()?,
            }
        }
        RESP_ERROR => Response::Error {
            message: String::from_utf8_lossy(&c.bytes()?).into_owned(),
        },
        t => return Err(format!("unknown response tag {t}")),
    };
    c.done()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_requests() -> Vec<Request> {
        vec![
            Request::Get { key: b"k".to_vec() },
            Request::Get { key: Vec::new() },
            Request::Put { key: b"key".to_vec(), value: vec![0, 1, 2, 255] },
            Request::Put { key: b"k".to_vec(), value: Vec::new() },
            Request::Delete { key: b"gone".to_vec() },
            Request::Range { start: b"a".to_vec(), end: b"z".to_vec(), limit: 100 },
            Request::Range { start: Vec::new(), end: Vec::new(), limit: 0 },
            Request::Watch { from_seq: u64::MAX, max: 1 },
        ]
    }

    fn sample_responses() -> Vec<Response> {
        vec![
            Response::Value { value: Some(vec![9, 8, 7]), rev: 42 },
            Response::Value { value: None, rev: 0 },
            Response::Committed { rev: u64::MAX },
            Response::Deleted { rev: Some(7) },
            Response::Deleted { rev: None },
            Response::Entries {
                entries: vec![
                    (b"a".to_vec(), b"1".to_vec(), 1),
                    (b"b".to_vec(), Vec::new(), 2),
                ],
            },
            Response::Entries { entries: Vec::new() },
            Response::Events {
                events: vec![
                    KvEvent { seq: 0, kind: EventKind::Put, key: b"x".to_vec(), rev: 1 },
                    KvEvent { seq: 1, kind: EventKind::Delete, key: b"x".to_vec(), rev: 2 },
                ],
                first_seq_available: 0,
                next_seq: 2,
            },
            Response::Error { message: "kv: keyspace full (64 cells)".into() },
        ]
    }

    #[test]
    fn requests_roundtrip() {
        for r in sample_requests() {
            let enc = encode_request(&r);
            assert_eq!(decode_request(&enc).unwrap(), r, "{r:?}");
        }
    }

    #[test]
    fn responses_roundtrip() {
        for r in sample_responses() {
            let enc = encode_response(&r);
            assert_eq!(decode_response(&enc).unwrap(), r, "{r:?}");
        }
    }

    #[test]
    fn every_truncation_errors_never_panics() {
        for r in sample_requests() {
            let enc = encode_request(&r);
            for cut in 0..enc.len() {
                assert!(decode_request(&enc[..cut]).is_err(), "{r:?} cut at {cut}");
            }
        }
        for r in sample_responses() {
            let enc = encode_response(&r);
            for cut in 0..enc.len() {
                assert!(decode_response(&enc[..cut]).is_err(), "{r:?} cut at {cut}");
            }
        }
    }

    #[test]
    fn garbage_rejected() {
        // Unknown tags.
        assert!(decode_request(&[99]).is_err());
        assert!(decode_response(&[99]).is_err());
        // Trailing bytes after a well-formed message.
        let mut enc = encode_request(&Request::Get { key: b"k".to_vec() });
        enc.push(0);
        assert!(decode_request(&enc).is_err());
        // Bad option flag / event kind.
        let mut enc = encode_response(&Response::Deleted { rev: None });
        enc[1] = 7;
        assert!(decode_response(&enc).is_err());
        // A length prefix far beyond the buffer.
        let mut enc = encode_request(&Request::Delete { key: b"abc".to_vec() });
        enc[1] = 0xFF;
        enc[2] = 0xFF;
        assert!(decode_request(&enc).is_err());
        // Empty input.
        assert!(decode_request(&[]).is_err());
        assert!(decode_response(&[]).is_err());
    }
}
