//! Open-loop load generator for pallas-kv.
//!
//! The generator precomputes a deterministic schedule — one
//! [`OpSpec`] per operation, each with a fixed arrival time at the
//! configured rate — then replays it through any [`Transport`].
//! Latency is measured from the *scheduled* arrival, not from when the
//! client got around to sending, so a stalled server inflates the tail
//! instead of silently thinning the arrival stream (no coordinated
//! omission). Keys follow either a uniform or a YCSB-style scrambled
//! zipfian distribution; values are a pure function of the key
//! ([`value_for`]), which lets every read be verified against the
//! expected bytes with no shared oracle state.

use std::time::{Duration, Instant};

use super::transport::{Request, Response, Transport};
use crate::telemetry::LogHistogram;
use crate::testutil::Rng;

/// Key popularity distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KeyDist {
    /// Every key equally likely.
    Uniform,
    /// YCSB-style scrambled zipfian with the given theta (clamped to
    /// `(0.01, 0.99)`); hot ranks are scattered over the keyspace so
    /// popularity is not correlated with key order.
    Zipfian(f64),
}

/// Operation mix as integer weights (need not sum to 100).
#[derive(Clone, Copy, Debug)]
pub struct MixConfig {
    /// Label used in reports (e.g. `"read-heavy"`).
    pub name: &'static str,
    /// Weight of point gets.
    pub get_w: u32,
    /// Weight of puts.
    pub put_w: u32,
    /// Weight of range scans.
    pub scan_w: u32,
}

/// Full load-generator configuration. Copyable so experiments can
/// derive per-mix variants from one base.
#[derive(Clone, Copy, Debug)]
pub struct LoadgenConfig {
    /// Total operations across all clients.
    pub ops: usize,
    /// Open-loop arrival rate in ops/sec (`<= 0` = as fast as possible).
    pub rate: f64,
    /// Size of the key universe; keys are `0..nkeys` as big-endian
    /// `u64` bytes (order-preserving on the wire).
    pub nkeys: u64,
    /// Value length written by puts and expected by verification.
    pub val_len: usize,
    /// Keys per scan (`Range` limit and span).
    pub scan_len: usize,
    /// Key distribution.
    pub dist: KeyDist,
    /// Operation mix.
    pub mix: MixConfig,
    /// Schedule seed; equal seeds yield identical schedules.
    pub seed: u64,
    /// When true, every key is expected to exist (the store was
    /// prefilled), so a get miss counts as a verification failure.
    pub prefilled: bool,
}

/// What a scheduled operation does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Point read.
    Get,
    /// Overwrite with [`value_for`] bytes.
    Put,
    /// Range scan of [`LoadgenConfig::scan_len`] keys.
    Scan,
}

/// One precomputed operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpSpec {
    /// Operation kind.
    pub kind: OpKind,
    /// Target key in `0..nkeys`.
    pub key: u64,
    /// Scheduled arrival, nanoseconds from the run epoch.
    pub arrival_ns: u64,
}

/// Aggregated result of a [`run`].
#[derive(Debug)]
pub struct LoadgenOutcome {
    /// Per-op latency (ns) from scheduled arrival to response.
    pub hist: LogHistogram,
    /// Operations completed (should equal `cfg.ops`).
    pub ops_done: u64,
    /// Responses that were [`Response::Error`].
    pub errors: u64,
    /// Responses whose payload did not match the [`value_for`] oracle.
    pub verify_failures: u64,
    /// Wall-clock seconds for the whole run.
    pub wall_secs: f64,
    /// `ops_done / wall_secs`.
    pub achieved_rate: f64,
}

/// splitmix64 finalizer: a cheap stateless bijective scramble.
#[inline]
fn scramble(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Wire encoding of a key: big-endian `u64`, so byte order matches
/// numeric order and range scans work.
#[inline]
pub fn key_bytes(key: u64) -> [u8; 8] {
    key.to_be_bytes()
}

/// The value oracle: `len` bytes derived deterministically from the
/// key. Puts write this, reads verify against it — so correctness
/// checking needs no shared mirror.
pub fn value_for(key: u64, len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(len);
    let mut state = key ^ 0xD1B5_4A32_D192_ED03;
    while out.len() < len {
        state = scramble(state);
        let chunk = state.to_le_bytes();
        let take = chunk.len().min(len - out.len());
        out.extend_from_slice(&chunk[..take]);
    }
    out
}

/// YCSB zipfian rank generator (Gray et al. rejection-free form).
struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
}

impl Zipf {
    fn new(n: u64, theta: f64) -> Self {
        let theta = theta.clamp(0.01, 0.99);
        let mut zetan = 0.0;
        for i in 1..=n.max(1) {
            zetan += 1.0 / (i as f64).powf(theta);
        }
        let zeta2 = 1.0 + 0.5f64.powf(theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n.max(2) as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipf { n, theta, alpha, zetan, eta }
    }

    /// Map uniform `u in [0,1)` to a rank in `0..n`; rank 0 is hottest.
    fn rank(&self, u: f64) -> u64 {
        if self.n <= 1 {
            return 0;
        }
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let r = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        r.min(self.n - 1)
    }
}

/// Build the deterministic operation schedule for `cfg`.
pub fn schedule(cfg: &LoadgenConfig) -> Vec<OpSpec> {
    let mut rng = Rng::new(cfg.seed);
    let zipf = match cfg.dist {
        KeyDist::Zipfian(theta) => Some(Zipf::new(cfg.nkeys, theta)),
        KeyDist::Uniform => None,
    };
    let total_w = (cfg.mix.get_w + cfg.mix.put_w + cfg.mix.scan_w).max(1) as u64;
    let ns_per_op = if cfg.rate > 0.0 { 1e9 / cfg.rate } else { 0.0 };
    (0..cfg.ops)
        .map(|i| {
            let pick = rng.below(total_w) as u32;
            let kind = if pick < cfg.mix.get_w {
                OpKind::Get
            } else if pick < cfg.mix.get_w + cfg.mix.put_w {
                OpKind::Put
            } else {
                OpKind::Scan
            };
            let key = match &zipf {
                Some(z) => scramble(z.rank(rng.f64())) % cfg.nkeys.max(1),
                None => rng.below(cfg.nkeys.max(1)),
            };
            OpSpec { kind, key, arrival_ns: (i as f64 * ns_per_op) as u64 }
        })
        .collect()
}

fn request_for(cfg: &LoadgenConfig, spec: &OpSpec) -> Request {
    match spec.kind {
        OpKind::Get => Request::Get { key: key_bytes(spec.key).to_vec() },
        OpKind::Put => Request::Put {
            key: key_bytes(spec.key).to_vec(),
            value: value_for(spec.key, cfg.val_len),
        },
        OpKind::Scan => Request::Range {
            start: key_bytes(spec.key).to_vec(),
            end: key_bytes(spec.key.saturating_add(cfg.scan_len as u64)).to_vec(),
            limit: cfg.scan_len as u32,
        },
    }
}

struct ClientTally {
    hist: LogHistogram,
    ops: u64,
    errors: u64,
    verify_failures: u64,
}

fn check(cfg: &LoadgenConfig, spec: &OpSpec, resp: &Response, t: &mut ClientTally) {
    match (spec.kind, resp) {
        (_, Response::Error { .. }) => t.errors += 1,
        (OpKind::Get, Response::Value { value, .. }) => match value {
            Some(v) => {
                if *v != value_for(spec.key, cfg.val_len) {
                    t.verify_failures += 1;
                }
            }
            None => {
                if cfg.prefilled {
                    t.verify_failures += 1;
                }
            }
        },
        (OpKind::Put, Response::Committed { rev }) => {
            if *rev == 0 {
                t.verify_failures += 1;
            }
        }
        (OpKind::Scan, Response::Entries { entries }) => {
            for (k, v, _rev) in entries {
                let ok = k.len() == 8
                    && *v == value_for(u64::from_be_bytes(k[..8].try_into().unwrap()), cfg.val_len);
                if !ok {
                    t.verify_failures += 1;
                }
            }
        }
        // Any other (kind, response) pairing is a protocol violation.
        _ => t.verify_failures += 1,
    }
}

/// Replay the schedule for `cfg` through the given transports — one
/// client thread per transport, each taking every `transports.len()`-th
/// op — pacing sends to the scheduled arrival times and recording
/// arrival-to-response latency.
pub fn run<T: Transport>(cfg: &LoadgenConfig, transports: Vec<T>) -> LoadgenOutcome {
    assert!(!transports.is_empty(), "loadgen needs at least one transport");
    let sched = schedule(cfg);
    let clients = transports.len();
    let epoch = Instant::now();
    let tallies: Vec<ClientTally> = std::thread::scope(|s| {
        let handles: Vec<_> = transports
            .into_iter()
            .enumerate()
            .map(|(ci, mut transport)| {
                let sched = &sched;
                s.spawn(move || {
                    let mut tally = ClientTally {
                        hist: LogHistogram::new(),
                        ops: 0,
                        errors: 0,
                        verify_failures: 0,
                    };
                    for spec in sched.iter().skip(ci).step_by(clients) {
                        // Open-loop pacing: wait for the scheduled
                        // arrival, coarse sleep then spin for the
                        // final stretch.
                        loop {
                            let now = epoch.elapsed().as_nanos() as u64;
                            if now >= spec.arrival_ns {
                                break;
                            }
                            let wait = spec.arrival_ns - now;
                            if wait > 500_000 {
                                std::thread::sleep(Duration::from_nanos(wait - 300_000));
                            } else {
                                std::hint::spin_loop();
                            }
                        }
                        let resp = transport.call(request_for(cfg, spec));
                        let now = epoch.elapsed().as_nanos() as u64;
                        tally.hist.record(now.saturating_sub(spec.arrival_ns));
                        tally.ops += 1;
                        check(cfg, spec, &resp, &mut tally);
                    }
                    tally
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall_secs = epoch.elapsed().as_secs_f64().max(1e-9);
    let mut hist = LogHistogram::new();
    let (mut ops_done, mut errors, mut verify_failures) = (0, 0, 0);
    for t in &tallies {
        hist.merge(&t.hist);
        ops_done += t.ops;
        errors += t.errors;
        verify_failures += t.verify_failures;
    }
    LoadgenOutcome {
        hist,
        ops_done,
        errors,
        verify_failures,
        wall_secs,
        achieved_rate: ops_done as f64 / wall_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::sync::{Arc, Mutex};

    fn base_cfg() -> LoadgenConfig {
        LoadgenConfig {
            ops: 20_000,
            rate: 1e6,
            nkeys: 1024,
            val_len: 32,
            scan_len: 8,
            dist: KeyDist::Zipfian(0.99),
            mix: MixConfig { name: "mixed", get_w: 80, put_w: 15, scan_w: 5 },
            seed: 42,
            prefilled: false,
        }
    }

    #[test]
    fn schedule_is_deterministic_in_the_seed() {
        let cfg = base_cfg();
        assert_eq!(schedule(&cfg), schedule(&cfg));
        let mut other = cfg;
        other.seed = 43;
        assert_ne!(schedule(&cfg), schedule(&other));
    }

    #[test]
    fn arrivals_follow_the_configured_rate() {
        let cfg = base_cfg(); // 1e6 ops/s = 1000 ns spacing
        let sched = schedule(&cfg);
        for (i, s) in sched.iter().enumerate() {
            assert_eq!(s.arrival_ns, i as u64 * 1000);
        }
        let mut unpaced = cfg;
        unpaced.rate = 0.0;
        assert!(schedule(&unpaced).iter().all(|s| s.arrival_ns == 0));
    }

    #[test]
    fn zipfian_is_skewed_and_uniform_is_not() {
        let count_hottest = |dist: KeyDist| {
            let mut cfg = base_cfg();
            cfg.dist = dist;
            let mut counts = std::collections::HashMap::new();
            for s in schedule(&cfg) {
                *counts.entry(s.key).or_insert(0u64) += 1;
            }
            counts.values().copied().max().unwrap()
        };
        let zipf_hot = count_hottest(KeyDist::Zipfian(0.99));
        let uni_hot = count_hottest(KeyDist::Uniform);
        // 20k ops over 1024 keys: uniform hottest ~ a few dozen;
        // zipfian theta=0.99 puts ~10% of mass on the hottest key.
        assert!(
            zipf_hot > 3 * uni_hot,
            "zipf hottest {zipf_hot} vs uniform hottest {uni_hot}"
        );
    }

    #[test]
    fn mix_weights_are_respected() {
        let mut cfg = base_cfg();
        cfg.mix = MixConfig { name: "reads", get_w: 100, put_w: 0, scan_w: 0 };
        assert!(schedule(&cfg).iter().all(|s| s.kind == OpKind::Get));
        cfg.mix = MixConfig { name: "writes", get_w: 0, put_w: 1, scan_w: 0 };
        assert!(schedule(&cfg).iter().all(|s| s.kind == OpKind::Put));
    }

    #[test]
    fn value_oracle_is_deterministic_and_length_exact() {
        for len in [0, 1, 7, 8, 9, 128] {
            let v = value_for(7, len);
            assert_eq!(v.len(), len);
            assert_eq!(v, value_for(7, len));
        }
        assert_ne!(value_for(1, 32), value_for(2, 32));
    }

    /// An honest in-memory server: the oracle should report zero
    /// failures against it.
    struct MockTransport {
        map: Arc<Mutex<BTreeMap<Vec<u8>, Vec<u8>>>>,
    }

    impl Transport for MockTransport {
        fn call(&mut self, req: Request) -> Response {
            let mut map = self.map.lock().unwrap();
            match req {
                Request::Get { key } => Response::Value {
                    value: map.get(&key).cloned(),
                    rev: 1,
                },
                Request::Put { key, value } => {
                    map.insert(key, value);
                    Response::Committed { rev: 1 }
                }
                Request::Delete { key } => Response::Deleted {
                    rev: map.remove(&key).map(|_| 1),
                },
                Request::Range { start, end, limit } => {
                    let entries = map
                        .range(start..end)
                        .take(if limit == 0 { usize::MAX } else { limit as usize })
                        .map(|(k, v)| (k.clone(), v.clone(), 1))
                        .collect();
                    Response::Entries { entries }
                }
                Request::Watch { .. } => Response::Events {
                    events: Vec::new(),
                    first_seq_available: 0,
                    next_seq: 0,
                },
            }
        }
    }

    #[test]
    fn run_verifies_cleanly_against_an_honest_server() {
        let mut cfg = base_cfg();
        cfg.ops = 2_000;
        cfg.rate = 0.0; // max speed; keep the test fast
        let map = Arc::new(Mutex::new(BTreeMap::new()));
        let transports: Vec<_> = (0..2)
            .map(|_| MockTransport { map: Arc::clone(&map) })
            .collect();
        let out = run(&cfg, transports);
        assert_eq!(out.ops_done, 2_000);
        assert_eq!(out.errors, 0);
        assert_eq!(out.verify_failures, 0);
        assert_eq!(out.hist.count(), 2_000);
        assert!(out.achieved_rate > 0.0);
    }

    /// A server that answers gets with garbage: every get must be
    /// flagged by the oracle.
    struct LyingTransport;

    impl Transport for LyingTransport {
        fn call(&mut self, req: Request) -> Response {
            match req {
                Request::Get { .. } => Response::Value { value: Some(vec![0xAB]), rev: 1 },
                _ => Response::Committed { rev: 1 },
            }
        }
    }

    #[test]
    fn run_flags_wrong_values() {
        let mut cfg = base_cfg();
        cfg.ops = 500;
        cfg.rate = 0.0;
        cfg.mix = MixConfig { name: "reads", get_w: 1, put_w: 0, scan_w: 0 };
        let out = run(&cfg, vec![LyingTransport]);
        assert_eq!(out.verify_failures, 500);
        assert_eq!(out.errors, 0);
    }
}
