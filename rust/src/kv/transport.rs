//! The request/response surface and the in-process channel transport.
//!
//! [`Request`] and [`Response`] are the *entire* client-visible API;
//! every transport (the channel pair here, TCP in [`super::net`])
//! moves exactly these values, so offline runs and networked runs
//! exercise the same serving code. The channel transport is the
//! default: deterministic, allocation-light, and dependency-free, so
//! experiments and CI never open a socket.
//!
//! A [`KvServer`] is a single MPMC work queue (one mpsc channel whose
//! receiver sits behind a mutex). Serving threads each hold a
//! [`KvWorker`] and a [`super::store::KvHandler`]; clients each hold a
//! [`ChannelTransport`] carrying a private reply channel per request.
//! Workers drain until every sender — the server handle and all
//! transports — is gone, which makes shutdown a pure drop-ordering
//! affair: drop the transports, then the server, and the workers
//! unblock and return.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::pmem::BlockAlloc;

use super::store::{KvEvent, KvHandler};

/// One client request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// Point read.
    Get {
        /// Key to read.
        key: Vec<u8>,
    },
    /// Create or overwrite.
    Put {
        /// Key to write.
        key: Vec<u8>,
        /// Value bytes (bounded by the store's cell payload).
        value: Vec<u8>,
    },
    /// Remove a key.
    Delete {
        /// Key to remove.
        key: Vec<u8>,
    },
    /// Ordered scan of `[start, end)` (`end` empty = unbounded above).
    Range {
        /// Inclusive lower key.
        start: Vec<u8>,
        /// Exclusive upper key; empty means no upper bound.
        end: Vec<u8>,
        /// Row cap; 0 means unlimited.
        limit: u32,
    },
    /// Replay retained watch events at or after `from_seq`.
    Watch {
        /// First sequence number wanted.
        from_seq: u64,
        /// Batch size cap.
        max: u32,
    },
}

/// One server reply.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Response {
    /// Reply to [`Request::Get`]. `value` is `None` (with `rev` 0) for
    /// a missing key.
    Value {
        /// The value, if the key exists.
        value: Option<Vec<u8>>,
        /// The value's revision (0 when missing).
        rev: u64,
    },
    /// Reply to [`Request::Put`]: the committed revision.
    Committed {
        /// Revision the put committed.
        rev: u64,
    },
    /// Reply to [`Request::Delete`]: the removed entry's revision, or
    /// `None` when the key was already absent.
    Deleted {
        /// Revision of the entry that was removed.
        rev: Option<u64>,
    },
    /// Reply to [`Request::Range`]: `(key, value, rev)` rows in key
    /// order.
    Entries {
        /// The matching rows.
        entries: Vec<(Vec<u8>, Vec<u8>, u64)>,
    },
    /// Reply to [`Request::Watch`].
    Events {
        /// Matching events in sequence order.
        events: Vec<KvEvent>,
        /// Oldest retained sequence number (greater than the request's
        /// `from_seq` means the watcher lost events and must re-sync).
        first_seq_available: u64,
        /// Sequence number to resume from.
        next_seq: u64,
    },
    /// Any failure, as text (typed errors don't cross the wire).
    Error {
        /// Human-readable failure description.
        message: String,
    },
}

/// A client connection: moves one [`Request`] to the server and blocks
/// for its [`Response`].
pub trait Transport: Send {
    /// Issue `req` and wait for the reply.
    fn call(&mut self, req: Request) -> Response;
}

impl<'s, 't, 'a, A: BlockAlloc> KvHandler<'s, 't, 'a, A> {
    /// Serve one request. Store-level errors (value too large,
    /// keyspace full, swap escalation) become [`Response::Error`];
    /// nothing panics on malformed client input.
    pub fn handle(&mut self, req: Request) -> Response {
        match req {
            Request::Get { key } => match self.get(&key) {
                Ok(Some((value, rev))) => Response::Value { value: Some(value), rev },
                Ok(None) => Response::Value { value: None, rev: 0 },
                Err(e) => Response::Error { message: e.to_string() },
            },
            Request::Put { key, value } => match self.put(&key, &value) {
                Ok(rev) => Response::Committed { rev },
                Err(e) => Response::Error { message: e.to_string() },
            },
            Request::Delete { key } => match self.delete(&key) {
                Ok(rev) => Response::Deleted { rev },
                Err(e) => Response::Error { message: e.to_string() },
            },
            Request::Range { start, end, limit } => {
                match self.range(&start, &end, limit as usize) {
                    Ok(entries) => Response::Entries { entries },
                    Err(e) => Response::Error { message: e.to_string() },
                }
            }
            Request::Watch { from_seq, max } => {
                let w = self.store().watch(from_seq, max as usize);
                Response::Events {
                    events: w.events,
                    first_seq_available: w.first_seq_available,
                    next_seq: w.next_seq,
                }
            }
        }
    }
}

/// A request plus the private channel its reply goes back on.
type Envelope = (Request, Sender<Response>);

/// The in-process server: a shared work queue that any number of
/// [`KvWorker`]s drain and any number of [`ChannelTransport`]s feed.
pub struct KvServer {
    tx: Sender<Envelope>,
    rx: Arc<Mutex<Receiver<Envelope>>>,
}

impl KvServer {
    /// A fresh, empty work queue.
    pub fn new() -> Self {
        let (tx, rx) = channel();
        KvServer { tx, rx: Arc::new(Mutex::new(rx)) }
    }

    /// A new client connection.
    pub fn connect(&self) -> ChannelTransport {
        let (reply_tx, reply_rx) = channel();
        ChannelTransport { tx: self.tx.clone(), reply_tx, reply_rx }
    }

    /// A worker handle for one serving thread.
    pub fn worker(&self) -> KvWorker {
        KvWorker { rx: Arc::clone(&self.rx) }
    }
}

impl Default for KvServer {
    fn default() -> Self {
        Self::new()
    }
}

/// One serving thread's end of the queue: give it a handler and run it
/// to completion (see [`KvWorker::run`]).
pub struct KvWorker {
    rx: Arc<Mutex<Receiver<Envelope>>>,
}

impl KvWorker {
    /// Serve until every sender (the [`KvServer`] and all its
    /// transports) is dropped; returns the number of requests served.
    ///
    /// The handler is parked before each blocking wait so an idle
    /// worker never stalls epoch reclamation (mmd keeps compacting and
    /// evicting while the queue is empty).
    pub fn run<A: BlockAlloc>(self, handler: &mut KvHandler<'_, '_, '_, A>) -> u64 {
        let mut served = 0u64;
        loop {
            handler.park();
            // The queue mutex is held only for the blocking recv
            // itself (the guard is a temporary), so dispatch is
            // serialized but request *processing* runs in parallel
            // across workers.
            let envelope = self.rx.lock().unwrap().recv();
            match envelope {
                Ok((req, reply)) => {
                    let resp = handler.handle(req);
                    served += 1;
                    // A client that gave up (dropped its transport
                    // mid-request) is not an error worth dying for.
                    let _ = reply.send(resp);
                }
                Err(_) => return served,
            }
        }
    }
}

/// The client half: owns a private reply channel and clones its sender
/// into every request envelope.
pub struct ChannelTransport {
    tx: Sender<Envelope>,
    reply_tx: Sender<Response>,
    reply_rx: Receiver<Response>,
}

impl Transport for ChannelTransport {
    fn call(&mut self, req: Request) -> Response {
        if self.tx.send((req, self.reply_tx.clone())).is_err() {
            return Response::Error { message: "kv server is gone".into() };
        }
        self.reply_rx.recv().unwrap_or(Response::Error {
            message: "kv server dropped the request".into(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::store::KvStore;
    use crate::pmem::BlockAllocator;
    use crate::trees::TreeArray;

    #[test]
    fn end_to_end_over_channels() {
        let alloc = BlockAllocator::new(4096, 64).unwrap();
        let tree = TreeArray::<u64, _>::new(&alloc, 8 * 512).unwrap();
        let store = unsafe { KvStore::new(&tree, 16, 64) }.unwrap();

        let server = KvServer::new();
        let workers: Vec<KvWorker> = (0..2).map(|_| server.worker()).collect();
        let mut clients: Vec<ChannelTransport> = (0..3).map(|_| server.connect()).collect();

        let served_total = std::thread::scope(|s| {
            let store_r = &store;
            let worker_handles: Vec<_> = workers
                .into_iter()
                .map(|w| {
                    s.spawn(move || {
                        let mut h = store_r.handler();
                        w.run(&mut h)
                    })
                })
                .collect();

            let client_handles: Vec<_> = clients
                .drain(..)
                .enumerate()
                .map(|(ci, mut tp)| {
                    s.spawn(move || {
                        for i in 0..50u64 {
                            let key = (ci as u64 * 1000 + i).to_be_bytes();
                            let r = tp.call(Request::Put { key: key.to_vec(), value: vec![ci as u8; 9] });
                            let rev = match r {
                                Response::Committed { rev } => rev,
                                other => panic!("put got {other:?}"),
                            };
                            match tp.call(Request::Get { key: key.to_vec() }) {
                                Response::Value { value: Some(v), rev: r2 } => {
                                    assert_eq!(v, vec![ci as u8; 9]);
                                    assert_eq!(r2, rev, "no other client touches this key");
                                }
                                other => panic!("get got {other:?}"),
                            }
                        }
                        // Missing key and typed-error mapping.
                        match tp.call(Request::Get { key: b"nope".to_vec() }) {
                            Response::Value { value: None, rev: 0 } => {}
                            other => panic!("miss got {other:?}"),
                        }
                        match tp.call(Request::Put { key: Vec::new(), value: vec![1] }) {
                            Response::Error { message } => assert!(message.contains("empty key")),
                            other => panic!("bad put got {other:?}"),
                        }
                    })
                })
                .collect();
            for h in client_handles {
                h.join().unwrap();
            }
            // All transports are gone; dropping the server unblocks
            // the workers.
            drop(server);
            worker_handles.into_iter().map(|h| h.join().unwrap()).sum::<u64>()
        });
        // 3 clients x (50 puts + 50 gets + 1 miss + 1 bad put).
        assert_eq!(served_total, 3 * 102);
        assert_eq!(store.len(), 150);
    }

    #[test]
    fn range_and_watch_over_channels() {
        let alloc = BlockAllocator::new(4096, 64).unwrap();
        let tree = TreeArray::<u64, _>::new(&alloc, 4 * 512).unwrap();
        let store = unsafe { KvStore::new(&tree, 16, 32) }.unwrap();
        let server = KvServer::new();
        let worker = server.worker();
        let mut tp = server.connect();
        std::thread::scope(|s| {
            let store_r = &store;
            let wh = s.spawn(move || {
                let mut h = store_r.handler();
                worker.run(&mut h)
            });
            for k in 0..10u64 {
                tp.call(Request::Put { key: k.to_be_bytes().to_vec(), value: k.to_le_bytes().to_vec() });
            }
            match tp.call(Request::Range {
                start: 2u64.to_be_bytes().to_vec(),
                end: 6u64.to_be_bytes().to_vec(),
                limit: 0,
            }) {
                Response::Entries { entries } => {
                    assert_eq!(entries.len(), 4);
                    assert_eq!(entries[0].0, 2u64.to_be_bytes().to_vec());
                    assert!(entries[3].2 > entries[0].2);
                }
                other => panic!("range got {other:?}"),
            }
            match tp.call(Request::Watch { from_seq: 0, max: 100 }) {
                Response::Events { events, first_seq_available, next_seq } => {
                    assert_eq!(first_seq_available, 0);
                    assert_eq!(events.len(), 10);
                    assert_eq!(next_seq, 10);
                }
                other => panic!("watch got {other:?}"),
            }
            match tp.call(Request::Delete { key: 3u64.to_be_bytes().to_vec() }) {
                Response::Deleted { rev: Some(_) } => {}
                other => panic!("delete got {other:?}"),
            }
            match tp.call(Request::Delete { key: 3u64.to_be_bytes().to_vec() }) {
                Response::Deleted { rev: None } => {}
                other => panic!("re-delete got {other:?}"),
            }
            drop(tp);
            drop(server);
            // 10 puts + 1 range + 1 watch + 2 deletes.
            assert_eq!(wh.join().unwrap(), 14);
        });
    }

    #[test]
    fn transport_survives_server_shutdown() {
        let server = KvServer::new();
        let mut tp = server.connect();
        // No worker will ever serve this; drop the server and the call
        // must come back as an error, not hang or panic. (The envelope
        // sits in the dead queue; the reply channel reports closure.)
        drop(server);
        // The queue sender is still alive inside `tp`, so send
        // succeeds but no reply ever arrives... except every sender of
        // the reply channel is dropped with the envelope when the
        // receiver side is gone. Either way: an Error response.
        let resp = tp.call(Request::Get { key: b"k".to_vec() });
        assert!(matches!(resp, Response::Error { .. }), "{resp:?}");
    }
}
