//! # pallas-kv — a networked key-value front-end over the no-VM stack.
//!
//! The paper's case rests on server-shaped workloads: what does
//! software-managed physical memory cost when a *service* — not a
//! microbenchmark — runs on top of it? This module is that service: an
//! etcd-like keyspace whose values live in fixed-size cells of a
//! [`crate::trees::TreeArray`], with mmd compaction, eviction, and
//! software page faults running underneath while clients hold it to a
//! tail-latency SLO.
//!
//! ## Layout
//!
//! * [`store`] — [`store::KvStore`]: the keyspace itself. A
//!   `BTreeMap` index (key → cell/revision) under one mutex, values
//!   packed into `cell_words`-sized runs of `u64` tree words. Every
//!   put commits **out of place**: reserve a fresh cell + globally
//!   unique revision under the index lock, write the cell through the
//!   seqlock writer *outside* the lock (this is where write faults on
//!   evicted leaves happen), then commit and free the old cell.
//!   Readers validate the cell's revision stamp after a seqlock-atomic
//!   batch read and retry on mismatch, so index and data need no
//!   common lock.
//! * [`transport`] — [`transport::Request`]/[`transport::Response`],
//!   the [`Transport`] trait, and the in-process channel
//!   implementation ([`transport::KvServer`]) every offline run uses.
//! * [`wire`] — a length-prefixed binary codec for the request and
//!   response types, shared by the TCP transport and usable for replay
//!   logs; decoding never panics on truncated input.
//! * [`net`] — the TCP transport + blocking accept-loop server, behind
//!   the `net` feature flag so default builds stay network-free.
//! * [`loadgen`] — the open-loop load generator: a deterministic
//!   fixed-rate arrival schedule (zipfian or uniform keys, mixed
//!   get/put/scan ratios) measured from *scheduled* arrival time into
//!   a [`crate::telemetry::LogHistogram`], so queueing delay is part
//!   of the recorded latency (no coordinated omission).
//!
//! The `kv-serve` experiment (`nvm run kv-serve`) wires all of this
//! over a pool too small for full residency, and the
//! `ablation_kv_tail` bench gates p99-under-churn against quiescent
//! p99.

pub mod loadgen;
#[cfg(feature = "net")]
pub mod net;
pub mod store;
pub mod transport;
pub mod wire;

pub use loadgen::{KeyDist, LoadgenConfig, LoadgenOutcome, MixConfig, OpKind, OpSpec};
pub use store::{EventKind, KvCounters, KvEvent, KvHandler, KvStore, WatchBatch};
pub use transport::{ChannelTransport, KvServer, KvWorker, Request, Response, Transport};
