//! TCP transport for pallas-kv (behind the `net` feature).
//!
//! Frames are `u32`-LE length prefix + one [`super::wire`] message.
//! The server is deliberately simple — one blocking thread per
//! connection, driven by [`serve_conn`] — because the point of this
//! repo is the memory stack under the service, not connection
//! scaling. Default builds (and CI) never compile this module; the
//! offline experiments use the in-process channel transport.
//!
//! ```no_run
//! use nvm::kv::net::TcpTransport;
//! use nvm::kv::{Request, Transport};
//!
//! let mut t = TcpTransport::connect("127.0.0.1:2379").unwrap();
//! let resp = t.call(Request::Get { key: b"k".to_vec() });
//! ```

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};

use super::transport::{KvServer, Request, Response, Transport};
use super::wire;

/// Largest accepted frame (16 MiB) — rejects hostile length prefixes
/// before allocating.
pub const MAX_FRAME: u32 = 16 << 20;

fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    if payload.len() as u64 > MAX_FRAME as u64 {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "frame too large"));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

fn read_frame(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len);
    if len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "frame too large"));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Client side: one blocking TCP connection speaking framed
/// [`super::wire`] messages.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Connect to a pallas-kv server.
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(TcpTransport { stream })
    }

    fn call_io(&mut self, req: &Request) -> io::Result<Response> {
        write_frame(&mut self.stream, &wire::encode_request(req))?;
        let frame = read_frame(&mut self.stream)?;
        wire::decode_response(&frame)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
    }
}

impl Transport for TcpTransport {
    fn call(&mut self, req: Request) -> Response {
        match self.call_io(&req) {
            Ok(resp) => resp,
            Err(e) => Response::Error { message: format!("kv net: {e}") },
        }
    }
}

/// Serve one accepted connection: read framed requests, forward each
/// through `forward`, write the framed response. Returns when the
/// peer closes the connection (Ok) or on an I/O / codec error.
pub fn serve_conn(
    stream: &mut TcpStream,
    mut forward: impl FnMut(Request) -> Response,
) -> io::Result<u64> {
    stream.set_nodelay(true)?;
    let mut served = 0u64;
    loop {
        let frame = match read_frame(stream) {
            Ok(f) => f,
            // Clean shutdown: peer closed between frames.
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(served),
            Err(e) => return Err(e),
        };
        let resp = match wire::decode_request(&frame) {
            Ok(req) => forward(req),
            Err(e) => Response::Error { message: format!("kv net: bad request: {e}") },
        };
        write_frame(stream, &wire::encode_response(&resp))?;
        served += 1;
    }
}

/// Blocking accept loop: forwards every decoded request into the
/// in-process [`KvServer`] queue (where [`super::transport::KvWorker`]s
/// drain it), one thread per connection, until `max_conns` connections
/// have come and gone (`None` = run forever). Takes the server by
/// value: clone workers off it first; when the loop returns, the
/// queue's sender drops and idle workers exit.
pub fn serve(listener: TcpListener, server: KvServer, max_conns: Option<usize>) -> io::Result<()> {
    std::thread::scope(|s| {
        let server = &server;
        let mut accepted = 0usize;
        for conn in listener.incoming() {
            let mut stream = conn?;
            s.spawn(move || {
                let mut transport = server.connect();
                let _ = serve_conn(&mut stream, |req| transport.call(req));
            });
            accepted += 1;
            if let Some(max) = max_conns {
                if accepted >= max {
                    break;
                }
            }
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::store::KvStore;
    use crate::pmem::BlockAllocator;
    use crate::trees::TreeArray;

    #[test]
    fn tcp_end_to_end() {
        let alloc = BlockAllocator::with_capacity_bytes(1 << 22).unwrap();
        let tree: TreeArray<u64> = TreeArray::new(&alloc, 8 * 512).unwrap();
        let store = unsafe { KvStore::new(&tree, 16, 64).unwrap() };
        let server = KvServer::new();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        std::thread::scope(|s| {
            let worker = server.worker();
            let wh = s.spawn(|| {
                let mut h = store.handler();
                worker.run(&mut h)
            });
            // serve() owns the queue: once its single connection ends
            // it returns, the sender drops, and the worker exits.
            s.spawn(move || serve(listener, server, Some(1)).unwrap());

            let mut t = TcpTransport::connect(addr).unwrap();
            assert_eq!(
                t.call(Request::Put { key: b"net".to_vec(), value: b"hello".to_vec() }),
                Response::Committed { rev: 1 }
            );
            assert_eq!(
                t.call(Request::Get { key: b"net".to_vec() }),
                Response::Value { value: Some(b"hello".to_vec()), rev: 1 }
            );
            assert_eq!(
                t.call(Request::Get { key: b"miss".to_vec() }),
                Response::Value { value: None, rev: 0 }
            );
            drop(t);
            assert_eq!(wh.join().unwrap(), 3);
        });
    }

    #[test]
    fn oversized_frames_rejected() {
        let mut buf: &[u8] = &[0xFF, 0xFF, 0xFF, 0xFF];
        assert!(read_frame(&mut buf).is_err());
        let mut out = Vec::new();
        let big = vec![0u8; MAX_FRAME as usize + 1];
        assert!(write_frame(&mut out, &big).is_err());
    }
}
