//! Minimal CLI argument parsing (clap is unreachable offline).
//!
//! Grammar: `nvm <command> [--flag value]...`
//! Commands: `list`, `run <experiment>`, `report <file>`,
//! `diff <old> <new>`, `merge <out> <in>...`, `serve`, `info`.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// Positional arguments (command first).
    pub positional: Vec<String>,
    /// `--key value` flags (`--key` alone = "true").
    pub flags: HashMap<String, String>,
}

impl Cli {
    /// Parse from an argument iterator (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err(Error::Config("empty flag '--'".into()));
                }
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                cli.flags.insert(key.to_string(), val);
            } else {
                cli.positional.push(a);
            }
        }
        Ok(cli)
    }

    /// Command (first positional), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Typed flag lookup with default.
    pub fn flag_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} wants an integer, got {v:?}"))),
        }
    }

    /// Typed float flag lookup with default.
    pub fn flag_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} wants a number, got {v:?}"))),
        }
    }

    /// Boolean flag (present = true).
    pub fn flag_bool(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// String flag.
    pub fn flag_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let c = parse("run table2 --sample 100000 --quick");
        assert_eq!(c.command(), Some("run"));
        assert_eq!(c.positional, vec!["run", "table2"]);
        assert_eq!(c.flag_u64("sample", 1).unwrap(), 100000);
        assert!(c.flag_bool("quick"));
        assert!(!c.flag_bool("missing"));
    }

    #[test]
    fn flag_without_value_is_true() {
        let c = parse("serve --verbose");
        assert_eq!(c.flag_str("verbose"), Some("true"));
        assert_eq!(c.positional, vec!["serve"]);
    }

    #[test]
    fn flag_greedily_takes_next_positional() {
        // Documented limitation: a bare flag followed by a positional
        // consumes it as the value. Callers put flags last.
        let c = parse("serve --verbose run");
        assert_eq!(c.flag_str("verbose"), Some("run"));
    }

    #[test]
    fn bad_int_flag_errors() {
        let c = parse("run --sample abc");
        assert!(c.flag_u64("sample", 1).is_err());
    }

    #[test]
    fn float_flag_parses_with_default() {
        let c = parse("run kv-serve --kv-rate 12500.5");
        assert_eq!(c.flag_f64("kv-rate", 25_000.0).unwrap(), 12500.5);
        assert_eq!(c.flag_f64("missing", 25_000.0).unwrap(), 25_000.0);
        let bad = parse("run --kv-rate abc");
        assert!(bad.flag_f64("kv-rate", 1.0).is_err());
    }

    #[test]
    fn empty_args_ok() {
        let c = Cli::parse(std::iter::empty()).unwrap();
        assert_eq!(c.command(), None);
    }
}
