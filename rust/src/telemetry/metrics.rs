//! The unified metric surface: every subsystem's stats struct
//! snapshots into one flat `name → value` map.
//!
//! Each stats struct (`TlbStats`, `AllocStats`, `EpochStats`,
//! `FaultStats`, `ContentionStats`, `FragSnapshot`, tenant books)
//! implements [`MetricSource`]; a [`Metrics`] registry collects any
//! number of them under dotted prefixes (`tlb.hits`,
//! `fault.mean_us`, `tenant.3.p99_us`). Experiments and benches hand
//! the flat map to the results writer instead of hand-formatting
//! note strings per subsystem.

use std::collections::BTreeMap;
use std::fmt;

use super::json::Json;
use super::stat::Summary;
use crate::pmem::{AllocStats, ContentionStats, EpochStats};
use crate::trees::TlbStats;

/// A subsystem whose counters can be snapshotted into flat
/// `name → value` pairs.
pub trait MetricSource {
    /// Default dotted prefix for this source's metrics
    /// (e.g. `"tlb"` yields `tlb.hits`).
    fn metric_prefix(&self) -> &'static str;

    /// Emit every metric as an un-prefixed `name, value` pair.
    fn emit(&self, out: &mut dyn FnMut(&str, f64));
}

/// A flat, sorted `name → value` snapshot across subsystems.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Metrics {
    values: BTreeMap<String, f64>,
}

impl Metrics {
    /// An empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Snapshot `source` under its default prefix.
    pub fn record(&mut self, source: &dyn MetricSource) {
        let prefix = source.metric_prefix();
        self.record_as(prefix, source);
    }

    /// Snapshot `source` under an explicit prefix (use for multiple
    /// instances of one source, e.g. `tenant.0`, `tenant.1`).
    pub fn record_as(&mut self, prefix: &str, source: &dyn MetricSource) {
        source.emit(&mut |name, value| {
            self.values.insert(format!("{prefix}.{name}"), value);
        });
    }

    /// Set one metric directly.
    pub fn set(&mut self, name: &str, value: f64) {
        self.values.insert(name.to_string(), value);
    }

    /// Look one metric up.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.values.get(name).copied()
    }

    /// Number of metrics recorded.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Iterate metrics in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.values.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Render as `name = value` note lines, one subsystem's worth of
    /// hand-formatting replaced everywhere.
    pub fn note_lines(&self) -> Vec<String> {
        self.iter()
            .map(|(name, value)| {
                if value == value.trunc() && value.abs() < 1e15 {
                    format!("{name} = {}", value as i64)
                } else {
                    format!("{name} = {value:.3}")
                }
            })
            .collect()
    }

    /// The map as a JSON object (sorted keys).
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for (name, value) in self.iter() {
            obj.set(name, Json::Num(value));
        }
        obj
    }

    /// Rebuild from a JSON object produced by [`Metrics::to_json`].
    pub fn from_json(json: &Json) -> Result<Metrics, String> {
        let Json::Obj(fields) = json else {
            return Err("metrics: expected an object".into());
        };
        let mut m = Metrics::new();
        for (name, value) in fields {
            let v = value
                .as_f64()
                .ok_or_else(|| format!("metrics: {name} is not a number"))?;
            m.set(name, v);
        }
        Ok(m)
    }
}

impl fmt::Display for Metrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for line in self.note_lines() {
            writeln!(f, "{line}")?;
        }
        Ok(())
    }
}

impl MetricSource for TlbStats {
    fn metric_prefix(&self) -> &'static str {
        "tlb"
    }

    fn emit(&self, out: &mut dyn FnMut(&str, f64)) {
        out("hits", self.hits as f64);
        out("misses", self.misses as f64);
        out("evictions", self.evictions as f64);
        out("invalidations", self.invalidations as f64);
        out("hit_rate", self.hit_rate());
    }
}

impl MetricSource for AllocStats {
    fn metric_prefix(&self) -> &'static str {
        "alloc"
    }

    fn emit(&self, out: &mut dyn FnMut(&str, f64)) {
        out("allocated", self.allocated as f64);
        out("peak", self.peak as f64);
        out("total_allocs", self.total_allocs as f64);
        out("total_frees", self.total_frees as f64);
        out("failed_allocs", self.failed_allocs as f64);
        out("limbo", self.limbo as f64);
        out("retired", self.retired as f64);
        out("reclaimed", self.reclaimed as f64);
        out("mean_reclaim_lag", self.mean_reclaim_lag());
    }
}

impl MetricSource for ContentionStats {
    fn metric_prefix(&self) -> &'static str {
        "contention"
    }

    fn emit(&self, out: &mut dyn FnMut(&str, f64)) {
        out("steals", self.steals as f64);
        out("refills", self.refills as f64);
        out("cas_retries", self.cas_retries as f64);
    }
}

impl MetricSource for EpochStats {
    fn metric_prefix(&self) -> &'static str {
        "epoch"
    }

    fn emit(&self, out: &mut dyn FnMut(&str, f64)) {
        out("epoch", self.epoch as f64);
        out("readers", self.readers as f64);
        out("retired", self.retired as f64);
        out("reclaimed", self.reclaimed as f64);
        out("limbo", self.limbo as f64);
        out("mean_reclaim_lag", self.mean_reclaim_lag());
        out("pins", self.pins as f64);
        out("saved_pins", self.saved_pins as f64);
    }
}

impl MetricSource for Summary {
    fn metric_prefix(&self) -> &'static str {
        "summary"
    }

    fn emit(&self, out: &mut dyn FnMut(&str, f64)) {
        out("n", self.n as f64);
        out("mean", self.mean);
        out("stddev", self.stddev);
        out("ci95", self.ci95);
        out("min", self.min);
        out("max", self.max);
        out("p50", self.p50);
        out("p99", self.p99);
        out("p999", self.p999);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_prefixes_and_sorts() {
        let tlb = TlbStats {
            hits: 90,
            misses: 10,
            evictions: 3,
            invalidations: 1,
        };
        let epoch = EpochStats {
            pins: 7,
            saved_pins: 21,
            ..EpochStats::default()
        };
        let mut m = Metrics::new();
        m.record(&tlb);
        m.record(&epoch);
        m.set("custom.value", 1.5);
        assert_eq!(m.get("tlb.hits"), Some(90.0));
        assert_eq!(m.get("tlb.hit_rate"), Some(0.9));
        assert_eq!(m.get("epoch.saved_pins"), Some(21.0));
        assert_eq!(m.get("custom.value"), Some(1.5));
        let names: Vec<&str> = m.iter().map(|(k, _)| k).collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted);
    }

    #[test]
    fn explicit_prefix_for_instances() {
        let tlb = TlbStats::default();
        let mut m = Metrics::new();
        m.record_as("tenant.0.tlb", &tlb);
        m.record_as("tenant.1.tlb", &tlb);
        assert_eq!(m.get("tenant.0.tlb.hits"), Some(0.0));
        assert_eq!(m.get("tenant.1.tlb.misses"), Some(0.0));
    }

    #[test]
    fn json_roundtrip() {
        let mut m = Metrics::new();
        m.set("a.b", 1.25);
        m.set("c", 3.0);
        let back = Metrics::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        assert!(Metrics::from_json(&Json::Num(1.0)).is_err());
    }

    #[test]
    fn note_lines_format() {
        let mut m = Metrics::new();
        m.set("fault.mean_us", 12.5);
        m.set("fault.count", 3.0);
        let lines = m.note_lines();
        assert_eq!(lines, vec!["fault.count = 3", "fault.mean_us = 12.500"]);
    }
}
