//! Render a results file for humans (aligned table) or for gnuplot
//! (whitespace-separated `.dat`).

use std::fmt::Write as _;

use super::results::{Record, ResultsFile};

/// Render the whole file as aligned text tables, one per record.
pub fn render_results(file: &ResultsFile) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# {} @ {} (schema v{})",
        file.label, file.commit, file.schema_version
    );
    for record in &file.records {
        render_record(&mut out, record);
    }
    out
}

fn render_record(out: &mut String, r: &Record) {
    let _ = writeln!(out, "\n## {} ({})", r.name, r.kind);
    if !r.config.is_empty() {
        let cfg: Vec<String> = r.config.iter().map(|(k, v)| format!("{k}={v}")).collect();
        let _ = writeln!(out, "config: {}", cfg.join(" "));
    }
    if !r.metrics.is_empty() {
        let _ = writeln!(
            out,
            "{:42} {:>6} {:>12} {:>10} {:>12} {:>12} {:>12}  {}",
            "metric", "n", "mean", "±ci95", "p50", "p99", "p999", "unit"
        );
        for m in &r.metrics {
            let s = &m.summary;
            if m.is_empty() {
                let _ = writeln!(out, "{:42} {:>6} (no data)", m.name, 0);
                continue;
            }
            let _ = writeln!(
                out,
                "{:42} {:>6} {:>12.4} {:>10.4} {:>12.4} {:>12.4} {:>12.4}  {}",
                m.name, s.n, s.mean, s.ci95, s.p50, s.p99, s.p999, m.unit
            );
        }
    }
    if !r.counters.is_empty() {
        let _ = writeln!(out, "counters:");
        for line in r.counters.note_lines() {
            let _ = writeln!(out, "  {line}");
        }
    }
    for v in &r.verdicts {
        let tag = if v.pass { "PASS" } else { "FAIL" };
        let _ = writeln!(out, "[{tag}] {}: {}", v.name, v.detail);
    }
    for t in &r.traces {
        let preview: Vec<String> = t
            .values
            .iter()
            .take(12)
            .map(|v| format!("{v:.2}"))
            .collect();
        let more = if t.values.len() > 12 { " ..." } else { "" };
        let _ = writeln!(
            out,
            "trace {} ({} points): {}{}",
            t.name,
            t.values.len(),
            preview.join(" "),
            more
        );
    }
    if !r.actions.is_empty() {
        let mut counts: Vec<(String, usize)> = Vec::new();
        for (_, action) in &r.actions {
            match counts.iter_mut().find(|(a, _)| a == action) {
                Some((_, n)) => *n += 1,
                None => counts.push((action.clone(), 1)),
            }
        }
        let summary: Vec<String> = counts
            .iter()
            .map(|(a, n)| format!("{a}x{n}"))
            .collect();
        let _ = writeln!(
            out,
            "actions ({} rows): {}",
            r.actions.len(),
            summary.join(" ")
        );
    }
    for note in &r.notes {
        let _ = writeln!(out, "note: {note}");
    }
}

/// Render as a gnuplot-friendly `.dat`: one row per metric, columns
/// `record metric n mean ci95 min max p50 p99 p999`, `#`-prefixed
/// header, and traces appended as their own `# trace` blocks.
pub fn render_dat(file: &ResultsFile) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "# {} @ {}", file.label, file.commit);
    let _ = writeln!(out, "# record metric n mean ci95 min max p50 p99 p999");
    for r in &file.records {
        for m in &r.metrics {
            let s = &m.summary;
            let _ = writeln!(
                out,
                "{} {} {} {} {} {} {} {} {} {}",
                dat_word(&r.name),
                dat_word(&m.name),
                s.n,
                s.mean,
                s.ci95,
                s.min,
                s.max,
                s.p50,
                s.p99,
                s.p999
            );
        }
    }
    for r in &file.records {
        for t in &r.traces {
            let _ = writeln!(out, "\n\n# trace {} {}", dat_word(&r.name), dat_word(&t.name));
            for (tick, value) in t.ticks.iter().zip(&t.values) {
                let _ = writeln!(out, "{tick} {value}");
            }
        }
    }
    out
}

/// `.dat` columns are whitespace-separated; squash any whitespace in
/// a name so the row stays parseable.
fn dat_word(s: &str) -> String {
    s.replace(char::is_whitespace, "_")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::results::{
        Direction, MetricRecord, Record, ResultsFile, Trace, SCHEMA_VERSION,
    };

    fn fixture() -> ResultsFile {
        let mut r = Record::new("fig4 gups", "bench");
        r.config("sample", "1000");
        r.metric(MetricRecord::from_samples(
            "gups.mean_ms",
            "ms",
            Direction::Lower,
            vec![1.0, 1.2, 1.1],
        ));
        r.metric(MetricRecord::from_samples("empty", "us", Direction::Info, vec![]));
        r.counters.set("tlb.hits", 10.0);
        r.verdict("fast_enough", true, "1.1 < 2.0");
        r.traces.push(Trace {
            name: "mmd.score".into(),
            ticks: vec![0, 1],
            values: vec![0.5, 0.25],
        });
        r.actions.push((0, "idle".into()));
        r.actions.push((1, "evict".into()));
        r.actions.push((2, "evict".into()));
        ResultsFile {
            schema_version: SCHEMA_VERSION,
            commit: "cafebabe".into(),
            label: "BENCH_t".into(),
            records: vec![r],
        }
    }

    #[test]
    fn table_mentions_everything() {
        let text = render_results(&fixture());
        for needle in [
            "BENCH_t",
            "fig4 gups",
            "gups.mean_ms",
            "(no data)",
            "tlb.hits = 10",
            "[PASS] fast_enough",
            "trace mmd.score (2 points)",
            "actions (3 rows): idlex1 evictx2",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn dat_rows_are_machine_parseable() {
        let text = render_dat(&fixture());
        let row = text
            .lines()
            .find(|l| l.contains("gups.mean_ms"))
            .expect("metric row");
        let cols: Vec<&str> = row.split_whitespace().collect();
        assert_eq!(cols.len(), 10);
        assert_eq!(cols[0], "fig4_gups");
        assert_eq!(cols[2], "3");
        assert!(cols[3].parse::<f64>().is_ok());
        assert!(text.contains("# trace fig4_gups mmd.score"));
        assert!(text.contains("1 0.25"));
    }
}
