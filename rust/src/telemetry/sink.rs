//! A scoped, process-global results sink.
//!
//! `ExpConfig` is `Copy` and threads through every experiment closure
//! by value, so a recorder cannot ride inside it. Instead the runner
//! installs a [`Record`] here before dispatching an experiment;
//! experiment code emits structured metrics/traces unconditionally
//! through these free functions, which are no-ops when no sink is
//! installed (the normal table-printing path pays one relaxed atomic
//! load).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use super::results::{MetricRecord, Record, Trace};

static ACTIVE: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Record>> = Mutex::new(None);

/// Serializes unit tests that install a sink (the sink is global;
/// the harness runs tests on parallel threads).
#[cfg(test)]
pub(crate) static TEST_LOCK: Mutex<()> = Mutex::new(());

/// Install a fresh record, replacing (and discarding) any prior one.
pub fn begin(name: &str, kind: &str) {
    let mut sink = SINK.lock().unwrap();
    *sink = Some(Record::new(name, kind));
    ACTIVE.store(true, Ordering::Release);
}

/// Is a sink installed right now?
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Run `f` against the installed record; no-op without one.
pub fn with(f: impl FnOnce(&mut Record)) {
    if !active() {
        return;
    }
    let mut sink = SINK.lock().unwrap();
    if let Some(record) = sink.as_mut() {
        f(record);
    }
}

/// Append a metric to the installed record.
pub fn metric(m: MetricRecord) {
    with(|r| {
        r.metrics.push(m);
    });
}

/// Append a time-series trace.
pub fn trace(t: Trace) {
    with(|r| {
        r.traces.push(t);
    });
}

/// Append `(tick, action)` rows to the action log.
pub fn actions(rows: impl IntoIterator<Item = (u64, String)>) {
    with(|r| {
        r.actions.extend(rows);
    });
}

/// Append a PASS/FAIL verdict.
pub fn verdict(name: &str, pass: bool, detail: &str) {
    with(|r| {
        r.verdict(name, pass, detail);
    });
}

/// Uninstall and return the record (ends the scope).
pub fn take() -> Option<Record> {
    let mut sink = SINK.lock().unwrap();
    ACTIVE.store(false, Ordering::Release);
    sink.take()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::results::Direction;

    // One test exercises the whole lifecycle, under TEST_LOCK: the
    // sink is global, so parallel installs would race.
    #[test]
    fn sink_lifecycle() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // Inactive: emissions are dropped, not buffered.
        assert!(take().is_none());
        metric(MetricRecord::from_value("lost", "", Direction::Info, 1.0));
        assert!(!active());
        assert!(take().is_none());

        begin("exp", "experiment");
        assert!(active());
        metric(MetricRecord::from_value("kept", "us", Direction::Lower, 2.0));
        verdict("ok", true, "2 < 3");
        trace(Trace {
            name: "score".into(),
            ticks: vec![0, 1],
            values: vec![0.5, 0.25],
        });
        actions([(1, "evict".to_string())]);
        let r = take().expect("record installed");
        assert!(!active());
        assert_eq!(r.name, "exp");
        assert!(r.metrics.iter().any(|m| m.name == "kept"));
        assert!(r.metrics.iter().all(|m| m.name != "lost"));
        assert!(r.verdicts.iter().any(|v| v.name == "ok"));
        assert!(r.traces.iter().any(|t| t.name == "score"));
        assert!(r.actions.contains(&(1, "evict".to_string())));

        // begin replaces any stale record.
        begin("a", "experiment");
        begin("b", "experiment");
        assert_eq!(take().unwrap().name, "b");
    }
}
