//! Unified telemetry: one stat engine, one metric surface, one
//! results pipeline.
//!
//! The paper's argument is a measurement claim — "the overhead of
//! software-based memory management is surprisingly small" — and for
//! eight PRs this repo could print that claim but not record it.
//! This layer closes the gap:
//!
//! * [`stat`] — streaming mean/stddev ([`stat::Running`]), t-based
//!   95% CIs, and p50/p99/p999 from a fixed-bucket log-scale
//!   histogram ([`stat::LogHistogram`]) cheap enough for hot paths.
//! * [`metrics`] — every subsystem stats struct behind one
//!   [`metrics::MetricSource`] trait, snapshotted into a flat
//!   `name → value` map ([`metrics::Metrics`]).
//! * [`results`] — the machine-readable schema every experiment and
//!   ablation bench emits (`BENCH_*.json`): config, commit, raw
//!   samples, derived stats, PASS/FAIL verdicts, traces, action logs.
//! * [`sink`] — the scoped global recorder experiments emit through
//!   (their `Copy` config can't carry one).
//! * [`report`] — render a results file as a table or gnuplot `.dat`.
//! * [`diff`] — compare two results files sample-wise with
//!   CI-overlap reasoning into per-metric regression verdicts.

pub mod diff;
pub mod json;
pub mod metrics;
pub mod report;
pub mod results;
pub mod sink;
pub mod stat;

pub use diff::{DiffReport, MetricDiff, Outcome, VerdictDiff};
pub use json::Json;
pub use metrics::{MetricSource, Metrics};
pub use results::{
    Direction, MetricRecord, Record, ResultsFile, ResultsWriter, Trace, Verdict, SCHEMA_VERSION,
};
pub use stat::{summarize, LogHistogram, Running, Summary};
