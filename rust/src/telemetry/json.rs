//! Minimal JSON value, writer, and parser (serde is unreachable
//! offline; this covers exactly what the results schema needs).
//!
//! Objects preserve insertion order so rendered files diff cleanly
//! line-by-line across commits. Numbers are written with Rust's
//! shortest round-trip `f64` formatting; non-finite values are not
//! representable in JSON and render as `null`.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always carried as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, insertion-ordered.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// An empty array.
    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert/append `key: value` (objects only; panics otherwise —
    /// a builder misuse, not a data error).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Json {
        match self {
            Json::Obj(fields) => fields.push((key.to_string(), value)),
            _ => panic!("Json::set on a non-object"),
        }
        self
    }

    /// Append to an array (panics on non-arrays).
    pub fn push(&mut self, value: Json) -> &mut Json {
        match self {
            Json::Arr(items) => items.push(value),
            _ => panic!("Json::push on a non-array"),
        }
        self
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Number view.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Non-negative integer view (rejects fractional values).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u64::MAX as f64 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool view.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Render as pretty-printed JSON (2-space indent, trailing
    /// newline) — the on-disk format of every results file.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                // Scalar-only arrays (sample vectors) stay on one line.
                let flat = items
                    .iter()
                    .all(|i| !matches!(i, Json::Arr(_) | Json::Obj(_)));
                if flat {
                    out.push('[');
                    for (n, item) in items.iter().enumerate() {
                        if n > 0 {
                            out.push_str(", ");
                        }
                        item.write(out, indent);
                    }
                    out.push(']');
                } else {
                    out.push_str("[\n");
                    for (n, item) in items.iter().enumerate() {
                        pad(out, indent + 1);
                        item.write(out, indent + 1);
                        if n + 1 < items.len() {
                            out.push(',');
                        }
                        out.push('\n');
                    }
                    pad(out, indent);
                    out.push(']');
                }
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (n, (k, v)) in fields.iter().enumerate() {
                    pad(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if n + 1 < fields.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (exactly one value plus whitespace).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters after the document"));
        }
        Ok(v)
    }
}

fn pad(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, v: f64) {
    if !v.is_finite() {
        out.push_str("null");
    } else if v == v.trunc() && v.abs() < 1e15 {
        // Integers render without an exponent or fraction.
        let _ = write!(out, "{}", v as i64);
    } else {
        // Rust's shortest round-trip formatting.
        let _ = write!(out, "{v}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> String {
        format!("json parse error at byte {}: {}", self.i, msg)
    }

    fn ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn eat(&mut self, c: u8) -> bool {
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.eat(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut items = Vec::new();
                self.ws();
                if self.eat(b']') {
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.ws();
                    items.push(self.value()?);
                    self.ws();
                    if self.eat(b']') {
                        return Ok(Json::Arr(items));
                    }
                    self.expect(b',')?;
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut fields = Vec::new();
                self.ws();
                if self.eat(b'}') {
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.ws();
                    let key = self.string()?;
                    self.ws();
                    self.expect(b':')?;
                    self.ws();
                    let val = self.value()?;
                    fields.push((key, val));
                    self.ws();
                    if self.eat(b'}') {
                        return Ok(Json::Obj(fields));
                    }
                    self.expect(b',')?;
                }
            }
            Some(_) => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let Some(&c) = self.b.get(self.i) else {
                return Err(self.err("unterminated string"));
            };
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(&e) = self.b.get(self.i) else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // High surrogate: a low surrogate escape
                                // MUST follow, and its value must land in
                                // the low-surrogate range — anything else
                                // is a malformed pair, not U+FFFD.
                                if self.eat(b'\\') && self.eat(b'u') {
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("unpaired high surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad \\u escape"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Re-decode the UTF-8 sequence starting here.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if len == 1 {
                        s.push(c as char);
                    } else {
                        let chunk = self
                            .b
                            .get(start..end)
                            .ok_or_else(|| self.err("truncated UTF-8"))?;
                        let piece = std::str::from_utf8(chunk)
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(piece);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let chunk = self
            .b
            .get(self.i..self.i + 4)
            .ok_or_else(|| self.err("truncated \\u escape"))?;
        let text = std::str::from_utf8(chunk).map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(text, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        let _ = self.eat(b'-');
        while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.eat(b'.') {
            while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.b.get(self.i), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if !self.eat(b'+') {
                let _ = self.eat(b'-');
            }
            while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if self.i == start {
            return Err(self.err("expected a value"));
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("malformed number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let mut o = Json::obj();
        o.set("name", Json::Str("bench".into()));
        o.set("pass", Json::Bool(true));
        o.set("nothing", Json::Null);
        o.set("samples", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5), Json::Num(-3.75)]));
        let mut inner = Json::obj();
        inner.set("p99", Json::Num(123.456));
        o.set("summary", inner);
        let text = o.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn numbers_roundtrip_shortest() {
        for v in [0.0, 1.0, -1.5, 0.1, 1e-9, 12345678.9, 1e15, -2.25e-3] {
            let text = Json::Num(v).render();
            let back = Json::parse(&text).unwrap();
            assert_eq!(back.as_f64().unwrap(), v, "{text}");
        }
        // Integral values render without a fraction.
        assert_eq!(Json::Num(42.0).render(), "42\n");
        // Non-finite values degrade to null rather than invalid JSON.
        assert_eq!(Json::Num(f64::NAN).render(), "null\n");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null\n");
    }

    #[test]
    fn strings_escape_and_unescape() {
        let s = "a \"quoted\" line\nwith\ttabs \\ and unicode: µs → ok";
        let text = Json::Str(s.into()).render();
        assert_eq!(Json::parse(&text).unwrap().as_str().unwrap(), s);
        // Escapes parse from external producers too.
        let v = Json::parse(r#""µs A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "µs A 😀");
    }

    #[test]
    fn object_order_preserved() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        match &v {
            Json::Obj(fields) => {
                let keys: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
                assert_eq!(keys, vec!["z", "a", "m"]);
            }
            _ => panic!("expected object"),
        }
        assert_eq!(v.get("a").and_then(Json::as_f64), Some(2.0));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn malformed_inputs_error() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"unterminated", "{} garbage",
            "[1] 2",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn as_u64_rejects_fractions() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }

    #[test]
    fn surrogate_pairs_combine() {
        // External producers (python -c 'json.dumps("😀")', jq) emit
        // astral characters as \u pairs: they must decode to ONE code
        // point, not replacement chars.
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
        let v = Json::parse(r#""x😀y""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "x😀y");
        // First astral code point (U+10000) and a BMP escape alongside.
        let v = Json::parse(r#""𐀀 µs""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{10000} µs");
    }

    #[test]
    fn lone_surrogates_rejected() {
        // Regression: a lone LOW surrogate used to silently decode to
        // U+FFFD instead of failing the parse.
        assert!(Json::parse(r#""\udc00""#).is_err());
        assert!(Json::parse(r#""\udfff x""#).is_err());
        // Regression: a high surrogate whose following \u escape is not
        // a low surrogate used to wrap around in u32 arithmetic (debug
        // overflow panic) instead of erroring. BMP follower:
        assert!(Json::parse("\"\\ud83d\\u0041\"").is_err());
        // ... and a second high surrogate as the follower:
        assert!(Json::parse("\"\\ud83d\\ud83d\"").is_err());
        // High surrogate at end of string / not followed by \u at all.
        assert!(Json::parse("\"\\ud83d\"").is_err());
        assert!(Json::parse("\"\\ud83dA\"").is_err());
    }
}
