//! Sample-wise comparison of two results files into per-metric
//! regression verdicts.
//!
//! The reasoning is confidence-interval overlap: each side's mean
//! carries an uncertainty margin (its t-based 95% CI when it has one;
//! a fixed relative noise floor when it is a single observation or a
//! histogram-derived percentile). Two metrics whose intervals overlap
//! are *unchanged*; disjoint intervals are judged by the metric's
//! declared direction — a worse disjoint mean is a **regression**.
//! Verdict flips from PASS to FAIL always count as regressions.
//!
//! Histogram-backed metrics additionally export their bucket arrays,
//! and overlapping means are re-examined at the bucket level: a
//! reconstructed quantile (p50/p90/p99/p999) that moved by more than
//! [`DIST_SHIFT_FLOOR`] flags a **distribution shift** even when the
//! means agree — a handful of 10x-slower ops in a hundred thousand
//! barely moves a mean but is exactly what a tail-latency gate exists
//! to catch.

use std::fmt;

use super::results::{Direction, MetricRecord, ResultsFile, Summary};
use super::stat::percentile_rank;

/// Relative margin used when a metric has no CI of its own (single
/// sample, or percentiles derived from a histogram): ±5% of the mean.
pub const NOISE_FLOOR: f64 = 0.05;

/// Minimum relative shift of a reconstructed quantile before a
/// bucket-level comparison calls a distribution change. Log-histogram
/// buckets carry up to ~6.25% quantization error per side; 25% keeps
/// plenty of headroom above the combined worst case while still
/// catching a tail that moved a bucket decade.
pub const DIST_SHIFT_FLOOR: f64 = 0.25;

/// What happened to one metric between the two files.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Disjoint intervals, moved in the better direction.
    Improved,
    /// Disjoint intervals, moved in the worse direction.
    Regressed,
    /// Disjoint intervals on an [`Direction::Info`] metric.
    Changed,
    /// Intervals overlap — no statistically visible change.
    Unchanged,
    /// One side (or both) carries no data.
    NoData,
    /// Metric exists only in the new file.
    Added,
    /// Metric exists only in the old file.
    Removed,
}

impl Outcome {
    /// Short tag for table rendering.
    pub fn tag(self) -> &'static str {
        match self {
            Outcome::Improved => "[+]",
            Outcome::Regressed => "[-]",
            Outcome::Changed => "[~]",
            Outcome::Unchanged => "[=]",
            Outcome::NoData => "[?]",
            Outcome::Added => "[a]",
            Outcome::Removed => "[r]",
        }
    }
}

/// One metric's comparison.
#[derive(Clone, Debug)]
pub struct MetricDiff {
    /// Owning record name.
    pub record: String,
    /// Metric name.
    pub metric: String,
    /// Unit label (from the new side when present).
    pub unit: String,
    /// Declared direction.
    pub direction: Direction,
    /// Old-side summary (zeroed when [`Outcome::Added`]).
    pub old: Summary,
    /// New-side summary (zeroed when [`Outcome::Removed`]).
    pub new: Summary,
    /// Mean delta as a fraction of the old mean (0 when undefined).
    pub delta: f64,
    /// The call.
    pub outcome: Outcome,
    /// Interval reasoning, human-readable.
    pub detail: String,
}

/// One verdict's comparison.
#[derive(Clone, Debug)]
pub struct VerdictDiff {
    /// Owning record name.
    pub record: String,
    /// Verdict name.
    pub name: String,
    /// Old pass state (`None` when the verdict is new).
    pub old_pass: Option<bool>,
    /// New pass state (`None` when the verdict disappeared).
    pub new_pass: Option<bool>,
}

impl VerdictDiff {
    /// A PASS (or absent) verdict that now FAILs.
    pub fn regressed(&self) -> bool {
        self.new_pass == Some(false) && self.old_pass != Some(false)
    }
}

/// The full comparison of two results files.
#[derive(Clone, Debug)]
pub struct DiffReport {
    /// `label @ commit` of the old side.
    pub old_id: String,
    /// `label @ commit` of the new side.
    pub new_id: String,
    /// Per-metric calls, in file order.
    pub metrics: Vec<MetricDiff>,
    /// Per-verdict calls.
    pub verdicts: Vec<VerdictDiff>,
}

impl DiffReport {
    /// Compare `new` against `old`.
    pub fn compare(old: &ResultsFile, new: &ResultsFile) -> DiffReport {
        let mut report = DiffReport {
            old_id: format!("{} @ {}", old.label, short(&old.commit)),
            new_id: format!("{} @ {}", new.label, short(&new.commit)),
            metrics: Vec::new(),
            verdicts: Vec::new(),
        };
        for nr in &new.records {
            let or = old.record(&nr.name);
            for nm in &nr.metrics {
                let om = or.and_then(|r| r.metrics.iter().find(|m| m.name == nm.name));
                report.metrics.push(match om {
                    Some(om) => compare_metric(&nr.name, om, nm),
                    None => MetricDiff {
                        record: nr.name.clone(),
                        metric: nm.name.clone(),
                        unit: nm.unit.clone(),
                        direction: nm.direction,
                        old: Summary::default(),
                        new: nm.summary,
                        delta: 0.0,
                        outcome: Outcome::Added,
                        detail: "no old-side metric".into(),
                    },
                });
            }
            for nv in &nr.verdicts {
                let ov = or.and_then(|r| r.verdicts.iter().find(|v| v.name == nv.name));
                report.verdicts.push(VerdictDiff {
                    record: nr.name.clone(),
                    name: nv.name.clone(),
                    old_pass: ov.map(|v| v.pass),
                    new_pass: Some(nv.pass),
                });
            }
        }
        // Old-side metrics/verdicts that vanished.
        for or in &old.records {
            let nr = new.record(&or.name);
            for om in &or.metrics {
                let gone = nr
                    .map(|r| r.metrics.iter().all(|m| m.name != om.name))
                    .unwrap_or(true);
                if gone {
                    report.metrics.push(MetricDiff {
                        record: or.name.clone(),
                        metric: om.name.clone(),
                        unit: om.unit.clone(),
                        direction: om.direction,
                        old: om.summary,
                        new: Summary::default(),
                        delta: 0.0,
                        outcome: Outcome::Removed,
                        detail: "no new-side metric".into(),
                    });
                }
            }
            for ov in &or.verdicts {
                let gone = nr
                    .map(|r| r.verdicts.iter().all(|v| v.name != ov.name))
                    .unwrap_or(true);
                if gone {
                    report.verdicts.push(VerdictDiff {
                        record: or.name.clone(),
                        name: ov.name.clone(),
                        old_pass: Some(ov.pass),
                        new_pass: None,
                    });
                }
            }
        }
        report
    }

    /// Count of regressions: worse disjoint metrics plus PASS→FAIL
    /// verdict flips. Nonzero means `diff` exits nonzero.
    pub fn regressions(&self) -> usize {
        let metric = self
            .metrics
            .iter()
            .filter(|m| m.outcome == Outcome::Regressed)
            .count();
        let verdict = self.verdicts.iter().filter(|v| v.regressed()).count();
        metric + verdict
    }

    /// Count of improvements (better disjoint metrics + FAIL→PASS).
    pub fn improvements(&self) -> usize {
        let metric = self
            .metrics
            .iter()
            .filter(|m| m.outcome == Outcome::Improved)
            .count();
        let verdict = self
            .verdicts
            .iter()
            .filter(|v| v.new_pass == Some(true) && v.old_pass == Some(false))
            .count();
        metric + verdict
    }
}

fn short(commit: &str) -> &str {
    if commit.len() >= 8 && commit.bytes().all(|b| b.is_ascii_hexdigit()) {
        &commit[..8]
    } else {
        commit
    }
}

/// The uncertainty margin around one side's mean.
fn margin(s: &Summary) -> f64 {
    if s.n >= 2 && s.ci95 > 0.0 {
        s.ci95
    } else {
        NOISE_FLOOR * s.mean.abs()
    }
}

/// A reconstructed quantile that moved beyond [`DIST_SHIFT_FLOOR`].
struct Shift {
    quantile: &'static str,
    old: u64,
    new: u64,
    rel: f64,
}

/// Nearest-rank quantile over exported `(bucket_low, count)` pairs
/// (ascending bucket order, as `LogHistogram::buckets` emits them).
fn bucket_quantile(buckets: &[(u64, u64)], p: f64) -> u64 {
    let total: u64 = buckets.iter().map(|&(_, c)| c).sum();
    if total == 0 {
        return 0;
    }
    let rank = percentile_rank(p, total);
    let mut seen = 0u64;
    for &(lo, c) in buckets {
        seen += c;
        if seen >= rank {
            return lo;
        }
    }
    buckets.last().map(|&(lo, _)| lo).unwrap_or(0)
}

/// The largest relative quantile shift between two bucket exports, if
/// any quantile moved beyond the floor. Means can agree to within the
/// noise floor while the tail moves an order of magnitude — this is
/// the comparison summary scalars cannot make.
fn distribution_shift(old: &[(u64, u64)], new: &[(u64, u64)]) -> Option<Shift> {
    if old.is_empty() || new.is_empty() {
        return None;
    }
    let mut worst: Option<Shift> = None;
    for (quantile, p) in [("p50", 0.50), ("p90", 0.90), ("p99", 0.99), ("p999", 0.999)] {
        let (o, n) = (bucket_quantile(old, p), bucket_quantile(new, p));
        let rel = (n as f64 - o as f64) / (o.max(1) as f64);
        if rel.abs() > DIST_SHIFT_FLOOR
            && worst.as_ref().map_or(true, |w| rel.abs() > w.rel.abs())
        {
            worst = Some(Shift { quantile, old: o, new: n, rel });
        }
    }
    worst
}

fn compare_metric(record: &str, old_m: &MetricRecord, new_m: &MetricRecord) -> MetricDiff {
    let old = &old_m.summary;
    let new = new_m.summary;
    let mut d = MetricDiff {
        record: record.to_string(),
        metric: new_m.name.clone(),
        unit: new_m.unit.clone(),
        direction: new_m.direction,
        old: *old,
        new,
        delta: 0.0,
        outcome: Outcome::NoData,
        detail: String::new(),
    };
    if old.n == 0 || new.n == 0 {
        d.detail = "one side has no samples".into();
        return d;
    }
    if old.mean != 0.0 {
        d.delta = (new.mean - old.mean) / old.mean.abs();
    }
    let (om, nm) = (margin(old), margin(&new));
    let overlap = (old.mean - om).max(new.mean - nm) <= (old.mean + om).min(new.mean + nm);
    if overlap {
        // Means agree — but when both sides exported histogram
        // buckets, a quantile can still have moved decades (100 slow
        // ops in 100k barely dent the mean). Judge the shape too.
        if let Some(shift) = distribution_shift(&old_m.buckets, &new_m.buckets) {
            d.outcome = match d.direction {
                Direction::Info => Outcome::Changed,
                Direction::Higher if shift.rel < 0.0 => Outcome::Regressed,
                Direction::Higher => Outcome::Improved,
                Direction::Lower if shift.rel > 0.0 => Outcome::Regressed,
                Direction::Lower => Outcome::Improved,
            };
            d.detail = format!(
                "means overlap but distribution shifted: {} {} -> {} raw ({:+.0}%)",
                shift.quantile,
                shift.old,
                shift.new,
                shift.rel * 100.0
            );
            return d;
        }
        d.outcome = Outcome::Unchanged;
        d.detail = format!(
            "CI overlap: {:.4}±{:.4} vs {:.4}±{:.4}",
            old.mean, om, new.mean, nm
        );
        return d;
    }
    let better = match d.direction {
        Direction::Higher => new.mean > old.mean,
        Direction::Lower => new.mean < old.mean,
        Direction::Info => {
            d.outcome = Outcome::Changed;
            d.detail = format!("disjoint CIs on an info metric ({:+.1}%)", d.delta * 100.0);
            return d;
        }
    };
    d.outcome = if better {
        Outcome::Improved
    } else {
        Outcome::Regressed
    };
    d.detail = format!(
        "disjoint CIs: {:.4}±{:.4} -> {:.4}±{:.4} ({:+.1}%, {} is better)",
        old.mean,
        om,
        new.mean,
        nm,
        d.delta * 100.0,
        d.direction.as_str()
    );
    d
}

impl fmt::Display for DiffReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "diff: {}  ->  {}", self.old_id, self.new_id)?;
        let mut current = "";
        for m in &self.metrics {
            if m.record != current {
                current = &m.record;
                writeln!(f, "\n## {current}")?;
            }
            writeln!(
                f,
                "  {} {:40} {:>12.4} -> {:>12.4} {:8} {}",
                m.outcome.tag(),
                m.metric,
                m.old.mean,
                m.new.mean,
                m.unit,
                m.detail
            )?;
        }
        if !self.verdicts.is_empty() {
            writeln!(f, "\n## verdicts")?;
            for v in &self.verdicts {
                let show = |p: Option<bool>| match p {
                    Some(true) => "PASS",
                    Some(false) => "FAIL",
                    None => "absent",
                };
                let tag = if v.regressed() { "[-]" } else { "[=]" };
                writeln!(
                    f,
                    "  {} {:40} {} -> {}",
                    tag,
                    format!("{}/{}", v.record, v.name),
                    show(v.old_pass),
                    show(v.new_pass)
                )?;
            }
        }
        writeln!(
            f,
            "\n{} regression(s), {} improvement(s), {} metric(s) compared",
            self.regressions(),
            self.improvements(),
            self.metrics.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::results::{Direction, MetricRecord, Record, ResultsFile, SCHEMA_VERSION};

    fn file_with(metrics: Vec<MetricRecord>, verdicts: Vec<(&str, bool)>) -> ResultsFile {
        let mut r = Record::new("exp", "experiment");
        r.metrics = metrics;
        for (name, pass) in verdicts {
            r.verdict(name, pass, "fixture");
        }
        ResultsFile {
            schema_version: SCHEMA_VERSION,
            commit: "0123456789abcdef".into(),
            label: "t".into(),
            records: vec![r],
        }
    }

    #[test]
    fn overlapping_cis_are_unchanged() {
        let old = file_with(
            vec![MetricRecord::from_samples(
                "lat",
                "us",
                Direction::Lower,
                vec![10.0, 11.0, 10.5, 10.2],
            )],
            vec![],
        );
        let new = file_with(
            vec![MetricRecord::from_samples(
                "lat",
                "us",
                Direction::Lower,
                vec![10.3, 10.9, 10.6, 10.4],
            )],
            vec![],
        );
        let d = DiffReport::compare(&old, &new);
        assert_eq!(d.metrics[0].outcome, Outcome::Unchanged);
        assert_eq!(d.regressions(), 0);
    }

    #[test]
    fn disjoint_worse_is_regression_by_direction() {
        let old = file_with(
            vec![MetricRecord::from_samples(
                "lat",
                "us",
                Direction::Lower,
                vec![10.0, 10.1, 9.9],
            )],
            vec![],
        );
        let new = file_with(
            vec![MetricRecord::from_samples(
                "lat",
                "us",
                Direction::Lower,
                vec![20.0, 20.2, 19.8],
            )],
            vec![],
        );
        let d = DiffReport::compare(&old, &new);
        assert_eq!(d.metrics[0].outcome, Outcome::Regressed);
        assert_eq!(d.regressions(), 1);
        // Same numbers, higher-is-better: an improvement.
        let old_h = file_with(
            vec![MetricRecord::from_samples(
                "tput",
                "Mop/s",
                Direction::Higher,
                vec![10.0, 10.1, 9.9],
            )],
            vec![],
        );
        let new_h = file_with(
            vec![MetricRecord::from_samples(
                "tput",
                "Mop/s",
                Direction::Higher,
                vec![20.0, 20.2, 19.8],
            )],
            vec![],
        );
        let d = DiffReport::compare(&old_h, &new_h);
        assert_eq!(d.metrics[0].outcome, Outcome::Improved);
        assert_eq!(d.regressions(), 0);
        assert_eq!(d.improvements(), 1);
    }

    #[test]
    fn single_samples_use_noise_floor() {
        let old = file_with(
            vec![MetricRecord::from_value("v", "", Direction::Lower, 100.0)],
            vec![],
        );
        // +3% is inside the ±5% noise floor.
        let close = file_with(
            vec![MetricRecord::from_value("v", "", Direction::Lower, 103.0)],
            vec![],
        );
        assert_eq!(
            DiffReport::compare(&old, &close).metrics[0].outcome,
            Outcome::Unchanged
        );
        // +20% is well outside it.
        let far = file_with(
            vec![MetricRecord::from_value("v", "", Direction::Lower, 120.0)],
            vec![],
        );
        let d = DiffReport::compare(&old, &far);
        assert_eq!(d.metrics[0].outcome, Outcome::Regressed);
        assert!((d.metrics[0].delta - 0.2).abs() < 1e-12);
    }

    #[test]
    fn empty_and_info_metrics_never_fail() {
        let old = file_with(
            vec![
                MetricRecord::from_samples("authored", "us", Direction::Lower, vec![]),
                MetricRecord::from_value("count", "", Direction::Info, 5.0),
            ],
            vec![],
        );
        let new = file_with(
            vec![
                MetricRecord::from_samples("authored", "us", Direction::Lower, vec![9.9]),
                MetricRecord::from_value("count", "", Direction::Info, 50.0),
            ],
            vec![],
        );
        let d = DiffReport::compare(&old, &new);
        assert_eq!(d.metrics[0].outcome, Outcome::NoData);
        assert_eq!(d.metrics[1].outcome, Outcome::Changed);
        assert_eq!(d.regressions(), 0);
    }

    fn hist_of(base: u64, outliers: u64) -> MetricRecord {
        // 99_900 ops at ~base, 200 at ~outliers: the outliers own the
        // top ~0.2% of the mass, so p999 sits in their bucket while
        // the mean barely notices them.
        let mut h = crate::telemetry::LogHistogram::new();
        for _ in 0..99_900u64 {
            h.record(base);
        }
        for _ in 0..200u64 {
            h.record(outliers);
        }
        MetricRecord::from_hist("op.latency", "us", Direction::Lower, &h, 1e-3)
    }

    #[test]
    fn overlapping_means_but_shifted_tail_is_flagged() {
        let old = file_with(vec![hist_of(100, 200)], vec![]);
        let new = file_with(vec![hist_of(100, 4_000)], vec![]);
        // Means overlap under the ±5% noise floor...
        let om = old.records[0].metrics[0].summary.mean;
        let nm = new.records[0].metrics[0].summary.mean;
        assert!((nm - om) / om < 2.0 * NOISE_FLOOR, "fixture: means must overlap");
        // ...but the bucket-level comparison sees the p999 move.
        let d = DiffReport::compare(&old, &new);
        assert_eq!(d.metrics[0].outcome, Outcome::Regressed);
        assert!(d.metrics[0].detail.contains("distribution shifted"), "{}", d.metrics[0].detail);
        assert_eq!(d.regressions(), 1);
        // The reverse direction is an improvement, not a regression.
        let d = DiffReport::compare(&new, &old);
        assert_eq!(d.metrics[0].outcome, Outcome::Improved);
    }

    #[test]
    fn identical_buckets_stay_unchanged() {
        let old = file_with(vec![hist_of(100, 200)], vec![]);
        let new = file_with(vec![hist_of(100, 200)], vec![]);
        let d = DiffReport::compare(&old, &new);
        assert_eq!(d.metrics[0].outcome, Outcome::Unchanged);
    }

    #[test]
    fn info_distribution_shift_is_changed_not_regressed() {
        let mk = |outliers| {
            let mut m = hist_of(100, outliers);
            m.direction = Direction::Info;
            m
        };
        let old = file_with(vec![mk(200)], vec![]);
        let new = file_with(vec![mk(4_000)], vec![]);
        let d = DiffReport::compare(&old, &new);
        assert_eq!(d.metrics[0].outcome, Outcome::Changed);
        assert_eq!(d.regressions(), 0);
    }

    #[test]
    fn bucket_quantile_walks_cumulative_counts() {
        let buckets = [(10u64, 50u64), (20, 30), (40, 20)];
        assert_eq!(bucket_quantile(&buckets, 0.25), 10);
        assert_eq!(bucket_quantile(&buckets, 0.50), 10);
        assert_eq!(bucket_quantile(&buckets, 0.79), 20);
        assert_eq!(bucket_quantile(&buckets, 0.81), 40);
        assert_eq!(bucket_quantile(&buckets, 1.0), 40);
        assert_eq!(bucket_quantile(&[], 0.5), 0);
    }

    #[test]
    fn verdict_flip_and_added_removed() {
        let old = file_with(
            vec![MetricRecord::from_value("gone", "", Direction::Lower, 1.0)],
            vec![("inv", true), ("dropped", true)],
        );
        let new = file_with(
            vec![MetricRecord::from_value("fresh", "", Direction::Lower, 1.0)],
            vec![("inv", false), ("born", true)],
        );
        let d = DiffReport::compare(&old, &new);
        let by_name = |n: &str| d.metrics.iter().find(|m| m.metric == n).unwrap();
        assert_eq!(by_name("fresh").outcome, Outcome::Added);
        assert_eq!(by_name("gone").outcome, Outcome::Removed);
        // inv flipped PASS -> FAIL: one regression.
        assert_eq!(d.regressions(), 1);
        let text = d.to_string();
        assert!(text.contains("inv"));
        assert!(text.contains("regression(s)"));
    }
}
