//! The stat engine behind the results pipeline: streaming moments,
//! t-based confidence intervals, and a fixed-bucket log-scale
//! histogram cheap enough for hot paths.
//!
//! Everything here is deterministic and allocation-light:
//!
//! * [`Running`] — Welford's streaming mean/variance, O(1) per sample,
//!   no stored samples. The building block for throughput counters.
//! * [`summarize`] / [`Summary`] — one pass over a sample vector into
//!   the record the results schema stores: n, mean, sample stddev, a
//!   t-based 95% confidence half-width, min/max, and nearest-rank
//!   p50/p99/p999.
//! * [`LogHistogram`] — an HDR-style histogram with power-of-two
//!   groups and [`HIST_SUBBUCKETS`] linear sub-buckets per group:
//!   `record` is a handful of integer ops (no floats, no allocation
//!   after construction), relative quantile error is bounded by
//!   `1/HIST_SUBBUCKETS` (6.25%), and histograms merge losslessly —
//!   per-thread recording with a merge at the end is the intended
//!   hot-path pattern.

/// Linear sub-buckets per power-of-two group of a [`LogHistogram`].
/// Bounds the relative error of a reported quantile to `1/16`.
pub const HIST_SUBBUCKETS: u64 = 16;

/// Bucket count: group 0 covers values `0..16` exactly; groups `1..=60`
/// cover `[16 << (g-1), 16 << g)` with 16 sub-buckets each, enough for
/// any `u64` value.
const HIST_BUCKETS: usize = 16 + 60 * 16;

/// Welford's streaming mean/variance accumulator (O(1) per sample, no
/// stored samples).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    /// An empty accumulator.
    pub fn new() -> Self {
        Running::default()
    }

    /// Add one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        if self.n == 1 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Samples pushed so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean of the samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample variance (n−1 denominator; 0 for fewer than 2 samples).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample (0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Derived statistics of one metric's samples — what the results
/// schema stores next to the raw sample vector.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: u64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n−1; 0 below 2 samples).
    pub stddev: f64,
    /// Half-width of the t-based 95% confidence interval of the mean
    /// (0 below 2 samples). The interval is `mean ± ci95`.
    pub ci95: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median (nearest rank).
    pub p50: f64,
    /// 99th percentile (nearest rank).
    pub p99: f64,
    /// 99.9th percentile (nearest rank).
    pub p999: f64,
}

/// Two-sided 97.5% Student-t quantile for `df` degrees of freedom —
/// the multiplier of a 95% confidence interval. Exact table through
/// df 30, the normal limit above (the error is < 2% there).
pub fn t975(df: u64) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    match df {
        0 => 0.0,
        d if d <= 30 => TABLE[(d - 1) as usize],
        _ => 1.96,
    }
}

/// Nearest-rank of percentile `p` over `n` samples, 1-based: the
/// smallest rank whose element has at least `p` of the mass at or
/// below it. One function so [`nearest_rank`] and
/// [`LogHistogram::percentile`] share the same edge convention:
/// `p <= 0` is the minimum (rank 1), `p >= 1` the maximum (rank n).
///
/// The naive `(p * n).ceil()` misindexes whenever the product lands
/// one ULP above an exact integer — `0.07 * 100.0` evaluates to
/// `7.000000000000001`, so `ceil` inflates the rank to 8 and the p7
/// of `1..=100` reports 8 instead of 7. The fix snaps to the nearest
/// integer when the product is within a few ULPs of one before
/// ceiling.
pub(crate) fn percentile_rank(p: f64, n: u64) -> u64 {
    if n == 0 {
        return 0;
    }
    if p <= 0.0 {
        return 1;
    }
    if p >= 1.0 {
        return n;
    }
    let exact = p * n as f64;
    let nearest = exact.round();
    // `p` carries up to 1/2 ULP of representation error and the
    // multiply adds another 1/2 ULP; 4 ULPs of slack covers both with
    // margin while staying far below the 1-unit gap between ranks.
    let rank = if (exact - nearest).abs() <= 4.0 * f64::EPSILON * exact {
        nearest
    } else {
        exact.ceil()
    };
    (rank as u64).clamp(1, n)
}

/// Nearest-rank percentile of an ascending-sorted slice: the smallest
/// element with at least `p` of the mass at or below it.
fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[(percentile_rank(p, sorted.len() as u64) - 1) as usize]
}

/// Summarize a sample vector. Empty input summarizes to all zeros
/// (`n == 0` marks it as no-data); one sample reports itself as every
/// location statistic with zero spread.
pub fn summarize(samples: &[f64]) -> Summary {
    if samples.is_empty() {
        return Summary::default();
    }
    let mut r = Running::new();
    for &x in samples {
        r.push(x);
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let stddev = r.stddev();
    let ci95 = if r.count() < 2 {
        0.0
    } else {
        t975(r.count() - 1) * stddev / (r.count() as f64).sqrt()
    };
    Summary {
        n: r.count(),
        mean: r.mean(),
        stddev,
        ci95,
        min: r.min(),
        max: r.max(),
        p50: nearest_rank(&sorted, 0.50),
        p99: nearest_rank(&sorted, 0.99),
        p999: nearest_rank(&sorted, 0.999),
    }
}

/// Fixed-bucket log-scale histogram over `u64` values (latencies in
/// nanoseconds, counts, sizes). See the module docs for the layout and
/// error bound. `record` is branch + shift + increment — hot-path
/// safe; keep one per thread and [`LogHistogram::merge`] at the end.
#[derive(Clone)]
pub struct LogHistogram {
    counts: Box<[u64; HIST_BUCKETS]>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl std::fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LogHistogram")
            .field("count", &self.count)
            .field("mean", &self.mean())
            .field("p50", &self.percentile(0.50))
            .field("p99", &self.percentile(0.99))
            .field("max", &self.max)
            .finish()
    }
}

/// Bucket index of value `v`.
fn bucket_index(v: u64) -> usize {
    if v < HIST_SUBBUCKETS {
        v as usize
    } else {
        // v >= 16: floor log2 is >= 4; the 4 bits after the leading
        // one select the sub-bucket.
        let k = 63 - v.leading_zeros() as u64; // k >= 4
        let group = (k - 3) as usize; // 1..=60
        let sub = ((v >> (k - 4)) - HIST_SUBBUCKETS) as usize; // 0..16
        16 + (group - 1) * 16 + sub
    }
}

/// Inclusive lower bound of bucket `i` (the value every member of the
/// bucket is >= to).
fn bucket_low(i: usize) -> u64 {
    if i < 16 {
        i as u64
    } else {
        let group = (i - 16) / 16 + 1;
        let sub = ((i - 16) % 16) as u64;
        (HIST_SUBBUCKETS + sub) << (group - 1)
    }
}

impl LogHistogram {
    /// An empty histogram (~8 KB, fixed).
    pub fn new() -> Self {
        LogHistogram {
            counts: Box::new([0u64; HIST_BUCKETS]),
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Record one value.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.wrapping_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Values recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean of the recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Exact smallest recorded value (0 when empty).
    pub fn min_value(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact largest recorded value.
    pub fn max_value(&self) -> u64 {
        self.max
    }

    /// Nearest-rank percentile, reported as the lower bound of the
    /// bucket holding the rank (within 6.25% of the true value; exact
    /// below 16). `p` in `[0, 1]`.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = percentile_rank(p, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // Clamp to the exact max so p100 never over-reports.
                return bucket_low(i).min(self.max);
            }
        }
        self.max
    }

    /// Merge another histogram into this one (lossless).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum = self.sum.wrapping_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Occupied buckets as `(lower_bound, count)` pairs — the compact
    /// export the results schema stores.
    pub fn buckets(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_low(i), c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        // 2, 4, 4, 4, 5, 5, 7, 9: mean 5, population stddev 2,
        // sample variance 32/7.
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for x in xs {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn summary_closed_form() {
        // 1..=5: mean 3, sample stddev sqrt(2.5),
        // ci95 = 2.776 * sqrt(2.5/5).
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.stddev - 2.5f64.sqrt()).abs() < 1e-12);
        let want_ci = 2.776 * (2.5f64 / 5.0).sqrt();
        assert!((s.ci95 - want_ci).abs() < 1e-9, "{} vs {want_ci}", s.ci95);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p99, 5.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_edge_cases() {
        let empty = summarize(&[]);
        assert_eq!(empty.n, 0);
        assert_eq!(empty.mean, 0.0);
        assert_eq!(empty.ci95, 0.0);

        let one = summarize(&[7.5]);
        assert_eq!(one.n, 1);
        assert_eq!(one.mean, 7.5);
        assert_eq!(one.stddev, 0.0);
        assert_eq!(one.ci95, 0.0, "one sample has no spread estimate");
        assert_eq!(one.p50, 7.5);
        assert_eq!(one.p999, 7.5);

        let flat = summarize(&[4.0; 32]);
        assert_eq!(flat.n, 32);
        assert_eq!(flat.mean, 4.0);
        assert_eq!(flat.stddev, 0.0);
        assert_eq!(flat.ci95, 0.0, "a constant series is certain");
        assert_eq!(flat.p50, 4.0);
        assert_eq!(flat.p99, 4.0);
    }

    #[test]
    fn t_table_monotone_toward_normal() {
        assert!((t975(1) - 12.706).abs() < 1e-9);
        assert!((t975(30) - 2.042).abs() < 1e-9);
        assert_eq!(t975(31), 1.96);
        assert_eq!(t975(1_000_000), 1.96);
        for df in 1..30 {
            assert!(t975(df) > t975(df + 1), "t must shrink with df");
        }
    }

    #[test]
    fn hist_small_values_exact() {
        let mut h = LogHistogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 16);
        assert_eq!(h.min_value(), 0);
        assert_eq!(h.max_value(), 15);
        // Below 16 every value has its own bucket: percentiles exact.
        assert_eq!(h.percentile(0.5), 7);
        assert_eq!(h.percentile(1.0), 15);
        assert_eq!(h.mean(), 7.5);
    }

    #[test]
    fn hist_bucket_boundaries() {
        // Group boundaries: 15 | 16 | 31 | 32 must land in distinct,
        // ordered buckets; within-bucket neighbors must share.
        assert_eq!(bucket_index(15), 15);
        assert_eq!(bucket_index(16), 16);
        assert_eq!(bucket_index(31), 31);
        assert_eq!(bucket_index(32), 32);
        assert_eq!(bucket_index(33), 32, "32 and 33 share a width-2 bucket");
        assert_eq!(bucket_index(34), 33);
        // Lower bounds invert the index mapping.
        for v in [0u64, 1, 15, 16, 17, 31, 32, 100, 1000, 4096, 65535, 1 << 40, u64::MAX] {
            let i = bucket_index(v);
            assert!(bucket_low(i) <= v, "low({i}) > {v}");
            if i + 1 < HIST_BUCKETS {
                assert!(bucket_low(i + 1) > v, "next bucket must start above {v}");
            }
        }
        // Relative error bound: lower bound within 1/16 of the value.
        for v in [100u64, 999, 12_345, 7_777_777, 1 << 50] {
            let lo = bucket_low(bucket_index(v));
            assert!((v - lo) as f64 / v as f64 <= 1.0 / 16.0 + 1e-12, "{v} -> {lo}");
        }
    }

    #[test]
    fn hist_percentiles_and_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for _ in 0..99 {
            a.record(100);
        }
        b.record(10_000);
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert_eq!(a.percentile(0.50), bucket_low(bucket_index(100)));
        assert_eq!(a.percentile(0.99), bucket_low(bucket_index(100)));
        // The single outlier holds the p100 rank; it reports its
        // bucket's lower bound (within the 1/16 error bound).
        assert_eq!(a.percentile(1.0), bucket_low(bucket_index(10_000)));
        assert!(a.percentile(1.0) <= a.max_value());
        assert_eq!(a.max_value(), 10_000);
        let mean = a.mean();
        assert!((mean - (99.0 * 100.0 + 10_000.0) / 100.0).abs() < 1e-9, "{mean}");
    }

    #[test]
    fn hist_buckets_export_roundtrip() {
        let mut h = LogHistogram::new();
        for v in [3u64, 3, 17, 40_000] {
            h.record(v);
        }
        let buckets = h.buckets();
        assert_eq!(buckets.iter().map(|&(_, c)| c).sum::<u64>(), 4);
        assert_eq!(buckets[0], (3, 2));
        // Rebuild by replaying lower bounds: counts and order survive.
        let mut r = LogHistogram::new();
        for &(lo, c) in &buckets {
            for _ in 0..c {
                r.record(lo);
            }
        }
        assert_eq!(r.count(), 4);
        assert_eq!(r.buckets(), buckets);
    }

    #[test]
    fn percentile_rank_exact_boundaries() {
        // Regression: `(p * n).ceil()` inflates the rank whenever the
        // product lands one ULP above an exact integer (0.07 * 100 =
        // 7.000000000000001 -> rank 8). Every k/100 percentile of
        // 1.0..=100.0 must return exactly k.
        let data: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        for k in 1..=100u32 {
            let p = k as f64 / 100.0;
            assert_eq!(nearest_rank(&data, p), k as f64, "p={p}");
        }
    }

    #[test]
    fn percentile_rank_edges() {
        assert_eq!(percentile_rank(0.0, 5), 1);
        assert_eq!(percentile_rank(-1.0, 5), 1);
        assert_eq!(percentile_rank(1.0, 5), 5);
        assert_eq!(percentile_rank(2.0, 5), 5);
        assert_eq!(percentile_rank(0.5, 1), 1);
        assert_eq!(percentile_rank(0.5, 0), 0);
        let data = [42.0];
        assert_eq!(nearest_rank(&data, 0.0), 42.0);
        assert_eq!(nearest_rank(&data, 1.0), 42.0);
    }

    #[test]
    fn hist_percentile_boundary_agrees_with_exact() {
        // Same ULP edge inside LogHistogram::percentile: with seven 1s
        // and ninety-three 2s, p7 must be the 7th smallest sample (1),
        // not the 8th (2).
        let mut h = LogHistogram::new();
        for _ in 0..7 {
            h.record(1);
        }
        for _ in 0..93 {
            h.record(2);
        }
        assert_eq!(h.percentile(0.07), 1);
        assert_eq!(h.percentile(0.08), 2);
        // Edge convention matches nearest_rank: p<=0 -> min, p>=1 -> max.
        assert_eq!(h.percentile(0.0), 1);
        assert_eq!(h.percentile(1.0), 2);
    }
}
