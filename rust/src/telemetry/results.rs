//! The machine-readable results schema and its writer.
//!
//! Every experiment and every ablation bench emits a [`Record`]
//! (config, metrics with raw samples + derived stats, PASS/FAIL
//! verdicts, score traces, action logs) into a [`ResultsFile`]
//! stamped with the schema version and the producing commit. Files
//! are plain JSON (`BENCH_*.json`), written atomically, and parse
//! back identically — the `report` and `diff` CLI commands consume
//! nothing else.

use std::fs;
use std::path::{Path, PathBuf};

use super::json::Json;
use super::metrics::Metrics;
use super::stat::{summarize, LogHistogram, Summary};

/// Results schema version; `diff`/`report` hard-fail on mismatch.
pub const SCHEMA_VERSION: u64 = 1;

/// Directory benches drop their per-binary results files into
/// (overridable via `NVM_BENCH_JSON_DIR`).
pub const DEFAULT_BENCH_DIR: &str = "target/bench-results";

/// Which way "better" points for a metric, so `diff` can call a
/// change a regression and not just a difference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Bigger is better (throughput, hit rates).
    Higher,
    /// Smaller is better (latency, retries, fragmentation).
    Lower,
    /// Informational only; `diff` reports changes but never fails.
    Info,
}

impl Direction {
    /// Wire name.
    pub fn as_str(self) -> &'static str {
        match self {
            Direction::Higher => "higher",
            Direction::Lower => "lower",
            Direction::Info => "info",
        }
    }

    /// Parse a wire name.
    pub fn parse(s: &str) -> Result<Direction, String> {
        match s {
            "higher" => Ok(Direction::Higher),
            "lower" => Ok(Direction::Lower),
            "info" => Ok(Direction::Info),
            other => Err(format!("unknown direction {other:?}")),
        }
    }
}

/// One measured metric: raw samples plus derived statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricRecord {
    /// Dotted metric name, unique within its record.
    pub name: String,
    /// Unit label (`"us"`, `"Mop/s"`, `"blocks"`, ...).
    pub unit: String,
    /// Which way better points.
    pub direction: Direction,
    /// Raw samples (may be empty when only derived stats exist,
    /// e.g. histogram-backed percentiles or authored trajectory
    /// points that were never run).
    pub samples: Vec<f64>,
    /// Derived statistics (`n == 0` marks a metric with no data).
    pub summary: Summary,
    /// Log-histogram buckets as `(bucket_low, count)` pairs, present
    /// only for histogram-backed metrics. Summary scalars alone cannot
    /// reveal tail-shape shifts; `diff` reconstructs quantiles from
    /// these. Optional on the wire (absent parses as empty) so files
    /// written before this field still load.
    pub buckets: Vec<(u64, u64)>,
}

impl MetricRecord {
    /// Build from raw samples; the summary is derived.
    pub fn from_samples(name: &str, unit: &str, direction: Direction, samples: Vec<f64>) -> Self {
        let summary = summarize(&samples);
        MetricRecord {
            name: name.to_string(),
            unit: unit.to_string(),
            direction,
            samples,
            summary,
            buckets: Vec::new(),
        }
    }

    /// Build from one observed value (tables hold single cells).
    pub fn from_value(name: &str, unit: &str, direction: Direction, value: f64) -> Self {
        MetricRecord::from_samples(name, unit, direction, vec![value])
    }

    /// Build from a hot-path histogram: percentiles without raw
    /// samples (scaled by `scale`, e.g. `1e-3` for ns → µs).
    pub fn from_hist(
        name: &str,
        unit: &str,
        direction: Direction,
        h: &LogHistogram,
        scale: f64,
    ) -> Self {
        let summary = Summary {
            n: h.count(),
            mean: h.mean() * scale,
            stddev: 0.0,
            ci95: 0.0,
            min: h.min_value() as f64 * scale,
            max: h.max_value() as f64 * scale,
            p50: h.percentile(0.50) as f64 * scale,
            p99: h.percentile(0.99) as f64 * scale,
            p999: h.percentile(0.999) as f64 * scale,
        };
        MetricRecord {
            name: name.to_string(),
            unit: unit.to_string(),
            direction,
            samples: Vec::new(),
            summary,
            buckets: h.buckets(),
        }
    }

    /// True when the metric carries no data at all.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty() && self.summary.n == 0
    }
}

/// One named PASS/FAIL verdict with its threshold reasoning.
#[derive(Clone, Debug, PartialEq)]
pub struct Verdict {
    /// Verdict name (stable across commits so `diff` can match it).
    pub name: String,
    /// Did it pass?
    pub pass: bool,
    /// Human-readable threshold reasoning
    /// (`"tlb hit rate 0.97 >= 0.90"`).
    pub detail: String,
}

/// A named time-series (mmd score trace, occupancy trajectory).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// Series name (`"mmd.score"`).
    pub name: String,
    /// Tick numbers.
    pub ticks: Vec<u64>,
    /// One value per tick.
    pub values: Vec<f64>,
}

/// One record: a single experiment or bench run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Record {
    /// Experiment/bench name (`"multi-tenant"`, `"ablation_translation"`).
    pub name: String,
    /// `"experiment"` or `"bench"`.
    pub kind: String,
    /// Flat config the run was produced under.
    pub config: Vec<(String, String)>,
    /// Measured metrics.
    pub metrics: Vec<MetricRecord>,
    /// Flat subsystem counter snapshot (the unified registry).
    pub counters: Metrics,
    /// PASS/FAIL verdicts.
    pub verdicts: Vec<Verdict>,
    /// Structured time-series.
    pub traces: Vec<Trace>,
    /// Daemon per-tick action log as `(tick, action)` rows.
    pub actions: Vec<(u64, String)>,
    /// Free-text notes (kept for context, never diffed).
    pub notes: Vec<String>,
}

impl Record {
    /// A new empty record.
    pub fn new(name: &str, kind: &str) -> Record {
        Record {
            name: name.to_string(),
            kind: kind.to_string(),
            ..Record::default()
        }
    }

    /// Append a config pair.
    pub fn config(&mut self, key: &str, value: impl ToString) -> &mut Record {
        self.config.push((key.to_string(), value.to_string()));
        self
    }

    /// Append a metric.
    pub fn metric(&mut self, m: MetricRecord) -> &mut Record {
        self.metrics.push(m);
        self
    }

    /// Append a verdict.
    pub fn verdict(&mut self, name: &str, pass: bool, detail: &str) -> &mut Record {
        self.verdicts.push(Verdict {
            name: name.to_string(),
            pass,
            detail: detail.to_string(),
        });
        self
    }

    /// True when every verdict passed (vacuously true with none).
    pub fn all_pass(&self) -> bool {
        self.verdicts.iter().all(|v| v.pass)
    }
}

/// A results file: schema + commit + a set of records.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResultsFile {
    /// Schema version ([`SCHEMA_VERSION`] when produced here).
    pub schema_version: u64,
    /// Commit hash of the producing tree (`"unknown"` outside git).
    pub commit: String,
    /// Trajectory label (`"BENCH_9"`, `"BENCH_ci"`).
    pub label: String,
    /// The records.
    pub records: Vec<Record>,
}

impl ResultsFile {
    /// Look a record up by name.
    pub fn record(&self, name: &str) -> Option<&Record> {
        self.records.iter().find(|r| r.name == name)
    }

    /// Serialize to the on-disk JSON shape.
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("schema_version", Json::Num(self.schema_version as f64));
        root.set("commit", Json::Str(self.commit.clone()));
        root.set("label", Json::Str(self.label.clone()));
        let mut records = Json::arr();
        for r in &self.records {
            records.push(record_to_json(r));
        }
        root.set("records", records);
        root
    }

    /// Parse + validate the on-disk JSON shape. Any shape violation
    /// is an error — schema problems must hard-fail, not degrade.
    pub fn from_json(json: &Json) -> Result<ResultsFile, String> {
        let version = json
            .get("schema_version")
            .and_then(Json::as_u64)
            .ok_or("missing schema_version")?;
        if version != SCHEMA_VERSION {
            return Err(format!(
                "schema_version {version} unsupported (expected {SCHEMA_VERSION})"
            ));
        }
        let commit = json
            .get("commit")
            .and_then(Json::as_str)
            .ok_or("missing commit")?
            .to_string();
        let label = json
            .get("label")
            .and_then(Json::as_str)
            .ok_or("missing label")?
            .to_string();
        let mut records = Vec::new();
        for (i, r) in json
            .get("records")
            .and_then(Json::as_arr)
            .ok_or("missing records array")?
            .iter()
            .enumerate()
        {
            records.push(record_from_json(r).map_err(|e| format!("records[{i}]: {e}"))?);
        }
        Ok(ResultsFile {
            schema_version: version,
            commit,
            label,
            records,
        })
    }

    /// Write atomically (tmp + rename) as pretty JSON.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
            }
        }
        let tmp = path.with_extension("json.tmp");
        fs::write(&tmp, self.to_json().render())
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        fs::rename(&tmp, path).map_err(|e| format!("rename to {}: {e}", path.display()))?;
        Ok(())
    }

    /// Load + parse a results file.
    pub fn load(path: &Path) -> Result<ResultsFile, String> {
        let text =
            fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        ResultsFile::from_json(&json).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Merge several results files into one labeled file (used by CI
    /// to fold per-bench drops into a single `BENCH_ci.json`).
    /// Record names must not collide.
    pub fn merge(label: &str, parts: &[ResultsFile]) -> Result<ResultsFile, String> {
        let mut out = ResultsFile {
            schema_version: SCHEMA_VERSION,
            commit: parts
                .first()
                .map(|p| p.commit.clone())
                .unwrap_or_else(|| commit_hash()),
            label: label.to_string(),
            records: Vec::new(),
        };
        for part in parts {
            for r in &part.records {
                if out.record(&r.name).is_some() {
                    return Err(format!("duplicate record {:?} while merging", r.name));
                }
                out.records.push(r.clone());
            }
        }
        Ok(out)
    }
}

/// Incrementally build + save a results file — the one write path
/// every experiment and bench shares.
#[derive(Clone, Debug)]
pub struct ResultsWriter {
    file: ResultsFile,
}

impl ResultsWriter {
    /// Start a results file with the current commit stamped in.
    pub fn new(label: &str) -> ResultsWriter {
        ResultsWriter {
            file: ResultsFile {
                schema_version: SCHEMA_VERSION,
                commit: commit_hash(),
                label: label.to_string(),
                records: Vec::new(),
            },
        }
    }

    /// Append a finished record.
    pub fn add(&mut self, record: Record) -> &mut ResultsWriter {
        self.file.records.push(record);
        self
    }

    /// The file built so far.
    pub fn file(&self) -> &ResultsFile {
        &self.file
    }

    /// Save to `path` and return the finished file.
    pub fn save(self, path: &Path) -> Result<ResultsFile, String> {
        self.file.save(path)?;
        Ok(self.file)
    }
}

/// Where bench binaries drop their results files.
pub fn bench_results_dir() -> PathBuf {
    std::env::var("NVM_BENCH_JSON_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(DEFAULT_BENCH_DIR))
}

/// Write one bench's record as `<dir>/<name>.json` — called by every
/// `ablation_*`/`fig*` binary after printing its human tables.
/// Failures are reported to stderr, never panicked: a bench must not
/// fail because the results dir is unwritable.
pub fn write_bench_record(record: Record) {
    let name = record.name.clone();
    let path = bench_results_dir().join(format!("{name}.json"));
    let mut w = ResultsWriter::new(&name);
    w.add(record);
    match w.save(&path) {
        Ok(_) => eprintln!("results: wrote {}", path.display()),
        Err(e) => eprintln!("results: {e}"),
    }
}

/// The producing commit: `NVM_COMMIT` env override, else `.git/HEAD`
/// (one level of ref indirection), else `"unknown"`.
pub fn commit_hash() -> String {
    if let Ok(c) = std::env::var("NVM_COMMIT") {
        if !c.is_empty() {
            return c;
        }
    }
    let head = match fs::read_to_string(".git/HEAD") {
        Ok(h) => h.trim().to_string(),
        Err(_) => return "unknown".to_string(),
    };
    if let Some(refname) = head.strip_prefix("ref: ") {
        if let Ok(hash) = fs::read_to_string(Path::new(".git").join(refname.trim())) {
            return hash.trim().to_string();
        }
        // Packed refs fall back to the ref name itself.
        return refname.trim().to_string();
    }
    head
}

/// Lower-case a label into a dotted-name-safe slug
/// (`"Mop/s (total)"` → `"mop_s_total"`).
pub fn slug(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut last_sep = true;
    for c in s.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c.to_ascii_lowercase());
            last_sep = false;
        } else if !last_sep {
            out.push('_');
            last_sep = true;
        }
    }
    while out.ends_with('_') {
        out.pop();
    }
    out
}

fn summary_to_json(s: &Summary) -> Json {
    let mut o = Json::obj();
    o.set("n", Json::Num(s.n as f64));
    o.set("mean", Json::Num(s.mean));
    o.set("stddev", Json::Num(s.stddev));
    o.set("ci95", Json::Num(s.ci95));
    o.set("min", Json::Num(s.min));
    o.set("max", Json::Num(s.max));
    o.set("p50", Json::Num(s.p50));
    o.set("p99", Json::Num(s.p99));
    o.set("p999", Json::Num(s.p999));
    o
}

fn summary_from_json(json: &Json) -> Result<Summary, String> {
    let field = |name: &str| {
        json.get(name)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("summary missing {name}"))
    };
    Ok(Summary {
        n: json
            .get("n")
            .and_then(Json::as_u64)
            .ok_or("summary missing n")?,
        mean: field("mean")?,
        stddev: field("stddev")?,
        ci95: field("ci95")?,
        min: field("min")?,
        max: field("max")?,
        p50: field("p50")?,
        p99: field("p99")?,
        p999: field("p999")?,
    })
}

fn record_to_json(r: &Record) -> Json {
    let mut o = Json::obj();
    o.set("name", Json::Str(r.name.clone()));
    o.set("kind", Json::Str(r.kind.clone()));
    let mut cfg = Json::obj();
    for (k, v) in &r.config {
        cfg.set(k, Json::Str(v.clone()));
    }
    o.set("config", cfg);
    let mut metrics = Json::arr();
    for m in &r.metrics {
        let mut mo = Json::obj();
        mo.set("name", Json::Str(m.name.clone()));
        mo.set("unit", Json::Str(m.unit.clone()));
        mo.set("direction", Json::Str(m.direction.as_str().to_string()));
        mo.set(
            "samples",
            Json::Arr(m.samples.iter().map(|v| Json::Num(*v)).collect()),
        );
        mo.set("summary", summary_to_json(&m.summary));
        if !m.buckets.is_empty() {
            // Histogram shape rides along as [bucket_low, count] pairs;
            // omitted entirely for sample-backed metrics so pre-existing
            // files and records stay byte-identical.
            mo.set(
                "buckets",
                Json::Arr(
                    m.buckets
                        .iter()
                        .map(|&(lo, c)| {
                            Json::Arr(vec![Json::Num(lo as f64), Json::Num(c as f64)])
                        })
                        .collect(),
                ),
            );
        }
        metrics.push(mo);
    }
    o.set("metrics", metrics);
    o.set("counters", r.counters.to_json());
    let mut verdicts = Json::arr();
    for v in &r.verdicts {
        let mut vo = Json::obj();
        vo.set("name", Json::Str(v.name.clone()));
        vo.set("pass", Json::Bool(v.pass));
        vo.set("detail", Json::Str(v.detail.clone()));
        verdicts.push(vo);
    }
    o.set("verdicts", verdicts);
    let mut traces = Json::arr();
    for t in &r.traces {
        let mut to = Json::obj();
        to.set("name", Json::Str(t.name.clone()));
        to.set(
            "ticks",
            Json::Arr(t.ticks.iter().map(|v| Json::Num(*v as f64)).collect()),
        );
        to.set(
            "values",
            Json::Arr(t.values.iter().map(|v| Json::Num(*v)).collect()),
        );
        traces.push(to);
    }
    o.set("traces", traces);
    let mut actions = Json::arr();
    for (tick, action) in &r.actions {
        let mut ao = Json::obj();
        ao.set("tick", Json::Num(*tick as f64));
        ao.set("action", Json::Str(action.clone()));
        actions.push(ao);
    }
    o.set("actions", actions);
    o.set(
        "notes",
        Json::Arr(r.notes.iter().map(|n| Json::Str(n.clone())).collect()),
    );
    o
}

fn record_from_json(json: &Json) -> Result<Record, String> {
    let str_field = |name: &str| {
        json.get(name)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("missing {name}"))
    };
    let mut r = Record::new(&str_field("name")?, &str_field("kind")?);
    match json.get("config") {
        Some(Json::Obj(fields)) => {
            for (k, v) in fields {
                let v = v.as_str().ok_or_else(|| format!("config.{k} not a string"))?;
                r.config.push((k.clone(), v.to_string()));
            }
        }
        _ => return Err("missing config object".into()),
    }
    for (i, m) in json
        .get("metrics")
        .and_then(Json::as_arr)
        .ok_or("missing metrics array")?
        .iter()
        .enumerate()
    {
        let ctx = |e: String| format!("metrics[{i}]: {e}");
        let name = m
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| ctx("missing name".into()))?;
        let unit = m
            .get("unit")
            .and_then(Json::as_str)
            .ok_or_else(|| ctx("missing unit".into()))?;
        let direction = Direction::parse(
            m.get("direction")
                .and_then(Json::as_str)
                .ok_or_else(|| ctx("missing direction".into()))?,
        )
        .map_err(ctx)?;
        let mut samples = Vec::new();
        for s in m
            .get("samples")
            .and_then(Json::as_arr)
            .ok_or_else(|| ctx("missing samples".into()))?
        {
            samples.push(s.as_f64().ok_or_else(|| ctx("non-numeric sample".into()))?);
        }
        let summary = summary_from_json(
            m.get("summary").ok_or_else(|| ctx("missing summary".into()))?,
        )
        .map_err(ctx)?;
        // Optional: files written before buckets existed simply lack
        // the field, which parses as an empty bucket list.
        let mut buckets = Vec::new();
        if let Some(b) = m.get("buckets") {
            for pair in b.as_arr().ok_or_else(|| ctx("buckets not an array".into()))? {
                let pair = pair
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| ctx("bucket not a [low, count] pair".into()))?;
                let lo = pair[0]
                    .as_u64()
                    .ok_or_else(|| ctx("bucket low not a u64".into()))?;
                let c = pair[1]
                    .as_u64()
                    .ok_or_else(|| ctx("bucket count not a u64".into()))?;
                buckets.push((lo, c));
            }
        }
        r.metrics.push(MetricRecord {
            name: name.to_string(),
            unit: unit.to_string(),
            direction,
            samples,
            summary,
            buckets,
        });
    }
    r.counters = Metrics::from_json(json.get("counters").ok_or("missing counters")?)?;
    for (i, v) in json
        .get("verdicts")
        .and_then(Json::as_arr)
        .ok_or("missing verdicts array")?
        .iter()
        .enumerate()
    {
        r.verdicts.push(Verdict {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("verdicts[{i}]: missing name"))?
                .to_string(),
            pass: v
                .get("pass")
                .and_then(Json::as_bool)
                .ok_or_else(|| format!("verdicts[{i}]: missing pass"))?,
            detail: v
                .get("detail")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("verdicts[{i}]: missing detail"))?
                .to_string(),
        });
    }
    for (i, t) in json
        .get("traces")
        .and_then(Json::as_arr)
        .ok_or("missing traces array")?
        .iter()
        .enumerate()
    {
        let ctx = format!("traces[{i}]");
        let mut trace = Trace {
            name: t
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{ctx}: missing name"))?
                .to_string(),
            ..Trace::default()
        };
        for v in t
            .get("ticks")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("{ctx}: missing ticks"))?
        {
            trace
                .ticks
                .push(v.as_u64().ok_or_else(|| format!("{ctx}: bad tick"))?);
        }
        for v in t
            .get("values")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("{ctx}: missing values"))?
        {
            trace
                .values
                .push(v.as_f64().ok_or_else(|| format!("{ctx}: bad value"))?);
        }
        if trace.ticks.len() != trace.values.len() {
            return Err(format!("{ctx}: ticks/values length mismatch"));
        }
        r.traces.push(trace);
    }
    for (i, a) in json
        .get("actions")
        .and_then(Json::as_arr)
        .ok_or("missing actions array")?
        .iter()
        .enumerate()
    {
        let tick = a
            .get("tick")
            .and_then(Json::as_u64)
            .ok_or_else(|| format!("actions[{i}]: missing tick"))?;
        let action = a
            .get("action")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("actions[{i}]: missing action"))?;
        r.actions.push((tick, action.to_string()));
    }
    for (i, n) in json
        .get("notes")
        .and_then(Json::as_arr)
        .ok_or("missing notes array")?
        .iter()
        .enumerate()
    {
        r.notes.push(
            n.as_str()
                .ok_or_else(|| format!("notes[{i}]: not a string"))?
                .to_string(),
        );
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> ResultsFile {
        let mut r = Record::new("multi-tenant", "experiment");
        r.config("sample", 200_000u64).config("threads", 4u64);
        r.metric(MetricRecord::from_samples(
            "zipfian.mop_s",
            "Mop/s",
            Direction::Higher,
            vec![10.5, 11.0, 10.75],
        ));
        r.metric(MetricRecord::from_value(
            "scan.evictions",
            "count",
            Direction::Info,
            42.0,
        ));
        let mut h = LogHistogram::new();
        for v in [120u64, 130, 140, 9_000] {
            h.record(v);
        }
        r.metric(MetricRecord::from_hist(
            "fault.latency",
            "us",
            Direction::Lower,
            &h,
            1e-3,
        ));
        r.counters.set("tlb.hit_rate", 0.97);
        r.counters.set("epoch.saved_pins", 1234.0);
        r.verdict("isolation_holds", true, "zipfian mrd < 1.10x baseline");
        r.verdict("flaky_contained", false, "errors leaked to benign tenant");
        r.traces.push(Trace {
            name: "mmd.score".into(),
            ticks: vec![0, 8, 16],
            values: vec![0.1, 0.35, 0.2],
        });
        r.actions.push((8, "compact_shard".into()));
        r.actions.push((16, "evict".into()));
        r.notes.push("quick mode".into());
        ResultsFile {
            schema_version: SCHEMA_VERSION,
            commit: "deadbeef".into(),
            label: "BENCH_test".into(),
            records: vec![r],
        }
    }

    #[test]
    fn roundtrip_identical() {
        let f = fixture();
        let back = ResultsFile::from_json(&Json::parse(&f.to_json().render()).unwrap()).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn save_load_roundtrip() {
        let f = fixture();
        let dir = std::env::temp_dir().join("nvm_results_test");
        let path = dir.join("BENCH_test.json");
        f.save(&path).unwrap();
        let back = ResultsFile::load(&path).unwrap();
        assert_eq!(back, f);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn schema_violations_hard_fail() {
        let f = fixture();
        let mut wrong_version = f.to_json();
        if let Json::Obj(fields) = &mut wrong_version {
            fields[0].1 = Json::Num(99.0);
        }
        assert!(ResultsFile::from_json(&wrong_version)
            .unwrap_err()
            .contains("schema_version"));
        let missing = Json::parse(r#"{"schema_version": 1, "commit": "x"}"#).unwrap();
        assert!(ResultsFile::from_json(&missing).is_err());
    }

    #[test]
    fn merge_rejects_duplicates() {
        let f = fixture();
        let merged = ResultsFile::merge("BENCH_ci", &[f.clone()]).unwrap();
        assert_eq!(merged.label, "BENCH_ci");
        assert_eq!(merged.records.len(), 1);
        assert!(ResultsFile::merge("x", &[f.clone(), f]).is_err());
    }

    #[test]
    fn slug_flattens_labels() {
        assert_eq!(slug("Mop/s (total)"), "mop_s_total");
        assert_eq!(slug("  paged+flaky  "), "paged_flaky");
        assert_eq!(slug("p99 µs"), "p99_s");
        assert_eq!(slug("resident"), "resident");
    }

    #[test]
    fn verdict_helpers() {
        let f = fixture();
        assert!(!f.records[0].all_pass());
        assert!(Record::new("x", "bench").all_pass());
    }

    #[test]
    fn buckets_survive_roundtrip() {
        let f = fixture();
        let hist_metric = &f.records[0].metrics[2];
        assert!(!hist_metric.buckets.is_empty(), "fixture must carry buckets");
        let back = ResultsFile::from_json(&Json::parse(&f.to_json().render()).unwrap()).unwrap();
        assert_eq!(back.records[0].metrics[2].buckets, hist_metric.buckets);
        // Sample-backed metrics never grow a buckets field on the wire.
        let text = f.to_json().render();
        assert_eq!(text.matches("\"buckets\"").count(), 1);
    }

    #[test]
    fn metrics_without_buckets_still_parse() {
        // Files written before the buckets field existed must load
        // unchanged (the committed BENCH_*.json trajectory).
        let mut f = fixture();
        f.records[0].metrics.remove(2);
        let json = f.to_json().render();
        assert!(!json.contains("\"buckets\""));
        let back = ResultsFile::from_json(&Json::parse(&json).unwrap()).unwrap();
        assert_eq!(back, f);
        assert!(back.records[0].metrics.iter().all(|m| m.buckets.is_empty()));
    }

    #[test]
    fn direction_wire_names() {
        for d in [Direction::Higher, Direction::Lower, Direction::Info] {
            assert_eq!(Direction::parse(d.as_str()).unwrap(), d);
        }
        assert!(Direction::parse("sideways").is_err());
    }
}
