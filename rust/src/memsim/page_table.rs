//! x86-64 four-level page-table walk model.
//!
//! The walker does not materialize page tables for 64 GB address spaces;
//! it *synthesizes* the physical address of each PTE deterministically,
//! which is all the cache hierarchy needs. The synthesis preserves the
//! real structure's locality: consecutive virtual pages have consecutive
//! PTE addresses, so 8 PTEs share a 64-byte line — the reason sequential
//! scans walk almost for free (paper §4.2's "translation hardware is
//! optimized to make this case fast").

use crate::memsim::PageSize;

/// Physical address region where simulated page tables live (above any
/// simulated data; data address spaces in the experiments are < 2^40).
pub const PT_REGION_BASE: u64 = 1 << 44;

/// Per-level spacing between the synthesized tables of that level.
const LEVEL_STRIDE: u64 = 1 << 40;

/// Stateless PTE-address synthesizer for a 4-level x86-64 table.
pub struct PageTable;

impl PageTable {
    /// Physical address of the level-`level` PTE consulted when walking
    /// `vaddr` with leaf size `page`.
    ///
    /// `level` counts walked levels starting at 0 = PML4. For 4 KB pages
    /// levels are PML4, PDPT, PD, PT; a 1 GB walk stops after PDPT.
    #[inline]
    pub fn pte_addr(level: u32, vaddr: u64, page: PageSize) -> u64 {
        debug_assert!(level < page.walk_levels());
        // Index of this PTE within a flattened per-level table: the
        // virtual address truncated to the level's coverage, divided by
        // the coverage of one entry at that level.
        let entry_shift = Self::entry_shift(level, page);
        let index = vaddr >> entry_shift;
        PT_REGION_BASE + level as u64 * LEVEL_STRIDE + index * 8
    }

    /// log2(bytes covered by one entry) at walk `level`.
    #[inline]
    fn entry_shift(level: u32, page: PageSize) -> u32 {
        // Leaf entries cover the page size; each level up covers 512x.
        page.shift() + 9 * (page.walk_levels() - 1 - level)
    }

    /// Number of levels a walk of `page` visits when `skip` levels are
    /// satisfied by the PTW cache.
    #[inline]
    pub fn levels_to_walk(page: PageSize, skip: u32) -> u32 {
        page.walk_levels().saturating_sub(skip)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adjacent_pages_share_pte_lines() {
        // Leaf level of a 4 KB walk: PTEs of consecutive pages are 8 B
        // apart -> 8 per 64 B line.
        let a = PageTable::pte_addr(3, 0x0000, PageSize::P4K);
        let b = PageTable::pte_addr(3, 0x1000, PageSize::P4K);
        assert_eq!(b - a, 8);
    }

    #[test]
    fn upper_levels_change_slowly() {
        // PD entries (level 2 of 4 KB walk) cover 2 MB.
        let a = PageTable::pte_addr(2, 0, PageSize::P4K);
        let b = PageTable::pte_addr(2, (2 << 20) - 1, PageSize::P4K);
        let c = PageTable::pte_addr(2, 2 << 20, PageSize::P4K);
        assert_eq!(a, b);
        assert_eq!(c - a, 8);
    }

    #[test]
    fn levels_dont_collide() {
        let l0 = PageTable::pte_addr(0, 0xABCD_E000, PageSize::P4K);
        let l3 = PageTable::pte_addr(3, 0xABCD_E000, PageSize::P4K);
        assert_ne!(l0, l3);
        assert!(l0 >= PT_REGION_BASE && l3 >= PT_REGION_BASE);
    }

    #[test]
    fn gigabyte_leaf_is_pdpte() {
        // 1 GB walk: leaf level (1) entries cover 1 GB.
        let a = PageTable::pte_addr(1, 0, PageSize::P1G);
        let b = PageTable::pte_addr(1, (1 << 30) - 1, PageSize::P1G);
        let c = PageTable::pte_addr(1, 1 << 30, PageSize::P1G);
        assert_eq!(a, b);
        assert_eq!(c - a, 8);
    }

    #[test]
    fn walk_level_count() {
        assert_eq!(PageTable::levels_to_walk(PageSize::P4K, 0), 4);
        assert_eq!(PageTable::levels_to_walk(PageSize::P4K, 3), 1);
        assert_eq!(PageTable::levels_to_walk(PageSize::P1G, 1), 1);
    }
}
