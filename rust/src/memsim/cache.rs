//! Set-associative cache model (tags + LRU only; no data).

/// Geometry and latency of one cache level.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Capacity in bytes.
    pub size: usize,
    /// Associativity.
    pub ways: usize,
    /// Line size in bytes.
    pub line: usize,
    /// Hit latency in cycles.
    pub latency: u64,
}

/// A set-associative, write-allocate, LRU cache (tag store only).
pub struct Cache {
    cfg: CacheConfig,
    line_shift: u32,
    set_mask: u64,
    /// tags[set * ways + way]; u64::MAX = invalid.
    tags: Vec<u64>,
    /// LRU stamps parallel to `tags`.
    stamps: Vec<u32>,
    clock: u32,
    hits: u64,
    misses: u64,
}

impl Cache {
    /// Build a cache from its configuration.
    pub fn new(cfg: CacheConfig) -> Self {
        assert!(cfg.line.is_power_of_two() && cfg.size % (cfg.ways * cfg.line) == 0);
        let sets = cfg.size / (cfg.ways * cfg.line);
        assert!(sets.is_power_of_two());
        Cache {
            cfg,
            line_shift: cfg.line.trailing_zeros(),
            set_mask: (sets - 1) as u64,
            tags: vec![u64::MAX; sets * cfg.ways],
            stamps: vec![0; sets * cfg.ways],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Hit latency.
    #[inline]
    pub fn latency(&self) -> u64 {
        self.cfg.latency
    }

    /// Line size in bytes.
    #[inline]
    pub fn line(&self) -> usize {
        self.cfg.line
    }

    /// Look up (and on miss, allocate) the line containing `addr`.
    /// Returns true on hit.
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        let line = addr >> self.line_shift;
        let set = (line & self.set_mask) as usize;
        let base = set * self.cfg.ways;
        self.clock = self.clock.wrapping_add(1);
        let ways = &mut self.tags[base..base + self.cfg.ways];
        for (w, tag) in ways.iter().enumerate() {
            if *tag == line {
                self.stamps[base + w] = self.clock;
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        self.insert_line(line, set);
        false
    }

    /// Insert without counting a demand access (prefetch fills).
    #[inline]
    pub fn fill(&mut self, addr: u64) {
        let line = addr >> self.line_shift;
        let set = (line & self.set_mask) as usize;
        let base = set * self.cfg.ways;
        // Already present? refresh nothing (prefetch hit is free).
        for w in 0..self.cfg.ways {
            if self.tags[base + w] == line {
                return;
            }
        }
        self.clock = self.clock.wrapping_add(1);
        self.insert_line(line, set);
    }

    /// Probe without modifying state. True if resident.
    pub fn probe(&self, addr: u64) -> bool {
        let line = addr >> self.line_shift;
        let set = (line & self.set_mask) as usize;
        let base = set * self.cfg.ways;
        self.tags[base..base + self.cfg.ways].contains(&line)
    }

    #[inline]
    fn insert_line(&mut self, line: u64, set: usize) {
        let base = set * self.cfg.ways;
        // LRU victim = smallest stamp (or an invalid way).
        let mut victim = 0usize;
        let mut best = u32::MAX;
        for w in 0..self.cfg.ways {
            if self.tags[base + w] == u64::MAX {
                victim = w;
                break;
            }
            if self.stamps[base + w] < best {
                best = self.stamps[base + w];
                victim = w;
            }
        }
        self.tags[base + victim] = line;
        self.stamps[base + victim] = self.clock;
    }

    /// (hits, misses) since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Forget all contents and zero the counters.
    pub fn reset(&mut self) {
        self.tags.fill(u64::MAX);
        self.stamps.fill(0);
        self.clock = 0;
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cache {
        // 4 sets x 2 ways x 64 B lines = 512 B.
        Cache::new(CacheConfig {
            size: 512,
            ways: 2,
            line: 64,
            latency: 4,
        })
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = tiny();
        assert!(!c.access(0x1000));
        assert!(c.access(0x1000));
        assert!(c.access(0x1004)); // same line
        assert_eq!(c.stats(), (2, 1));
    }

    #[test]
    fn conflict_eviction_lru() {
        let mut c = tiny();
        // Three lines mapping to set 0 (line addr multiples of 4*64=256).
        c.access(0); // A
        c.access(256); // B
        c.access(0); // A again (B becomes LRU)
        c.access(512); // C evicts B
        assert!(c.access(0)); // A still resident
        assert!(!c.access(256)); // B was evicted
    }

    #[test]
    fn fill_does_not_count_demand() {
        let mut c = tiny();
        c.fill(0x2000);
        assert_eq!(c.stats(), (0, 0));
        assert!(c.access(0x2000)); // prefetched line hits
    }

    #[test]
    fn probe_is_pure() {
        let mut c = tiny();
        assert!(!c.probe(0x40));
        c.access(0x40);
        assert!(c.probe(0x40));
        assert_eq!(c.stats(), (0, 1));
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = tiny();
        // 16 distinct lines round-robin >> 8-line capacity: all misses
        // after warmup.
        for round in 0..4 {
            for i in 0..16u64 {
                let hit = c.access(i * 64);
                if round > 0 {
                    assert!(!hit, "line {i} round {round} unexpectedly hit");
                }
            }
        }
    }

    #[test]
    fn working_set_fitting_always_hits_after_warmup() {
        let mut c = tiny();
        for _ in 0..3 {
            for i in 0..8u64 {
                c.access(i * 64);
            }
        }
        let (h, m) = c.stats();
        assert_eq!(m, 8); // only compulsory misses
        assert_eq!(h, 16);
    }
}
