//! Cycle-approximate memory-hierarchy simulator: the virtual-memory vs.
//! physical-addressing cost model.
//!
//! The paper simulates physical addressing by running tree-based programs
//! on 1 GB huge pages (≈ zero TLB misses) and compares against contiguous
//! arrays on 4 KB pages. That trick needs the authors' 128 GB testbed and
//! perf counters; here the same comparison is produced by an explicit
//! model (DESIGN.md substitution table):
//!
//! * [`Tlb`] — set-associative TLBs per page size + a shared STLB.
//! * [`PageTable`] — x86-64 4-level walker whose PTE loads go through the
//!   data-cache hierarchy (that locality is why linear scans "suffered
//!   almost no TLB [cost]" in the paper — 8 PTEs share a cache line).
//! * [`PtwCache`] — page-walk caches (PML4/PDPT/PD), skipping upper walk
//!   levels.
//! * [`Cache`] — L1/L2/L3 set-associative write-allocate caches.
//! * [`Prefetcher`] — a next-line stream prefetcher (the paper's "hardware
//!   optimizations ... such as prefetchers").
//! * [`Hierarchy`] — composes the above; `access(addr)` returns the
//!   serialized cycle cost of one memory access in either
//!   [`AddressMode::Physical`] or [`AddressMode::Virtual`].
//!
//! Latencies are calibrated to the paper's testbed (i7-7700 Kaby Lake,
//! DESIGN.md §5); the quantities that matter are *ratios*, which are
//! robust to absolute-latency error.

mod cache;
mod config;
mod hierarchy;
mod page_table;
mod prefetch;
mod ptw_cache;
mod stats;
mod tlb;

pub use cache::{Cache, CacheConfig};
pub use config::{HierarchyConfig, PageSize};
pub use hierarchy::{AddressMode, Hierarchy};
pub use page_table::PageTable;
pub use prefetch::Prefetcher;
pub use ptw_cache::PtwCache;
pub use stats::{EnergyModel, SimStats};
pub use tlb::{Tlb, TlbConfig};
