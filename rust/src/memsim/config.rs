//! Hierarchy configuration, calibrated to the paper's i7-7700 testbed.

use crate::memsim::{CacheConfig, TlbConfig};

/// Hardware page sizes (x86-64; the paper's §2 flexibility discussion).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PageSize {
    /// 4 KB base pages.
    P4K,
    /// 2 MB huge pages.
    P2M,
    /// 1 GB huge pages (the paper's physical-addressing simulation).
    P1G,
}

impl PageSize {
    /// Page size in bytes.
    #[inline]
    pub fn bytes(self) -> u64 {
        match self {
            PageSize::P4K => 4 << 10,
            PageSize::P2M => 2 << 20,
            PageSize::P1G => 1 << 30,
        }
    }

    /// log2(bytes).
    #[inline]
    pub fn shift(self) -> u32 {
        match self {
            PageSize::P4K => 12,
            PageSize::P2M => 21,
            PageSize::P1G => 30,
        }
    }

    /// Page-table levels that must be walked on a TLB miss (x86-64:
    /// 4 KB → 4, 2 MB → 3, 1 GB → 2).
    #[inline]
    pub fn walk_levels(self) -> u32 {
        match self {
            PageSize::P4K => 4,
            PageSize::P2M => 3,
            PageSize::P1G => 2,
        }
    }
}

/// Full hierarchy configuration.
#[derive(Clone, Debug)]
pub struct HierarchyConfig {
    /// L1 data cache.
    pub l1: CacheConfig,
    /// L2 unified cache.
    pub l2: CacheConfig,
    /// L3 shared cache.
    pub l3: CacheConfig,
    /// DRAM access latency (cycles), paid on L3 miss.
    pub dram_latency: u64,
    /// First-level DTLB per page size.
    pub dtlb_4k: TlbConfig,
    /// DTLB for 2 MB pages.
    pub dtlb_2m: TlbConfig,
    /// DTLB for 1 GB pages.
    pub dtlb_1g: TlbConfig,
    /// Unified second-level TLB.
    pub stlb: TlbConfig,
    /// STLB hit penalty (cycles) added on a DTLB miss that hits STLB.
    pub stlb_latency: u64,
    /// Page-walk-cache entries per cached level.
    pub pwc_entries: usize,
    /// Stream prefetch degree (lines brought ahead); 0 disables.
    pub prefetch_degree: u32,
    /// Whether the STLB holds 1 GB entries (Kaby Lake's does not; this
    /// matters for the paper's §4.3 huge-page artifact).
    pub stlb_holds_1g: bool,
}

impl HierarchyConfig {
    /// The paper's testbed: Intel i7-7700 (Kaby Lake), 3.6 GHz.
    pub fn kaby_lake() -> Self {
        HierarchyConfig {
            l1: CacheConfig {
                size: 32 << 10,
                ways: 8,
                line: 64,
                latency: 4,
            },
            l2: CacheConfig {
                size: 256 << 10,
                ways: 4,
                line: 64,
                latency: 12,
            },
            l3: CacheConfig {
                size: 8 << 20,
                ways: 16,
                line: 64,
                latency: 42,
            },
            dram_latency: 250,
            dtlb_4k: TlbConfig { entries: 64, ways: 4 },
            dtlb_2m: TlbConfig { entries: 32, ways: 4 },
            dtlb_1g: TlbConfig { entries: 4, ways: 4 },
            stlb: TlbConfig {
                entries: 1536,
                ways: 12,
            },
            stlb_latency: 9,
            pwc_entries: 32,
            prefetch_degree: 2,
            stlb_holds_1g: false,
        }
    }
}

impl Default for HierarchyConfig {
    fn default() -> Self {
        Self::kaby_lake()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_size_arithmetic() {
        assert_eq!(PageSize::P4K.bytes(), 4096);
        assert_eq!(1u64 << PageSize::P2M.shift(), PageSize::P2M.bytes());
        assert_eq!(PageSize::P1G.walk_levels(), 2);
    }

    #[test]
    fn kaby_lake_sane() {
        let c = HierarchyConfig::kaby_lake();
        assert_eq!(c.l1.size / (c.l1.ways * c.l1.line), 64); // 64 sets
        assert!(c.dram_latency > c.l3.latency);
    }
}
