//! Page-walk caches: small fully-associative caches of upper-level page
//! table entries (PML4E/PDPTE/PDE), as in Intel's paging-structure
//! caches. A hit at level L lets the walker skip levels 0..=L.

use crate::memsim::PageSize;

/// One paging-structure cache per skippable level.
///
/// Level numbering follows the walk: level 0 = PML4 (bits 47..39),
/// level 1 = PDPT, level 2 = PD. The final level (PT) is never cached —
/// its payload *is* the translation, which lives in the TLB.
pub struct PtwCache {
    /// Per level: tags of cached upper-bit prefixes (LRU by Vec order,
    /// front = MRU). Tiny (≤32), linear scan is fastest.
    levels: [Vec<u64>; 3],
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl PtwCache {
    /// `capacity` entries per cached level; 0 disables the cache
    /// entirely (every walk starts at the PML4).
    pub fn new(capacity: usize) -> Self {
        PtwCache {
            levels: [Vec::new(), Vec::new(), Vec::new()],
            capacity,
            hits: 0,
            misses: 0,
        }
    }

    /// Prefix tag for `vaddr` covering walk levels 0..=level.
    #[inline]
    fn tag(level: usize, vaddr: u64) -> u64 {
        // level 0 covers 512 GB regions (shift 39), level 1: 1 GB
        // (shift 30), level 2: 2 MB (shift 21).
        let shift = [39u32, 30, 21][level];
        vaddr >> shift
    }

    /// Deepest walk level that can be *skipped to* for `vaddr`, given the
    /// page size being walked. Returns the number of levels the walker
    /// can skip (0 = start at PML4) and records hit/miss stats.
    pub fn lookup(&mut self, vaddr: u64, page: PageSize) -> u32 {
        if self.capacity == 0 {
            self.misses += 1;
            return 0;
        }
        // For a 4 KB walk (4 levels) the best hit is the PDE cache (skip
        // 3); for 2 MB (3 levels) the PDPTE cache (skip 2); for 1 GB
        // (2 levels) the PML4E cache (skip 1).
        let deepest = (page.walk_levels() - 1).min(3) as usize;
        for level in (0..deepest).rev() {
            let tag = Self::tag(level, vaddr);
            if let Some(pos) = self.levels[level].iter().position(|&t| t == tag) {
                // Move to front (LRU).
                let t = self.levels[level].remove(pos);
                self.levels[level].insert(0, t);
                self.hits += 1;
                return (level + 1) as u32;
            }
        }
        self.misses += 1;
        0
    }

    /// Install entries for all skippable levels of this walk.
    pub fn insert(&mut self, vaddr: u64, page: PageSize) {
        if self.capacity == 0 {
            return;
        }
        let deepest = (page.walk_levels() - 1).min(3) as usize;
        for level in 0..deepest {
            let tag = Self::tag(level, vaddr);
            if !self.levels[level].contains(&tag) {
                self.levels[level].insert(0, tag);
                self.levels[level].truncate(self.capacity);
            }
        }
    }

    /// (hits, misses).
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Flush.
    pub fn reset(&mut self) {
        for l in &mut self.levels {
            l.clear();
        }
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_miss_then_deep_skip() {
        let mut pwc = PtwCache::new(8);
        assert_eq!(pwc.lookup(0x1234_5678, PageSize::P4K), 0);
        pwc.insert(0x1234_5678, PageSize::P4K);
        // Same 2 MB region: PDE hit lets the walker skip 3 levels.
        assert_eq!(pwc.lookup(0x1234_0000, PageSize::P4K), 3);
    }

    #[test]
    fn far_address_only_upper_hit() {
        let mut pwc = PtwCache::new(8);
        pwc.insert(0, PageSize::P4K);
        // Same 1 GB region, different 2 MB region: PDPTE hit (skip 2).
        assert_eq!(pwc.lookup(4 << 20, PageSize::P4K), 2);
        // Different 512 GB region: full walk.
        assert_eq!(pwc.lookup(1 << 40, PageSize::P4K), 0);
    }

    #[test]
    fn gigabyte_walks_use_pml4e_only() {
        let mut pwc = PtwCache::new(8);
        pwc.insert(0, PageSize::P1G);
        assert_eq!(pwc.lookup(512 << 20, PageSize::P1G), 1); // skip PML4
    }

    #[test]
    fn capacity_evicts_lru() {
        let mut pwc = PtwCache::new(2);
        pwc.insert(0 << 21, PageSize::P4K);
        pwc.insert(1 << 21, PageSize::P4K);
        pwc.insert(2 << 21, PageSize::P4K); // evicts tag of region 0 at PDE level
        assert_eq!(pwc.lookup(0, PageSize::P4K), 2); // PDE gone, PDPTE still covers
    }
}
