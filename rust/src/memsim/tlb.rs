//! Set-associative TLB model.

/// TLB geometry.
#[derive(Clone, Copy, Debug)]
pub struct TlbConfig {
    /// Total entries.
    pub entries: usize,
    /// Associativity (entries must be divisible by ways into a power of
    /// two number of sets; fully associative when `ways == entries`).
    pub ways: usize,
}

/// A set-associative, LRU TLB keyed by virtual page number.
pub struct Tlb {
    sets: usize,
    ways: usize,
    set_mask: u64,
    vpns: Vec<u64>,
    stamps: Vec<u32>,
    clock: u32,
    hits: u64,
    misses: u64,
}

impl Tlb {
    /// Build a TLB from its configuration.
    pub fn new(cfg: TlbConfig) -> Self {
        assert!(cfg.entries % cfg.ways == 0);
        let sets = cfg.entries / cfg.ways;
        assert!(sets.is_power_of_two());
        Tlb {
            sets,
            ways: cfg.ways,
            set_mask: (sets - 1) as u64,
            vpns: vec![u64::MAX; cfg.entries],
            stamps: vec![0; cfg.entries],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Look up `vpn`; true on hit (LRU refreshed).
    #[inline]
    pub fn lookup(&mut self, vpn: u64) -> bool {
        let set = (vpn & self.set_mask) as usize;
        let base = set * self.ways;
        self.clock = self.clock.wrapping_add(1);
        for w in 0..self.ways {
            if self.vpns[base + w] == vpn {
                self.stamps[base + w] = self.clock;
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        false
    }

    /// Install a translation for `vpn` (LRU eviction).
    #[inline]
    pub fn insert(&mut self, vpn: u64) {
        let set = (vpn & self.set_mask) as usize;
        let base = set * self.ways;
        let mut victim = 0usize;
        let mut best = u32::MAX;
        for w in 0..self.ways {
            if self.vpns[base + w] == u64::MAX {
                victim = w;
                break;
            }
            if self.stamps[base + w] < best {
                best = self.stamps[base + w];
                victim = w;
            }
        }
        self.vpns[base + victim] = vpn;
        self.stamps[base + victim] = self.clock;
    }

    /// (hits, misses).
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Reach in pages (total entries).
    pub fn entries(&self) -> usize {
        self.sets * self.ways
    }

    /// Flush all entries and counters.
    pub fn reset(&mut self) {
        self.vpns.fill(u64::MAX);
        self.stamps.fill(0);
        self.clock = 0;
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_insert_hit() {
        let mut t = Tlb::new(TlbConfig { entries: 8, ways: 4 });
        assert!(!t.lookup(42));
        t.insert(42);
        assert!(t.lookup(42));
    }

    #[test]
    fn reach_limits_hits() {
        // 64-entry 4-way TLB: sequential working set of 64 pages fits;
        // 128 pages round-robin thrashes.
        let mut t = Tlb::new(TlbConfig { entries: 64, ways: 4 });
        for _ in 0..3 {
            for vpn in 0..64u64 {
                if !t.lookup(vpn) {
                    t.insert(vpn);
                }
            }
        }
        let (h, m) = t.stats();
        assert_eq!(m, 64);
        assert_eq!(h, 128);

        let mut t2 = Tlb::new(TlbConfig { entries: 64, ways: 4 });
        let mut late_hits = 0;
        for round in 0..3 {
            for vpn in 0..128u64 {
                let hit = t2.lookup(vpn);
                if !hit {
                    t2.insert(vpn);
                }
                if round == 2 && hit {
                    late_hits += 1;
                }
            }
        }
        assert_eq!(late_hits, 0); // LRU + round robin = always miss
    }

    #[test]
    fn fully_assoc_small() {
        let mut t = Tlb::new(TlbConfig { entries: 4, ways: 4 });
        for vpn in 0..4 {
            t.insert(vpn);
        }
        for vpn in 0..4 {
            assert!(t.lookup(vpn));
        }
        t.insert(99); // evicts LRU (vpn 0)
        assert!(!t.lookup(0));
        assert!(t.lookup(99));
    }
}
